"""Serving launcher: batched prefill + decode loop (smoke scale on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import api

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.key(0)
    params = api.init(key, cfg)
    s_max = args.prompt_len + args.gen
    batch = api.synth_batch(key, cfg, "prefill", args.batch, args.prompt_len)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg, s_max=s_max))
    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.gen):
        toks.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} prefill({args.batch}x{args.prompt_len})="
          f"{t_prefill*1e3:.1f}ms decode {args.gen} steps="
          f"{t_decode*1e3:.1f}ms ({t_decode/args.gen*1e3:.2f} ms/tok)")
    print("generated ids[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
