"""Serving launcher — two serving paths behind one entry point.

Model serving (batched prefill + decode loop, smoke scale on CPU):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --batch 4 --prompt-len 32 --gen 16

QR-as-a-service (shape-bucketed continuous batching over the batched
fault-tolerant pipeline — DESIGN.md §11):

  PYTHONPATH=src python -m repro.launch.serve --mode qr \
      --requests 24 --fault-period 3
"""
from __future__ import annotations

import argparse
import time


def _serve_model(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import api

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.key(0)
    params = api.init(key, cfg)
    s_max = args.prompt_len + args.gen
    batch = api.synth_batch(key, cfg, "prefill", args.batch, args.prompt_len)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg, s_max=s_max))
    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.gen):
        toks.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} prefill({args.batch}x{args.prompt_len})="
          f"{t_prefill*1e3:.1f}ms decode {args.gen} steps="
          f"{t_decode*1e3:.1f}ms ({t_decode/args.gen*1e3:.2f} ms/tok)")
    print("generated ids[0]:", out[0].tolist())


def _serve_qr(args) -> None:
    import numpy as np

    from repro.serve import (
        BucketSpec,
        CostModel,
        PeriodicFaultInjector,
        QRServer,
    )

    buckets = (BucketSpec(256, 32), BucketSpec(512, 64))
    injector = None
    if args.fault_period:
        injector = PeriodicFaultInjector.sampled(
            args.fault_period, variant="redundant", p=args.p, seed=args.seed
        )
    server = QRServer(
        buckets, p=args.p,
        model=CostModel(max_batch_cap=args.max_batch),
        fault_injector=injector,
    )
    print("planner decisions:")
    for plan in server.planner_decisions():
        print(f"  bucket {plan['bucket']}: panel_width={plan['panel_width']} "
              f"local_r={plan['local_r']} max_batch={plan['max_batch']}")
    t0 = time.perf_counter()
    traces = server.prewarm()
    print(f"prewarm: {sum(traces.values())} trace(s) "
          f"in {time.perf_counter() - t0:.2f}s {traces}")

    rng = np.random.default_rng(args.seed)
    mats = []
    for i in range(args.requests):
        spec = buckets[i % len(buckets)]
        n = int(rng.integers(max(2, spec.n_pad // 2), spec.n_pad + 1))
        m = int(rng.integers(n, spec.m_pad - (spec.n_pad - n) + 1))
        mats.append(rng.standard_normal((m, n)).astype(np.float32))

    t0 = time.perf_counter()
    responses = server.serve(mats)
    wall = time.perf_counter() - t0
    lat_us = np.array([r.latency_s for r in responses]) * 1e6
    s = server.stats
    print(f"served {s.served} requests in {wall:.2f}s "
          f"({s.served / wall:.1f} req/s), {s.drains} drains "
          f"({s.faulted_drains} faulted, {s.reserved} re-served, "
          f"{s.filler_slots} filler slots)")
    print(f"dispatches/drain: {sorted(set(s.dispatches_per_drain))} "
          f"latency p50={np.percentile(lat_us, 50) / 1e3:.1f}ms "
          f"p99={np.percentile(lat_us, 99) / 1e3:.1f}ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("model", "qr"), default="model")
    # model serving
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    # QR serving
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--fault-period", type=int, default=3,
                    help="strike every Nth drain (0 disables injection)")
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mode == "qr":
        _serve_qr(args)
    else:
        if not args.arch:
            raise SystemExit("--arch is required for --mode model")
        _serve_model(args)


if __name__ == "__main__":
    main()
