"""Sharding policies per (arch × shape) cell — params, optimizer, batch,
and decode caches (DESIGN.md §6).

Policies:
  * train/prefill: batch over ('pod','data'); TP dims over 'model'; Adam
    state ZeRO-1 over the batch axes.
  * weight-gathered layout (``gather_axis='data'``) for archs whose bf16
    params exceed the model-axis HBM budget (mixtral-8x22b) — FSDP-style
    per-layer all-gather, emitted by GSPMD from the sharding specs alone.
  * decode caches: KV heads over 'model', batch over 'data'; when the
    batch is too small to shard (long_500k, B=1), the cache *sequence* dim
    (attention) / *state* dim (SSM) shards over 'data' instead.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import api
from repro.models.partitioning import param_shardings
from repro.models.sharding import batch_axes, mesh_context
from repro.optim import adamw

__all__ = [
    "param_bytes", "plan_cell", "CellPlan",
]

HBM_BUDGET = 12e9          # leave headroom of the 16 GB v5e HBM


def _axes_size(mesh, entry) -> int:
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def sanitize_specs(spec_tree, struct_tree, mesh):
    """Drop axis assignments that don't divide the actual dim (pjit argument
    shardings — unlike internal constraints — require exact divisibility:
    non-divisible vocab sizes, KV-head counts below the model-axis width,
    layer-stack dims, 1500-frame encoders...)."""

    def fix(spec, struct):
        if spec is None or not isinstance(spec, P):
            return spec
        parts = list(spec)
        parts += [None] * (len(struct.shape) - len(parts))
        out = []
        homeless = []
        for i, entry in enumerate(parts):
            if entry is None:
                out.append(None)
                continue
            size = _axes_size(mesh, entry)
            if struct.shape[i] % size == 0 and struct.shape[i] >= size:
                out.append(entry)
            else:
                out.append(None)
                homeless.append(entry)
        # relocate dropped assignments to a free divisible dim (largest
        # first) — e.g. FSDP sharding of a 29568-wide ff dim (not a
        # multiple of 256) moves to the 8192-wide d_model dim instead of
        # replicating 38 GB of weights.  Only multi-axis (FSDP) entries
        # relocate: moving a plain TP axis onto a contraction dim changes
        # the compute partitioning and can hit XLA SPMD's replicate-
        # repartition fallback (crashed the mamba2 embedding).
        for entry in homeless:
            if isinstance(entry, str) or len(entry) < 2:
                continue
            size = _axes_size(mesh, entry)
            cand = [
                i for i, cur in enumerate(out)
                if cur is None and struct.shape[i] % size == 0
                and struct.shape[i] >= size
            ]
            if cand:
                best = max(cand, key=lambda i: struct.shape[i])
                out[best] = entry
        return P(*out)

    is_leaf = lambda x: isinstance(x, P) or x is None
    return jax.tree.map(fix, spec_tree, struct_tree, is_leaf=is_leaf)


def param_bytes(cfg) -> int:
    specs = api.param_specs(cfg)
    return sum(
        int(np.prod(s.shape)) * s.dtype.itemsize for s in jax.tree.leaves(specs)
    )


def _gather_axis_for(cfg, mesh, kind: str) -> str | None:
    """Weight-gathered (FSDP) layout when model-axis sharding alone can't
    hold the weights.  Training uses a much tighter budget: beyond the bf16
    params themselves, the backward's loop-carried gradient accumulators
    mirror the param layout, so FSDP (whose backward reduce-scatters each
    layer's grads) is the only way the biggest archs fit.  Measured on
    qwen2-vl-72b: replicated-over-data grads kept ~4× params bf16 of
    temp buffers alive."""
    per_model_shard = param_bytes(cfg) / mesh.shape["model"]
    budget = 4e9 if kind == "train" else HBM_BUDGET
    return "data" if per_model_shard > budget else None


def _batch_spec(mesh, name: str, kind: str):
    ba = batch_axes(mesh)
    if name == "positions":              # (3, B, S)
        return P(None, ba)
    if name == "frames":                 # (B, F, d)
        return P(ba)
    return P(ba)                         # tokens / labels / loss_weight


def _cache_spec(path_name: str, parent: str, leaf, mesh, batch: int):
    """Shape-aware decode-cache specs (see module docstring).

    KV tensors prefer head-sharding over 'model'; when the head count does
    not divide the axis (GQA kv=8 on model=16), the *time* dim shards
    instead (flash-decode layout: distributed softmax over the cache).
    Small-batch cells (long_500k, B=1) shard time/state over 'data'.
    """
    ba = batch_axes(mesh)
    data_sz = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    model_sz = mesh.shape["model"]
    big_batch = batch >= data_sz
    nd = len(leaf.shape)
    if path_name in ("k", "v"):
        if parent == "cross_kv":         # (L, B, F, KH, hd)
            head_ax = 3
            time_ax = 2
        else:                            # (L, B, KH, T, hd)
            head_ax = 2
            time_ax = 3
        spec = [None] * nd
        if big_batch:
            spec[1] = ba
        n_heads = leaf.shape[head_ax]
        if n_heads % model_sz == 0:
            spec[head_ax] = "model"
        else:
            spec[time_ax] = "model"
        if not big_batch and spec[time_ax] is None:
            spec[time_ax] = ba
        return P(*spec)
    if path_name == "ssm":               # (L, B, nh, hp, N)
        if big_batch:
            return P(None, ba, "model", None, None)
        return P(None, None, "model", None, ba)
    if path_name in ("conv_x",):         # (L, B, K, d_inner)
        base = [None] * nd
        base[-1] = "model"
        if big_batch:
            base[1] = ba
        return P(*base)
    if path_name in ("conv_bc",):
        base = [None] * nd
        if big_batch:
            base[1] = ba
        return P(*base)
    return P(*([None] * nd))


def cache_shardings(cache_specs, mesh, batch: int):
    def walk(tree, name, parent):
        if isinstance(tree, dict):
            return {k: walk(v, k, name) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, name, parent) for v in tree)
        if tree is None:
            return None
        return _cache_spec(name, parent, tree, mesh, batch)

    return walk(cache_specs, "", "")


def _cell_policies(cfg, shape_spec, mesh, accounting: bool):
    """Per-cell structural policy (DESIGN.md §6):

    * sequence parallelism for train/prefill of attention families — divides
      stored activations (scan carries) by the model-axis size;
    * gradient-accumulation microbatches sized so the per-device residual
      carries stay under ~4 GB (SSM families have no SP: their inter-chunk
      recurrence is sequential in S).
    """
    updates: dict = {}
    kind = shape_spec.kind
    if kind in ("train", "prefill") and cfg.family in ("dense", "moe", "vlm", "encdec"):
        updates["seq_parallel"] = True
    if kind == "decode" and cfg.n_experts:
        dsz = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dsz *= mesh.shape[a]
        if shape_spec.global_batch % dsz == 0:
            updates["moe_decode_groups"] = dsz
    if accounting:
        updates["unroll"] = True
        updates["scan_layers"] = False
    microbatches = 1
    if kind == "train":
        data_sz = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                data_sz *= mesh.shape[a]
        b_loc = max(shape_spec.global_batch // data_sz, 1)
        div = mesh.shape["model"] if updates.get("seq_parallel") else 1
        carry = cfg.n_layers * b_loc * shape_spec.seq_len * cfg.d_model * 2 / div
        # MoE dispatch transients: ~16 (B, S·k, d)-class buffers coexist
        # through a layer's forward+backward (dispatch buffer, expert
        # activations, gather/scatter cotangents in f32) — all scale
        # 1/microbatch.  Multiplier measured on the qwen2-moe cell.
        moe_t = (
            16 * b_loc * shape_spec.seq_len * cfg.top_k * cfg.d_model * 2
            if cfg.n_experts else 0
        )
        while (max(carry, moe_t) / microbatches > 4e9
               and microbatches < b_loc):
            microbatches *= 2
    return dataclasses.replace(cfg, **updates), microbatches


class CellPlan:
    """Everything needed to lower one (arch × shape × mesh) cell."""

    def __init__(self, cfg, shape_spec, mesh, opt_cfg=None, *, accounting=False):
        cfg, self.microbatches = _cell_policies(cfg, shape_spec, mesh, accounting)
        self.cfg = cfg
        self.shape = shape_spec
        self.mesh = mesh
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        with mesh_context(mesh):
            self.gather_axis = _gather_axis_for(cfg, mesh, shape_spec.kind)
            if self.gather_axis:
                # FSDP: per-layer gather inside the scan body.  (A per-
                # expert scan was tried for MoE and REGRESSED memory ~2×:
                # the expert-loop backward stores per-iteration residuals —
                # see EXPERIMENTS.md §Perf iteration log.)
                cfg = dataclasses.replace(cfg, fsdp=True)
                self.cfg = cfg
            pspecs = api.param_specs(cfg)
            self.param_spec_tree = sanitize_specs(
                param_shardings(pspecs, gather_axis=self.gather_axis),
                pspecs, mesh,
            )
            self.param_specs = pspecs
            kind = shape_spec.kind
            b, s = shape_spec.global_batch, shape_spec.seq_len
            self.batch_struct = api.batch_specs(cfg, kind, b, s)
            self.batch_spec_tree = sanitize_specs(
                {k: _batch_spec(mesh, k, kind) for k in self.batch_struct},
                self.batch_struct, mesh,
            )
            if kind == "train":
                self.opt_struct = jax.eval_shape(adamw.init, pspecs)
                self.opt_spec_tree = adamw.state_shardings(
                    self.param_spec_tree, pspecs, mesh,
                    zero1_axis=batch_axes(mesh),
                )
            elif kind == "decode":
                self.cache_struct = api.decode_cache_specs(cfg, b, s)
                self.cache_spec_tree = sanitize_specs(
                    cache_shardings(self.cache_struct, mesh, b),
                    self.cache_struct, mesh,
                )

    # -- step functions -----------------------------------------------
    def named(self, spec_tree):
        return jax.tree.map(
            lambda sp: None if sp is None else NamedSharding(self.mesh, sp),
            spec_tree,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )

    def lowerable(self):
        """Returns (fn, args_structs, in_shardings, out_shardings)."""
        cfg, opt_cfg = self.cfg, self.opt_cfg
        kind = self.shape.kind
        mb = self.microbatches
        if kind == "train":
            def train_step(params, opt_state, batch):
                def total_loss(p):
                    if mb == 1:
                        return api.loss_fn(p, batch, cfg)

                    def split(x):
                        if x.shape[0] == 3:      # M-RoPE positions (3, B, S)
                            y = x.reshape((3, mb, x.shape[1] // mb) + x.shape[2:])
                            return jnp.moveaxis(y, 1, 0)
                        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

                    splits = jax.tree.map(split, batch)
                    # remat each microbatch: without this, backward keeps
                    # every micro's layer-scan carries alive simultaneously
                    # and grad accumulation saves no memory at all
                    micro_loss = jax.checkpoint(
                        lambda p_, m_: api.loss_fn(p_, m_, cfg),
                        policy=jax.checkpoint_policies.nothing_saveable,
                    )
                    if cfg.unroll:               # accounting build: no while loop
                        micros = [
                            jax.tree.map(lambda x, i=i: x[i], splits)
                            for i in range(mb)
                        ]
                        return sum(micro_loss(p, m) for m in micros) / mb

                    def micro(acc, m_batch):
                        return acc + micro_loss(p, m_batch) / mb, None

                    out, _ = jax.lax.scan(micro, 0.0, splits)
                    return out

                loss, grads = jax.value_and_grad(total_loss)(params)
                # ZeRO-2: shard gradients like the Adam moments (params'
                # sharding + batch axes on the largest free dim).  GSPMD
                # propagates this into the backward scans' loop-carried
                # accumulators, which otherwise hold the full replicated
                # gradient tree double-buffered (~4× params bf16 on the
                # biggest archs — measured on qwen2-vl-72b).
                grads = jax.tree.map(
                    lambda g, sp: g if sp is None else
                    jax.lax.with_sharding_constraint(
                        g, NamedSharding(self.mesh, sp)),
                    grads, self.opt_spec_tree["m"],
                    is_leaf=lambda x: x is None,
                )
                new_p, new_o, metrics = adamw.update(opt_cfg, params, grads, opt_state)
                return new_p, new_o, {"loss": loss, **metrics}

            args = (self.param_specs, self.opt_struct, self.batch_struct)
            ins = (self.param_spec_tree, self.opt_spec_tree, self.batch_spec_tree)
            outs = (self.param_spec_tree, self.opt_spec_tree, None)
            return train_step, args, ins, outs
        if kind == "prefill":
            def prefill_step(params, batch):
                return api.prefill(params, batch, cfg)

            args = (self.param_specs, self.batch_struct)
            ins = (self.param_spec_tree, self.batch_spec_tree)
            return prefill_step, args, ins, None
        if kind == "decode":
            def serve_step(params, cache, batch):
                return api.decode_step(params, cache, batch["tokens"], cfg)

            args = (self.param_specs, self.cache_struct, self.batch_struct)
            ins = (
                self.param_spec_tree,
                self.cache_spec_tree,
                self.batch_spec_tree,
            )
            outs = (None, self.cache_spec_tree)
            return serve_step, args, ins, outs
        raise ValueError(kind)
