"""Training launcher.

Smoke-scale by default (reduced config, 1-device mesh — runs on this CPU
container); ``--mesh single|multi`` selects the production meshes for
dry-run-style launches on a real fleet.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --on-failure rebuild --fail "10:0" --straggle "20:1:3"
"""
from __future__ import annotations

import argparse


def parse_events(fail: str, straggle: str, recover: str):
    from repro.runtime.trainer import FaultEvent

    events = []
    for spec, kind in ((fail, "fail"), (recover, "recover")):
        for item in filter(None, spec.split(",")):
            step, rep = item.split(":")
            events.append(FaultEvent(step=int(step), kind=kind, replica=int(rep)))
    for item in filter(None, straggle.split(",")):
        parts = item.split(":")
        step, rep = int(parts[0]), int(parts[1])
        dur = int(parts[2]) if len(parts) > 2 else 1
        events.append(FaultEvent(step=step, kind="straggle", replica=rep, duration=dur))
    return tuple(events)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full published config (needs a real fleet)")
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 2x2) | single | multi")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--on-failure", default="blank",
                    choices=["blank", "shrink", "rebuild"])
    ap.add_argument("--fail", default="", help="step:replica[,...]")
    ap.add_argument("--recover", default="", help="step:replica[,...]")
    ap.add_argument("--straggle", default="", help="step:replica[:dur][,...]")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    import jax

    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.mesh == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "auto":
        n = len(jax.devices())
        mesh = make_smoke_mesh(data=n, model=1)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_smoke_mesh(data=d, model=m)

    tcfg = TrainerConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        on_failure=args.on_failure,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        lr=args.lr,
    )
    dcfg = DataConfig(
        vocab=cfg.vocab,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        family=cfg.family,
        enc_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    trainer = Trainer(cfg, tcfg, mesh, dcfg)
    params, opt = trainer.init_state()
    trainer.run(
        params, opt,
        fault_schedule=parse_events(args.fail, args.straggle, args.recover),
    )
    print("\n".join(trainer.events_log))
    print(f"final loss: {trainer.metrics_log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
