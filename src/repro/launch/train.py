"""Training launcher.

Smoke-scale by default (reduced config, 1-device mesh — runs on this CPU
container); ``--mesh single|multi`` selects the production meshes for
dry-run-style launches on a real fleet.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --on-failure rebuild --fail "10:0" --straggle "20:1:3"

``--faults <name>`` replays a stock trainer scenario from
:mod:`repro.bench.scenarios` (event schedule, mesh width, recovery policy,
and expected fault-stat counts) against any ``--arch`` / ``--optimizer`` —
the CLI twin of the ``fault_scenarios`` bench case, exiting non-zero when
the run's fault stats miss the scenario's expectations:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --optimizer powersgd --faults shrink_then_rebuild
"""
from __future__ import annotations

import argparse
import os
import sys


def parse_events(fail: str, straggle: str, recover: str):
    from repro.runtime.trainer import FaultEvent

    events = []
    for spec, kind in ((fail, "fail"), (recover, "recover")):
        for item in filter(None, spec.split(",")):
            step, rep = item.split(":")
            events.append(FaultEvent(step=int(step), kind=kind, replica=int(rep)))
    for item in filter(None, straggle.split(",")):
        parts = item.split(":")
        step, rep = int(parts[0]), int(parts[1])
        dur = int(parts[2]) if len(parts) > 2 else 1
        events.append(FaultEvent(step=step, kind="straggle", replica=rep, duration=dur))
    return tuple(events)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full published config (needs a real fleet)")
    ap.add_argument("--mesh", default="auto",
                    help="auto | dxm (e.g. 2x2) | single | multi")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--on-failure", default="blank",
                    choices=["blank", "shrink", "rebuild"])
    ap.add_argument("--optimizer", default=None,
                    choices=["adamw", "powersgd", "orthosgd", "lowrank"],
                    help="default adamw (or the --faults scenario's choice)")
    ap.add_argument("--faults", default="",
                    help="stock trainer scenario name from "
                         "repro.bench.scenarios (overrides the event "
                         "schedule, mesh width, and recovery policy)")
    ap.add_argument("--fail", default="", help="step:replica[,...]")
    ap.add_argument("--recover", default="", help="step:replica[,...]")
    ap.add_argument("--straggle", default="", help="step:replica[:dur][,...]")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    sc = None
    if args.faults:
        # Stock schedules need their full replica width; mirror the bench
        # CLI and pin 8 host devices before the first jax import.
        if "jax" not in sys.modules:
            flag = "--xla_force_host_platform_device_count=8"
            flags = os.environ.get("XLA_FLAGS", "")
            if "--xla_force_host_platform_device_count" not in flags:
                os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()
        from repro.bench.scenarios import get_scenarios

        stock = {s.name: s for s in get_scenarios() if s.kind == "trainer"}
        if args.faults not in stock:
            raise SystemExit(
                f"unknown --faults scenario {args.faults!r}; trainer "
                "scenarios: " + ", ".join(sorted(stock))
            )
        sc = stock[args.faults]

    import jax

    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.mesh import make_production_mesh, make_smoke_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if sc is not None:
        mesh = make_smoke_mesh(data=sc.data_width, model=sc.model_width)
    elif args.mesh == "single":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh == "auto":
        n = len(jax.devices())
        mesh = make_smoke_mesh(data=n, model=1)
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_smoke_mesh(data=d, model=m)

    tcfg = TrainerConfig(
        steps=sc.steps if sc is not None else args.steps,
        microbatches=args.microbatches,
        on_failure=sc.on_failure if sc is not None else args.on_failure,
        optimizer=args.optimizer or (sc.optimizer if sc is not None
                                     else "adamw"),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=sc.ckpt_every if sc is not None else args.ckpt_every,
        buddy_levels=sc.buddy_levels if sc is not None else 1,
        lr=args.lr,
    )
    dcfg = DataConfig(
        vocab=cfg.vocab,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        family=cfg.family,
        enc_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    trainer = Trainer(cfg, tcfg, mesh, dcfg)
    params, opt = trainer.init_state()
    schedule = (tuple(sc.events) if sc is not None
                else parse_events(args.fail, args.straggle, args.recover))
    trainer.run(params, opt, fault_schedule=schedule)
    print("\n".join(trainer.events_log))
    print(f"final loss: {trainer.metrics_log[-1]['loss']:.4f}")
    if sc is not None:
        stats = {k: int(v) for k, v in trainer.fault_stats.items() if v}
        print(f"fault stats: {stats}")
        missed = {k: (int(trainer.fault_stats[k]), want)
                  for k, want in sc.expect.items()
                  if int(trainer.fault_stats[k]) != want}
        if missed:
            raise SystemExit(
                f"scenario {sc.name}: fault stats missed expectations "
                f"(got, want) = {missed}"
            )
        print(f"scenario {sc.name}: fault stats match expectations")


if __name__ == "__main__":
    main()
