"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run entry point must
set ``XLA_FLAGS`` before the first jax call.

Topology: TPU v5e pods, 16×16 = 256 chips per pod; the multi-pod mesh adds
a leading "pod" axis over DCN.  ``make_tsqr_mesh`` flattens all devices
into one "rows" axis — the layout the collective butterfly runs on
(log2(256) = 8, log2(512) = 9 exchange levels).

Construction goes through :mod:`repro.compat.make_mesh` so the ``axis_types``
kwarg is applied only on jax versions that understand it.
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_tsqr_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_tsqr_mesh(*, multi_pod: bool = False):
    n = 512 if multi_pod else 256
    return make_mesh((n,), ("rows",))


def make_smoke_mesh(data: int = 1, model: int = 1):
    return make_mesh((data, model), ("data", "model"))
