import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the jitted step (train_step / prefill_step / serve_step) is lowered with
ShapeDtypeStruct stand-ins (no allocation), compiled for the production
mesh, and its ``memory_analysis()`` / ``cost_analysis()`` plus the
collective schedule parsed from the partitioned HLO are recorded to JSON —
the raw inputs of EXPERIMENTS.md §Dry-run and §Roofline.

The two lines above MUST precede every other import: jax locks the device
count at first initialization, and only the dry-run wants 512 placeholder
host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch tsqr   # paper's cells
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _bytes_of_shape_text(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective-type result bytes (per-device, SPMD module) + counts.

    ``-done`` ops are skipped so async pairs aren't double counted.
    """
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_text, kind = m.group(1), m.group(2)
        out[kind]["bytes"] += _bytes_of_shape_text(shape_text)
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if k in COLLECTIVES)
    out["total_count"] = sum(v["count"] for k, v in out.items() if k in COLLECTIVES)
    return out


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for field in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}


# ---------------------------------------------------------------------------
# Accounting probes: XLA's HloCostAnalysis counts while-loop (scan) bodies
# ONCE, so exact per-step FLOP/byte/collective totals come from *unrolled*
# reduced-depth builds, extrapolated linearly in layer count (exact: layers
# are structurally identical).  Weights w give  target = Σ w_i · probe_i.
# ---------------------------------------------------------------------------

def _probe_plan(cfg):
    if cfg.family in ("dense", "moe", "vlm"):
        period = 2 if cfg.local_global else 1
        n = cfg.n_layers // period
        return (
            [{"n_layers": period}, {"n_layers": 2 * period}],
            [2.0 - n, n - 1.0],
        )
    if cfg.family == "ssm":
        n = cfg.n_layers
        return [{"n_layers": 1}, {"n_layers": 2}], [2.0 - n, n - 1.0]
    if cfg.family == "encdec":
        n = cfg.n_layers               # enc and dec depths move together
        return (
            [{"n_layers": 1, "n_enc_layers": 1},
             {"n_layers": 2, "n_enc_layers": 2}],
            [2.0 - n, n - 1.0],
        )
    if cfg.family == "hybrid":
        # cost(u units, t tail) affine; target (13, 3) from (1,0),(2,0),(1,3)
        u = cfg.n_layers // cfg.attn_every
        t = cfg.n_layers - u * cfg.attn_every
        e = cfg.attn_every
        w1 = 1.0 - (u - 1.0) - (t / 3.0)
        return (
            [{"n_layers": e}, {"n_layers": 2 * e}, {"n_layers": e + 3}],
            [w1, u - 1.0, t / 3.0],
        )
    raise ValueError(cfg.family)


def _extract_scalars(rec: dict) -> dict:
    out = {}
    for k in ("flops", "transcendentals", "bytes accessed"):
        if k in rec["cost"]:
            out[f"cost.{k}"] = rec["cost"][k]
    for c in COLLECTIVES:
        out[f"coll.{c}.bytes"] = rec["collectives"][c]["bytes"]
        out[f"coll.{c}.count"] = rec["collectives"][c]["count"]
    out["coll.total_bytes"] = rec["collectives"]["total_bytes"]
    out["coll.total_count"] = rec["collectives"]["total_count"]
    return out


def _lower_cell(cfg, shape, mesh, *, accounting: bool) -> dict:
    from repro.launch.shardings import CellPlan
    from repro.models.sharding import mesh_context

    plan = CellPlan(cfg, shape, mesh, accounting=accounting)
    fn, args, ins, outs = plan.lowerable()
    # donate params/opt (train) and cache (decode): new state aliases old —
    # without this the dry-run double-counts every weight & Adam buffer
    donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
    t0 = time.time()
    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=plan.named(ins),
                         out_shardings=plan.named(outs) if outs is not None else None,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    hlo = compiled.as_text()
    return {
        "gather_axis": plan.gather_axis,
        "microbatches": plan.microbatches,
        "seq_parallel": plan.cfg.seq_parallel,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": memory_dict(compiled),
        "cost": cost_dict(compiled),
        "collectives": parse_collectives(hlo),
        "hlo_bytes": len(hlo),
    }


def run_model_cell(arch: str, shape_name: str, multi_pod: bool,
                   accounting: bool = True) -> dict:
    import dataclasses

    from repro.configs.base import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = _lower_cell(cfg, shape, mesh, accounting=False)
    rec.update(
        arch=arch, shape=shape_name, kind=shape.kind,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=int(np.prod(list(mesh.shape.values()))),
        seq_len=shape.seq_len, global_batch=shape.global_batch,
    )
    if accounting:
        overrides, weights = _probe_plan(cfg)
        probes = []
        for ov in overrides:
            pcfg = dataclasses.replace(cfg, **ov)
            prec = _lower_cell(pcfg, shape, mesh, accounting=True)
            probes.append({"overrides": ov, **_extract_scalars(prec),
                           "compile_s": prec["compile_s"]})
        extrap = {}
        for k in probes[0]:
            if k in ("overrides", "compile_s"):
                continue
            extrap[k] = float(sum(w * p[k] for w, p in zip(weights, probes)))
        rec["accounting"] = {
            "probes": probes, "weights": weights, "extrapolated": extrap,
        }
    return rec


def run_tsqr_cell(workload_name: str, multi_pod: bool) -> dict:
    from repro.configs.tsqr_paper import WORKLOADS
    from repro.launch.mesh import make_tsqr_mesh
    from repro.qr import QRConfig, factorize
    import jax.numpy as jnp

    w = WORKLOADS[workload_name]
    mesh = make_tsqr_mesh(multi_pod=multi_pod)
    p = mesh.shape["rows"]
    a = jax.ShapeDtypeStruct((w.n_rows, w.n_cols), jnp.dtype(w.dtype))

    t0 = time.time()

    compute_q = w.variant != "tree"     # tree: only rank 0 holds R (no Q)

    def run(a_):
        res = factorize(
            a_, QRConfig(variant=w.variant, compute_q=compute_q),
            mesh=mesh, axis="rows", jit=False,
        )
        return res.r, res.valid, res.q

    from jax.sharding import NamedSharding, PartitionSpec as P
    jitted = jax.jit(run, in_shardings=NamedSharding(mesh, P("rows")))
    lowered = jitted.lower(a)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    hlo = compiled.as_text()
    return {
        "arch": "tsqr",
        "shape": workload_name,
        "kind": "tsqr",
        "mesh": f"{p}flat",
        "n_devices": p,
        "variant": w.variant,
        "rows": w.n_rows,
        "cols": w.n_cols,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": memory_dict(compiled),
        "cost": cost_dict(compiled),
        "collectives": parse_collectives(hlo),
        "hlo_bytes": len(hlo),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-accounting", action="store_true",
                    help="skip the unrolled L=1/2 accounting probes")
    args = ap.parse_args()

    from repro.configs.base import get_config, list_archs, shapes_for
    from repro.configs.tsqr_paper import WORKLOADS

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells: list[tuple] = []
    if args.arch in ("all", "tsqr"):
        names = list(WORKLOADS) if args.shape == "all" else [args.shape]
        cells += [("tsqr", n) for n in names]
    if args.arch != "tsqr":
        archs = list_archs() if args.arch == "all" else [args.arch]
        for a in archs:
            cfg = get_config(a)
            shapes = (
                [s.name for s in shapes_for(cfg)]
                if args.shape == "all" else [args.shape]
            )
            cells += [(a, s) for s in shapes]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag.replace("/", "-") + ".json")
            try:
                # roofline accounting is single-pod only; multi-pod proves
                # the pod axis shards
                acct = (not args.no_accounting) and not mp
                rec = (run_tsqr_cell(shape, mp) if arch == "tsqr"
                       else run_model_cell(arch, shape, mp, accounting=acct))
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                mem = rec["memory"].get("total_hbm_bytes", 0)
                fl = (rec.get("accounting", {}).get("extrapolated", {})
                      .get("cost.flops", rec["cost"].get("flops", 0)))
                print(f"[dryrun OK ] {tag}: compile={rec['compile_s']}s "
                      f"flops/dev={fl:.3e} "
                      f"coll={rec['collectives']['total_bytes']:.3e}B "
                      f"hbm/dev≈{mem/1e9:.2f}GB", flush=True)
            except Exception as e:
                failures += 1
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[dryrun ERR] {tag}: {type(e).__name__}: {str(e)[:200]}",
                      flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
