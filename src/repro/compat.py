"""Version-compatibility shims for the installed jax.

The repo targets recent jax (the explicit-sharding era:
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map(..., check_vma=...)``) but must degrade gracefully on older
releases (0.4.x) where those names/kwargs do not exist.  Everything in the
repo that builds a mesh or enters ``shard_map`` goes through this module so
the compatibility decision is made exactly once.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = [
    "AxisType",
    "HAS_AXIS_TYPES",
    "axis_types_kwargs",
    "make_mesh",
    "mesh_from_devices",
    "shard_map",
]

try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # older jax: meshes are implicitly "auto" everywhere

    class AxisType:  # type: ignore[no-redef]
        """Stand-in so ``(AxisType.Auto,) * n`` spellings keep working."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` when the installed jax understands it."""
    if HAS_AXIS_TYPES:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when supported."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names, **axis_types_kwargs(len(axis_names))
        )
    except TypeError:  # make_mesh predates the axis_types kwarg
        return jax.make_mesh(axis_shapes, axis_names)


def mesh_from_devices(devices, axis_names) -> Mesh:
    """``Mesh(devices, names)`` from an explicit device array (elastic
    shrink/rebuild paths), with Auto axis types when supported."""
    try:
        return Mesh(devices, axis_names, **axis_types_kwargs(len(axis_names)))
    except TypeError:
        return Mesh(devices, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    Replication/VMA checking is disabled in all cases: the collective engine
    mixes host-planned ``ppermute`` routes with per-rank control values,
    which the static checkers cannot type.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:  # pre-check_vma spelling
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
