"""Version-compatibility shims for the installed jax.

The repo targets recent jax (the explicit-sharding era:
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.shard_map(..., check_vma=...)``) but must degrade gracefully on older
releases (0.4.x) where those names/kwargs do not exist.  Everything in the
repo that builds a mesh or enters ``shard_map`` goes through this module so
the compatibility decision is made exactly once.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = [
    "AxisType",
    "HAS_AXIS_TYPES",
    "axis_types_kwargs",
    "make_mesh",
    "mesh_fingerprint",
    "mesh_from_devices",
    "optimization_barrier",
    "shard_map",
]


# -- lax.optimization_barrier under vmap ------------------------------------
#
# The trailing-update oracle (repro.kernels.ref) uses optimization_barrier
# to pin XLA rewrites so the eager driver and the scan pipeline stay
# bitwise-comparable at narrow panel widths — but jax (through at least
# 0.4.37) never registered a vmap batching rule for the primitive, which
# breaks the batched (vmapped) pipeline.  The barrier is an identity on
# every leaf, so the rule is trivial: bind through, dims unchanged.  When
# the internal primitive moves, fall back to the identity function (vmap
# keeps working; the last-ulp pinning is best-effort by nature).

def _make_optimization_barrier():
    try:
        from jax import lax

        barrier = lax.optimization_barrier
    except (ImportError, AttributeError):
        return lambda x: x
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching

        if optimization_barrier_p not in batching.primitive_batchers:
            def _batch_rule(args, dims):
                return optimization_barrier_p.bind(*args), dims

            batching.primitive_batchers[optimization_barrier_p] = _batch_rule
    except (ImportError, AttributeError):
        # Private primitive moved but the public op still exists: keep the
        # barrier (the single-matrix bit-identity contract depends on it)
        # and let vmapped narrow-width calls fail loudly — a silent
        # identity here would surface as mysterious last-ulp mismatches in
        # the hypothesis sweep instead of an error pointing at this shim.
        pass
    return barrier


optimization_barrier = _make_optimization_barrier()

try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # older jax: meshes are implicitly "auto" everywhere

    class AxisType:  # type: ignore[no-redef]
        """Stand-in so ``(AxisType.Auto,) * n`` spellings keep working."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` when the installed jax understands it."""
    if HAS_AXIS_TYPES:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes, axis_names) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when supported."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names, **axis_types_kwargs(len(axis_names))
        )
    except TypeError:  # make_mesh predates the axis_types kwarg
        return jax.make_mesh(axis_shapes, axis_names)


def mesh_from_devices(devices, axis_names) -> Mesh:
    """``Mesh(devices, names)`` from an explicit device array (elastic
    shrink/rebuild paths), with Auto axis types when supported."""
    try:
        return Mesh(devices, axis_names, **axis_types_kwargs(len(axis_names)))
    except TypeError:
        return Mesh(devices, axis_names)


def mesh_fingerprint(mesh: Mesh) -> tuple:
    """Hashable mesh-equivalence-class key: two meshes over the same devices
    in the same topology fingerprint identically, even when the ``Mesh``
    objects are distinct (the elastic ``rebuild_mesh`` path re-instantiates
    the template).  ``Mesh.__hash__`` is already value-based on current jax,
    but the trainer's step cache and the jit-cache keys must not depend on
    that implementation detail — this makes the equivalence class explicit.
    """
    return (
        mesh.axis_names,
        mesh.devices.shape,
        tuple(d.id for d in mesh.devices.flat),
    )


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    Replication/VMA checking is disabled in all cases: the collective engine
    mixes host-planned ``ppermute`` routes with per-rank control values,
    which the static checkers cannot type.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:  # pre-check_vma spelling
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
