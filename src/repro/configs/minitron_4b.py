"""Minitron-4B — pruned Nemotron, squared-ReLU MLP [arXiv:2407.14679; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    vocab=256_000,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=10_000.0,
    d_ff=9216,
    act="relu2",
    norm="rmsnorm",
    source="[arXiv:2407.14679; hf]",
))
