"""Gemma2-9B — local+global alternating, logit softcap [arXiv:2408.00118; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    vocab=256_000,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    rope_theta=10_000.0,
    sliding_window=4096,
    local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    d_ff=14_336,
    act="geglu",
    norm="rmsnorm_offset",
    post_norms=True,
    tie_embeddings=True,
    source="[arXiv:2408.00118; hf]",
))
