"""The paper's own workload: fault-tolerant TSQR of tall-skinny matrices.

Not a neural architecture — these are the factorization workloads the
paper's tables/figures are built from, used by the benchmark harness and
the TSQR dry-run cells.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TSQRWorkload:
    name: str
    n_rows: int          # global m
    n_cols: int          # n (m >> n)
    variant: str
    dtype: str = "float32"


# One workload per paper scenario: the 4-process walkthroughs of Figs. 1-5
# scaled to the production mesh, plus the PowerSGD-shaped panels the
# optimizer layer factorizes every step.
WORKLOADS = {
    "paper_fig1": TSQRWorkload("paper_fig1", 1 << 20, 32, "tree"),
    "paper_fig2": TSQRWorkload("paper_fig2", 1 << 20, 32, "redundant"),
    "paper_fig4": TSQRWorkload("paper_fig4", 1 << 20, 32, "replace"),
    "paper_fig5": TSQRWorkload("paper_fig5", 1 << 20, 32, "selfhealing"),
    "powersgd_panel": TSQRWorkload("powersgd_panel", 1 << 22, 128, "redundant"),
    "wide_panel": TSQRWorkload("wide_panel", 1 << 21, 256, "redundant"),
}
