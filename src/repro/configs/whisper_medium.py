"""Whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,                 # decoder
    n_enc_layers=24,
    d_model=1024,
    vocab=51_865,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    act="gelu",
    norm="ln",
    attn_bias=True,
    tie_embeddings=True,
    enc_frames=1500,
    source="[arXiv:2212.04356; unverified]",
))
