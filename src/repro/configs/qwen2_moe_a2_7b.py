"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    vocab=151_936,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    rope_theta=1_000_000.0,
    attn_bias=True,
    d_ff=1408,                      # routed-expert ff (spec: d_ff=1408)
    n_experts=60,
    top_k=4,
    d_expert_ff=1408,
    n_shared_experts=4,             # shared expert = 4 × 1408 = 5632
    act="swiglu",
    norm="rmsnorm",
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
))
