"""Mixtral-8x22B — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab=32_768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    d_ff=16_384,
    n_experts=8,
    top_k=2,
    d_expert_ff=16_384,
    act="swiglu",
    norm="rmsnorm",
    source="[arXiv:2401.04088; hf]",
))
