"""Qwen2-VL-72B — M-RoPE, dynamic resolution (vision frontend stubbed)
[arXiv:2409.12191; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    vocab=152_064,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=1_000_000.0,
    attn_bias=True,
    mrope_sections=(16, 24, 24),
    d_ff=29_568,
    act="swiglu",
    norm="rmsnorm",
    source="[arXiv:2409.12191; hf]",
))
