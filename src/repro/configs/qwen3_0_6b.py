"""Qwen3-0.6B — qk_norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    vocab=151_936,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
    d_ff=3072,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="[hf:Qwen/Qwen3-0.6B; hf]",
))
