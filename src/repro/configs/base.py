"""Config system: model/train/serve configs and the ``--arch`` registry.

One file per assigned architecture lives next to this module; each calls
:func:`register` with the exact published configuration.  Reduced smoke
variants (same family, tiny dims) are derived with :meth:`ModelConfig.smoke`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "register",
    "get_config",
    "list_archs",
    "shapes_for",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.  Field groups are only read by the
    families that use them (e.g. ``ssm_*`` by mamba2/zamba2)."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int

    # -- attention --------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    sliding_window: int | None = None        # window for local-attention layers
    local_global: bool = False               # gemma2 alternating pattern
    attn_bias: bool = False                  # qwen2-family qkv bias
    pad_heads_to: int = 0                    # zero-pad query heads (sharding)

    # -- mlp / norm ---------------------------------------------------------
    d_ff: int = 0
    act: str = "swiglu"               # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"             # rmsnorm | rmsnorm_offset | ln_nonparam | ln
    post_norms: bool = False          # gemma2 sandwich norms
    tie_embeddings: bool = False

    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    n_shared_experts: int = 0         # qwen2-moe shared-expert multiple
    capacity_factor: float = 1.25
    expert_parallel: int = 1          # EP sub-factor of the model axis (§Perf)
    moe_decode_groups: int = 0        # decode dispatch groups (= data shards)
    moe_scan_experts: bool = False    # FSDP: gather one expert at a time

    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # -- hybrid (zamba2) ------------------------------------------------------
    attn_every: int = 0               # shared attn block applied every N layers

    # -- enc-dec (whisper) ----------------------------------------------------
    n_enc_layers: int = 0
    enc_frames: int = 1500            # conv-frontend output length (stubbed)

    # -- VLM (qwen2-vl) ---------------------------------------------------------
    mrope_sections: tuple[int, ...] = ()

    # -- numerics / structure -----------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True          # lax.scan over the layer stack
    unroll: bool = False              # accounting build: python-unroll every loop
    q_chunk: int = 0                  # flash-style query chunking (0 = auto)
    seq_parallel: bool = False        # Megatron-SP residual-stream layout
    fsdp: bool = False                # weight-gathered layer params (see partitioning)
    source: str = ""                  # [source; verified-tier] provenance

    # ---------------------------------------------------------------------
    @property
    def d_head(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def smoke(self, **overrides: Any) -> "ModelConfig":
        """A reduced config of the same family for CPU smoke tests."""
        small: dict[str, Any] = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            vocab=256,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            d_expert_ff=64 if self.d_expert_ff else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=16 if self.n_enc_layers else 1500,
            sliding_window=16 if self.sliding_window else None,
            mrope_sections=(4, 2, 2) if self.mrope_sections else (),
            dtype="float32",
            remat=False,
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
    sub_quadratic_only: bool = False


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1, sub_quadratic_only=True),
}

_REGISTRY: dict[str, ModelConfig] = {}

_ARCH_MODULES = [
    "qwen2_moe_a2_7b",
    "mixtral_8x22b",
    "gemma2_9b",
    "olmo_1b",
    "qwen3_0_6b",
    "minitron_4b",
    "whisper_medium",
    "mamba2_2_7b",
    "zamba2_7b",
    "qwen2_vl_72b",
    "tsqr_paper",
]


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig) -> list[ShapeSpec]:
    """The assigned shape cells for an architecture.

    ``long_500k`` runs only for sub-quadratic families (SSM / hybrid) —
    pure full-attention archs skip it (DESIGN.md §6).
    """
    out = []
    for spec in SHAPES.values():
        if spec.sub_quadratic_only and cfg.family not in ("ssm", "hybrid"):
            continue
        out.append(spec)
    return out
