"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; unverified]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    vocab=32_000,
    ssm_state=64,
    ssm_expand=2,               # d_inner = 7168, 112 heads of 64
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    attn_every=6,               # 13 shared-attn applications + 3 tail layers
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    rope_theta=10_000.0,
    d_ff=14_336,
    act="swiglu",
    norm="rmsnorm",
    source="[arXiv:2411.15242; unverified]",
))
