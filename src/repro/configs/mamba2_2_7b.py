"""Mamba2-2.7B — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    vocab=50_280,
    ssm_state=128,
    ssm_expand=2,               # d_inner = 5120, 80 heads of 64
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    norm="rmsnorm",
    source="[arXiv:2405.21060; unverified]",
))
