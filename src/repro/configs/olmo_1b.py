"""OLMo-1B — non-parametric LN [arXiv:2402.00838; hf]."""
from .base import ModelConfig, register

register(ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    vocab=50_304,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    rope_theta=10_000.0,
    d_ff=8192,
    act="swiglu",
    norm="ln_nonparam",
    tie_embeddings=True,
    source="[arXiv:2402.00838; hf]",
))
