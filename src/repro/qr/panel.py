"""Engine-agnostic panel factorization for the QR pipeline layer.

This is the panel-local half of the fault-tolerant TSQR, extracted from
``repro.core.tsqr`` so that both QR workloads share it:

  * the tall-and-skinny entry points (:mod:`repro.qr.tsqr`) factor one
    panel — the whole matrix;
  * the right-looking blocked driver (:mod:`repro.qr.blocked`) factors one
    panel per column block of a general m×n matrix.

A :class:`PanelFactorizer` bundles the two panel-local policies — which
local QR runs before the butterfly (``local_qr``) and how many
CholeskyQR-style re-orthonormalization passes polish the explicit Q
(``reorth``) — and exposes them against the generic collective engine:
``reduce_r`` runs any :class:`~repro.collective.plan.Plan` with the QR
combiner on any :class:`~repro.collective.comm.Comm` backend, so the same
factorizer executes on ``SimComm`` and ``ShardMapComm`` under every fault
variant.  Nothing here knows about meshes, fault specs, or column blocking.

The combine is ``QR([R_lo; R_hi])`` ordered by the level bit of the *block*
index so every member of a block computes an identical R (making the
butterfly a true all-reduce — every survivor ends with the same final R,
which lets Q be formed locally as ``A R⁻¹`` without a backward tree pass).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.collective.combiners import (
    QRCombiner,
    StackedCombiner,
    SumCombiner,
    posdiag as _posdiag,
    qr_r,
)
from repro.collective.comm import Comm
from repro.collective.engine import execute_plan, ft_allreduce
from repro.collective.plan import Plan

__all__ = [
    "FUSED_PANEL_COMBINER",
    "PanelFactorizer",
    "chol_r",
    "form_q",
    "local_qr_fns",
    "resolve_local_qr",
]


# ---------------------------------------------------------------------------
# Local QR building blocks
# ---------------------------------------------------------------------------

def qr_r_jnp(a):
    """Householder QR, R factor only (LAPACK on CPU, QR-decomp HLO on TPU)."""
    return qr_r(a)


def qr_r_cqr2(a):
    """CholeskyQR2 R factor — the MXU-native local QR (see kernels/).

    Rides the fused 2-sweep R-only pipeline: the butterfly only carries R,
    so no tall intermediate is ever materialized (the seed computed the full
    4-sweep factorization and discarded Q).
    """
    from repro.kernels import ops as kops

    return kops.cholesky_qr2_r(a)


def qr_r_cqr2_pallas(a):
    from repro.kernels import ops as kops

    return kops.cholesky_qr2_r(a, use_pallas=True)


local_qr_fns: dict[str, Callable] = {
    "jnp": qr_r_jnp,
    "cqr2": qr_r_cqr2,
    "cqr2_pallas": qr_r_cqr2_pallas,
}


def resolve_local_qr(local_qr: str | Callable) -> Callable:
    return local_qr_fns[local_qr] if isinstance(local_qr, str) else local_qr


def chol_r(g):
    """Upper-triangular R from a panel Gram matrix (CholeskyQR local R).

    The blocked driver's zero-extra-sweep local factorization: the panel's
    Gram arrives for free from the previous trailing update's lookahead
    accumulator, so the local R costs one (b, b) Cholesky and no panel read.
    κ(panel)² enters the Gram — certified for κ ≲ 1/√ε like CholeskyQR.
    """
    return _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2))


def _identity(x):
    return x


# The blocked driver's one-butterfly-per-panel payload (DESIGN.md §10):
# leaf 0 is the panel's local R (QR combine), leaf 1 the local cross
# products A_panelᵀ A_trail (sum combine).  Module-level so every jit/LRU
# cache keyed on the combiner shares one hashable instance.
FUSED_PANEL_COMBINER = StackedCombiner(
    (QRCombiner(local_qr=_identity), SumCombiner())
)


# ---------------------------------------------------------------------------
# Q formation (QR-specific; the reduction rides the generic engine)
# ---------------------------------------------------------------------------

def form_q(a_blocks, r, comm: Comm, reorth: int = 1):
    """Q = A·R⁻¹ locally (every survivor holds the same final R), followed by
    ``reorth`` CholeskyQR-style re-orthonormalization passes whose Gram
    reduction rides the fault-tolerant butterfly (``gram_sum`` combiner).

    Returns ``(q, r)`` with ``r`` updated so ``Q = A·r⁻¹`` still holds after
    the polish passes.  Requires every rank to hold a correct ``r`` (an
    all-valid plan, or replicas fetched first): Q spans *all* row-blocks, so
    a permanently-lost block makes the global Q undefined.
    """
    import jax.scipy.linalg as jsl

    def solve_r(q_in, rr):
        # q = a @ rr^{-1}  ==  solve rr^T y = a^T  (rr upper → rr^T lower)
        y = jsl.solve_triangular(
            jnp.swapaxes(rr, -1, -2), jnp.swapaxes(q_in, -1, -2), lower=True
        )
        return jnp.swapaxes(y, -1, -2)

    q = solve_r(a_blocks, r)
    for _ in range(reorth):
        g = jnp.swapaxes(q, -1, -2) @ q
        g_sum, _ = ft_allreduce(g, comm, op="gram_sum")
        r2 = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g_sum), -1, -2))
        q = solve_r(q, r2)
        r = _posdiag(r2 @ r)
    return q, r


# ---------------------------------------------------------------------------
# The factorizer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PanelFactorizer:
    """Panel-local policy bundle: local QR choice + reorthogonalization.

    ``local_qr`` — key into :data:`local_qr_fns` or a callable mapping a
    (…, m, n) panel to its (…, n, n) R factor; runs as the butterfly's
    ``prepare`` step.  ``reorth`` — CholeskyQR polish passes in
    :meth:`form_q` (each one Gram all-reduce over the same butterfly).
    """

    local_qr: str | Callable = "jnp"
    reorth: int = 1

    def local_fn(self) -> Callable:
        return resolve_local_qr(self.local_qr)

    def combiner(self) -> QRCombiner:
        return QRCombiner(self.local_fn())

    def reduce_r(self, a_panel, comm: Comm, plan: Plan, *, fast=None):
        """Butterfly-reduce the panel to its global R: local QR (``prepare``)
        then ``QR([R_lo; R_hi])`` per level.  Returns ``(r, valid)``."""
        return execute_plan(a_panel, comm, plan, self.combiner(), fast=fast)

    def reduce_r_prepared(self, r_local, comm: Comm, plan: Plan, *, fast=None):
        """Same reduction, but the local R factors are already computed
        (the blocked driver derives them from the lookahead Gram)."""
        return execute_plan(
            r_local, comm, plan, QRCombiner(local_qr=_identity), fast=fast
        )

    def reduce_panel_fused(
        self, r_local, c_local, comm: Comm, plan: Plan, *, fast=None
    ):
        """ONE butterfly for both panel results: the stacked
        ``(R, Σ AᵖᵀAᵗ)`` payload rides a single plan — ``log P`` rounds
        instead of the ``2·log P`` of two serialized butterflies, and the
        replica copies of the stacked tuple double as fault-tolerance
        copies for *both* leaves.  Returns ``((r, c_sum), valid)``;
        per-leaf bit-identical to :meth:`reduce_r_prepared` followed by the
        ``sum`` all-reduce over the same plan (same combine order, same
        exchanges — only the messages are batched)."""
        return execute_plan(
            (r_local, c_local), comm, plan, FUSED_PANEL_COMBINER, fast=fast
        )

    def form_q(self, a_panel, r, comm: Comm):
        return form_q(a_panel, r, comm, self.reorth)
