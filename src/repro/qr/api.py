"""The unified QR entry facade: one config object, one ``factorize`` call.

The QR entry points grew organically — ``blocked_qr_sim`` /
``blocked_qr_batched`` / ``blocked_qr_shard_map`` and the three ``tsqr_*``
functions each carried a dozen duplicated kwargs, three of them
stringly-typed tri-states (``pipeline``/``fuse``: ``"auto"/"on"/"off"``,
``recover``: ``"replica"/"off"``) whose typos used to fall through to
driver internals.  This module is the redesign:

  * :class:`Pipeline` / :class:`Fuse` / :class:`Recover` — real enums for
    the tri-state flags, coerced and validated at every public entry with
    actionable error messages (the string spellings still work).
  * :class:`QRConfig` — ONE frozen, hashable dataclass holding every
    static policy knob.  Because it is hashable it doubles as the
    jit-cache key: the module-level ``lru_cache`` compile builders in
    :mod:`repro.qr.blocked` key on ``(geometry, config)`` instead of the
    old ad-hoc 10-tuples, so "same config" and "same compiled program"
    are the same statement.
  * :func:`factorize` — the single facade the serving layer
    (:mod:`repro.serve`) consumes.  It routes by input rank and mesh
    presence:

      ==========================  =================================
      input                       driver
      ==========================  =================================
      (P, m_local, n), no mesh    blocked QR, simulated ranks
      (B, P, m_local, n), no mesh batched blocked QR — one dispatch
      (m, n) + mesh               blocked QR under ``shard_map``
      any of the above with       single-panel TSQR (the paper's
      ``panel_width=None``        tall-and-skinny workload)
      ==========================  =================================

The legacy kwarg entry points remain as thin delegating shims that emit
``DeprecationWarning`` (see :mod:`repro.qr.blocked` / :mod:`repro.qr.tsqr`);
ruff's banned-api rule fails new uses of them outside the shim modules.
"""
from __future__ import annotations

import dataclasses
import enum
import warnings

from repro.collective.faults import FaultSpec
from repro.collective.plan import VARIANTS

__all__ = [
    "Fuse",
    "Pipeline",
    "QRConfig",
    "Recover",
    "Redundancy",
    "factorize",
]


# ---------------------------------------------------------------------------
# Enums for the tri-state flags
# ---------------------------------------------------------------------------

class _CoercibleEnum(enum.Enum):
    """Enum with string coercion and an actionable failure mode."""

    @classmethod
    def coerce(cls, value):
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            try:
                return cls(value.lower())
            except ValueError:
                pass
        options = ", ".join(
            f"{cls.__name__}.{m.name} ({m.value!r})" for m in cls
        )
        raise ValueError(
            f"{cls.__name__.lower()} must be one of: {options}; "
            f"got {value!r}.  Import the enum from repro.qr.api "
            "(string spellings are accepted case-insensitively)."
        )


class Pipeline(_CoercibleEnum):
    """Scan-compiled single-program pipeline vs the eager per-panel driver.

    ``AUTO`` compiles fault-free runs into the one-dispatch pipeline and
    falls back to the eager general driver whenever any plan carries
    faults; ``ON`` demands the pipeline (raises on faulty plans); ``OFF``
    forces the eager driver (the bit-identity oracle).
    """

    AUTO = "auto"
    ON = "on"
    OFF = "off"


class Fuse(_CoercibleEnum):
    """One stacked butterfly per panel vs the split two-butterfly schedule.

    ``AUTO`` fuses every panel the fault schedule allows; ``ON`` demands
    fusion everywhere (raises when update-phase faults are scheduled);
    ``OFF`` restores the serialized two-butterfly schedule (bit-identical
    results either way — DESIGN.md §10).
    """

    AUTO = "auto"
    ON = "on"
    OFF = "off"


class Recover(_CoercibleEnum):
    """Replica-fetch restoration of ranks lost inside a panel reduction.

    ``REPLICA`` (default) restores invalid ranks from butterfly replicas at
    phase boundaries; ``OFF`` demonstrates the honest NaN-cascade of
    running without recovery.
    """

    REPLICA = "replica"
    OFF = "off"


class Redundancy(_CoercibleEnum):
    """Which fault-tolerance scheme backs the panel reductions.

    ``BUTTERFLY`` (default) is the paper's scheme: full replicas of every
    intermediate R ride the recursive-doubling exchanges, tolerating
    ``2^s - 1`` fail-stop deaths at 100% redundancy overhead.  ``CODED``
    is the checksum-coded scheme (DESIGN.md §12): ``parity`` extra ranks
    hold Cauchy-weighted linear combinations of the local factors, so up
    to ``parity`` lost, straggling, *or silently-corrupted* contributions
    are reconstructed from parity — at an overhead of ``c/P`` extra
    payload instead of the butterfly's ``(P-1)×``, and with numerical
    verification that *detects* SDC replication propagates silently.
    """

    BUTTERFLY = "butterfly"
    CODED = "coded"


# ---------------------------------------------------------------------------
# The config
# ---------------------------------------------------------------------------

_LOCAL_R = ("auto", "chol", "jnp", "cqr2", "cqr2_pallas")


@dataclasses.dataclass(frozen=True)
class QRConfig:
    """Every static policy knob of a QR factorization, in one frozen value.

    ``panel_width=None`` selects the single-panel TSQR workload (the whole
    matrix is one panel); an int selects the right-looking blocked driver.
    ``local_r="auto"`` resolves per workload — ``"chol"`` (zero-extra-sweep
    lookahead Gram) for blocked, ``"jnp"`` (Householder) for TSQR.
    ``gram=True`` selects the Gram-butterfly TSQR (shard_map only).

    The instance is hashable and serves directly as the jit-cache key of
    the module-level compile builders: two calls with equal configs and
    equal geometry share one compiled program.
    """

    panel_width: int | None = None
    variant: str = "redundant"
    local_r: str = "auto"
    reorth: int = 1
    compute_q: bool = False
    use_pallas: bool = False
    interpret: bool | None = None
    block_rows: int | None = None
    pipeline: Pipeline = Pipeline.AUTO
    fuse: Fuse = Fuse.AUTO
    recover: Recover = Recover.REPLICA
    gram: bool = False
    redundancy: Redundancy = Redundancy.BUTTERFLY
    parity: int = 2

    def __post_init__(self):
        coerce = object.__setattr__
        coerce(self, "pipeline", Pipeline.coerce(self.pipeline))
        coerce(self, "fuse", Fuse.coerce(self.fuse))
        coerce(self, "recover", Recover.coerce(self.recover))
        coerce(self, "redundancy", Redundancy.coerce(self.redundancy))
        if self.panel_width is not None and self.panel_width <= 0:
            raise ValueError(
                f"panel_width must be a positive int or None (single-panel "
                f"TSQR), got {self.panel_width!r}"
            )
        if self.variant not in VARIANTS:
            raise ValueError(
                f"unknown variant {self.variant!r}; choose from {VARIANTS}"
            )
        if isinstance(self.local_r, str) and self.local_r not in _LOCAL_R:
            raise ValueError(
                f"unknown local_r {self.local_r!r}; choose from {_LOCAL_R} "
                "or pass a callable mapping a panel to its R factor"
            )
        if self.reorth < 0:
            raise ValueError(f"reorth must be >= 0, got {self.reorth}")
        if self.block_rows is not None and self.block_rows <= 0:
            raise ValueError(
                f"block_rows must be a positive int (an explicit Pallas "
                f"streaming panel height) or None (autotuned per "
                f"shape-class), got {self.block_rows!r}"
            )
        if self.gram and self.panel_width is not None:
            raise ValueError(
                "gram=True selects the Gram-butterfly TSQR, which factors "
                "the whole matrix as one panel — it is incompatible with "
                f"panel_width={self.panel_width} (use panel_width=None)"
            )
        if self.panel_width is None and self.local_r == "chol":
            raise ValueError(
                "local_r='chol' derives the panel R from the blocked "
                "driver's lookahead Gram accumulator, which the single-panel "
                "TSQR does not run; use local_r='auto'/'jnp'/'cqr2'/"
                "'cqr2_pallas', or gram=True for the Gram-butterfly TSQR"
            )
        if self.parity < 1:
            raise ValueError(
                f"parity must be >= 1 (the number of checksum ranks the "
                f"coded scheme adds), got {self.parity}"
            )
        if self.redundancy is Redundancy.CODED:
            if self.gram:
                raise ValueError(
                    "redundancy='coded' codes the per-rank R contributions; "
                    "the Gram-butterfly TSQR reduces a Gram matrix over the "
                    "butterfly instead — the two schemes do not compose "
                    "(use gram=False)"
                )
            if self.pipeline is Pipeline.ON:
                raise ValueError(
                    "pipeline='on' demands the scan-compiled butterfly "
                    "pipeline, which is replica-redundancy only; the coded "
                    "scheme runs the eager per-panel driver (use "
                    "pipeline='auto' or 'off')"
                )

    # -- resolution helpers -------------------------------------------------

    def resolved_local_r(self) -> str:
        """Concrete local factorization for the selected workload."""
        if self.local_r != "auto":
            return self.local_r
        return "chol" if self.panel_width is not None else "jnp"

    def canonical(self) -> "QRConfig":
        """The compile-relevant projection of this config — used as the
        jit-cache key, so knobs that do not change the traced program
        (``pipeline`` mode, ``recover`` policy) are normalized away and
        ``local_r="auto"`` is resolved.  Two configs with equal
        ``canonical()`` share one compiled pipeline."""
        return dataclasses.replace(
            self,
            local_r=self.resolved_local_r(),
            pipeline=Pipeline.AUTO,
            recover=Recover.REPLICA,
            # block_rows only shapes Pallas kernel tiling — the jnp oracles
            # have no streaming panels, so it must not split their cache key
            block_rows=self.block_rows if self.use_pallas else None,
            # AUTO and ON trace the same fused program (ON only tightens
            # host-side validation); OFF is the split-schedule program
            fuse=Fuse.OFF if self.fuse is Fuse.OFF else Fuse.AUTO,
            # parity only shapes the traced program under the coded scheme
            parity=self.parity if self.redundancy is Redundancy.CODED else 2,
        )

    def factorizer(self):
        """The :class:`~repro.qr.panel.PanelFactorizer` this config implies."""
        from .panel import PanelFactorizer

        local_r = self.resolved_local_r()
        return PanelFactorizer(
            local_qr="jnp" if local_r == "chol" else local_r,
            reorth=self.reorth,
        )


# ---------------------------------------------------------------------------
# Deprecation machinery for the legacy kwarg entry points
# ---------------------------------------------------------------------------

def warn_deprecated_entry(name: str) -> None:
    warnings.warn(
        f"{name}() is deprecated: build a repro.qr.api.QRConfig and call "
        "repro.qr.api.factorize(a, config) instead (same drivers, same "
        "results — the legacy kwargs map 1:1 onto QRConfig fields; see the "
        "migration table in README.md)",
        DeprecationWarning,
        stacklevel=3,
    )


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

def _route_error(a, mesh) -> str:
    return (
        f"cannot route input of shape {getattr(a, 'shape', None)} with "
        f"mesh={'present' if mesh is not None else 'absent'}: factorize "
        "expects (P, m_local, n) row blocks or a batched (B, P, m_local, n) "
        "stack without a mesh, or a global (m, n) matrix with mesh= (and "
        "its row-sharding axis=)"
    )


def factorize(
    a,
    config: QRConfig | None = None,
    *,
    mesh=None,
    axis: str | None = None,
    faults=None,
    jit: bool = True,
):
    """Factorize ``a`` under ``config`` — the one QR entry point.

    Routing is by input rank and mesh presence (see the module table):
    3-D input is P row blocks on simulated ranks, 4-D is a batch of B such
    stacks drained in ONE device dispatch, and 2-D input with ``mesh=``
    runs under ``shard_map`` row-sharded over ``axis`` (defaulting to the
    mesh's sole axis).  ``config.panel_width=None`` selects the
    single-panel TSQR workload; an int selects the blocked driver.

    ``faults`` is the per-call fault injection: a
    :class:`~repro.collective.faults.FaultSpec` for TSQR, a
    :class:`~repro.qr.blocked.PanelFaultSchedule` for the blocked driver
    (validated — passing the wrong kind is an error, not silence).
    Returns :class:`~repro.qr.tsqr.TSQRResult` or
    :class:`~repro.qr.blocked.BlockedQRResult` accordingly.
    """
    from . import blocked as _blocked
    from . import tsqr as _tsqr

    if config is None:
        config = QRConfig()
    elif not isinstance(config, QRConfig):
        raise TypeError(
            f"config must be a repro.qr.api.QRConfig, got "
            f"{type(config).__name__} — construct one (all fields have "
            "defaults) rather than passing loose kwargs"
        )
    tsqr_mode = config.panel_width is None
    if faults is not None:
        want = FaultSpec if tsqr_mode else _blocked.PanelFaultSchedule
        if not isinstance(faults, want):
            raise TypeError(
                f"faults must be a {want.__name__} for this workload "
                f"(panel_width={config.panel_width}), got "
                f"{type(faults).__name__}"
            )

    if mesh is not None:
        if config.redundancy is Redundancy.CODED:
            raise ValueError(
                "redundancy='coded' is a simulated-ranks scheme: the coded "
                "world holds P data ranks plus `parity` checksum ranks, and "
                "the decode indexes the gather root's row — neither maps "
                "onto the fixed-size shard_map mesh; run the 3-D simulated "
                "entry (or redundancy='butterfly' under the mesh)"
            )
        if getattr(a, "ndim", None) != 2:
            raise ValueError(_route_error(a, mesh))
        if axis is None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    f"mesh has axes {mesh.axis_names}; pass axis= to pick "
                    "the row-sharding axis"
                )
            axis = mesh.axis_names[0]
        if tsqr_mode:
            if config.gram:
                return _tsqr._factorize_gram_shard(
                    a, config, mesh=mesh, axis=axis, jit=jit
                )
            return _tsqr._factorize_shard(
                a, config, mesh=mesh, axis=axis, fault_spec=faults, jit=jit
            )
        return _blocked._factorize_shard_map(
            a, config, mesh=mesh, axis=axis, faults=faults, jit=jit
        )

    if config.gram:
        raise ValueError(
            "gram=True (the Gram-butterfly TSQR) is a shard_map-only "
            "driver; pass mesh= (and axis=), or use gram=False"
        )
    ndim = getattr(a, "ndim", None)
    if ndim == 3:
        if tsqr_mode:
            return _tsqr._factorize_sim(a, config, fault_spec=faults)
        return _blocked._factorize_sim(a, config, faults=faults)
    if ndim == 4:
        if config.redundancy is Redundancy.CODED:
            raise ValueError(
                "batched factorization is the fault-free hot path, where "
                "coded parity buys nothing over the plain butterfly; use "
                "redundancy='butterfly' for batches, or factor matrices "
                "one at a time through the 3-D entry for coded runs"
            )
        if faults is not None:
            raise ValueError(
                "batched factorization is the fault-free hot path (a real "
                "fleet replans at step boundaries); serve faulted batches "
                "matrix-by-matrix through the 3-D entry instead — that is "
                "exactly what repro.serve does on a mid-flight fault"
            )
        if tsqr_mode:
            return _tsqr._factorize_batched(a, config)
        return _blocked._factorize_batched(a, config)
    raise ValueError(_route_error(a, mesh))
