"""Shared scaffold for the module-level cached ``shard_map`` compiles.

The four compiled shard entry points (TSQR, Gram-butterfly TSQR, and the
blocked driver's pipeline and general paths) wrap a per-rank body the same
way: row-sharded input over one mesh axis, every output row-sharded over
the same axis, optional ``jax.jit``.  Keeping the wrapper here means the
spec plumbing changes in one place — the builders in :mod:`repro.qr.tsqr`
and :mod:`repro.qr.blocked` contribute only their bodies and their
hashable LRU keys.

Traffic-accounting note (:mod:`repro.kernels.traffic`): kernel calls made
*inside* a shard body note their bytes at trace time, so with these cached
compiles a warm repeat call records nothing — exact per-call accounting is
a property of the sim paths and of the pipeline wrapper (which notes its
own totals); see DESIGN.md §9.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

__all__ = ["dummy_q", "shard_compile"]


def dummy_q(a_blk) -> jnp.ndarray:
    """Zero-row placeholder returned when the explicit Q is not wanted (the
    out_specs arity must not depend on ``compute_q``)."""
    return jnp.zeros((0, a_blk.shape[-1]), a_blk.dtype)


def shard_compile(body, *, mesh, axis: str, n_outputs: int, jit: bool):
    """``jit(shard_map(body))`` with one row-sharded input and ``n_outputs``
    outputs sharded over the same axis."""
    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis),) * n_outputs,
    )
    return jax.jit(shard) if jit else shard
