"""Fault-tolerant, communication-avoiding TSQR (Coti 2015) entry points.

The tall-and-skinny workload of the paper: one panel — the whole matrix —
factored by the generic collective engine (:mod:`repro.collective`) with
the QR combiner.  The panel-local machinery (local QR fns, ``form_q``)
lives in :mod:`repro.qr.panel` as the :class:`~repro.qr.panel.
PanelFactorizer` shared with the blocked general-matrix driver
(:mod:`repro.qr.blocked`); this module contributes only the entry-point
plumbing (plan construction, backends, result container).

The four variants of the paper are driven by a host-computed
:class:`~repro.collective.plan.Plan` and execute identically on the
:class:`~repro.collective.comm.SimComm` (single device, leading (P,) axis)
and :class:`~repro.collective.comm.ShardMapComm` (SPMD, ``lax.ppermute``)
backends:

  * ``tree``        — Alg. 1, the baseline reduction tree (zero redundancy);
  * ``redundant``   — Alg. 2, butterfly *exchange*: both buddies combine, so
                      every intermediate R̃ exists in ``2^s`` copies;
  * ``replace``     — Alg. 3, identical fault-free, reroutes to a replica of
                      a dead buddy;
  * ``selfhealing`` — Alg. 4–6, additionally respawns dead ranks from a
                      replica at every level.

Hot-path notes (DESIGN.md §7): fault-free plans ride the engine's
straight-line fast path automatically, and the CQR2 local QRs use the
fused 2-sweep R-only pipeline (``cholesky_qr2_r``) — the butterfly only
carries R, so no tall intermediate is ever materialized.

Compilation model (DESIGN.md §9): the ``shard_map`` entry points are
module-level cached compiles keyed on ``(mesh, plan, factorizer, …)`` —
the seed rebuilt ``jax.jit(shard)`` on every call, discarding the compile
cache — so repeat calls with identical statics and shapes perform zero
new traces (CI retrace-guarded).  :class:`TSQRResult` is a registered
pytree, so ``jax.vmap(tsqr_sim …)`` batches B independent factorizations.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.collective.combiners import posdiag as _posdiag
from repro.collective.comm import ShardMapComm, SimComm
from repro.collective.engine import ft_allreduce
from repro.collective.faults import FaultSpec
from repro.collective.plan import Plan, make_plan
from repro.kernels import dispatch as _dispatch

from ._shard import dummy_q, shard_compile
from .api import QRConfig, Redundancy, warn_deprecated_entry
from .panel import PanelFactorizer, form_q

__all__ = [
    "TSQRResult",
    "tsqr_sim",
    "tsqr_shard_map",
    "tsqr_gram_shard_map",
]


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TSQRResult:
    """Per-rank outcome of a fault-tolerant TSQR.

    ``r``        — (P, n, n) in sim / per-device (n, n) under shard_map.
    ``valid``    — who holds a correct final R (the paper's semantics).
    ``q``        — optional per-rank (m_local, n) orthonormal factor.
    ``plan``     — the communication plan that was executed (accounting):
                   a butterfly :class:`~repro.collective.plan.Plan` or a
                   :class:`~repro.collective.coded.CodedPlan`.
    ``detected`` — coded runs only: (P,) device bool flagging ranks whose
                   payload failed checksum verification (silent data
                   corruption the butterfly would have propagated).
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    plan: Plan
    detected: jax.Array | None = None


# Registered as a pytree (arrays as leaves, the host plan as static aux) so
# results flow through jax transformations — `jax.vmap(tsqr_sim …)` batches
# B independent tall-skinny factorizations directly.
jax.tree_util.register_pytree_node(
    TSQRResult,
    lambda res: ((res.r, res.valid, res.q, res.detected), (res.plan,)),
    lambda aux, ch: TSQRResult(
        r=ch[0], valid=ch[1], q=ch[2], detected=ch[3], plan=aux[0]
    ),
)


# ---------------------------------------------------------------------------
# Module-level compiled programs (zero-retrace: the old per-call
# ``jax.jit(shard)`` rebuilt the wrapper — and discarded the compile cache —
# on every invocation; these builders key on the hashable statics and the
# jit cache underneath keys on the payload shapes)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _compiled_tsqr_shard(mesh, axis: str, plan: Plan, pf: PanelFactorizer,
                         want_q: bool, jit: bool):
    comm = ShardMapComm(plan.n_ranks, axis)

    def body(a_blk):
        _dispatch.note_trace("tsqr_shard_map")
        r, valid = pf.reduce_r(a_blk, comm, plan)
        q = None
        if want_q:
            q, r = pf.form_q(a_blk, r, comm)
        return r[None], valid[None], q if want_q else dummy_q(a_blk)

    return shard_compile(body, mesh=mesh, axis=axis, n_outputs=3, jit=jit)


@functools.lru_cache(maxsize=64)
def _compiled_tsqr_gram_shard(mesh, axis: str, p: int, reorth: int,
                              jit: bool):
    comm = ShardMapComm(p, axis)

    def body(a_blk):
        _dispatch.note_trace("tsqr_gram_shard_map")
        a32 = a_blk.astype(jnp.float32)
        g = jnp.einsum("mi,mj->ij", a32, a32)
        g, _ = ft_allreduce(g, comm, op="gram_sum")
        r = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2))
        q, r = form_q(a_blk, r, comm, reorth)
        return r[None], q

    return shard_compile(body, mesh=mesh, axis=axis, n_outputs=2, jit=jit)


# ---------------------------------------------------------------------------
# factorize() implementations (routed to by repro.qr.api.factorize)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _compiled_tsqr_coded(config: QRConfig, plan):
    """One compiled coded TSQR per ``(canonical config, coded plan)`` —
    the coded analogue of the butterfly's cached builders, so repeat calls
    under the same fault picture perform zero new traces (CI-guarded)."""
    from repro.collective.coded import execute_coded

    p = plan.n_data
    world = SimComm(plan.n_ranks)
    data_comm = SimComm(p)
    pf = config.factorizer()

    def fn(a, observed):
        _dispatch.note_trace("tsqr_coded")
        val, fv, det = execute_coded(
            a, world, plan, pf.combiner(), observed=observed
        )
        r, valid, detected = val[:p], fv[:p], det[:p]
        q = None
        if config.compute_q:
            q, r = pf.form_q(a, r, data_comm)
        return r, valid, q, detected

    return jax.jit(fn)


def _factorize_sim_coded(
    a_blocks, config: QRConfig, fault_spec, observed
) -> TSQRResult:
    """Checksum-coded TSQR (DESIGN.md §12): ``config.parity`` checksum
    ranks are appended to the P data blocks, Cauchy-encoded at
    distribution time, and up to ``parity`` dead / straggling / corrupted
    contributions are reconstructed from parity in-collective — no
    ``replica_fetch``, and declared-corrupt payloads are *verified*
    against their reconstruction (``detected``)."""
    from repro.collective.coded import make_coded_plan

    p = a_blocks.shape[0]
    plan = make_coded_plan(p, config.parity, fault_spec)
    if config.compute_q and not plan.final_valid[:p].all():
        raise ValueError(
            "compute_q requires every data rank to end valid; this fault "
            f"spec exceeds the coded erasure budget (c={config.parity}) — "
            f"final_valid={plan.final_valid[:p]}"
        )
    fun = _compiled_tsqr_coded(config.canonical(), plan)
    _dispatch.note_dispatch("tsqr_coded")
    r, valid, q, detected = fun(a_blocks, observed)
    return TSQRResult(
        r=r, valid=valid, q=(q if config.compute_q else None), plan=plan,
        detected=detected,
    )


def _factorize_sim(
    a_blocks,
    config: QRConfig,
    *,
    fault_spec: FaultSpec | None = None,
    observed=None,
) -> TSQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n).

    This is the backend the test-suite and the hypothesis robustness sweeps
    drive; the algorithm body is shared with the shard_map driver.

    ``observed`` (coded runs only) is what the data ranks *currently*
    hold — parity is always encoded from ``a_blocks``, the distribution-
    time truth, so a scenario injects silent corruption by perturbing
    ``observed`` and the checksum verification catches the divergence.
    """
    if config.redundancy is Redundancy.CODED:
        return _factorize_sim_coded(a_blocks, config, fault_spec, observed)
    if observed is not None:
        raise ValueError(
            "observed= models silently-corrupted payloads, which only the "
            "coded scheme can act on; use redundancy='coded'"
        )
    p = a_blocks.shape[0]
    plan = make_plan(config.variant, p, fault_spec)
    if config.compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance); got final_valid="
            f"{plan.final_valid}"
        )
    comm = SimComm(p)
    pf = config.factorizer()
    r, valid = pf.reduce_r(a_blocks, comm, plan)
    q = None
    if config.compute_q:
        q, r = pf.form_q(a_blocks, r, comm)
    return TSQRResult(r=r, valid=valid, q=q, plan=plan)


@functools.lru_cache(maxsize=64)
def _compiled_tsqr_batched(p: int, config: QRConfig):
    """One compiled vmap-batched TSQR per ``(P, canonical config)``: B
    independent tall-skinny factorizations in one device dispatch (the
    single-panel analogue of the blocked batched pipeline)."""
    comm = SimComm(p)
    plan = make_plan(config.variant, p)
    pf = config.factorizer()

    def fn(a):
        _dispatch.note_trace("tsqr_batched")
        r, valid = pf.reduce_r(a, comm, plan)
        q = None
        if config.compute_q:
            q, r = pf.form_q(a, r, comm)
        return r, valid, q
    return jax.jit(jax.vmap(fn)), plan


def _factorize_batched(a_batch, config: QRConfig) -> TSQRResult:
    """B independent TSQRs in one device dispatch; ``a_batch`` is
    (B, P, m_local, n).  Fault-free only, like the blocked batched path."""
    if a_batch.ndim != 4:
        raise ValueError(
            f"a_batch must be (B, P, m_local, n), got shape {a_batch.shape}"
        )
    p = a_batch.shape[1]
    fun, plan = _compiled_tsqr_batched(p, config.canonical())
    if config.compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan; variant "
            f"{config.variant!r} leaves ranks invalid even fault-free"
        )
    _dispatch.note_dispatch("tsqr_batched")
    r, valid, q = fun(a_batch)
    return TSQRResult(r=r, valid=valid, q=q, plan=plan)


def _factorize_gram_shard(
    a_global, config: QRConfig, *, mesh, axis: str, jit: bool = True
) -> TSQRResult:
    """Beyond-paper optimized TSQR: the **Gram butterfly** (EXPERIMENTS.md
    §Perf, cell C).

    The paper's combine is ``QR([R̃ᵢ; R̃ⱼ])`` at every butterfly level —
    log₂(P) Householder factorizations of 2n×n on the critical path, each
    sequential and VPU-bound on TPU.  This variant keeps the *same
    butterfly* (same exchanges, same 2^s-copy redundancy, same fault
    semantics) but swaps the combiner to ``gram_sum``: it carries Gram
    matrices ``G = Σ AᵢᵀAᵢ``, one Cholesky at the end, and a CholeskyQR2
    polish for Householder-grade orthogonality.  Per level the combine is
    an n×n add instead of an O(n³) QR; the local work is one MXU Gram
    matmul instead of a Householder panel.  Wire bytes are n² per exchange
    shipped square — n(n+1)/2 with symmetric packing, which
    ``Plan.bytes_on_wire(symmetric=True)`` now prices (see
    benchmarks/comm_volume.py).

    Numerics: κ(A)² enters the Gram, so the polish round is mandatory;
    certified for κ(A) ≲ 1/√ε like CQR2.
    """
    p = mesh.shape[axis]
    fun = _compiled_tsqr_gram_shard(mesh, axis, p, config.reorth, jit)
    _dispatch.note_dispatch("tsqr_gram_shard_map")
    r, q = fun(a_global)
    return TSQRResult(r=r, valid=jnp.ones((p,), bool), q=q,
                      plan=make_plan("redundant", p))


def _factorize_shard(
    a_global,
    config: QRConfig,
    *,
    mesh,
    axis: str,
    fault_spec: FaultSpec | None = None,
    jit: bool = True,
) -> TSQRResult:
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Returns r (P, n, n) — one (replicated-if-valid) copy per rank — valid
    (P,) and q (m, n) row-sharded (or None).

    The permutation plan is host-computed from ``fault_spec``; on a real
    fleet the runtime re-invokes this with a fresh plan after each health
    change (step-boundary replanning, DESIGN.md §2).
    """
    p = mesh.shape[axis]
    plan = make_plan(config.variant, p, fault_spec)
    if config.compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance)"
        )
    pf = config.factorizer()
    fun = _compiled_tsqr_shard(mesh, axis, plan, pf, config.compute_q, jit)
    _dispatch.note_dispatch("tsqr_shard_map")
    r, valid, q = fun(a_global)
    return TSQRResult(
        r=r, valid=valid, q=(q if config.compute_q else None), plan=plan
    )


# ---------------------------------------------------------------------------
# Legacy kwarg entry points (deprecated shims over the implementations)
# ---------------------------------------------------------------------------

def _config_of(compute_q, reorth, local_qr) -> QRConfig:
    return QRConfig(
        panel_width=None, local_r=local_qr, reorth=reorth,
        compute_q=compute_q,
    )


def tsqr_sim(
    a_blocks,
    *,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
) -> TSQRResult:
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig`
    (``panel_width=None`` selects TSQR) and call
    :func:`repro.qr.api.factorize` on the (P, m_local, n) row blocks
    instead; results are bit-identical (this delegates to the same
    implementation)."""
    warn_deprecated_entry("tsqr_sim")
    config = dataclasses.replace(
        _config_of(compute_q, reorth, local_qr), variant=variant
    )
    return _factorize_sim(a_blocks, config, fault_spec=fault_spec)


def tsqr_gram_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    reorth: int = 1,
    jit: bool = True,
):
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig` with
    ``gram=True`` and call :func:`repro.qr.api.factorize` with ``mesh=``
    instead (same Gram-butterfly driver, bit-identical results)."""
    warn_deprecated_entry("tsqr_gram_shard_map")
    config = QRConfig(panel_width=None, gram=True, reorth=reorth)
    return _factorize_gram_shard(
        a_global, config, mesh=mesh, axis=axis, jit=jit
    )


def tsqr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
    jit: bool = True,
):
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig`
    (``panel_width=None``) and call :func:`repro.qr.api.factorize` with
    ``mesh=``/``axis=`` instead (same compiled driver, bit-identical
    results)."""
    warn_deprecated_entry("tsqr_shard_map")
    config = dataclasses.replace(
        _config_of(compute_q, reorth, local_qr), variant=variant
    )
    return _factorize_shard(
        a_global, config, mesh=mesh, axis=axis, fault_spec=fault_spec,
        jit=jit,
    )
