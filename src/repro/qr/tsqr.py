"""Fault-tolerant, communication-avoiding TSQR (Coti 2015) entry points.

The tall-and-skinny workload of the paper: one panel — the whole matrix —
factored by the generic collective engine (:mod:`repro.collective`) with
the QR combiner.  The panel-local machinery (local QR fns, ``form_q``)
lives in :mod:`repro.qr.panel` as the :class:`~repro.qr.panel.
PanelFactorizer` shared with the blocked general-matrix driver
(:mod:`repro.qr.blocked`); this module contributes only the entry-point
plumbing (plan construction, backends, result container).

The four variants of the paper are driven by a host-computed
:class:`~repro.collective.plan.Plan` and execute identically on the
:class:`~repro.collective.comm.SimComm` (single device, leading (P,) axis)
and :class:`~repro.collective.comm.ShardMapComm` (SPMD, ``lax.ppermute``)
backends:

  * ``tree``        — Alg. 1, the baseline reduction tree (zero redundancy);
  * ``redundant``   — Alg. 2, butterfly *exchange*: both buddies combine, so
                      every intermediate R̃ exists in ``2^s`` copies;
  * ``replace``     — Alg. 3, identical fault-free, reroutes to a replica of
                      a dead buddy;
  * ``selfhealing`` — Alg. 4–6, additionally respawns dead ranks from a
                      replica at every level.

Hot-path notes (DESIGN.md §7): fault-free plans ride the engine's
straight-line fast path automatically, and the CQR2 local QRs use the
fused 2-sweep R-only pipeline (``cholesky_qr2_r``) — the butterfly only
carries R, so no tall intermediate is ever materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collective.combiners import posdiag as _posdiag
from repro.collective.comm import ShardMapComm, SimComm
from repro.collective.engine import ft_allreduce
from repro.collective.faults import FaultSpec
from repro.collective.plan import Plan, make_plan
from repro.compat import shard_map

from .panel import PanelFactorizer, form_q

__all__ = [
    "TSQRResult",
    "tsqr_sim",
    "tsqr_shard_map",
    "tsqr_gram_shard_map",
]


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TSQRResult:
    """Per-rank outcome of a fault-tolerant TSQR.

    ``r``      — (P, n, n) in sim / per-device (n, n) under shard_map.
    ``valid``  — who holds a correct final R (the paper's semantics).
    ``q``      — optional per-rank (m_local, n) orthonormal factor.
    ``plan``   — the communication plan that was executed (accounting).
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    plan: Plan


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def tsqr_sim(
    a_blocks,
    *,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
) -> TSQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n).

    This is the backend the test-suite and the hypothesis robustness sweeps
    drive; the algorithm body is shared with :func:`tsqr_shard_map`.
    """
    p = a_blocks.shape[0]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance); got final_valid="
            f"{plan.final_valid}"
        )
    comm = SimComm(p)
    pf = PanelFactorizer(local_qr=local_qr, reorth=reorth)
    r, valid = pf.reduce_r(a_blocks, comm, plan)
    q = None
    if compute_q:
        q, r = pf.form_q(a_blocks, r, comm)
    return TSQRResult(r=r, valid=valid, q=q, plan=plan)


def tsqr_gram_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    reorth: int = 1,
    jit: bool = True,
):
    """Beyond-paper optimized TSQR: the **Gram butterfly** (EXPERIMENTS.md
    §Perf, cell C).

    The paper's combine is ``QR([R̃ᵢ; R̃ⱼ])`` at every butterfly level —
    log₂(P) Householder factorizations of 2n×n on the critical path, each
    sequential and VPU-bound on TPU.  This variant keeps the *same
    butterfly* (same exchanges, same 2^s-copy redundancy, same fault
    semantics) but swaps the combiner to ``gram_sum``: it carries Gram
    matrices ``G = Σ AᵢᵀAᵢ``, one Cholesky at the end, and a CholeskyQR2
    polish for Householder-grade orthogonality.  Per level the combine is
    an n×n add instead of an O(n³) QR; the local work is one MXU Gram
    matmul instead of a Householder panel.  Wire bytes are n² per exchange
    shipped square — n(n+1)/2 with symmetric packing, which
    ``Plan.bytes_on_wire(symmetric=True)`` now prices (see
    benchmarks/comm_volume.py).

    Numerics: κ(A)² enters the Gram, so the polish round is mandatory;
    certified for κ(A) ≲ 1/√ε like CQR2.
    """
    p = mesh.shape[axis]
    comm = ShardMapComm(p, axis)

    def body(a_blk):
        a32 = a_blk.astype(jnp.float32)
        g = jnp.einsum("mi,mj->ij", a32, a32)
        g, _ = ft_allreduce(g, comm, op="gram_sum")
        r = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2))
        q, r = form_q(a_blk, r, comm, reorth)
        return r[None], q

    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis)),
    )
    fun = jax.jit(shard) if jit else shard
    r, q = fun(a_global)
    return TSQRResult(r=r, valid=jnp.ones((p,), bool), q=q,
                      plan=make_plan("redundant", p))


def tsqr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
    jit: bool = True,
):
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Returns ``(r, valid, q)`` with r (P, n, n) — one (replicated-if-valid)
    copy per rank — valid (P,) and q (m, n) row-sharded (or None).

    The permutation plan is host-computed from ``fault_spec``; on a real
    fleet the runtime re-invokes this with a fresh plan after each health
    change (step-boundary replanning, DESIGN.md §2).
    """
    p = mesh.shape[axis]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance)"
        )
    comm = ShardMapComm(p, axis)
    pf = PanelFactorizer(local_qr=local_qr, reorth=reorth)
    want_q = compute_q

    def body(a_blk):
        a = a_blk  # (m_local, n)
        r, valid = pf.reduce_r(a, comm, plan)
        q = None
        if want_q:
            q, r = pf.form_q(a, r, comm)
        out_q = q if want_q else jnp.zeros((0, a.shape[-1]), a.dtype)
        return r[None], valid[None], out_q

    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    fun = jax.jit(shard) if jit else shard
    r, valid, q = fun(a_global)
    return TSQRResult(
        r=r, valid=valid, q=(q if want_q else None), plan=plan
    )
