"""Fault-tolerant right-looking blocked QR for general m×n matrices.

Coti's follow-on to the TSQR paper ("Fault Tolerant QR Factorization for
General Matrices", arXiv:1604.02504) extends the redundant-computation
trick beyond tall-and-skinny: use TSQR as the *panel* factorization inside
a right-looking blocked QR, and the butterfly's ``2^s``-copy redundancy
protects every panel's reduced factors for free.  This driver implements
that on the repo's collective engine:

  per column panel ``k`` (width ``b``):
    1. **Panel TSQR** — each rank's local R of the panel block rides the
       fault-tolerant butterfly (QR combiner, any variant/plan); every
       valid rank ends holding the identical global ``R_kk``.  The
       redundant copies double as the fault-tolerance replicas — the
       "broadcast" of the implicit panel factor costs nothing extra.
    2. **Explicit panel Q** — ``Q_k = A_panel R_kk⁻¹`` locally (plus
       ``reorth`` CholeskyQR polish passes over the same butterfly).
    3. **Block row of R** — ``W = R_totᵀ⁻¹ · Σ_ranks A_panelᵀ A_trail``:
       the cross products are summed by a second fault-tolerant butterfly
       (``sum`` combiner), so ``W = Q_kᵀ A_trail`` is replicated too.
    4. **Trailing update** — ``A_trail ← A_trail − Q_k W`` by the fused
       Pallas kernel (:mod:`repro.kernels.trailing_update`), which also
       accumulates the *next* panel's Gram + cross products in the same
       pass.  The trailing block is touched exactly **once per panel**
       (hard-gated by the ``general_qr`` bench case); panel-local reads
       are narrow (m×b).

**Failure semantics, per panel** (DESIGN.md §8): a death during phase 1 or
phase 3 follows the variant's butterfly guarantee (``2^s − 1`` at entry of
exchange ``s``).  Ranks that lose a replicated factor are restored at the
phase boundary via :func:`~repro.collective.engine.replica_fetch` — the
blocked-QR analogue of Self-Healing's respawn, hoisted to the panel
boundary where a real runtime replans (``recover="replica"``, default).
With ``recover="off"`` the honest no-recovery consequence is observable:
the NaN-poisoned rank corrupts every later panel's reduction — exactly why
the general-matrix paper needs a recovery story at all.  ``valid`` reports
the *strict survivors* (ranks valid through every reduction with no
replica fetch); ``reports`` carries the per-panel tolerance verdicts and
recovery counts.
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.collective.comm import Comm, ShardMapComm, SimComm
from repro.collective.engine import ft_allreduce, replica_fetch
from repro.collective.faults import FaultSpec, within_tolerance
from repro.collective.plan import Plan, make_plan
from repro.compat import shard_map
from repro.kernels import ops as kops

from .panel import PanelFactorizer, chol_r

__all__ = [
    "PanelFaultSchedule",
    "PanelReport",
    "BlockedQRResult",
    "blocked_qr_sim",
    "blocked_qr_shard_map",
    "panel_widths",
]


def panel_widths(n: int, panel_width: int) -> tuple[int, ...]:
    """Column widths of the ``⌈n / panel_width⌉`` panels (ragged tail)."""
    if panel_width <= 0:
        raise ValueError(f"panel_width must be positive, got {panel_width}")
    k = math.ceil(n / panel_width)
    return tuple(
        min(panel_width, n - i * panel_width) for i in range(k)
    )


@dataclasses.dataclass(frozen=True)
class PanelFaultSchedule:
    """Fail-stop deaths scheduled into a blocked factorization.

    ``panel[k]`` strikes during panel ``k``'s TSQR reduction (phase 1);
    ``update[k]`` during its cross-product reduction (phase 3 — "death
    during the trailing update": the local subtraction has no communication,
    so the W butterfly is where a mid-update death is observable).  Each
    value is a :class:`~repro.collective.faults.FaultSpec` whose steps index
    that butterfly's exchanges.
    """

    panel: Mapping[int, FaultSpec] = dataclasses.field(default_factory=dict)
    update: Mapping[int, FaultSpec] = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, panel=None, update=None) -> "PanelFaultSchedule":
        """From ``{panel_index: FaultSpec | {rank: step}}`` mappings."""

        def norm(d):
            return {
                int(k): v if isinstance(v, FaultSpec) else FaultSpec.of(v)
                for k, v in (d or {}).items()
            }

        return cls(panel=norm(panel), update=norm(update))

    def __bool__(self) -> bool:
        return bool(self.panel) or bool(self.update)


@dataclasses.dataclass(frozen=True)
class PanelReport:
    """Host-side verdicts for one panel (the guarantee bookkeeping)."""

    panel: int
    plan_r: Plan
    plan_w: Plan | None
    within_tolerance_r: bool
    within_tolerance_w: bool
    recovered_r: int          # ranks restored from a replica after phase 1
    recovered_w: int          # …after phase 3
    recoverable: bool         # some rank held every replicated factor

    @property
    def within_tolerance(self) -> bool:
        return self.within_tolerance_r and self.within_tolerance_w


@dataclasses.dataclass
class BlockedQRResult:
    """Outcome of a fault-tolerant blocked QR.

    ``r``      — (P, n, n) in sim / per-device (n, n) under shard_map: the
                 assembled upper-triangular factor (replicated row blocks).
    ``valid``  — (P,) strict survivors: valid through every panel's
                 reductions without replica recovery.
    ``q``      — optional per-rank (m_local, n) explicit orthonormal factor.
    ``reports``— per-panel :class:`PanelReport` (tolerance + recovery).
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    reports: tuple[PanelReport, ...]
    panel_width: int

    @property
    def n_panels(self) -> int:
        return len(self.reports)

    @property
    def recoverable(self) -> bool:
        return all(rep.recoverable for rep in self.reports)


# ---------------------------------------------------------------------------
# Host-side planning
# ---------------------------------------------------------------------------

def _build_reports(
    variant: str,
    p: int,
    widths: tuple[int, ...],
    faults: PanelFaultSchedule,
    recover: str,
) -> tuple[PanelReport, ...]:
    n_panels = len(widths)
    for key in set(faults.panel) | set(faults.update):
        if not 0 <= key < n_panels:
            raise ValueError(
                f"fault schedule names panel {key}, but only {n_panels} "
                "panels exist"
            )
    if (n_panels - 1) in faults.update:
        raise ValueError(
            f"panel {n_panels - 1} is the last panel — it has no trailing "
            "update to die during"
        )
    reports = []
    for k in range(n_panels):
        spec_r = faults.panel.get(k, FaultSpec.none())
        plan_r = make_plan(variant, p, spec_r)
        tol_r = within_tolerance(variant, spec_r, plan_r.n_steps)
        last = k == n_panels - 1
        plan_w = None
        tol_w = True
        if not last:
            spec_w = faults.update.get(k, FaultSpec.none())
            plan_w = make_plan(variant, p, spec_w)
            tol_w = within_tolerance(variant, spec_w, plan_w.n_steps)
        recoverable = bool(plan_r.final_valid.any()) and (
            plan_w is None or bool(plan_w.final_valid.any())
        )
        # recovered_* counts ranks replica_fetch actually restores — zero
        # when recovery is disabled (the ranks stay poisoned).
        fetching = recover == "replica" and recoverable
        reports.append(
            PanelReport(
                panel=k,
                plan_r=plan_r,
                plan_w=plan_w,
                within_tolerance_r=tol_r,
                within_tolerance_w=tol_w,
                recovered_r=(
                    int((~plan_r.final_valid).sum()) if fetching else 0
                ),
                recovered_w=(
                    int((~plan_w.final_valid).sum())
                    if fetching and plan_w is not None else 0
                ),
                recoverable=recoverable,
            )
        )
    return tuple(reports)


# ---------------------------------------------------------------------------
# The driver body (backend-agnostic: arrays may carry a leading (P,) axis
# under SimComm, or be per-rank local blocks under ShardMapComm)
# ---------------------------------------------------------------------------

def _solve_w(r_tot, c_sum):
    """W = R_totᵀ⁻¹ C  (C = Σ A_panelᵀ A_trail, so W = Q_kᵀ A_trail)."""
    import jax.scipy.linalg as jsl

    return jsl.solve_triangular(
        jnp.swapaxes(r_tot, -1, -2), c_sum, lower=True
    )


def _blocked_body(
    a,
    comm: Comm,
    reports: tuple[PanelReport, ...],
    widths: tuple[int, ...],
    pf: PanelFactorizer,
    *,
    local_r: str,
    compute_q: bool,
    use_pallas: bool,
    interpret: bool | None,
):
    m_local, n = a.shape[-2], a.shape[-1]
    kw = dict(use_pallas=use_pallas, interpret=interpret)
    r_full = jnp.zeros(a.shape[:-2] + (n, n), jnp.float32)
    valid = comm.take(np.ones(comm.n_ranks, dtype=bool))
    q_cols = []
    trail = a
    s = kops.panel_cross(a, split=widths[0], **kw)          # pipeline prime
    c0 = 0
    for rep, b in zip(reports, widths):
        nt = n - c0 - b
        panel = trail[..., :, :b]
        g_loc = s[..., :, :b]
        c_loc = s[..., :, b:]
        # -- phase 1: panel TSQR over the butterfly -------------------------
        if local_r == "chol":
            r_loc = chol_r(g_loc)                 # free: lookahead Gram
        else:
            r_loc = pf.local_fn()(panel.astype(jnp.float32))
        r_kk, valid_r = pf.reduce_r_prepared(r_loc, comm, rep.plan_r)
        valid = valid & valid_r
        all_valid_r = bool(rep.plan_r.final_valid.all())
        if rep.recovered_r:
            r_kk = replica_fetch(r_kk, comm, rep.plan_r.final_valid)
        # -- phase 2: explicit panel Q (+ reorth polish) --------------------
        # The polish's gram all-reduce mixes every rank's contribution, so
        # it needs every rank to hold a finite r_kk; when a no-recovery run
        # left poisoned ranks, skip the polish — survivors keep their exact
        # unpolished factor instead of inheriting the NaN.
        clean = all_valid_r or bool(rep.recovered_r)
        pf_k = pf if clean else dataclasses.replace(pf, reorth=0)
        q_k, r_tot = pf_k.form_q(panel.astype(jnp.float32), r_kk, comm)
        q_k = q_k.astype(a.dtype)
        if compute_q:
            q_cols.append(q_k)
        # -- phase 3: block row of R via the sum butterfly ------------------
        if nt:
            c_sum, valid_w = ft_allreduce(
                c_loc, comm, op="sum", plan=rep.plan_w
            )
            valid = valid & valid_w
            if rep.recovered_w:
                c_sum = replica_fetch(c_sum, comm, rep.plan_w.final_valid)
            w = _solve_w(r_tot, c_sum)
            r_full = r_full.at[..., c0:c0 + b, c0:].set(
                jnp.concatenate([r_tot, w], axis=-1)
            )
            # -- phase 4: one-sweep trailing update + lookahead -------------
            trail, s = kops.trailing_update(
                trail[..., :, b:], q_k, w.astype(a.dtype),
                next_width=widths[rep.panel + 1], **kw
            )
        else:
            r_full = r_full.at[..., c0:c0 + b, c0:].set(r_tot)
        c0 += b
    q = jnp.concatenate(q_cols, axis=-1) if compute_q else None
    return r_full, valid, q


def _setup(
    m_local: int,
    n: int,
    panel_width: int,
    variant: str,
    p: int,
    faults: PanelFaultSchedule | None,
    local_r: str,
    reorth: int,
    recover: str,
) -> tuple[tuple[int, ...], tuple[PanelReport, ...], PanelFactorizer]:
    """Shared entry-point validation + host planning (sim and shard_map)."""
    if recover not in ("replica", "off"):
        raise ValueError(f"recover must be 'replica' or 'off', got {recover!r}")
    widths = panel_widths(n, panel_width)
    if m_local < max(widths):
        raise ValueError(
            f"each rank's row block ({m_local} rows) must be at least as "
            f"tall as the widest panel ({max(widths)}); shrink panel_width "
            "or use fewer ranks"
        )
    from .panel import local_qr_fns

    if local_r != "chol" and local_r not in local_qr_fns:
        raise ValueError(
            f"unknown local_r {local_r!r}; choose 'chol' (zero-extra-sweep "
            f"lookahead Gram) or one of {sorted(local_qr_fns)}"
        )
    reports = _build_reports(
        variant, p, widths, faults or PanelFaultSchedule(), recover
    )
    pf = PanelFactorizer(
        local_qr="jnp" if local_r == "chol" else local_r, reorth=reorth
    )
    return widths, reports, pf


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def blocked_qr_sim(
    a_blocks,
    *,
    panel_width: int,
    variant: str = "redundant",
    faults: PanelFaultSchedule | None = None,
    compute_q: bool = False,
    local_r: str = "chol",
    reorth: int = 1,
    use_pallas: bool = False,
    interpret: bool | None = None,
    recover: str = "replica",
) -> BlockedQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n) — the
    general-matrix analogue of :func:`repro.qr.tsqr.tsqr_sim`."""
    p, m_local, n = a_blocks.shape
    widths, reports, pf = _setup(
        m_local, n, panel_width, variant, p, faults, local_r, reorth, recover
    )
    r, valid, q = _blocked_body(
        a_blocks, SimComm(p), reports, widths, pf,
        local_r=local_r, compute_q=compute_q, use_pallas=use_pallas,
        interpret=interpret,
    )
    return BlockedQRResult(
        r=r, valid=valid, q=q, reports=reports, panel_width=panel_width
    )


def blocked_qr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    panel_width: int,
    variant: str = "redundant",
    faults: PanelFaultSchedule | None = None,
    compute_q: bool = False,
    local_r: str = "chol",
    reorth: int = 1,
    use_pallas: bool = False,
    interpret: bool | None = None,
    recover: str = "replica",
    jit: bool = True,
) -> BlockedQRResult:
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Same body as :func:`blocked_qr_sim` under ``shard_map`` — exchanges
    lower to ``lax.ppermute``, replica fetches ride the same wires.
    Returns r (P, n, n) (one copy per rank), valid (P,), q (m, n)
    row-sharded or None.
    """
    from jax.sharding import PartitionSpec as P

    p = mesh.shape[axis]
    m, n = a_global.shape
    widths, reports, pf = _setup(
        m // p, n, panel_width, variant, p, faults, local_r, reorth, recover
    )
    comm = ShardMapComm(p, axis)
    want_q = compute_q

    def body(a_blk):
        r, valid, q = _blocked_body(
            a_blk, comm, reports, widths, pf,
            local_r=local_r, compute_q=want_q, use_pallas=use_pallas,
            interpret=interpret,
        )
        out_q = q if want_q else jnp.zeros((0, n), a_blk.dtype)
        return r[None], valid[None], out_q

    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    fun = jax.jit(shard) if jit else shard
    r, valid, q = fun(a_global)
    return BlockedQRResult(
        r=r, valid=valid, q=(q if want_q else None),
        reports=reports, panel_width=panel_width,
    )
