"""Fault-tolerant right-looking blocked QR for general m×n matrices.

Coti's follow-on to the TSQR paper ("Fault Tolerant QR Factorization for
General Matrices", arXiv:1604.02504) extends the redundant-computation
trick beyond tall-and-skinny: use TSQR as the *panel* factorization inside
a right-looking blocked QR, and the butterfly's ``2^s``-copy redundancy
protects every panel's reduced factors for free.  This driver implements
that on the repo's collective engine:

  per column panel ``k`` (width ``b``):
    1. **Panel TSQR** — each rank's local R of the panel block rides the
       fault-tolerant butterfly (QR combiner, any variant/plan); every
       valid rank ends holding the identical global ``R_kk``.  The
       redundant copies double as the fault-tolerance replicas — the
       "broadcast" of the implicit panel factor costs nothing extra.
    2. **Explicit panel Q** — ``Q_k = A_panel R_kk⁻¹`` locally (plus
       ``reorth`` CholeskyQR polish passes over the same butterfly).
    3. **Block row of R** — ``W = R_totᵀ⁻¹ · Σ_ranks A_panelᵀ A_trail``:
       the cross products ride the *same* butterfly as the panel R by
       default (``fuse="auto"``): a stacked ``(R, Σ AᵖᵀAᵗ)`` payload under
       one plan costs ``log P`` rounds per panel instead of the ``2·log P``
       of two serialized butterflies, and the replica copies of the stacked
       tuple double as fault-tolerance copies for *both* leaves (one
       :func:`~repro.collective.engine.replica_fetch` restores R and the
       cross products together).  ``fuse="off"`` restores the split
       schedule — a second ``sum`` butterfly after Q formation —
       bit-identical results either way (DESIGN.md §10).
    4. **Trailing update** — ``A_trail ← A_trail − Q_k W`` by the fused
       Pallas kernel (:mod:`repro.kernels.trailing_update`), which also
       accumulates the *next* panel's Gram + cross products in the same
       pass.  The trailing block is touched exactly **once per panel**
       (hard-gated by the ``general_qr`` bench case); panel-local reads
       are narrow (m×b).

**Failure semantics, per panel** (DESIGN.md §8): a death during phase 1 or
phase 3 follows the variant's butterfly guarantee (``2^s − 1`` at entry of
exchange ``s``).  Ranks that lose a replicated factor are restored at the
phase boundary via :func:`~repro.collective.engine.replica_fetch` — the
blocked-QR analogue of Self-Healing's respawn, hoisted to the panel
boundary where a real runtime replans (``recover="replica"``, default).
With ``recover="off"`` the honest no-recovery consequence is observable:
the NaN-poisoned rank corrupts every later panel's reduction — exactly why
the general-matrix paper needs a recovery story at all.  ``valid`` reports
the *strict survivors* (ranks valid through every reduction with no
replica fetch); ``reports`` carries the per-panel tolerance verdicts and
recovery counts.

**Compilation model** (DESIGN.md §9): the eager per-panel loop above is
the *fault* path.  Fault-free runs auto-dispatch to the scan-compiled
fixed-shape pipeline — padded maximal trailing width, shifted layout, one
``lax.scan`` trace for all uniform panels plus a static ragged epilogue —
which executes the whole factorization as ONE jitted device program,
bit-identical to the eager driver, with module-level cached compiles
(zero retrace on repeat calls) and a ``vmap``-batched B-matrix variant
(:func:`blocked_qr_batched`).  Under the default fused schedule the
pipeline is *double-buffered*: each panel's single stacked butterfly is
issued the moment the producing trailing sweep lands its lookahead
accumulators and consumed one scan stage later (the pending reduction
rides the carry), decoupling every collective from its consumer by a full
stage.  Trace/dispatch counts, per-panel collective rounds and overlap
depth are measured by :mod:`repro.kernels.dispatch` /
:mod:`repro.kernels.traffic` and hard-gated by the ``dispatch`` and
``overlap`` bench cases.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections.abc import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.collective.coded import CodedPlan, execute_coded, make_coded_plan
from repro.collective.comm import Comm, ShardMapComm, SimComm
from repro.collective.engine import ft_allreduce, recover_payload
from repro.collective.faults import FaultSpec, within_tolerance
from repro.collective.plan import Plan, make_plan
from repro.kernels import autotune as _autotune
from repro.kernels import dispatch as _dispatch
from repro.kernels import ops as kops
from repro.kernels import traffic as _traffic
from repro.kernels.backend import resolve_backend

from ._shard import dummy_q, shard_compile
from .api import (
    Fuse, Pipeline, QRConfig, Recover, Redundancy, warn_deprecated_entry,
)
from .panel import FUSED_PANEL_COMBINER, PanelFactorizer, chol_r

__all__ = [
    "PanelFaultSchedule",
    "PanelReport",
    "BlockedQRResult",
    "blocked_qr_sim",
    "blocked_qr_batched",
    "blocked_qr_shard_map",
    "panel_widths",
]

PIPELINE_NAME = "blocked_qr_pipeline"    # trace/dispatch counter key


def panel_widths(n: int, panel_width: int) -> tuple[int, ...]:
    """Column widths of the ``⌈n / panel_width⌉`` panels (ragged tail)."""
    if panel_width <= 0:
        raise ValueError(f"panel_width must be positive, got {panel_width}")
    k = math.ceil(n / panel_width)
    return tuple(
        min(panel_width, n - i * panel_width) for i in range(k)
    )


@dataclasses.dataclass(frozen=True)
class PanelFaultSchedule:
    """Fail-stop deaths scheduled into a blocked factorization.

    ``panel[k]`` strikes during panel ``k``'s TSQR reduction (phase 1);
    ``update[k]`` during its cross-product reduction (phase 3 — "death
    during the trailing update": the local subtraction has no communication,
    so the W butterfly is where a mid-update death is observable).  Each
    value is a :class:`~repro.collective.faults.FaultSpec` whose steps index
    that butterfly's exchanges.
    """

    panel: Mapping[int, FaultSpec] = dataclasses.field(default_factory=dict)
    update: Mapping[int, FaultSpec] = dataclasses.field(default_factory=dict)

    @classmethod
    def of(cls, panel=None, update=None) -> "PanelFaultSchedule":
        """From ``{panel_index: FaultSpec | {rank: step}}`` mappings."""

        def norm(d):
            return {
                int(k): v if isinstance(v, FaultSpec) else FaultSpec.of(v)
                for k, v in (d or {}).items()
            }

        return cls(panel=norm(panel), update=norm(update))

    def __bool__(self) -> bool:
        return bool(self.panel) or bool(self.update)


@dataclasses.dataclass(frozen=True)
class PanelReport:
    """Host-side verdicts for one panel (the guarantee bookkeeping).

    ``fused`` — this panel rides the single-butterfly double-buffered
    schedule: its R and cross-product leaves ship as one stacked payload
    over ``plan_r`` (``log P`` rounds instead of ``2·log P``), issued the
    moment the producing trailing sweep lands its lookahead accumulators
    and consumed one pipeline stage later.  The last panel has no cross
    leaf; its ``fused`` bit records that its R-only reduction is issued
    ahead on the same schedule.  A panel with an update-phase fault cannot
    fuse — the scheduled death indexes the second butterfly's exchanges,
    so that butterfly must exist (the split schedule).
    """

    panel: int
    plan_r: Plan | CodedPlan
    plan_w: Plan | CodedPlan | None
    within_tolerance_r: bool
    within_tolerance_w: bool
    recovered_r: int          # contributions restored after phase 1
    recovered_w: int          # …after phase 3
    recoverable: bool         # some rank held every replicated factor
    fused: bool = False       # one stacked butterfly, issued one stage ahead
    scheme: str = "butterfly"  # which redundancy scheme recovered_* used:
    #   "butterfly" — invalid ranks re-fetched full replicas at the phase
    #   boundary; "coded" — erased contributions (deaths, stragglers,
    #   declared corruptions) reconstructed from Cauchy parity *inside*
    #   the collective (recovered_* counts reconstructed contributions).

    @property
    def within_tolerance(self) -> bool:
        return self.within_tolerance_r and self.within_tolerance_w


@dataclasses.dataclass
class BlockedQRResult:
    """Outcome of a fault-tolerant blocked QR.

    ``r``      — (P, n, n) in sim / per-device (n, n) under shard_map: the
                 assembled upper-triangular factor (replicated row blocks).
    ``valid``  — (P,) strict survivors: valid through every panel's
                 reductions without replica recovery.
    ``q``      — optional per-rank (m_local, n) explicit orthonormal factor.
    ``reports``— per-panel :class:`PanelReport` (tolerance + recovery).
    ``detected`` — coded runs only: (P,) device bool, OR over all panels,
                 flagging ranks whose payload failed checksum verification.
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    reports: tuple[PanelReport, ...]
    panel_width: int
    detected: jax.Array | None = None

    @property
    def n_panels(self) -> int:
        return len(self.reports)

    @property
    def recoverable(self) -> bool:
        return all(rep.recoverable for rep in self.reports)


# Registered as a pytree (arrays as leaves, host reports as static aux) so
# results flow through jax transformations — `jax.vmap(blocked_qr_sim …)`
# batches B independent factorizations directly.
jax.tree_util.register_pytree_node(
    BlockedQRResult,
    lambda res: (
        (res.r, res.valid, res.q, res.detected),
        (res.reports, res.panel_width),
    ),
    lambda aux, ch: BlockedQRResult(
        r=ch[0], valid=ch[1], q=ch[2], detected=ch[3],
        reports=aux[0], panel_width=aux[1],
    ),
)


def _data_valid(plan) -> np.ndarray:
    """Per-*data*-rank slice of ``final_valid`` — coded plans append parity
    rows the driver's validity logic must not see."""
    return plan.final_valid[: getattr(plan, "n_data", plan.n_ranks)]


# ---------------------------------------------------------------------------
# Host-side planning
# ---------------------------------------------------------------------------

def _build_reports(
    variant: str,
    p: int,
    widths: tuple[int, ...],
    faults: PanelFaultSchedule,
    recover: Recover,
    fuse: Fuse,
    redundancy: Redundancy = Redundancy.BUTTERFLY,
    parity: int = 2,
) -> tuple[PanelReport, ...]:
    n_panels = len(widths)
    coded = redundancy is Redundancy.CODED
    for key in set(faults.panel) | set(faults.update):
        if not 0 <= key < n_panels:
            raise ValueError(
                f"fault schedule names panel {key}, but only {n_panels} "
                "panels exist"
            )
    if (n_panels - 1) in faults.update:
        raise ValueError(
            f"panel {n_panels - 1} is the last panel — it has no trailing "
            "update to die during"
        )
    reports = []
    for k in range(n_panels):
        spec_r = faults.panel.get(k, FaultSpec.none())
        last = k == n_panels - 1
        plan_w = None
        tol_w = True
        # A panel fuses its two reductions into one stacked butterfly
        # unless the schedule pins a death to the *second* butterfly
        # specifically — panel-phase faults ride the fused plan_r (a
        # mid-reduction death strikes both leaves at once, and the one
        # replica fetch restores both).
        fused = fuse is not Fuse.OFF and (last or k not in faults.update)
        if coded:
            # Coded redundancy: per-panel CodedPlan over the P + parity
            # world.  "Within tolerance" is the erasure budget — at most
            # ``parity`` dead/slow/corrupt contributions, reconstructed
            # in-collective (no phase-boundary fetch).
            plan_r = make_coded_plan(p, parity, spec_r)
            tol_r = plan_r.recoverable
            if not last:
                spec_w = faults.update.get(k, FaultSpec.none())
                plan_w = make_coded_plan(p, parity, spec_w)
                tol_w = plan_w.recoverable
            recoverable = plan_r.recoverable and (
                plan_w is None or plan_w.recoverable
            )
            rec_r = plan_r.n_erased if plan_r.recoverable else 0
            if fused and plan_w is not None:
                rec_w = rec_r  # one stacked reduction reconstructs both
            else:
                rec_w = (
                    plan_w.n_erased
                    if plan_w is not None and plan_w.recoverable else 0
                )
        else:
            plan_r = make_plan(variant, p, spec_r)
            tol_r = within_tolerance(variant, spec_r, plan_r.n_steps)
            if not last:
                spec_w = faults.update.get(k, FaultSpec.none())
                plan_w = make_plan(variant, p, spec_w)
                tol_w = within_tolerance(variant, spec_w, plan_w.n_steps)
            recoverable = bool(plan_r.final_valid.any()) and (
                plan_w is None or bool(plan_w.final_valid.any())
            )
            # recovered_* counts ranks replica_fetch actually restores —
            # zero when recovery is disabled (the ranks stay poisoned).
            fetching = recover is Recover.REPLICA and recoverable
            rec_r = int((~plan_r.final_valid).sum()) if fetching else 0
            if fused and plan_w is not None:
                rec_w = rec_r  # the one stacked fetch restores both leaves
            else:
                rec_w = (
                    int((~plan_w.final_valid).sum())
                    if fetching and plan_w is not None else 0
                )
        reports.append(
            PanelReport(
                panel=k,
                plan_r=plan_r,
                plan_w=plan_w,
                within_tolerance_r=tol_r,
                within_tolerance_w=tol_w,
                recovered_r=rec_r,
                recovered_w=rec_w,
                recoverable=recoverable,
                fused=fused,
                scheme="coded" if coded else "butterfly",
            )
        )
    if fuse is Fuse.ON:
        bad = [r.panel for r in reports if not r.fused]
        if bad:
            raise ValueError(
                f"fuse=Fuse.ON but panels {bad} carry update-phase faults, "
                "which require the split two-butterfly schedule; schedule "
                "the death on the panel phase or use Fuse.AUTO"
            )
    return tuple(reports)


# ---------------------------------------------------------------------------
# The driver body (backend-agnostic: arrays may carry a leading (P,) axis
# under SimComm, or be per-rank local blocks under ShardMapComm)
# ---------------------------------------------------------------------------

def _solve_w(r_tot, c_sum, pad_to: int | None = None):
    """W = R_totᵀ⁻¹ C  (C = Σ A_panelᵀ A_trail, so W = Q_kᵀ A_trail).

    ``pad_to`` right-pads the RHS with zero columns to a canonical width
    before solving (and slices the result back).  XLA's *batched*
    triangular solve picks its lowering by RHS shape, so per-column results
    are not width-stable; both blocked drivers solve every panel at the
    same padded maximal width ``n_pad − b``, which makes the eager driver
    and the fixed-shape pipeline solve bit-identical by construction (the
    appended zero columns solve to exact zeros).
    """
    import jax.scipy.linalg as jsl

    nt = c_sum.shape[-1]
    if pad_to is not None and pad_to > nt:
        widths = [(0, 0)] * (c_sum.ndim - 1) + [(0, pad_to - nt)]
        c_sum = jnp.pad(c_sum, widths)
    w = jsl.solve_triangular(
        jnp.swapaxes(r_tot, -1, -2), c_sum, lower=True
    )
    return w[..., :nt] if pad_to is not None and pad_to > nt else w


def _blocked_body(
    a,
    comm: Comm,
    reports: tuple[PanelReport, ...],
    widths: tuple[int, ...],
    pf: PanelFactorizer,
    *,
    local_r: str,
    compute_q: bool,
    use_pallas: bool,
    interpret: bool | None,
    block_rows: int | None = None,
    world: Comm | None = None,
):
    m_local, n = a.shape[-2], a.shape[-1]
    n_pad = widths[0] * len(widths)
    kw = dict(use_pallas=use_pallas, interpret=interpret,
              block_rows=block_rows)
    r_full = jnp.zeros(a.shape[:-2] + (n, n), jnp.float32)
    valid = comm.take(np.ones(comm.n_ranks, dtype=bool))
    # coded runs reduce over the P + parity ``world`` comm; ``detected``
    # accumulates per-panel checksum-verification flags over data ranks
    coded = world is not None
    detected = (
        comm.take(np.zeros(comm.n_ranks, dtype=bool)) if coded else None
    )
    q_cols = []
    trail = a
    s = kops.panel_cross(a, split=widths[0], **kw)          # pipeline prime

    def local_r_of(panel, g):
        if local_r == "chol":
            return chol_r(g)                      # free: lookahead Gram
        return pf.local_fn()(panel.astype(jnp.float32))

    def coded_reduce(payload, plan, combiner):
        p = comm.n_ranks
        val, fv, det = execute_coded(payload, world, plan, combiner)
        return jax.tree.map(lambda t: t[:p], val), fv[:p], det[:p]

    def issue(rep, panel, g_loc, c_loc):
        """Put a fused panel's single butterfly on the wire: the stacked
        (R, Σ AᵖᵀAᵗ) payload over ``plan_r`` (the last panel's payload is
        R-only).  Called right after the trailing sweep that produced the
        lookahead accumulators — one pipeline stage ahead of consumption,
        so the collective is in flight while the panel's bookkeeping and
        the next consume stage run."""
        r_loc = local_r_of(panel, g_loc)
        if rep.plan_w is None:
            if coded:
                r_kk, valid_r, det = coded_reduce(
                    r_loc, rep.plan_r, FUSED_PANEL_COMBINER.parts[0]
                )
                return r_kk, None, valid_r, None, det
            r_kk, valid_r = pf.reduce_r_prepared(r_loc, comm, rep.plan_r)
            return r_kk, None, valid_r, None, None
        if coded:
            (r_kk, c_sum), v, det = coded_reduce(
                (r_loc, c_loc), rep.plan_r, FUSED_PANEL_COMBINER
            )
            return r_kk, c_sum, v, v, det
        (r_kk, c_sum), v = pf.reduce_panel_fused(r_loc, c_loc, comm,
                                                 rep.plan_r)
        return r_kk, c_sum, v, v, None

    pending = None
    if reports[0].fused:
        b0 = widths[0]
        pending = issue(
            reports[0], trail[..., :, :b0], s[..., :, :b0], s[..., :, b0:]
        )
    c0 = 0
    for rep, b in zip(reports, widths):
        nt = n - c0 - b
        panel = trail[..., :, :b]
        # -- phase 1: panel reduction(s) over the butterfly -----------------
        if rep.fused:
            r_kk, c_sum, valid_r, valid_w, det = pending
            pending = None
        else:
            r_loc = local_r_of(panel, s[..., :, :b])
            if coded:
                r_kk, valid_r, det = coded_reduce(
                    r_loc, rep.plan_r, FUSED_PANEL_COMBINER.parts[0]
                )
            else:
                r_kk, valid_r = pf.reduce_r_prepared(r_loc, comm, rep.plan_r)
                det = None
            c_sum = valid_w = None
        valid = valid & valid_r
        if det is not None:
            detected = detected | det
        all_valid_r = bool(_data_valid(rep.plan_r).all())
        if rep.recovered_r:
            # recover_payload dispatches per scheme: butterfly plans fetch
            # full replicas from donors; coded plans already reconstructed
            # in-collective, so it only validates the erasure budget held.
            if rep.fused and c_sum is not None:
                # ONE fetch restores both stacked leaves — the replica
                # copies of the fused payload double as FT copies for R
                # and the cross products alike.
                r_kk, c_sum = recover_payload(
                    (r_kk, c_sum), comm, rep.plan_r.final_valid,
                    plan=rep.plan_r,
                )
            else:
                r_kk = recover_payload(
                    r_kk, comm, rep.plan_r.final_valid, plan=rep.plan_r
                )
        # -- phase 2: explicit panel Q (+ reorth polish) --------------------
        # The polish's gram all-reduce mixes every rank's contribution, so
        # it needs every rank to hold a finite r_kk; when a no-recovery run
        # left poisoned ranks, skip the polish — survivors keep their exact
        # unpolished factor instead of inheriting the NaN.
        clean = all_valid_r or bool(rep.recovered_r)
        pf_k = pf if clean else dataclasses.replace(pf, reorth=0)
        q_k, r_tot = pf_k.form_q(panel.astype(jnp.float32), r_kk, comm)
        q_k = q_k.astype(a.dtype)
        if compute_q:
            q_cols.append(q_k)
        # -- phase 3: block row of R ----------------------------------------
        if nt:
            if not rep.fused:
                # split schedule: the cross products ride a second,
                # serialized sum butterfly (its own plan — update-phase
                # deaths strike here)
                if coded:
                    c_sum, valid_w, det_w = coded_reduce(
                        s[..., :, b:], rep.plan_w,
                        FUSED_PANEL_COMBINER.parts[1],
                    )
                    detected = detected | det_w
                else:
                    c_sum, valid_w = ft_allreduce(
                        s[..., :, b:], comm, op="sum", plan=rep.plan_w
                    )
                valid = valid & valid_w
                if rep.recovered_w:
                    c_sum = recover_payload(
                        c_sum, comm, rep.plan_w.final_valid, plan=rep.plan_w
                    )
            w = _solve_w(r_tot, c_sum, pad_to=n_pad - widths[0])
            r_full = r_full.at[..., c0:c0 + b, c0:].set(
                jnp.concatenate([r_tot, w], axis=-1)
            )
            # -- phase 4: one-sweep trailing update + lookahead -------------
            b2 = widths[rep.panel + 1]
            trail, s = kops.trailing_update(
                trail[..., :, b:], q_k, w.astype(a.dtype),
                next_width=b2, **kw
            )
            nxt = reports[rep.panel + 1]
            if nxt.fused:
                # double-buffer: the next panel's butterfly launches as
                # soon as the sweep lands its lookahead accumulators
                pending = issue(
                    nxt, trail[..., :, :b2], s[..., :, :b2], s[..., :, b2:]
                )
        else:
            r_full = r_full.at[..., c0:c0 + b, c0:].set(r_tot)
        c0 += b
    q = jnp.concatenate(q_cols, axis=-1) if compute_q else None
    return r_full, valid, q, detected


# ---------------------------------------------------------------------------
# The scan-compiled fixed-shape pipeline (fault-free hot path)
#
# The eager driver above re-traces per panel: the trailing width shrinks, so
# K panels mean K distinct shapes, K compilations, and O(K) device
# dispatches.  The pipeline removes the shape dependence with a *shifted*
# layout: the working matrix stays at the padded maximal width n_pad = K·b
# (zero columns on the right, produced in-kernel by the column-masked
# ``pad_cross`` prime), and after each panel the trailing block is shifted
# left by b — the live panel is always columns [0, b), the trailing block
# always columns [b, n_pad).  Every scan iteration therefore has identical
# shapes, one ``lax.scan`` trace covers all K−1 uniform panels (the ragged
# last panel is a static epilogue in the same program), and the whole
# factorization compiles to ONE device program that never retraces.  Zero
# pad columns ride every sweep without perturbing the real columns: the
# results are bit-identical to the eager driver (hypothesis-swept).
# ---------------------------------------------------------------------------

def _plans_fault_free(reports: tuple[PanelReport, ...]) -> bool:
    """Pipeline eligibility: every collective of every panel rides the
    straight-line fast path (also excludes ``tree``, whose fault-free plans
    leave non-root ranks invalid — the general driver handles it)."""
    return all(
        rep.plan_r.is_fault_free
        and (rep.plan_w is None or rep.plan_w.is_fault_free)
        for rep in reports
    )


def _resolve_pipeline(pipeline: Pipeline, reports) -> bool:
    """Decide the path for a validated mode: True → the scan-compiled
    single program, False → the eager general driver."""
    fault_free = _plans_fault_free(reports)
    if pipeline is Pipeline.ON and not fault_free:
        raise ValueError(
            "pipeline=Pipeline.ON requires fault-free plans (the "
            "scan-compiled program has no validity machinery); faulty plans "
            "route to the general driver under Pipeline.AUTO"
        )
    return fault_free and pipeline is not Pipeline.OFF


def _pipeline_body(
    a,
    comm: Comm,
    plan: Plan,
    widths: tuple[int, ...],
    pf: PanelFactorizer,
    *,
    local_r: str,
    compute_q: bool,
    use_pallas: bool,
    interpret: bool | None,
    block_rows: int | None = None,
    fused: bool = True,
):
    """The traced single-program body (backend-agnostic like
    :func:`_blocked_body`; ``plan`` is the one fault-free plan every
    collective of every panel shares).  ``fused=True`` (the default path)
    runs the double-buffered one-butterfly-per-panel schedule; ``False``
    the split two-butterfly baseline — bit-identical results either way."""
    if fused:
        return _pipeline_body_fused(
            a, comm, plan, widths, pf, local_r=local_r, compute_q=compute_q,
            use_pallas=use_pallas, interpret=interpret,
            block_rows=block_rows,
        )
    b, k_panels, b_last = widths[0], len(widths), widths[-1]
    n = a.shape[-1]
    n_pad = b * k_panels
    kw = dict(use_pallas=use_pallas, interpret=interpret,
              block_rows=block_rows)

    def panel_qr(panel, g):
        if local_r == "chol":
            r_loc = chol_r(g)
        else:
            r_loc = pf.local_fn()(panel.astype(jnp.float32))
        r_kk, _ = pf.reduce_r_prepared(r_loc, comm, plan)
        q_k, r_tot = pf.form_q(panel.astype(jnp.float32), r_kk, comm)
        return q_k.astype(a.dtype), r_tot

    # -- prime: padded working copy + panel-0 lookahead, one sweep ----------
    if n_pad == n:
        awork = a
        s = kops._panel_cross_raw(a, split=b, **kw)
    else:
        awork, s = kops._pad_cross_raw(a, split=b, out_width=n_pad, **kw)

    # -- K−1 uniform panels: one traced body, scanned -----------------------
    def step(carry, _):
        awork, s = carry
        q_k, r_tot = panel_qr(awork[..., :, :b], s[..., :, :b])
        c_sum, _ = ft_allreduce(s[..., :, b:], comm, op="sum", plan=plan)
        w = _solve_w(r_tot, c_sum)
        a_new, s_new = kops._trailing_update_raw(
            awork[..., :, b:], q_k, w.astype(a.dtype), next_width=b, **kw
        )
        # shift left by b: drop the finished panel, keep the width with
        # fresh zero columns (the pad stays exactly zero inductively).
        carry = (
            jnp.concatenate([a_new, jnp.zeros_like(awork[..., :, :b])], -1),
            jnp.concatenate([s_new, jnp.zeros_like(s[..., :, :b])], -1),
        )
        r_row = jnp.concatenate([r_tot, w], axis=-1)       # (…, b, n_pad)
        return carry, ((r_row, q_k) if compute_q else r_row)

    if k_panels > 1:
        (awork, s), ys = lax.scan(step, (awork, s), None, length=k_panels - 1)
        r_rows = ys[0] if compute_q else ys
        q_cols = ys[1] if compute_q else None

    # -- ragged epilogue: the last panel (static, no trailing update) -------
    q_last, r_last = panel_qr(
        awork[..., :, :b_last], s[..., :b_last, :b_last]
    )

    # -- reassemble R (and Q) in original column coordinates ----------------
    r_full = jnp.zeros(a.shape[:-2] + (n, n), jnp.float32)
    for k in range(k_panels - 1):
        c0 = k * b
        r_full = r_full.at[..., c0:c0 + b, c0:].set(
            r_rows[k][..., :, :n - c0]
        )
    c0 = (k_panels - 1) * b
    r_full = r_full.at[..., c0:, c0:].set(r_last)
    q = None
    if compute_q:
        q = jnp.concatenate(
            [q_cols[k] for k in range(k_panels - 1)] + [q_last], axis=-1
        )
    valid = comm.take(np.ones(comm.n_ranks, dtype=bool))
    return r_full, valid, q


def _pipeline_body_fused(
    a,
    comm: Comm,
    plan: Plan,
    widths: tuple[int, ...],
    pf: PanelFactorizer,
    *,
    local_r: str,
    compute_q: bool,
    use_pallas: bool,
    interpret: bool | None,
    block_rows: int | None = None,
):
    """The double-buffered single-program body: ONE stacked butterfly per
    panel instead of two (``log P`` rounds per panel), issued the moment
    the producing sweep lands its lookahead accumulators and consumed one
    pipeline stage later — the pending reduction rides the ``lax.scan``
    carry, so the issue and use sites are decoupled by a full stage and an
    async-collective runtime overlaps each butterfly with the surrounding
    panel bookkeeping instead of paying two serialized collectives per
    panel.  Per-leaf bit-identical to the split schedule (the stacked
    engine runs the same combines over the same plan; only the messages
    are batched), hence bit-identical to the eager driver too."""
    b, k_panels, b_last = widths[0], len(widths), widths[-1]
    n = a.shape[-1]
    n_pad = b * k_panels
    kw = dict(use_pallas=use_pallas, interpret=interpret,
              block_rows=block_rows)

    def local_r_of(panel, g):
        if local_r == "chol":
            return chol_r(g)
        return pf.local_fn()(panel.astype(jnp.float32))

    def issue(awork, s):
        # stacked (R, cross) payload of the live panel, one butterfly;
        # the zero pad columns of the cross leaf reduce to exact zeros
        r_loc = local_r_of(awork[..., :, :b], s[..., :, :b])
        (r_red, c_red), _ = pf.reduce_panel_fused(
            r_loc, s[..., :, b:], comm, plan
        )
        return r_red, c_red

    def issue_last(panel, g):
        # the last panel has no cross leaf; reduce at the exact ragged
        # width — a width-b issue would Cholesky the zero-padded
        # (singular) Gram
        r_red, _ = pf.reduce_r_prepared(local_r_of(panel, g), comm, plan)
        return r_red

    def consume(panel, r_red):
        q_k, r_tot = pf.form_q(panel.astype(jnp.float32), r_red, comm)
        return q_k.astype(a.dtype), r_tot

    # -- prime: padded working copy + panel-0 lookahead + first issue -------
    if n_pad == n:
        awork = a
        s = kops._panel_cross_raw(a, split=b, **kw)
    else:
        awork, s = kops._pad_cross_raw(a, split=b, out_width=n_pad, **kw)

    rows: list = []           # per-panel (…, b, n_pad) R rows, panels 0..K−2
    qs: list = []
    if k_panels == 1:
        r_red = issue_last(awork[..., :, :b_last], s[..., :b_last, :b_last])
    else:
        r_red, c_red = issue(awork, s)

        # -- K−2 uniform stages: consume the carried reduction, sweep, and
        # put the next panel's butterfly on the wire before the scan yields
        def step(carry, _):
            awork, s, r_red, c_red = carry
            q_k, r_tot = consume(awork[..., :, :b], r_red)
            w = _solve_w(r_tot, c_red)
            a_new, s_new = kops._trailing_update_raw(
                awork[..., :, b:], q_k, w.astype(a.dtype), next_width=b, **kw
            )
            # shift left by b: drop the finished panel, keep the width with
            # fresh zero columns (the pad stays exactly zero inductively)
            awork = jnp.concatenate(
                [a_new, jnp.zeros_like(awork[..., :, :b])], -1
            )
            s = jnp.concatenate([s_new, jnp.zeros_like(s[..., :, :b])], -1)
            r_red, c_red = issue(awork, s)
            r_row = jnp.concatenate([r_tot, w], axis=-1)
            return (awork, s, r_red, c_red), (
                (r_row, q_k) if compute_q else r_row
            )

        if k_panels > 2:
            (awork, s, r_red, c_red), ys = lax.scan(
                step, (awork, s, r_red, c_red), None, length=k_panels - 2
            )
            r_rows = ys[0] if compute_q else ys
            rows = [r_rows[k] for k in range(k_panels - 2)]
            if compute_q:
                qs = [ys[1][k] for k in range(k_panels - 2)]

        # -- static penultimate stage: the ragged last panel needs an
        # R-only issue at width b_last, so its producing sweep sits outside
        # the scan ------------------------------------------------------
        q_k, r_tot = consume(awork[..., :, :b], r_red)
        w = _solve_w(r_tot, c_red)
        a_new, s_new = kops._trailing_update_raw(
            awork[..., :, b:], q_k, w.astype(a.dtype), next_width=b, **kw
        )
        r_red = issue_last(
            a_new[..., :, :b_last], s_new[..., :b_last, :b_last]
        )
        rows.append(jnp.concatenate([r_tot, w], axis=-1))
        if compute_q:
            qs.append(q_k)
        awork = a_new             # last panel lives in columns [0, b_last)

    # -- epilogue: consume the last carried reduction -----------------------
    q_last, r_last = consume(awork[..., :, :b_last], r_red)

    # -- reassemble R (and Q) in original column coordinates ----------------
    r_full = jnp.zeros(a.shape[:-2] + (n, n), jnp.float32)
    for k in range(k_panels - 1):
        c0 = k * b
        r_full = r_full.at[..., c0:c0 + b, c0:].set(rows[k][..., :, :n - c0])
    c0 = (k_panels - 1) * b
    r_full = r_full.at[..., c0:, c0:].set(r_last)
    q = None
    if compute_q:
        q = jnp.concatenate(qs + [q_last], axis=-1)
    valid = comm.take(np.ones(comm.n_ranks, dtype=bool))
    return r_full, valid, q


@functools.lru_cache(maxsize=64)
def _compiled_sim_pipeline(
    p: int,
    widths: tuple[int, ...],
    config: QRConfig,
    batched: bool,
):
    """One compiled program per ``(geometry, canonical config)``; the jit
    cache under it keys on the payload's (treedef, shapes, dtypes) — repeat
    calls with identical shapes perform zero new traces (CI
    retrace-guarded).  ``config`` must be :meth:`QRConfig.canonical` so
    policy knobs that do not change the traced program never split the
    cache (the old builder keyed on an ad-hoc 10-tuple of loose kwargs)."""
    comm = SimComm(p)
    plan = make_plan(config.variant, p)
    pf = config.factorizer()

    def fn(a):
        _dispatch.note_trace(PIPELINE_NAME)
        return _pipeline_body(
            a, comm, plan, widths, pf,
            local_r=config.resolved_local_r(), compute_q=config.compute_q,
            use_pallas=config.use_pallas, interpret=config.interpret,
            block_rows=config.block_rows,
            fused=config.fuse is not Fuse.OFF,
        )

    return jax.jit(jax.vmap(fn) if batched else fn)


def _note_reductions(
    name: str,
    reports: tuple[PanelReport, ...],
    widths: tuple[int, ...],
    c_widths: tuple[int, ...],
    reorth_counts: tuple[int, ...],
    reorth_plan: Plan,
    wire_scale: int = 1,
) -> None:
    """Per-butterfly collective accounting: serial rounds, plan-priced wire
    bytes (packed symmetric leaves, dense rectangular leaves), and the
    overlap flag.  One ``panel_reduce`` record per butterfly — a fused
    panel is ONE record carrying the stacked payload, a split panel two —
    plus a ``reorth_reduce`` record for the polish passes.  Every record
    has ``dispatches=0, sweeps=0`` so the HBM-sweep and single-dispatch
    gates never see the collective accounting.

    ``c_widths`` is the cross-leaf width each panel actually reduces (the
    padded ``n_pad − b`` in the pipeline, the live trailing width in the
    eager driver); ``reorth_counts`` the polish passes each panel's
    ``form_q`` ran (0 when a no-recovery fault skipped the polish);
    ``wire_scale`` the batch factor (B matrices ride each message)."""
    for rep, b, cw, n_reorth in zip(reports, widths, c_widths, reorth_counts):
        overlapped = 1 if rep.fused and rep.panel > 0 else 0
        if rep.fused or rep.plan_w is None:
            leaves = [(b, b, 4, False)]
            if rep.plan_w is not None:
                leaves.append((b, cw, 4, False))
            recs = [(rep.plan_r, leaves, overlapped)]
        else:
            recs = [
                (rep.plan_r, [(b, b, 4, False)], 0),
                (rep.plan_w, [(b, cw, 4, False)], 0),
            ]
        for plan, leaves, ov in recs:
            rounds = plan.round_count()
            _traffic.note(
                "panel_reduce", dispatches=0, rounds=rounds,
                wire_bytes=wire_scale * plan.bytes_on_wire_stacked(leaves),
                overlapped=ov,
            )
            _dispatch.note_rounds(name, rounds)
            if ov:
                _dispatch.note_overlap(name, ov)
        if n_reorth:
            rounds = n_reorth * reorth_plan.round_count()
            _traffic.note(
                "reorth_reduce", dispatches=0, rounds=rounds,
                wire_bytes=wire_scale * n_reorth
                * reorth_plan.bytes_on_wire_stacked([(b, b, 4, True)]),
            )
            _dispatch.note_rounds(name, rounds)


def _note_eager_reductions(
    name: str,
    reports: tuple[PanelReport, ...],
    widths: tuple[int, ...],
    n: int,
    pf: PanelFactorizer,
) -> None:
    """Collective accounting for one eager (general-driver) factorization:
    cross leaves at their live trailing widths, polish skipped on panels a
    no-recovery fault left unclean."""
    c0 = 0
    c_widths = []
    for b in widths:
        c_widths.append(n - c0 - b)
        c0 += b
    reorth_counts = tuple(
        pf.reorth
        if bool(_data_valid(rep.plan_r).all()) or rep.recovered_r else 0
        for rep in reports
    )
    plan0 = reports[0].plan_r
    _note_reductions(
        name, reports, widths, tuple(c_widths), reorth_counts,
        make_plan("redundant", getattr(plan0, "n_data", plan0.n_ranks)),
    )


def _note_pipeline(shape, dtype, widths, traced: int,
                   reports: tuple[PanelReport, ...], reorth: int) -> None:
    """Per-call traffic/dispatch accounting for the pipeline (the kernels
    inside the scan are traced once but *execute* once per panel, so the
    wrapper records the exact per-call totals: K sweeps, 1 dispatch).  Only
    the trailing path is modeled — a ``cqr2``/``cqr2_pallas`` local QR adds
    narrow (m×b) panel-local sweeps that are not recorded (their wrappers'
    own notes are suppressed at trace time; the eager driver remains the
    reference for panel-local accounting).  Collective records ride along:
    one ``panel_reduce`` per butterfly (fused panels: one stacked record at
    the padded cross width) plus the ``reorth_reduce`` polish."""
    _dispatch.note_dispatch(PIPELINE_NAME)
    lead = int(np.prod(shape[:-2], dtype=np.int64))
    m, n = shape[-2], shape[-1]
    b, k_panels = widths[0], len(widths)
    n_pad = b * k_panels
    it = jnp.dtype(dtype).itemsize
    if n_pad == n:
        recs = [("panel_cross", lead * m * n * it, lead * b * n * 4)]
    else:
        recs = [(
            "pad_cross",
            lead * m * n * it,
            lead * (m * n_pad * it + b * n_pad * 4),
        )]
    nt = n_pad - b
    for _ in range(k_panels - 1):
        recs.append((
            "trailing_update",
            lead * (m * nt * it + m * b * it + b * nt * it),
            lead * (m * nt * it + b * nt * 4),
        ))
    first = True
    for op, read, write in recs:
        _traffic.note(
            op, sweeps=1, read_bytes=read, write_bytes=write,
            dispatches=1 if first else 0, traces=traced if first else 0,
        )
        first = False
    p = reports[0].plan_r.n_ranks
    c_widths = tuple(
        n_pad - b if k < k_panels - 1 else 0 for k in range(k_panels)
    )
    _note_reductions(
        PIPELINE_NAME, reports, widths, c_widths, (reorth,) * k_panels,
        make_plan("redundant", p),
        wire_scale=int(np.prod(shape[:-3], dtype=np.int64)),
    )


def _tuned_config(config: QRConfig, m_local: int, n: int, dtype) -> QRConfig:
    """Resolve ``block_rows=None`` to the installed autotune winner for this
    geometry **before** the config reaches a compile builder's lru key.
    The tuned int is part of the canonical config, so installing a new
    table (a) takes effect on the next call for the affected shape-classes
    and (b) leaves every other geometry's cached program untouched — the
    zero-warm-retrace contract the CI guard pins.  The trailing-update
    class keys the lookup: it is the driver's dominant sweep and shares its
    panel height with every kernel in the body.  No installed entry →
    ``block_rows`` stays None (kernels fall back to the aligned default at
    trace time, which never changes, so the key is still stable)."""
    if not config.use_pallas or config.block_rows is not None:
        return config
    e = _autotune.lookup(
        "trailing_update", m_local, n, dtype,
        backend=resolve_backend(config.interpret),
    )
    if e is None:
        return config
    return dataclasses.replace(config, block_rows=int(e["block_rows"]))


def _run_sim_pipeline(a, widths, config: QRConfig, reports, *, batched=False):
    config = _tuned_config(config, a.shape[-2], a.shape[-1], a.dtype)
    fun = _compiled_sim_pipeline(
        a.shape[-3], widths, config.canonical(), batched
    )
    t0 = _dispatch.trace_count(PIPELINE_NAME)
    # suppress the wrappers' own notes while the body traces (a cqr2 local
    # QR would otherwise record phantom once-per-trace kernel launches);
    # _note_pipeline records the exact per-call totals below.
    with _traffic.suppress(), _dispatch.suppress():
        out = fun(a)
    _note_pipeline(
        a.shape, a.dtype, widths,
        _dispatch.trace_count(PIPELINE_NAME) - t0, reports, config.reorth,
    )
    return out


def _setup(
    m_local: int,
    n: int,
    p: int,
    config: QRConfig,
    faults: PanelFaultSchedule | None,
) -> tuple[tuple[int, ...], tuple[PanelReport, ...], PanelFactorizer]:
    """Shared entry-point geometry validation + host planning (sim and
    shard_map).  Policy validation already happened in ``QRConfig``."""
    if config.panel_width is None:
        raise ValueError(
            "the blocked driver needs panel_width; panel_width=None selects "
            "the single-panel TSQR workload (route through "
            "repro.qr.api.factorize)"
        )
    widths = panel_widths(n, config.panel_width)
    if m_local < max(widths):
        raise ValueError(
            f"each rank's row block ({m_local} rows) must be at least as "
            f"tall as the widest panel ({max(widths)}); shrink panel_width "
            "or use fewer ranks"
        )
    reports = _build_reports(
        config.variant, p, widths, faults or PanelFaultSchedule(),
        config.recover, config.fuse, config.redundancy, config.parity,
    )
    return widths, reports, config.factorizer()


# ---------------------------------------------------------------------------
# factorize() implementations (routed to by repro.qr.api.factorize)
# ---------------------------------------------------------------------------

def _factorize_sim(
    a_blocks, config: QRConfig, *, faults: PanelFaultSchedule | None = None
) -> BlockedQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n) — the
    general-matrix analogue of the TSQR sim driver.  Fault-free runs
    compile into the single-dispatch scan pipeline per ``config.pipeline``;
    faulty plans route to the eager host-replanned general driver."""
    p, m_local, n = a_blocks.shape
    widths, reports, pf = _setup(m_local, n, p, config, faults)
    coded = config.redundancy is Redundancy.CODED
    detected = None
    if not coded and _resolve_pipeline(config.pipeline, reports):
        r, valid, q = _run_sim_pipeline(a_blocks, widths, config, reports)
    else:
        # coded runs always take the eager driver (the scan pipeline's
        # one-plan butterfly schedule is replica-redundancy only;
        # pipeline=ON + coded is rejected at config validation)
        eager_cfg = _tuned_config(config, m_local, n, a_blocks.dtype)
        r, valid, q, detected = _blocked_body(
            a_blocks, SimComm(p), reports, widths, pf,
            local_r=config.resolved_local_r(), compute_q=config.compute_q,
            use_pallas=config.use_pallas, interpret=config.interpret,
            block_rows=eager_cfg.block_rows,
            world=SimComm(p + config.parity) if coded else None,
        )
        _note_eager_reductions("blocked_qr_sim", reports, widths, n, pf)
    return BlockedQRResult(
        r=r, valid=valid, q=q, reports=reports,
        panel_width=config.panel_width, detected=detected,
    )


def _factorize_batched(a_batch, config: QRConfig) -> BlockedQRResult:
    """B independent factorizations in **one** device dispatch.

    ``a_batch`` is (B, P, m_local, n): B user matrices, each row-blocked
    over the same P simulated ranks.  The scan pipeline is ``vmap``-ped
    over the leading axis inside one compiled program, so serving B
    requests costs one launch.  Each element matches the 3-D sim driver on
    that matrix to ~1 ulp of the triangular solves (XLA's *batched*
    triangular-solve lowering reorders intra-solve arithmetic, so the
    agreement is fp-tight rather than bitwise — the ``dispatch`` bench
    case gates it hard; see DESIGN.md §9).  Fault-free only (a real fleet
    replans at step boundaries; faulted batches go matrix-by-matrix
    through the general driver — :mod:`repro.serve` automates exactly
    that).  Returns a result with leading (B,) axes on ``r``/``valid``
    (and ``q``).
    """
    if a_batch.ndim != 4:
        raise ValueError(
            f"a_batch must be (B, P, m_local, n), got shape {a_batch.shape}"
        )
    _, p, m_local, n = a_batch.shape
    widths, reports, _ = _setup(m_local, n, p, config, None)
    if not _plans_fault_free(reports):
        raise ValueError(
            f"variant {config.variant!r} is not pipeline-eligible (its "
            "fault-free plans leave ranks invalid, which the scan-compiled "
            "program has no machinery to track); batch via jax.vmap over "
            "the 3-D sim entry instead"
        )
    r, valid, q = _run_sim_pipeline(
        a_batch, widths, config, reports, batched=True
    )
    return BlockedQRResult(
        r=r, valid=valid, q=q, reports=reports,
        panel_width=config.panel_width,
    )


@functools.lru_cache(maxsize=64)
def _compiled_shard_pipeline(
    mesh, axis: str, p: int, widths, config: QRConfig, jit: bool
):
    """One compiled shard_map pipeline per ``(mesh geometry, canonical
    config)`` — ``config`` must be :meth:`QRConfig.canonical` so policy
    knobs that don't change the traced program never split the cache."""
    comm = ShardMapComm(p, axis)
    plan = make_plan(config.variant, p)
    pf = config.factorizer()
    want_q = config.compute_q

    def body(a_blk):
        _dispatch.note_trace(PIPELINE_NAME)
        r, valid, q = _pipeline_body(
            a_blk, comm, plan, widths, pf,
            local_r=config.resolved_local_r(), compute_q=want_q,
            use_pallas=config.use_pallas, interpret=config.interpret,
            block_rows=config.block_rows,
            fused=config.fuse is not Fuse.OFF,
        )
        return r[None], valid[None], q if want_q else dummy_q(a_blk)

    return shard_compile(body, mesh=mesh, axis=axis, n_outputs=3, jit=jit)


@functools.lru_cache(maxsize=64)
def _compiled_shard_general(
    mesh, axis: str, p: int, reports, widths, config: QRConfig, jit: bool
):
    """The host-replanned general driver under ``shard_map`` — cached at
    module level (the old per-call ``jax.jit(shard)`` rebuilt the wrapper
    and discarded the compile cache on every invocation).  Keyed on the
    fault-bearing ``reports`` (they alter the traced collective schedule)
    plus the canonical config."""
    comm = ShardMapComm(p, axis)
    pf = config.factorizer()
    want_q = config.compute_q

    def body(a_blk):
        _dispatch.note_trace("blocked_qr_shard_map")
        r, valid, q, _ = _blocked_body(
            a_blk, comm, reports, widths, pf,
            local_r=config.resolved_local_r(), compute_q=want_q,
            use_pallas=config.use_pallas, interpret=config.interpret,
            block_rows=config.block_rows,
        )
        return r[None], valid[None], q if want_q else dummy_q(a_blk)

    return shard_compile(body, mesh=mesh, axis=axis, n_outputs=3, jit=jit)


def _factorize_shard_map(
    a_global,
    config: QRConfig,
    *,
    mesh,
    axis: str,
    faults: PanelFaultSchedule | None = None,
    jit: bool = True,
) -> BlockedQRResult:
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Same body as the sim driver under ``shard_map`` — exchanges lower to
    ``lax.ppermute``, replica fetches ride the same wires.  Fault-free
    runs compile into the single-dispatch scan pipeline; faulted plans
    route to the general driver.  Both programs are cached at module
    level, so repeat calls with identical statics and shapes perform zero
    new traces.  Returns r (P, n, n) (one copy per rank), valid (P,),
    q (m, n) row-sharded or None.
    """
    p = mesh.shape[axis]
    m, n = a_global.shape
    widths, reports, pf = _setup(m // p, n, p, config, faults)
    config = _tuned_config(config, m // p, n, a_global.dtype)
    if _resolve_pipeline(config.pipeline, reports):
        fun = _compiled_shard_pipeline(
            mesh, axis, p, widths, config.canonical(), jit
        )
        t0 = _dispatch.trace_count(PIPELINE_NAME)
        with _traffic.suppress(), _dispatch.suppress():
            r, valid, q = fun(a_global)
        _note_pipeline(
            (p, m // p, n), a_global.dtype, widths,
            _dispatch.trace_count(PIPELINE_NAME) - t0, reports, pf.reorth,
        )
    else:
        fun = _compiled_shard_general(
            mesh, axis, p, reports, widths, config.canonical(), jit
        )
        _dispatch.note_dispatch("blocked_qr_shard_map")
        r, valid, q = fun(a_global)
        _note_eager_reductions(
            "blocked_qr_shard_map", reports, widths, n, pf
        )
    return BlockedQRResult(
        r=r, valid=valid, q=(q if config.compute_q else None),
        reports=reports, panel_width=config.panel_width,
    )


# ---------------------------------------------------------------------------
# Legacy kwarg entry points (deprecated shims over the implementations)
# ---------------------------------------------------------------------------

def blocked_qr_sim(
    a_blocks,
    *,
    panel_width: int,
    variant: str = "redundant",
    faults: PanelFaultSchedule | None = None,
    compute_q: bool = False,
    local_r: str = "chol",
    reorth: int = 1,
    use_pallas: bool = False,
    interpret: bool | None = None,
    recover: str = "replica",
    pipeline: str = "auto",
    fuse: str = "auto",
) -> BlockedQRResult:
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig` and
    call :func:`repro.qr.api.factorize` on the (P, m_local, n) row blocks
    instead.  The kwargs map 1:1 onto config fields; results are
    bit-identical (this shim delegates to the same implementation)."""
    warn_deprecated_entry("blocked_qr_sim")
    config = QRConfig(
        panel_width=panel_width, variant=variant, local_r=local_r,
        reorth=reorth, compute_q=compute_q, use_pallas=use_pallas,
        interpret=interpret, pipeline=pipeline, fuse=fuse, recover=recover,
    )
    return _factorize_sim(a_blocks, config, faults=faults)


def blocked_qr_batched(
    a_batch,
    *,
    panel_width: int,
    variant: str = "redundant",
    compute_q: bool = False,
    local_r: str = "chol",
    reorth: int = 1,
    use_pallas: bool = False,
    interpret: bool | None = None,
    fuse: str = "auto",
) -> BlockedQRResult:
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig` and
    call :func:`repro.qr.api.factorize` on the (B, P, m_local, n) batch
    instead (one device dispatch either way, bit-identical results)."""
    warn_deprecated_entry("blocked_qr_batched")
    config = QRConfig(
        panel_width=panel_width, variant=variant, local_r=local_r,
        reorth=reorth, compute_q=compute_q, use_pallas=use_pallas,
        interpret=interpret, fuse=fuse,
    )
    return _factorize_batched(a_batch, config)


def blocked_qr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    panel_width: int,
    variant: str = "redundant",
    faults: PanelFaultSchedule | None = None,
    compute_q: bool = False,
    local_r: str = "chol",
    reorth: int = 1,
    use_pallas: bool = False,
    interpret: bool | None = None,
    recover: str = "replica",
    jit: bool = True,
    pipeline: str = "auto",
    fuse: str = "auto",
) -> BlockedQRResult:
    """Deprecated kwarg shim — build a :class:`~repro.qr.api.QRConfig` and
    call :func:`repro.qr.api.factorize` with ``mesh=``/``axis=`` instead
    (same shard_map drivers, bit-identical results)."""
    warn_deprecated_entry("blocked_qr_shard_map")
    config = QRConfig(
        panel_width=panel_width, variant=variant, local_r=local_r,
        reorth=reorth, compute_q=compute_q, use_pallas=use_pallas,
        interpret=interpret, pipeline=pipeline, fuse=fuse, recover=recover,
    )
    return _factorize_shard_map(
        a_global, config, mesh=mesh, axis=axis, faults=faults, jit=jit
    )
