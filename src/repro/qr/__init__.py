"""The QR panel-pipeline layer: every QR workload on the collective engine.

Built in three tiers (DESIGN.md §8):

  * :mod:`repro.qr.panel`   — :class:`~repro.qr.panel.PanelFactorizer`, the
    engine-agnostic panel machinery (local QR choice, butterfly R
    reduction, explicit-Q formation with CholeskyQR polish).  Knows nothing
    about meshes, fault specs, or column blocking.
  * :mod:`repro.qr.tsqr`    — the paper's tall-and-skinny workload: one
    panel, four fault variants, sim + shard_map backends.
  * :mod:`repro.qr.blocked` — fault-tolerant right-looking blocked QR for
    general m×n matrices (arXiv:1604.02504's extension): TSQR per column
    panel, butterfly-replicated factors doubling as fault-tolerance
    replicas, and the one-sweep-per-panel fused trailing update
    (:mod:`repro.kernels.trailing_update`).

The unified entry facade lives in :mod:`repro.qr.api`: a frozen hashable
:class:`~repro.qr.api.QRConfig` (doubling as the jit-cache key) plus one
:func:`~repro.qr.api.factorize` call that routes sim / batched / shard_map
by input rank and mesh presence.  The per-driver kwarg entry points below
remain as deprecated delegating shims.

``repro.core.tsqr`` remains as a thin back-compat facade over this package.
"""
from .api import Fuse, Pipeline, QRConfig, Recover, factorize
from .blocked import (
    BlockedQRResult,
    PanelFaultSchedule,
    PanelReport,
    blocked_qr_batched,
    blocked_qr_shard_map,
    blocked_qr_sim,
    panel_widths,
)
from .panel import PanelFactorizer, chol_r, form_q, local_qr_fns
from .tsqr import TSQRResult, tsqr_gram_shard_map, tsqr_shard_map, tsqr_sim

__all__ = [
    "BlockedQRResult",
    "Fuse",
    "PanelFactorizer",
    "PanelFaultSchedule",
    "PanelReport",
    "Pipeline",
    "QRConfig",
    "Recover",
    "TSQRResult",
    "blocked_qr_batched",
    "blocked_qr_shard_map",
    "blocked_qr_sim",
    "chol_r",
    "factorize",
    "form_q",
    "local_qr_fns",
    "panel_widths",
    "tsqr_gram_shard_map",
    "tsqr_shard_map",
    "tsqr_sim",
]
