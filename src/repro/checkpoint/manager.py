"""Checkpoint manager: atomic, async, step-tagged, keep-last-k.

Trees are flattened to ``path → array`` and written as ``.npz`` plus a JSON
manifest; directories are renamed into place only when complete, so a crash
mid-write never corrupts the restore point.  ``save_async`` snapshots to
host memory synchronously (device_get) and writes on a background thread —
the training loop never blocks on the filesystem.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager", "flatten_tree", "unflatten_like"]


def flatten_tree(tree) -> dict[str, np.ndarray]:
    out = {}

    def walk(t, path):
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, f"{path}/{k}" if path else str(k))
        elif isinstance(t, (tuple, list)):
            for i, v in enumerate(t):
                walk(v, f"{path}/{i}")
        elif t is None:
            out[f"{path}#none"] = np.zeros((0,), np.int8)
        else:
            out[path] = np.asarray(t)

    walk(tree, "")
    return out


def unflatten_like(template, flat: dict[str, np.ndarray]):
    def walk(t, path):
        if isinstance(t, dict):
            return {k: walk(v, f"{path}/{k}" if path else str(k)) for k, v in t.items()}
        if isinstance(t, (tuple, list)):
            return type(t)(walk(v, f"{path}/{i}") for i, v in enumerate(t))
        if t is None:
            assert f"{path}#none" in flat, path
            return None
        arr = flat[path]
        assert arr.shape == tuple(t.shape), (path, arr.shape, t.shape)
        return arr

    return walk(template, "")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()

    # ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ---------------------------------------------------------------
    def _write(self, step: int, flat: dict[str, np.ndarray], meta: dict):
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        meta = dict(meta, step=step, n_arrays=len(flat))
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, tree, meta: dict | None = None, *, block: bool = True):
        host = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)) if x is not None else None,
            tree,
            is_leaf=lambda x: x is None,
        )
        flat = flatten_tree(host)
        if block:
            with self._lock:
                self._write(step, flat, meta or {})
            return None
        self.wait()

        def go():
            with self._lock:
                self._write(step, flat, meta or {})

        self._thread = threading.Thread(target=go, daemon=True)
        self._thread.start()
        return self._thread

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, step: int | None = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        with np.load(os.path.join(self._step_dir(step), "state.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            meta = json.load(f)
        return unflatten_like(template, flat), meta
