"""Diskless (in-memory buddy) checkpointing — the paper's §II lineage.

The paper motivates exploiting redundancy by pointing at diskless
checkpointing [Plank et al.] where "the memory of other processes" stores
each process's state.  We apply the *same replica-placement routing as the
collective butterfly*: ``push(level)`` replays the ``redundant`` plan's
per-level ``(src, dst)`` exchange pairs (:mod:`repro.collective.plan` —
level ``s`` pairs rank ``r`` with ``r XOR 2^s``), so after ``s`` levels each
shard exists ``2^s`` times and the scheme tolerates ``2^s − 1``
simultaneous rank losses — the identical bound, from the identical routing
tables, as the factorization (DESIGN.md §3.3).  There is no separate
placement math to keep in sync: a change to the planner changes the buddy
placement with it.

This host-side store simulates the per-rank memories: ``checkpoint(...)``
replicates every rank's shard along the plan's exchange routes;
``recover(rank)`` walks the replica set for the first live copy —
``findReplica`` at the checkpoint layer.
"""
from __future__ import annotations

import copy

import numpy as np

from repro.collective import Plan, make_plan

__all__ = ["BuddyStore"]


class BuddyStore:
    def __init__(self, n_ranks: int):
        if n_ranks & (n_ranks - 1):
            raise ValueError("buddy store needs a power-of-two rank count")
        self.n_ranks = n_ranks
        # The fault-free redundant plan IS the replica-placement table:
        # steps[s].perm_rounds pairs r with its level-s XOR buddy.
        self.plan: Plan = make_plan("redundant", n_ranks)
        # holdings[r] = {owner_rank: (step, state)} — what r keeps in memory
        self.holdings: list[dict[int, tuple[int, object]]] = [
            {} for _ in range(n_ranks)
        ]
        self.alive = np.ones(n_ranks, dtype=bool)

    # ------------------------------------------------------------------
    def checkpoint(self, step: int, shards: dict[int, object], levels: int = 1):
        """Each live rank stores its own shard, then pushes copies along the
        redundant plan's exchange routes for ``levels`` butterfly levels
        (2^levels copies total, capped at the plan depth)."""
        for r, shard in shards.items():
            if not self.alive[r]:
                continue
            snap = copy.deepcopy(shard)
            self.holdings[r][r] = (step, snap)
        for plan_step in self.plan.steps[:levels]:
            for rnd in plan_step.perm_rounds:
                for src, dst in rnd:
                    if not (self.alive[src] and self.alive[dst]):
                        continue
                    for owner, item in list(self.holdings[src].items()):
                        self.holdings[dst].setdefault(owner, item)

    def fail(self, rank: int):
        self.alive[rank] = False
        self.holdings[rank] = {}

    def respawn(self, rank: int):
        self.alive[rank] = True

    def replicas_of(self, rank: int) -> list[int]:
        return [
            r for r in range(self.n_ranks)
            if self.alive[r] and rank in self.holdings[r]
        ]

    def recover(self, rank: int):
        """findReplica at the checkpoint layer: first live copy wins."""
        for r in self.replicas_of(rank):
            step, state = self.holdings[r][rank]
            return step, copy.deepcopy(state)
        raise KeyError(f"no live replica of rank {rank}'s shard")

    def copies(self, rank: int) -> int:
        return len(self.replicas_of(rank))
