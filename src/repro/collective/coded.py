"""Checksum-coded redundancy: the second fault-tolerance scheme.

The butterfly (``plan.py`` / ``engine.py``) buys its ``2^s − 1`` tolerance
with *replication*: every exchange doubles the number of full copies of the
partial result, a 100% redundancy overhead in wire traffic, and the copies
are blind to anything that is not a clean process death.  This module
implements the coded-computing alternative (coded parallel QR,
arXiv:2311.11943; Bosilca-style ABFT checksums, arXiv:0806.3121): the ``P``
data ranks are augmented with ``c`` checksum ranks, each holding a fixed
linear combination — *parity* — of the prepared per-rank contributions:

    ``p_j = Σ_i w_{ji} · prepare(x_i)``            (j = 0 .. c−1)

The weights are a Cauchy matrix (``w_{ji} = 1 / (P + j − i)``), so **every**
square submatrix is nonsingular: *any* ℓ ≤ c lost contributions can be
re-solved from *any* ℓ surviving parity lanes (an MDS erasure code).  The
parity is maintained as a data invariant — it is encoded on-device when the
data is distributed, before any fault can strike, and therefore costs no
priced wire (storage/compute redundancy, not communication; see DESIGN.md
§12).

**Topology.**  One coded reduction is four statically-planned phases over
the ``W = P + c`` world (executed by :func:`execute_coded`, each phase its
own ``comm.exchange`` so :class:`~repro.collective.instrument.
InstrumentedComm` observes exactly what :meth:`CodedPlan.bytes_on_wire`
prices — no validity byte ships, the routing is fully host-static):

  1. *gather* — a binomial tree over the ``S`` surviving data ranks to a
     root.  Each message carries the running combine (``tree_combine`` of
     the inner combiner, operands in rank order — for ℓ = 0 this is the
     **same balanced combine tree as the butterfly**, so the fault-free
     result is bit-identical) plus ℓ *reconstruction lanes*: the weighted
     sums ``q_j = Σ_{i∈S} w_{ji} prepare(x_i)``, combined by addition.
     ``(S−1)`` messages of ``(1+ℓ)`` payload units.
  2. *parity sends* — the ℓ parity lanes chosen for decoding each send
     ``p_j`` to the root: the *deficit* ``p_j − q_j = Σ_{i∈lost} w_{ji} x_i``
     restricts the checksum to exactly the lost contributions.  ℓ messages.
  3. *raw sends* — each declared-corrupt rank forwards its raw contribution
     to the root for verification (it is quarantined from phase 1: its
     true value is erasure-decoded like a death's, and the checksum compare
     of raw vs reconstruction is what *detects* the corruption).
  4. *broadcast* — the root solves the ℓ×ℓ Cauchy system (host-computed
     float64 coefficients, applied as trace-static scalars), absorbs the
     reconstructed contributions into the result, and broadcasts it down a
     binomial tree to every data rank (dead data ranks are respawned into
     the result — the selfhealing contract) and every alive parity rank.

Fault semantics beyond the butterfly's:

  * **deaths** — up to ``c`` simultaneous deaths are tolerated *including
    deaths before any exchange* (the butterfly loses a rank-0-step death's
    contribution outright; parity already holds it).
  * **stragglers** (``FaultSpec.slow``) — not awaited: excluded from the
    gather, reconstructed from parity, handed the result in the broadcast.
  * **silent corruption** (``FaultSpec.corrupt``) — reconstructed *and*
    detected: the returned ``detected`` vector flags ranks whose raw
    payload disagrees with its parity reconstruction beyond the dtype's
    documented tolerance.
  * **over-budget erasures** (ℓ > alive parity lanes) — honest degradation:
    no routing exists, the plan is marked unrecoverable, every rank returns
    ``valid=False`` with NaN-poisoned payloads.  No silent garbage.

Reconstruction re-orders the combine (lost rows are absorbed after the
survivor fold) and rides float weights, so faulted results match the
fault-free value to a documented fp bound rather than bitwise — see
:func:`reconstruction_tol`; ℓ = 0 is bitwise.

SimComm-only, like :func:`~repro.collective.engine.ft_allreduce_jit`:
standalone compilation of the coded program implies the (W,)-leading
simulated layout.  The payload may be any pytree the inner combiner
accepts, including :class:`~repro.collective.combiners.StackedCombiner`
tuples — lane weights are scalars, applied tree-wide.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch as _dispatch

from .combiners import Combiner, get_combiner
from .comm import Comm, ShardMapComm, SimComm
from .engine import _poison, _wire_codec
from .faults import FaultSpec
from .instrument import InstrumentedComm
from .plan import leaf_bytes, payload_numel

__all__ = [
    "CodedCombiner",
    "CodedPlan",
    "coded_allreduce",
    "coded_allreduce_jit",
    "coded_weights",
    "encode_parity",
    "execute_coded",
    "make_coded_plan",
    "reconstruction_tol",
]

Pair = tuple[int, int]


def coded_weights(n_data: int, n_parity: int) -> np.ndarray:
    """The ``(c, P)`` Cauchy checksum-weight matrix ``w_{ji} = 1/(P+j−i)``.

    Node sets ``{P+j}`` and ``{i}`` are disjoint, so every square submatrix
    is nonsingular (the Cauchy determinant): any ℓ erasures are decodable
    from any ℓ surviving lanes.  Entries live in ``(0, 1]`` — parity stays
    at the payload's magnitude, unlike Vandermonde powers.
    """
    a = np.arange(n_data, n_data + n_parity, dtype=np.float64)
    b = np.arange(n_data, dtype=np.float64)
    return 1.0 / (a[:, None] - b[None, :])


def reconstruction_tol(dtype) -> float:
    """Documented fp bound for parity reconstruction, relative to payload
    magnitude: decode solves an ℓ×ℓ Cauchy system whose conditioning (ℓ ≤ c,
    small) amplifies rounding by a few orders of magnitude over machine eps.
    ``sqrt(eps) · 8`` covers the worst observed case with ~10× margin; it is
    also the threshold separating fp noise from genuine corruption in the
    checksum verification."""
    return float(np.sqrt(np.finfo(np.dtype(dtype)).eps) * 8.0)


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class CodedPlan:
    """Host-computed static routing for one coded reduction.

    Mirrors :class:`~repro.collective.plan.Plan`'s contract: numpy fields,
    value-keyed hash (plans key jit/LRU caches), and exact communication
    accounting.  ``erased`` is the union of dead, slow, and corrupt *data*
    ranks — everything reconstructed from parity; ``parity_used`` the global
    ids of the lanes consumed; ``decode[e, t]`` the float64 coefficient of
    deficit ``t`` in the reconstruction of ``erased[e]``.
    """

    n_data: int
    n_parity: int
    death: np.ndarray            # (W,) effective death vector consumed
    erased: tuple[int, ...]      # data ranks reconstructed from parity
    corrupt: tuple[int, ...]     # alive data ranks verified against parity
    slow: tuple[int, ...]        # stragglers (reconstructed, not awaited)
    survivors: tuple[int, ...]   # data ranks in the gather tree
    parity_used: tuple[int, ...]  # global rank ids of consumed parity lanes
    root: int
    gather_rounds: tuple[tuple[Pair, ...], ...]
    bcast_rounds: tuple[tuple[Pair, ...], ...]
    final_valid: np.ndarray      # (W,) who holds the final value
    weights: np.ndarray          # (c, P) float64 checksum weights
    decode: np.ndarray           # (l, l) float64 erasure-decode coefficients
    recoverable: bool

    # -- value identity (hashable-static, same contract as Plan) ------------
    @functools.cached_property
    def _sig(self) -> tuple:
        return (
            self.n_data,
            self.n_parity,
            self.death.tobytes(),
            self.erased,
            self.corrupt,
            self.slow,
            self.survivors,
            self.parity_used,
            self.root,
            self.gather_rounds,
            self.bcast_rounds,
            self.final_valid.tobytes(),
            self.weights.tobytes(),
            self.decode.tobytes(),
            self.recoverable,
        )

    def __eq__(self, other) -> bool:
        return isinstance(other, CodedPlan) and self._sig == other._sig

    def __hash__(self) -> int:
        return hash(self._sig)

    @property
    def n_ranks(self) -> int:
        """World size ``W = P + c`` (the comm the plan executes over)."""
        return self.n_data + self.n_parity

    @property
    def n_erased(self) -> int:
        return len(self.erased)

    @functools.cached_property
    def is_fault_free(self) -> bool:
        return self.recoverable and not self.erased

    # -- communication accounting (the coded bench case hard-gates this) ----
    def message_count(self) -> int:
        """Point-to-point messages: gather + parity sends + raw sends +
        broadcast.  Zero when unrecoverable — nothing useful can ship."""
        if not self.recoverable:
            return 0
        return (
            (len(self.survivors) - 1)
            + len(self.parity_used)
            + len(self.corrupt)
            + self._n_bcast()
        )

    def round_count(self) -> int:
        """Serial communication rounds — the latency proxy.  Parity/raw
        sends serialize per message (all target the root)."""
        if not self.recoverable:
            return 0
        return (
            len(self.gather_rounds)
            + len(self.parity_used)
            + len(self.corrupt)
            + len(self.bcast_rounds)
        )

    def _n_bcast(self) -> int:
        return sum(len(r) for r in self.bcast_rounds)

    def payload_units(self) -> int:
        """Messages weighted by payload multiplicity: gather messages carry
        the result plus ℓ reconstruction lanes — ``(1+ℓ)`` payload units —
        everything else carries one.  This is the factor
        :meth:`bytes_on_wire` prices, and exactly what the executor ships
        (``InstrumentedComm`` observes the agreement)."""
        if not self.recoverable:
            return 0
        l = len(self.erased)
        return (
            (len(self.survivors) - 1) * (1 + l)
            + len(self.parity_used)
            + len(self.corrupt)
            + self._n_bcast()
        )

    def bytes_on_wire(
        self, n_cols: int, itemsize: int = 4, *, symmetric: bool = False
    ) -> int:
        """Total payload bytes moved by the plan (cf. ``Plan.bytes_on_wire``
        — but weighted per message by :meth:`payload_units`, since gather
        messages stack reconstruction lanes next to the result)."""
        return self.payload_units() * payload_numel(n_cols, symmetric) * itemsize

    def bytes_on_wire_stacked(self, leaves) -> int:
        """Exact wire bytes for a stacked / multi-leaf payload; ``leaves``
        are ``(rows, cols, itemsize, symmetric)`` specs as in
        ``Plan.bytes_on_wire_stacked``."""
        per_unit = sum(leaf_bytes(*spec) for spec in leaves)
        return self.payload_units() * per_unit


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def _binomial_gather(members: list[int]) -> tuple[tuple[Pair, ...], ...]:
    """Binomial gather to ``members[0]``; the receiver of every pair has the
    lower list index, so the combine is the same balanced in-order tree the
    butterfly computes (bitwise-identical result for a full power-of-two
    member list)."""
    rounds: list[tuple[Pair, ...]] = []
    n, s = len(members), 0
    while (1 << s) < n:
        pairs = [
            (members[i + (1 << s)], members[i])
            for i in range(0, n, 2 << s)
            if i + (1 << s) < n
        ]
        rounds.append(tuple(pairs))
        s += 1
    return tuple(rounds)


def _binomial_bcast(members: list[int]) -> tuple[tuple[Pair, ...], ...]:
    """Binomial broadcast from ``members[0]``: coverage doubles per round,
    ``len(members) − 1`` messages, unique sources and destinations."""
    rounds: list[tuple[Pair, ...]] = []
    n, have = len(members), 1
    while have < n:
        rounds.append(tuple(
            (members[i], members[i + have]) for i in range(min(have, n - have))
        ))
        have *= 2
    return tuple(rounds)


@functools.lru_cache(maxsize=512)
def _make_coded_plan_cached(
    n_data: int, n_parity: int, spec: FaultSpec
) -> CodedPlan:
    w = n_data + n_parity
    death = spec.death_vector(w)
    # The coded collective has no butterfly steps: a listed death, whatever
    # its step, is conservatively absent for the whole reduction (parity was
    # encoded at distribution time, before any death — the invariant that
    # makes even a step-0 death recoverable).
    dead = {r for r, _ in spec.deaths}
    slow = set(spec.slow)
    corrupt = set(spec.corrupt)
    for kind, rs in (("corrupt", corrupt), ("slow", slow)):
        bad = [r for r in rs if r >= w]
        if bad:
            raise ValueError(f"{kind} ranks {bad} out of range for W={w}")
    weights = coded_weights(n_data, n_parity)
    # Usable parity lanes: alive, on time, and themselves uncorrupted.  A
    # corrupt or slow parity rank is simply an unusable lane (there is no
    # second-order checksum to verify parity against).
    parity_ok = [
        r for r in range(n_data, w)
        if r not in dead and r not in slow and r not in corrupt
    ]
    erased = tuple(sorted(
        i for i in range(n_data) if i in dead or i in slow or i in corrupt
    ))
    corrupt_data = tuple(sorted(i for i in range(n_data) if i in corrupt))
    survivors = tuple(i for i in range(n_data) if i not in set(erased))
    l = len(erased)
    recoverable = l <= len(parity_ok) and len(survivors) > 0
    if not recoverable:
        return CodedPlan(
            n_data=n_data, n_parity=n_parity, death=death, erased=erased,
            corrupt=corrupt_data, slow=tuple(sorted(slow)),
            survivors=survivors, parity_used=(), root=-1,
            gather_rounds=(), bcast_rounds=(),
            final_valid=np.zeros(w, dtype=bool), weights=weights,
            decode=np.zeros((0, 0)), recoverable=False,
        )
    parity_used = tuple(parity_ok[:l])
    root = survivors[0]
    # Broadcast recipients: every data rank (dead data ranks are respawned
    # into the result — the selfhealing contract, so the blocked driver's
    # later panels see a full complement) plus every alive parity rank.
    recips = [
        r for r in range(w)
        if r != root and (r < n_data or r not in dead)
    ]
    if l:
        sub = weights[
            np.array([p - n_data for p in parity_used], dtype=np.intp)[:, None],
            np.array(erased, dtype=np.intp)[None, :],
        ]
        decode = np.linalg.inv(sub)
    else:
        decode = np.zeros((0, 0))
    final_valid = np.ones(w, dtype=bool)
    for r in range(n_data, w):
        final_valid[r] = r not in dead
    return CodedPlan(
        n_data=n_data, n_parity=n_parity, death=death, erased=erased,
        corrupt=corrupt_data, slow=tuple(sorted(slow)),
        survivors=survivors, parity_used=parity_used, root=root,
        gather_rounds=_binomial_gather(list(survivors)),
        bcast_rounds=_binomial_bcast([root] + recips),
        final_valid=final_valid, weights=weights, decode=decode,
        recoverable=True,
    )


def make_coded_plan(
    n_data: int,
    n_parity: int,
    fault_spec: FaultSpec | None = None,
) -> CodedPlan:
    """Host-plan a coded reduction over ``n_data`` data + ``n_parity``
    checksum ranks.  Memoized on ``(P, c, spec)`` like :func:`make_plan`;
    the returned plan is hashable-static and keys jit caches."""
    if n_data < 1:
        raise ValueError(f"need at least one data rank, got {n_data}")
    if n_parity < 1:
        raise ValueError(
            f"coded redundancy needs at least one parity rank, got {n_parity}"
        )
    return _make_coded_plan_cached(n_data, n_parity, fault_spec or FaultSpec.none())


# ---------------------------------------------------------------------------
# Encode / decode combiner family
# ---------------------------------------------------------------------------

def encode_parity(prepared, plan: CodedPlan):
    """Overwrite the ``c`` parity rows of a (W,)-leading prepared payload
    with the checksum linear combinations of the data rows.

    This is the distribution-time invariant: an on-device einsum, **outside**
    any ``comm.exchange`` — parity costs compute and storage, never priced
    wire (DESIGN.md §12).  Works leaf-wise over any payload pytree.
    """
    p = plan.n_data

    def enc(leaf):
        wts = jnp.asarray(plan.weights, dtype=leaf.dtype)
        parity = jnp.tensordot(wts, leaf[:p], axes=(1, 0))
        return leaf.at[p:].set(parity)

    return jax.tree.map(enc, prepared)


@dataclasses.dataclass(frozen=True)
class CodedCombiner(Combiner):
    """Encode/reduce/decode on the tree-payload protocol, generic over any
    inner combiner (sum/mean/max/gram_sum/qr, including stacked tuples).

    ``tree_prepare`` composes the inner prepare with the parity encode;
    ``tree_combine``/``tree_finalize`` delegate (finalize normalizes by the
    *data* rank count — parity adds no data).  The lane/decode/verify
    methods are the coded-specific algebra :func:`execute_coded` drives:
    reconstruction lanes are weighted sums (scalar weights applied
    tree-wide, so any inner payload structure works), decode applies the
    host-solved Cauchy coefficients, and ``absorb`` folds reconstructed
    contributions back through the inner combine.
    """

    inner: Combiner = None  # type: ignore[assignment]
    plan: CodedPlan = None  # type: ignore[assignment]
    name = "coded"

    def __post_init__(self):
        if self.inner is None or self.plan is None:
            raise ValueError("CodedCombiner needs an inner combiner and a plan")

    # -- tree-payload protocol ---------------------------------------------
    def tree_prepare(self, x):
        return encode_parity(self.inner.tree_prepare(x), self.plan)

    def tree_combine(self, lo, hi):
        return self.inner.tree_combine(lo, hi)

    def tree_finalize(self, x, n_ranks: int):
        return self.inner.tree_finalize(x, self.plan.n_data)

    def wire_pack_flags(self, val) -> list[bool]:
        return self.inner.wire_pack_flags(val)

    # -- per-leaf protocol has no meaning (encode is positional over ranks) -
    def prepare(self, x):
        raise TypeError("CodedCombiner operates at tree level")

    def combine(self, lo, hi):
        raise TypeError("CodedCombiner operates at tree level")

    def finalize(self, x, n_ranks: int):
        raise TypeError("CodedCombiner operates at tree level")

    # -- coded-specific algebra --------------------------------------------
    def make_lanes(self, val):
        """Per-rank reconstruction lanes: leaf ``(W, ...)`` → ``(W, ℓ, ...)``
        with lane ``t`` holding ``w_{t,i} · val_i`` on survivor rows (zero on
        erased and parity rows — they do not feed the gather)."""
        plan = self.plan
        w_, l = plan.n_ranks, len(plan.erased)
        lane_w = np.zeros((w_, l))
        for t, pr in enumerate(plan.parity_used):
            lane_w[: plan.n_data, t] = plan.weights[pr - plan.n_data]
        lane_w[list(plan.erased), :] = 0.0

        def mk(leaf):
            wv = jnp.asarray(lane_w, dtype=leaf.dtype)
            wv = wv.reshape((w_, l) + (1,) * (leaf.ndim - 1))
            return leaf[:, None] * wv

        return jax.tree.map(mk, val)

    def lane_combine(self, acc, recv):
        """Lanes are weighted sums: combine by addition (zeros from
        non-receivers are the identity)."""
        return jax.tree.map(jnp.add, acc, recv)

    def decode_erased(self, deficits):
        """Solve the erasure system: ``deficits[t] = p_t − q_t`` (payload
        trees) → ``{erased_rank: reconstructed contribution}``.  The decode
        coefficients are trace-static host float64 scalars."""
        dec = self.plan.decode
        out = {}
        for e_idx, er in enumerate(self.plan.erased):
            acc = None
            for t in range(len(deficits)):
                term = jax.tree.map(
                    lambda d, c=float(dec[e_idx, t]): c * d, deficits[t]
                )
                acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
            out[er] = acc
        return out

    def absorb(self, res, reconstructed):
        """Fold the reconstructed contributions into the survivor result in
        erased-rank order (this re-orders the combine relative to the
        fault-free tree — the documented fp deviation)."""
        for er in self.plan.erased:
            res = self.inner.tree_combine(res, reconstructed[er])
        return res

    def verify(self, raw, reconstructed):
        """Checksum verification: does the raw payload of a declared-corrupt
        rank disagree with its parity reconstruction beyond fp noise?
        Returns a device bool."""
        err = None
        scale = None
        for a, b in zip(jax.tree.leaves(raw), jax.tree.leaves(reconstructed)):
            e = jnp.max(jnp.abs(a - b))
            s = jnp.max(jnp.abs(b))
            err = e if err is None else jnp.maximum(err, e)
            scale = s if scale is None else jnp.maximum(scale, s)
        dtypes = [leaf.dtype for leaf in jax.tree.leaves(raw)]
        tol = max(reconstruction_tol(dt) for dt in dtypes)
        return err > tol * (scale + 1.0)


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------

def _base_comm(comm: Comm) -> Comm:
    return comm.inner if isinstance(comm, InstrumentedComm) else comm


def _pad_world(x, plan: CodedPlan):
    """Accept a data-only (P,)-leading payload and zero-extend the parity
    rows (they are overwritten by the encode)."""
    def pad(leaf):
        if leaf.shape[0] == plan.n_ranks:
            return leaf
        if leaf.shape[0] == plan.n_data:
            z = jnp.zeros((plan.n_parity,) + leaf.shape[1:], leaf.dtype)
            return jnp.concatenate([leaf, z], axis=0)
        raise ValueError(
            f"payload leading axis {leaf.shape[0]} matches neither P="
            f"{plan.n_data} nor W={plan.n_ranks}"
        )

    return jax.tree.map(pad, x)


def execute_coded(
    x,
    comm: Comm,
    plan: CodedPlan,
    combiner: Combiner | str,
    *,
    observed=None,
):
    """Run one coded reduction.  Returns ``(value, valid, detected)``.

    ``x`` is a pytree of per-rank payloads with a leading ``(P,)`` or
    ``(W,)`` axis (``SimComm(W)`` layout; parity rows are recomputed by the
    encode either way).  ``value`` is the un-finalized combine on every
    valid rank; ``valid`` the per-rank host-predicted validity
    (``plan.final_valid``); ``detected`` a ``(W,)`` device bool flagging
    ranks whose payload failed checksum verification.  Each phase issues
    its own exchanges, so observed traffic equals
    ``plan.bytes_on_wire{,_stacked}`` exactly — no validity byte ships.

    ``observed`` models silent data corruption faithfully: parity is
    encoded from ``x`` (the truth at distribution time, *before* any fault
    strikes — the ABFT invariant), while ranks contribute from ``observed``
    (what they actually hold now; defaults to ``x``).  A scenario injects
    SDC by mutating a declared-corrupt rank's row of ``observed`` only —
    the checksum compare of the raw observed payload against its parity
    reconstruction is then a *numerical* detection, not an echo of the
    fault spec.
    """
    inner = get_combiner(combiner)
    if isinstance(inner, CodedCombiner):
        coded = inner
        inner = coded.inner
    else:
        coded = CodedCombiner(inner=inner, plan=plan)
    if isinstance(_base_comm(comm), ShardMapComm):
        raise ValueError(
            "coded collectives execute on the SimComm backend only: the "
            "root-side decode indexes rank rows of the (W,)-leading layout"
        )
    w_ = plan.n_ranks
    if comm.n_ranks != w_:
        raise ValueError(
            f"comm has {comm.n_ranks} ranks but the plan's world is "
            f"W = {plan.n_data} + {plan.n_parity} = {w_}"
        )
    x = _pad_world(x, plan)
    for leaf in jax.tree.leaves(x):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            raise TypeError(
                "coded redundancy requires an inexact payload dtype (the "
                f"checksum weights are non-integer), got {leaf.dtype}"
            )
    val = coded.tree_prepare(x)
    if observed is not None:
        # Data rows contribute what the ranks hold *now* (possibly silently
        # corrupted); parity rows keep the distribution-time encode of the
        # truth — corruption cannot strike data and checksum coherently.
        vobs = inner.tree_prepare(_pad_world(observed, plan))
        p = plan.n_data
        val = jax.tree.map(lambda t, o: o.at[p:].set(t[p:]), val, vobs)
    detected = jnp.zeros((w_,), dtype=bool)
    if not plan.recoverable:
        # Honest degradation: more erasures than parity lanes (or no data
        # survivor).  Nothing can ship; poison everything, validity False.
        return (
            jax.tree.map(_poison, val),
            comm.take(plan.final_valid),
            detected,
        )
    pack, unpack = _wire_codec(inner, val)
    l = len(plan.erased)
    root = plan.root
    # --- phase 1: binomial gather over survivors, result + ℓ lanes ---------
    lanes = None
    if l:
        lanes = coded.make_lanes(val)
        lpack, lunpack = _wire_codec(inner, lanes)
    for pairs in plan.gather_rounds:
        got = np.zeros(w_, dtype=bool)
        got[[d for _, d in pairs]] = True
        g = comm.take(got)
        if l:
            rv, rl = comm.exchange((pack(val), lpack(lanes)), pairs)
            lanes = coded.lane_combine(lanes, lunpack(rl))
        else:
            rv = comm.exchange(pack(val), pairs)
        comb = coded.tree_combine(val, unpack(rv))  # receiver is lo
        val = jax.tree.map(lambda c, v: comm.bwhere(g, c, v), comb, val)
    # --- phase 2: parity sends → deficits p_t − q_t ------------------------
    deficits = []
    for t, pr in enumerate(plan.parity_used):
        rv = unpack(comm.exchange(pack(val), ((pr, root),)))
        deficits.append(jax.tree.map(
            lambda r, ln, t=t: r[root] - ln[root, t], rv, lanes
        ))
    # --- phase 3: raw sends from declared-corrupt ranks --------------------
    raws = {}
    for ci in plan.corrupt:
        rv = unpack(comm.exchange(pack(val), ((ci, root),)))
        raws[ci] = jax.tree.map(lambda r: r[root], rv)
    # --- decode + absorb + verify (root-local compute, no wire) ------------
    res = jax.tree.map(lambda v: v[root], val)
    if l:
        reconstructed = coded.decode_erased(deficits)
        res = coded.absorb(res, reconstructed)
        for ci in plan.corrupt:
            detected = detected.at[ci].set(
                coded.verify(raws[ci], reconstructed[ci])
            )
    val = jax.tree.map(lambda v, r: v.at[root].set(r), val, res)
    # --- phase 4: binomial broadcast root → all recipients -----------------
    for pairs in plan.bcast_rounds:
        got = np.zeros(w_, dtype=bool)
        got[[d for _, d in pairs]] = True
        g = comm.take(got)
        rv = unpack(comm.exchange(pack(val), pairs))
        val = jax.tree.map(lambda r, v: comm.bwhere(g, r, v), rv, val)
    # Dead parity rows never receive: poison them so accidental use is loud.
    fv = comm.take(plan.final_valid)
    val = jax.tree.map(lambda v: comm.bwhere(fv, v, _poison(v)), val)
    return val, fv, detected


def coded_allreduce(
    x,
    comm: Comm,
    *,
    op: Combiner | str = "sum",
    n_parity: int | None = None,
    fault_spec: FaultSpec | None = None,
    plan: CodedPlan | None = None,
    observed=None,
):
    """Checksum-coded fault-tolerant all-reduce (cf. :func:`ft_allreduce`).

    ``comm`` spans the ``W = P + c`` world; pass either a prebuilt ``plan``
    or ``n_parity`` (with an optional ``fault_spec`` naming deaths /
    stragglers / corruptions in world coordinates).  Returns ``(value,
    valid, detected)`` with the finalized reduction of the ``P`` data
    contributions on every valid rank.  ``observed`` — see
    :func:`execute_coded`.
    """
    if plan is None:
        if n_parity is None:
            raise ValueError("coded_allreduce needs a plan or n_parity")
        plan = make_coded_plan(comm.n_ranks - n_parity, n_parity, fault_spec)
    combiner = get_combiner(op)
    val, valid, detected = execute_coded(
        x, comm, plan, combiner, observed=observed
    )
    val = combiner.tree_finalize(val, plan.n_data)
    return val, valid, detected


# ---------------------------------------------------------------------------
# Retrace-proof compiled entry point
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _coded_allreduce_compiled(comm: Comm, plan: CodedPlan, op):
    @jax.jit
    def fun(x, observed):
        _dispatch.note_trace("coded_allreduce")
        return coded_allreduce(x, comm, op=op, plan=plan, observed=observed)

    return fun


def coded_allreduce_jit(
    x,
    comm: Comm,
    *,
    op: Combiner | str = "sum",
    n_parity: int | None = None,
    fault_spec: FaultSpec | None = None,
    plan: CodedPlan | None = None,
    observed=None,
):
    """:func:`coded_allreduce` as a cached, zero-retrace device program —
    the same contract as :func:`~repro.collective.engine.ft_allreduce_jit`
    (SimComm only; the plan and combiner are hashable statics, so a repeat
    call with identical statics performs zero new traces — pinned by the CI
    retrace guard)."""
    if not isinstance(comm, SimComm):
        raise ValueError(
            "coded_allreduce_jit compiles a standalone program, which only "
            "the SimComm backend supports"
        )
    if plan is None:
        if n_parity is None:
            raise ValueError("coded_allreduce_jit needs a plan or n_parity")
        plan = make_coded_plan(comm.n_ranks - n_parity, n_parity, fault_spec)
    fun = _coded_allreduce_compiled(comm, plan, get_combiner(op))
    _dispatch.note_dispatch("coded_allreduce")
    return fun(x, observed)
