"""Pluggable combiners for the fault-tolerant butterfly engine.

The paper's plan/route/validity machinery (redundant exchange, replica
rerouting, self-healing respawn) only requires the per-level combine to be
*associative over contiguous index blocks*: after level ``s`` every valid
rank holds the combine of its whole ``2^(s+1)`` block, so any block member
is a replica.  A :class:`Combiner` packages the three algorithm-specific
pieces the engine needs:

  * ``prepare``  — the local transform applied before level 0 (local QR for
    TSQR, identity for arithmetic reductions);
  * ``combine``  — merge the lower-block and upper-block partials.  The
    engine always presents operands ordered by the level bit of the block
    index, so order-sensitive combines (QR row-stacking) produce
    bit-identical results on every member of a block — the property that
    makes the butterfly a true all-reduce;
  * ``finalize`` — post-butterfly fixup (mean divides by the rank count).

``wire_symmetric`` declares that payloads are symmetric matrices, enabling
the n(n+1)/2 packed wire accounting in :meth:`repro.collective.plan.Plan.
bytes_on_wire`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

__all__ = [
    "Combiner",
    "SumCombiner",
    "MeanCombiner",
    "MaxCombiner",
    "GramSumCombiner",
    "QRCombiner",
    "get_combiner",
    "COMBINERS",
    "posdiag",
    "qr_r",
]


def posdiag(r):
    """Normalize an upper-triangular factor to a non-negative diagonal.

    Makes the R factor unique, so every rank (and the numpy oracle) computes
    bit-comparable results.
    """
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def qr_r(a):
    """Householder QR, R factor only, sign-normalized."""
    return posdiag(jnp.linalg.qr(a, mode="r"))


class Combiner:
    """Protocol for butterfly combiners.  Subclasses override ``combine``."""

    name: str = "?"
    # Payload is a symmetric matrix → n(n+1)/2 packed wire encoding applies.
    wire_symmetric: bool = False

    def prepare(self, x):
        """Local transform before the first exchange (per payload leaf)."""
        return x

    def combine(self, lo, hi):
        """Merge two block partials; ``lo`` is the lower-index block."""
        raise NotImplementedError

    def finalize(self, x, n_ranks: int):
        """Post-butterfly fixup (per payload leaf)."""
        return x


@dataclasses.dataclass(frozen=True)
class SumCombiner(Combiner):
    name = "sum"

    def combine(self, lo, hi):
        return lo + hi


@dataclasses.dataclass(frozen=True)
class MeanCombiner(Combiner):
    name = "mean"

    def combine(self, lo, hi):
        return lo + hi

    def finalize(self, x, n_ranks: int):
        return x / n_ranks


@dataclasses.dataclass(frozen=True)
class MaxCombiner(Combiner):
    name = "max"

    def combine(self, lo, hi):
        return jnp.maximum(lo, hi)


@dataclasses.dataclass(frozen=True)
class GramSumCombiner(Combiner):
    """Sum of symmetric Gram payloads (the Gram-butterfly TSQR and the
    CholeskyQR reorthogonalization both ride this).  Arithmetically a plain
    sum; the separate combiner records that the wire payload is symmetric,
    so accounting can price the n(n+1)/2 packed encoding."""

    name = "gram_sum"
    wire_symmetric = True

    def combine(self, lo, hi):
        return lo + hi


@dataclasses.dataclass(frozen=True)
class QRCombiner(Combiner):
    """The paper's TSQR combine: ``R = qr([R_lo; R_hi])`` with the operands
    row-stacked in block order.  ``local_qr`` is the level-0 panel
    factorization (Householder, CholeskyQR2, or the Pallas kernel)."""

    local_qr: Callable = qr_r
    name = "qr_combine"

    def prepare(self, x):
        return self.local_qr(x)

    def combine(self, lo, hi):
        return qr_r(jnp.concatenate([lo, hi], axis=-2))


COMBINERS: dict[str, Callable[[], Combiner]] = {
    "sum": SumCombiner,
    "mean": MeanCombiner,
    "max": MaxCombiner,
    "gram_sum": GramSumCombiner,
    "qr_combine": QRCombiner,
    "qr": QRCombiner,
}


def get_combiner(op) -> Combiner:
    """Resolve a combiner name (or pass an instance through)."""
    if isinstance(op, Combiner):
        return op
    try:
        return COMBINERS[op]()
    except KeyError:
        raise ValueError(
            f"unknown combiner {op!r}; choose from {sorted(set(COMBINERS))}"
        ) from None
