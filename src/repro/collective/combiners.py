"""Pluggable combiners for the fault-tolerant butterfly engine.

The paper's plan/route/validity machinery (redundant exchange, replica
rerouting, self-healing respawn) only requires the per-level combine to be
*associative over contiguous index blocks*: after level ``s`` every valid
rank holds the combine of its whole ``2^(s+1)`` block, so any block member
is a replica.  A :class:`Combiner` packages the three algorithm-specific
pieces the engine needs:

  * ``prepare``  — the local transform applied before level 0 (local QR for
    TSQR, identity for arithmetic reductions);
  * ``combine``  — merge the lower-block and upper-block partials.  The
    engine always presents operands ordered by the level bit of the block
    index, so order-sensitive combines (QR row-stacking) produce
    bit-identical results on every member of a block — the property that
    makes the butterfly a true all-reduce;
  * ``finalize`` — post-butterfly fixup (mean divides by the rank count).

``wire_symmetric`` declares that payloads are symmetric matrices, enabling
the n(n+1)/2 packed wire accounting in :meth:`repro.collective.plan.Plan.
bytes_on_wire`.

**Stacked payloads.**  :class:`StackedCombiner` bundles several combiners
into one: the payload is a tuple with one sub-payload per part, each part's
algebra applied to its own leaves under a *single* plan.  One butterfly
then carries everything — the blocked-QR driver ships its panel-R leaf and
its cross-product leaf together, halving the per-panel collective rounds
from ``2·log P`` to ``log P`` while the replica copies of the stacked
payload double as fault-tolerance copies for *both* results (the validity
bit of the fused collective is exactly the AND of the per-part validities,
which are identical because the routing is shared).  The engine calls the
``tree_*`` methods, which plain combiners map leaf-wise and the stacked
combiner routes per part; wire packing is decided per leaf
(:meth:`Combiner.wire_pack_flags`), so a stacked payload with one
symmetric-packable leaf and one dense leaf ships each optimally.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .packing import packable

__all__ = [
    "Combiner",
    "SumCombiner",
    "MeanCombiner",
    "MaxCombiner",
    "GramSumCombiner",
    "QRCombiner",
    "StackedCombiner",
    "stacked",
    "get_combiner",
    "COMBINERS",
    "posdiag",
    "qr_r",
]


def posdiag(r):
    """Normalize an upper-triangular factor to a non-negative diagonal.

    Makes the R factor unique, so every rank (and the numpy oracle) computes
    bit-comparable results.
    """
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def qr_r(a):
    """Householder QR, R factor only, sign-normalized."""
    return posdiag(jnp.linalg.qr(a, mode="r"))


class Combiner:
    """Protocol for butterfly combiners.  Subclasses override ``combine``."""

    name: str = "?"
    # Payload is a symmetric matrix → n(n+1)/2 packed wire encoding applies.
    wire_symmetric: bool = False

    def prepare(self, x):
        """Local transform before the first exchange (per payload leaf)."""
        return x

    def combine(self, lo, hi):
        """Merge two block partials; ``lo`` is the lower-index block."""
        raise NotImplementedError

    def finalize(self, x, n_ranks: int):
        """Post-butterfly fixup (per payload leaf)."""
        return x

    # -- tree-level protocol (what the engine actually calls) ---------------
    # Plain combiners apply their per-leaf algebra across the whole payload
    # pytree; StackedCombiner overrides these to route per part.

    def tree_prepare(self, x):
        return jax.tree.map(self.prepare, x)

    def tree_combine(self, lo, hi):
        return jax.tree.map(self.combine, lo, hi)

    def tree_finalize(self, x, n_ranks: int):
        return jax.tree.map(lambda leaf: self.finalize(leaf, n_ranks), x)

    def wire_pack_flags(self, val) -> list[bool]:
        """Per-leaf wire-packing decision, aligned with
        ``jax.tree.leaves(val)``: a leaf ships packed iff its governing
        combiner declares ``wire_symmetric`` *and* the leaf is a (batched)
        square matrix — mixed payloads pack exactly the leaves that qualify
        (the old all-or-nothing rule shipped everything square whenever any
        leaf was rectangular)."""
        return [
            self.wire_symmetric and packable(leaf)
            for leaf in jax.tree.leaves(val)
        ]


@dataclasses.dataclass(frozen=True)
class SumCombiner(Combiner):
    name = "sum"

    def combine(self, lo, hi):
        return lo + hi


@dataclasses.dataclass(frozen=True)
class MeanCombiner(Combiner):
    name = "mean"

    def combine(self, lo, hi):
        return lo + hi

    def finalize(self, x, n_ranks: int):
        return x / n_ranks


@dataclasses.dataclass(frozen=True)
class MaxCombiner(Combiner):
    name = "max"

    def combine(self, lo, hi):
        return jnp.maximum(lo, hi)


@dataclasses.dataclass(frozen=True)
class GramSumCombiner(Combiner):
    """Sum of symmetric Gram payloads (the Gram-butterfly TSQR and the
    CholeskyQR reorthogonalization both ride this).  Arithmetically a plain
    sum; the separate combiner records that the wire payload is symmetric,
    so accounting can price the n(n+1)/2 packed encoding."""

    name = "gram_sum"
    wire_symmetric = True

    def combine(self, lo, hi):
        return lo + hi


@dataclasses.dataclass(frozen=True)
class QRCombiner(Combiner):
    """The paper's TSQR combine: ``R = qr([R_lo; R_hi])`` with the operands
    row-stacked in block order.  ``local_qr`` is the level-0 panel
    factorization (Householder, CholeskyQR2, or the Pallas kernel)."""

    local_qr: Callable = qr_r
    name = "qr_combine"

    def prepare(self, x):
        return self.local_qr(x)

    def combine(self, lo, hi):
        return qr_r(jnp.concatenate([lo, hi], axis=-2))


@dataclasses.dataclass(frozen=True)
class StackedCombiner(Combiner):
    """Several combiners fused under one plan: the payload is a tuple with
    one sub-payload (any pytree) per part.

    The butterfly's redundancy argument only needs the combine to be
    associative over contiguous index blocks; a product of associative
    combines is associative, so a stacked payload inherits every variant's
    guarantee unchanged — and because all parts share the routing, the
    fused collective's validity bit equals each part's, making the fused
    reduction bit-identical to running the parts as separate butterflies
    over the same plan (hypothesis-swept).  Per-leaf wire packing is
    delegated to each part, so e.g. ``stacked("gram_sum", "sum")`` ships a
    packed symmetric leaf next to a dense rectangular one.
    """

    parts: tuple[Combiner, ...] = ()
    name = "stacked"

    def __post_init__(self):
        if not self.parts:
            raise ValueError("StackedCombiner needs at least one part")

    def _subs(self, x) -> tuple:
        if not isinstance(x, (tuple, list)) or len(x) != len(self.parts):
            raise TypeError(
                f"stacked payload must be a tuple of {len(self.parts)} "
                f"sub-payloads (one per part), got {type(x).__name__}"
            )
        return tuple(x)

    # The per-leaf protocol has no meaning here — which part's algebra a
    # leaf belongs to is positional, so the engine must go through tree_*.
    def prepare(self, x):
        raise TypeError("StackedCombiner operates at tree level")

    def combine(self, lo, hi):
        raise TypeError("StackedCombiner operates at tree level")

    def finalize(self, x, n_ranks: int):
        raise TypeError("StackedCombiner operates at tree level")

    def tree_prepare(self, x):
        return tuple(
            p.tree_prepare(s) for p, s in zip(self.parts, self._subs(x))
        )

    def tree_combine(self, lo, hi):
        return tuple(
            p.tree_combine(sl, sh)
            for p, sl, sh in zip(self.parts, self._subs(lo), self._subs(hi))
        )

    def tree_finalize(self, x, n_ranks: int):
        return tuple(
            p.tree_finalize(s, n_ranks)
            for p, s in zip(self.parts, self._subs(x))
        )

    def wire_pack_flags(self, val) -> list[bool]:
        flags: list[bool] = []
        for p, s in zip(self.parts, self._subs(val)):
            flags.extend(p.wire_pack_flags(s))
        return flags


def stacked(*ops) -> StackedCombiner:
    """Build a :class:`StackedCombiner` from combiner names or instances —
    ``stacked("qr", "sum")`` is the blocked driver's one-butterfly-per-panel
    payload (panel R leaf + cross-product leaf)."""
    return StackedCombiner(parts=tuple(get_combiner(op) for op in ops))


COMBINERS: dict[str, Callable[[], Combiner]] = {
    "sum": SumCombiner,
    "mean": MeanCombiner,
    "max": MaxCombiner,
    "gram_sum": GramSumCombiner,
    "qr_combine": QRCombiner,
    "qr": QRCombiner,
}


def get_combiner(op) -> Combiner:
    """Resolve a combiner name (or pass an instance through)."""
    if isinstance(op, Combiner):
        return op
    try:
        return COMBINERS[op]()
    except KeyError:
        raise ValueError(
            f"unknown combiner {op!r}; choose from {sorted(set(COMBINERS))}"
        ) from None
