"""The plan-driven, fault-tolerant butterfly-collective engine.

This is the generic half of the paper's contribution, factored out of the
TSQR implementation: :func:`execute_plan` runs any
:class:`~repro.collective.plan.Plan` (tree / redundant / replace /
selfhealing) with any :class:`~repro.collective.combiners.Combiner`,
threading validity bits alongside every payload and performing the
Self-Healing restore rounds.  It is written once against
:class:`~repro.collective.comm.Comm`, so every combiner executes identically
on :class:`~repro.collective.comm.SimComm` (single device, leading (P,)
axis) and :class:`~repro.collective.comm.ShardMapComm` (SPMD,
``lax.ppermute``).

:func:`ft_allreduce` is the public entry point for arithmetic reductions —
a recursive-doubling all-reduce over the same butterfly as TSQR, inheriting
the paper's ``2^s − 1`` fault tolerance for free.  It replaces the old
fault-oblivious ``butterfly_allreduce_sum``: PowerSGD's Gram reductions,
the CholeskyQR reorthogonalization passes, and the trainer's BLANK-mode
gradient reduction all route through it.

Validity semantics: a dead rank's contribution is zero-filled (XLA
collective-permute semantics) and flagged invalid — the step-boundary
analogue of ULFM's error returns.  The host plan predicts the same validity;
tests assert the two agree bit-for-bit.  Invalid payload slots are poisoned
(NaN for inexact dtypes) so accidental use is loud.

Payloads may be arbitrary pytrees (one shared validity bit per rank): the
trainer routes whole gradient trees through one call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .combiners import Combiner, get_combiner
from .comm import Comm
from .faults import NEVER, FaultSpec
from .plan import Plan, make_plan

__all__ = ["execute_plan", "ft_allreduce"]


def _poison(leaf):
    """Fill for invalid slots: NaN where representable, zero otherwise."""
    if jnp.issubdtype(leaf.dtype, jnp.inexact):
        return jnp.full_like(leaf, jnp.nan)
    return jnp.zeros_like(leaf)


def execute_plan(x, comm: Comm, plan: Plan, combiner: Combiner | str):
    """Run ``plan`` over ``x`` with ``combiner``.  Returns ``(value, valid)``.

    ``x`` is a pytree of per-rank payloads (leading (P,) axis under
    ``SimComm``, local blocks under ``ShardMapComm``).  ``value`` is the
    un-finalized combine (callers wanting mean semantics etc. should use
    :func:`ft_allreduce`); ``valid`` is the per-rank validity bit, which
    matches ``plan.final_valid`` bit-for-bit.
    """
    combiner = get_combiner(combiner)
    val = jax.tree.map(combiner.prepare, x)
    d = comm.take(plan.death)
    my = comm.ranks()
    valid = d > 0
    for step in plan.steps:
        s = step.level
        can = valid & (d > s)
        # ---- exchange (possibly several unique-source rounds) -------------
        recv = jax.tree.map(jnp.zeros_like, val)
        recv_v = jnp.zeros_like(can)
        for rnd in step.perm_rounds:
            rr, rv = comm.exchange((val, can), rnd)
            recv = jax.tree.map(jnp.add, recv, rr)  # each rank receives ≤once
            recv_v = recv_v | rv
        # ---- combine: operands ordered by this level's block bit ----------
        mine_first = ((my >> s) & 1) == 0
        lo = jax.tree.map(lambda m, o: comm.bwhere(mine_first, m, o), val, recv)
        hi = jax.tree.map(lambda m, o: comm.bwhere(mine_first, o, m), val, recv)
        new = jax.tree.map(combiner.combine, lo, hi)
        valid = can & recv_v
        val = jax.tree.map(lambda nv: comm.bwhere(valid, nv, _poison(nv)), new)
        # ---- Self-Healing: respawn dead ranks from a replica ---------------
        if step.restore_rounds:
            for rnd in step.restore_rounds:
                rr, rv = comm.exchange((val, valid), rnd)
                got = rv & ~valid
                val = jax.tree.map(
                    lambda cur, rec: comm.bwhere(got, rec, cur), val, rr
                )
                valid = valid | got
            respawned = comm.take(step.respawned)
            d = jnp.where(respawned, jnp.asarray(NEVER, d.dtype), d)
    return val, valid


def ft_allreduce(
    x,
    comm: Comm,
    *,
    op: Combiner | str = "sum",
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    plan: Plan | None = None,
):
    """Fault-tolerant all-reduce over the paper's butterfly.

    Fault-free this is exactly the redundant-TSQR communication pattern with
    the requested combiner; under a ``fault_spec`` (or explicit ``plan``) it
    inherits the variant's tolerance — ``2^s − 1`` failures at the entry of
    exchange ``s`` — and survivors end with the full reduction.

    Returns ``(value, valid)``: ``value`` is the finalized reduction (pytree
    like ``x``), ``valid`` the per-rank validity bit.  Invalid ranks hold
    poisoned (NaN) payloads.
    """
    if plan is None:
        plan = make_plan(variant, comm.n_ranks, fault_spec)
    combiner = get_combiner(op)
    val, valid = execute_plan(x, comm, plan, combiner)
    val = jax.tree.map(lambda leaf: combiner.finalize(leaf, plan.n_ranks), val)
    return val, valid
