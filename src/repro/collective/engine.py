"""The plan-driven, fault-tolerant butterfly-collective engine.

This is the generic half of the paper's contribution, factored out of the
TSQR implementation: :func:`execute_plan` runs any
:class:`~repro.collective.plan.Plan` (tree / redundant / replace /
selfhealing) with any :class:`~repro.collective.combiners.Combiner`,
threading validity bits alongside every payload and performing the
Self-Healing restore rounds.  It is written once against
:class:`~repro.collective.comm.Comm`, so every combiner executes identically
on :class:`~repro.collective.comm.SimComm` (single device, leading (P,)
axis) and :class:`~repro.collective.comm.ShardMapComm` (SPMD,
``lax.ppermute``).

:func:`ft_allreduce` is the public entry point for arithmetic reductions —
a recursive-doubling all-reduce over the same butterfly as TSQR, inheriting
the paper's ``2^s − 1`` fault tolerance for free.  It replaces the old
fault-oblivious ``butterfly_allreduce_sum``: PowerSGD's Gram reductions,
the CholeskyQR reorthogonalization passes, and the trainer's BLANK-mode
gradient reduction all route through it.

**Fault-free fast path.**  ~100% of production steps run a fault-free plan:
one perm-round per level, nobody dies, every rank stays valid.  The general
executor still paid per level for machinery only faults need — a
``zeros_like`` + ``add`` receive-staging loop (multi-round Replace
multicast), a validity bit on the wire, per-rank validity updates and
NaN-poison writes.  When the host plan proves fault-freeness
(:func:`plan_is_fault_free`), :func:`execute_plan` dispatches to a
straight-line butterfly — exchange, order by the level bit, combine — that
both the jnp and Pallas combiners ride, and returns the host-predicted
(all-true) validity.  The result is bit-identical to the general path
(asserted across the test suite); pass ``fast=False`` to force the general
executor.

**Symmetric wire packing.**  Combiners that declare ``wire_symmetric``
(``gram_sum``) carry symmetric (…, n, n) payloads; both executors pack them
to the n(n+1)/2 upper triangle at the comm boundary
(:mod:`repro.collective.packing`), so the wire carries exactly what
``Plan.bytes_on_wire(symmetric=True)`` prices.  The decision is per leaf
(:meth:`Combiner.wire_pack_flags`): a mixed payload — e.g. a stacked
symmetric Gram leaf next to a dense rectangular cross leaf — packs exactly
the leaves that qualify, priced by ``Plan.bytes_on_wire_stacked``.

Validity semantics: a dead rank's contribution is zero-filled (XLA
collective-permute semantics) and flagged invalid — the step-boundary
analogue of ULFM's error returns.  The host plan predicts the same validity;
tests assert the two agree bit-for-bit.  Invalid payload slots are poisoned
(NaN for inexact dtypes) so accidental use is loud.

Payloads may be arbitrary pytrees (one shared validity bit per rank): the
trainer routes whole gradient trees through one call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch as _dispatch

from .combiners import Combiner, get_combiner
from .comm import Comm, ShardMapComm, SimComm
from .faults import NEVER, FaultSpec
from .packing import pack_sym, unpack_sym
from .plan import Plan, _split_rounds, make_plan

__all__ = ["execute_plan", "ft_allreduce", "ft_allreduce_jit",
           "plan_is_fault_free", "recover_payload", "replica_fetch"]


def _poison(leaf):
    """Fill for invalid slots: NaN where representable, zero otherwise."""
    if jnp.issubdtype(leaf.dtype, jnp.inexact):
        return jnp.full_like(leaf, jnp.nan)
    return jnp.zeros_like(leaf)


def plan_is_fault_free(plan: Plan) -> bool:
    """Fast-path eligibility — cached on the plan (:attr:`Plan.
    is_fault_free`), so the K×3 collectives of a blocked factorization pay
    the step walk once instead of once per call."""
    return plan.is_fault_free


def _wire_codec(combiner: Combiner, val):
    """(pack, unpack) applied at the comm boundary, decided **per leaf**:
    a leaf ships the n(n+1)/2 upper triangle iff its governing combiner
    declares ``wire_symmetric`` and the leaf is square
    (:meth:`Combiner.wire_pack_flags` — a stacked payload routes the
    decision per part).  Everything else passes through dense, so a mixed
    payload with one symmetric leaf and one rectangular leaf ships each
    optimally instead of falling back to all-dense."""
    flags = combiner.wire_pack_flags(val)
    if not any(flags):
        def ident(t):
            return t

        return ident, ident

    treedef = jax.tree.structure(val)
    ns = [leaf.shape[-1] for leaf in jax.tree.leaves(val)]

    def pack(t):
        return treedef.unflatten([
            pack_sym(leaf) if f else leaf
            for leaf, f in zip(jax.tree.leaves(t), flags)
        ])

    def unpack(t):
        return treedef.unflatten([
            unpack_sym(leaf, n) if f else leaf
            for leaf, f, n in zip(jax.tree.leaves(t), flags, ns)
        ])

    return pack, unpack


def _execute_fast(x, comm: Comm, plan: Plan, combiner: Combiner):
    """Straight-line fault-free butterfly: no receive staging, no validity
    bit on the wire, no poison writes.  Requires :func:`plan_is_fault_free`;
    bit-identical to the general executor on such plans."""
    val = combiner.tree_prepare(x)
    pack, unpack = _wire_codec(combiner, val)
    my = comm.ranks()
    for step in plan.steps:
        recv = unpack(comm.exchange(pack(val), step.perm_rounds[0]))
        mine_first = ((my >> step.level) & 1) == 0
        lo = jax.tree.map(lambda m, o: comm.bwhere(mine_first, m, o), val, recv)
        hi = jax.tree.map(lambda m, o: comm.bwhere(mine_first, o, m), val, recv)
        val = combiner.tree_combine(lo, hi)
    return val, comm.take(plan.final_valid)


def execute_plan(
    x,
    comm: Comm,
    plan: Plan,
    combiner: Combiner | str,
    *,
    fast: bool | None = None,
):
    """Run ``plan`` over ``x`` with ``combiner``.  Returns ``(value, valid)``.

    ``x`` is a pytree of per-rank payloads (leading (P,) axis under
    ``SimComm``, local blocks under ``ShardMapComm``).  ``value`` is the
    un-finalized combine (callers wanting mean semantics etc. should use
    :func:`ft_allreduce`); ``valid`` is the per-rank validity bit, which
    matches ``plan.final_valid`` bit-for-bit.

    ``fast=None`` auto-dispatches to the fault-free fast path when the host
    plan permits; ``False`` forces the general executor; ``True`` demands
    the fast path (raises if the plan is not fault-free).
    """
    combiner = get_combiner(combiner)
    fault_free = plan.is_fault_free
    if fast is True and not fault_free:
        raise ValueError(
            "fast=True requires a fault-free plan (one perm-round per step, "
            "no deaths, all ranks valid)"
        )
    if fault_free and fast is not False:
        return _execute_fast(x, comm, plan, combiner)

    val = combiner.tree_prepare(x)
    pack, unpack = _wire_codec(combiner, val)
    d = comm.take(plan.death)
    my = comm.ranks()
    valid = d > 0
    for step in plan.steps:
        s = step.level
        can = valid & (d > s)
        # ---- exchange (possibly several unique-source rounds) -------------
        pval = pack(val)
        recv_p = jax.tree.map(jnp.zeros_like, pval)
        recv_v = jnp.zeros_like(can)
        for rnd in step.perm_rounds:
            rr, rv = comm.exchange((pval, can), rnd)
            recv_p = jax.tree.map(jnp.add, recv_p, rr)  # each rank receives ≤once
            recv_v = recv_v | rv
        recv = unpack(recv_p)
        # ---- combine: operands ordered by this level's block bit ----------
        mine_first = ((my >> s) & 1) == 0
        lo = jax.tree.map(lambda m, o: comm.bwhere(mine_first, m, o), val, recv)
        hi = jax.tree.map(lambda m, o: comm.bwhere(mine_first, o, m), val, recv)
        new = combiner.tree_combine(lo, hi)
        valid = can & recv_v
        val = jax.tree.map(lambda nv: comm.bwhere(valid, nv, _poison(nv)), new)
        # ---- Self-Healing: respawn dead ranks from a replica ---------------
        if step.restore_rounds:
            for rnd in step.restore_rounds:
                rr, rv = comm.exchange((pack(val), valid), rnd)
                rr = unpack(rr)
                got = rv & ~valid
                val = jax.tree.map(
                    lambda cur, rec: comm.bwhere(got, rec, cur), val, rr
                )
                valid = valid | got
            respawned = comm.take(step.respawned)
            d = jnp.where(respawned, jnp.asarray(NEVER, d.dtype), d)
    return val, valid


def replica_fetch(x, comm: Comm, valid) -> object:
    """Restore invalid ranks' payloads from replicas of the reduced value.

    After a within-tolerance butterfly, every *valid* rank holds an
    identical copy of the reduction — the redundant copies the paper buys
    with the exchange.  This converts that data existence into recovery at
    a step boundary: each invalid rank receives the value from a valid
    donor (round-robin, decomposed into unique-source rounds exactly like
    the Replace multicast).  ``valid`` is the *host-side* (P,) prediction
    (``plan.final_valid``) — routing must be trace-time static, the same
    step-boundary replanning contract as the plans themselves.

    The blocked-QR driver uses this between panels: a rank that lost a
    panel's R or W re-joins the pipeline instead of poisoning every later
    panel's reduction.  Raises ``ValueError`` when no rank is valid —
    the value is genuinely extinct and no routing can recover it.
    """
    valid = np.asarray(valid, dtype=bool)
    if valid.all():
        return x
    if not valid.any():
        raise ValueError("replica_fetch: no valid rank holds the value")
    donors = np.flatnonzero(valid)
    starved = np.flatnonzero(~valid)
    pairs = [
        (int(donors[i % len(donors)]), int(r)) for i, r in enumerate(starved)
    ]
    for rnd in _split_rounds(pairs):
        got = np.zeros(valid.shape[0], dtype=bool)
        got[[d for _, d in rnd]] = True
        g = comm.take(got)
        recv = comm.exchange(x, rnd)
        x = jax.tree.map(lambda cur, rec: comm.bwhere(g, rec, cur), x, recv)
    return x


def recover_payload(x, comm: Comm, valid, *, plan=None) -> object:
    """Scheme-dispatching phase-boundary recovery — the only entry drivers
    may call (ruff TID251 bans direct ``replica_fetch`` use outside this
    module).

    * Butterfly plans (or no plan): replication holds full copies of the
      reduced value on every valid rank, so invalid ranks fetch from donors
      (:func:`replica_fetch`).
    * Coded plans (:class:`~repro.collective.coded.CodedPlan`): recovery
      already happened *inside* the collective — erased contributions were
      reconstructed from parity at the root and the broadcast handed the
      result to every recipient (dead data ranks respawned) — so there is
      nothing left to fetch.  An invalid rank here means the erasure budget
      was exceeded; no donor path exists (parity is not a replica), which
      this surfaces as ``ValueError`` instead of silently fetching garbage.
    """
    from .coded import CodedPlan  # local: coded imports this module

    if plan is not None and isinstance(plan, CodedPlan):
        valid = np.asarray(valid, dtype=bool)
        if not valid[: plan.n_data].all():
            raise ValueError(
                "recover_payload: coded recovery happens in-collective; "
                "invalid data ranks after a coded reduce mean the erasure "
                "budget was exceeded and no donor path exists"
            )
        return x
    return replica_fetch(x, comm, valid)


def ft_allreduce(
    x,
    comm: Comm,
    *,
    op: Combiner | str = "sum",
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    plan: Plan | None = None,
    fast: bool | None = None,
):
    """Fault-tolerant all-reduce over the paper's butterfly.

    Fault-free this is exactly the redundant-TSQR communication pattern with
    the requested combiner (ridden on the straight-line fast path); under a
    ``fault_spec`` (or explicit ``plan``) it inherits the variant's
    tolerance — ``2^s − 1`` failures at the entry of exchange ``s`` — and
    survivors end with the full reduction.

    Returns ``(value, valid)``: ``value`` is the finalized reduction (pytree
    like ``x``), ``valid`` the per-rank validity bit.  Invalid ranks hold
    poisoned (NaN) payloads.
    """
    if plan is None:
        plan = make_plan(variant, comm.n_ranks, fault_spec)
    combiner = get_combiner(op)
    val, valid = execute_plan(x, comm, plan, combiner, fast=fast)
    val = combiner.tree_finalize(val, plan.n_ranks)
    return val, valid


# ---------------------------------------------------------------------------
# Retrace-proof compiled entry point
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _ft_allreduce_compiled(comm: Comm, plan: Plan, op, fast):
    """One compiled butterfly per ``(comm, plan, combiner)`` — the jit cache
    underneath keys on the payload's ``(treedef, shapes, dtypes)``, so the
    full cache key is exactly ``(plan, combiner-name, treedef, shapes)``."""

    @jax.jit
    def fun(x):
        _dispatch.note_trace("ft_allreduce")
        return ft_allreduce(x, comm, op=op, plan=plan, fast=fast)

    return fun


@functools.lru_cache(maxsize=256)
def _ft_allreduce_shard_compiled(mesh, comm: ShardMapComm, plan: Plan, op, fast):
    """One compiled SPMD butterfly per ``(mesh-equivalence-class, plan,
    combiner)``.  The ``mesh`` position of the key is the equivalence class:
    ``Mesh`` hashes by value (device ids + axis names), so an elastically
    rebuilt mesh over the same devices hits the same entry — the same
    contract the TSQR/blocked shard builders rely on.  The payload keeps the
    SimComm global view (leading ``(P,)`` axis); ``shard_map`` hands each
    rank its ``(1, …)`` slice and the engine runs on local blocks over real
    ``ppermute`` wires, so the returned layout — and, fault-free, the bits —
    match the SimComm program exactly (same plans, same combine order)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map as _shard_map

    axis = comm.axis

    def body(x):
        _dispatch.note_trace("ft_allreduce")
        local = jax.tree.map(lambda leaf: leaf[0], x)
        val, ok = ft_allreduce(local, comm, op=op, plan=plan, fast=fast)
        return jax.tree.map(lambda leaf: leaf[None], val), ok[None]

    fun = _shard_map(
        body, mesh=mesh, in_specs=P(axis), out_specs=(P(axis), P(axis))
    )
    return jax.jit(fun)


def ft_allreduce_jit(
    x,
    comm: Comm,
    *,
    op: Combiner | str = "sum",
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    plan: Plan | None = None,
    fast: bool | None = None,
    mesh=None,
):
    """:func:`ft_allreduce` as a cached, zero-retrace device program.

    The plan is hashable-static (value-keyed ``Plan.__hash__``) and the
    combiner resolves to a frozen instance, so the whole butterfly closes
    over them and compiles once per ``(plan, combiner, treedef, shapes)`` —
    a repeat call with identical statics performs **zero** new traces (the
    ``dispatch`` bench case and the CI retrace guard pin this).

    Backends:

    * :class:`~repro.collective.comm.SimComm` — the payload carries the
      leading ``(P,)`` axis; the butterfly compiles standalone.
    * :class:`~repro.collective.comm.ShardMapComm` — pass ``mesh=``; the
      payload keeps the same global ``(P,)``-leading layout and the cached
      compile wraps the butterfly in ``shard_map`` over ``comm.axis``
      (exchanges lower to ``collective-permute``).  The cache keys on the
      mesh *equivalence class* (``Mesh`` hashes by value), so an elastic
      rebuild over the same devices reuses the compile.  Fault-free results
      are bit-identical to the SimComm program; faulted plans degrade
      identically in kind (same validity bits, same poisoned slots).  For a
      collective *inside* an enclosing ``shard_map`` body, keep calling
      :func:`ft_allreduce` directly — the enclosing program is what gets
      compiled there.
    """
    if plan is None:
        plan = make_plan(variant, comm.n_ranks, fault_spec)
    if isinstance(comm, SimComm):
        fun = _ft_allreduce_compiled(comm, plan, get_combiner(op), fast)
    elif isinstance(comm, ShardMapComm):
        if mesh is None:
            raise ValueError(
                "ft_allreduce_jit on ShardMapComm needs mesh= (the Mesh "
                "whose axis the comm permutes over) to build the enclosing "
                "shard_map program"
            )
        if comm.axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} do not include comm axis "
                f"{comm.axis!r}"
            )
        if mesh.shape[comm.axis] != comm.n_ranks:
            raise ValueError(
                f"mesh axis {comm.axis!r} has {mesh.shape[comm.axis]} "
                f"devices but comm.n_ranks={comm.n_ranks}"
            )
        fun = _ft_allreduce_shard_compiled(
            mesh, comm, plan, get_combiner(op), fast
        )
    else:
        raise ValueError(
            f"ft_allreduce_jit supports SimComm and ShardMapComm, got "
            f"{type(comm).__name__}"
        )
    _dispatch.note_dispatch("ft_allreduce")
    return fun(x)
