"""Communication backends for the fault-tolerant butterfly collectives.

The engine in :mod:`repro.collective.engine` (and therefore every consumer:
TSQR, ``ft_allreduce``, PowerSGD orthogonalization) is written once against
this small interface and executes on either backend:

  * :class:`ShardMapComm` — the production path: SPMD inside
    ``shard_map``, exchanges are ``lax.ppermute`` (XLA
    ``collective-permute`` on ICI).  Per-rank values are scalars / local
    blocks.
  * :class:`SimComm` — a single-device simulation where every per-rank value
    carries a leading ``(P,)`` axis and exchanges are gathers.  This is what
    the CPU test-suite and the hypothesis robustness sweeps run on: it is
    bit-identical in algorithm structure (same plans, same combine order)
    but needs no multi-device runtime.

Both backends fill non-receiving ranks with zeros, matching XLA
``collective-permute`` semantics (a rank absent from the permutation's
destination list receives zeros — the moral equivalent of ULFM's error
return, which the validity bits then adjudicate).

``exchange`` maps over pytrees, so the engine can route whole gradient
trees (the trainer's BLANK-mode all-reduce) as easily as a single R factor.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = ["Comm", "SimComm", "ShardMapComm"]

Pair = tuple[int, int]


class Comm:
    """Interface: per-rank SPMD values or (P,)-leading simulated values."""

    n_ranks: int

    def ranks(self):  # rank id: scalar (SPMD) or (P,) vector (sim)
        raise NotImplementedError

    def take(self, host_vec):  # per-rank slice of a host (P,) vector
        raise NotImplementedError

    def exchange(self, x, perm: Sequence[Pair]):
        """Permute per-rank payloads; non-receivers get zeros."""
        raise NotImplementedError

    def bwhere(self, cond, a, b):
        """`where` with a per-rank scalar condition, broadcast over payload."""
        raise NotImplementedError

    def leaf_nbytes(self, leaf) -> int:
        """Per-rank wire bytes of one payload leaf — the hook the
        per-round byte counters (:mod:`repro.collective.instrument`) use.
        Backends differ: a ``SimComm`` leaf carries the whole (P,)-leading
        array, a ``ShardMapComm`` leaf is already the local block."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SimComm(Comm):
    """Single-device simulation: leading (P,) axis on every per-rank value."""

    n_ranks: int

    def ranks(self):
        return jnp.arange(self.n_ranks)

    def take(self, host_vec):
        arr = jnp.asarray(host_vec)
        assert arr.shape[0] == self.n_ranks
        return arr

    def exchange(self, x, perm: Sequence[Pair]):
        def go(leaf):
            out = jnp.zeros_like(leaf)
            if not perm:
                return out
            src = jnp.array([s for s, _ in perm], dtype=jnp.int32)
            dst = jnp.array([d for _, d in perm], dtype=jnp.int32)
            return out.at[dst].set(leaf[src])

        return jax.tree.map(go, x)

    def bwhere(self, cond, a, b):
        a, b = jnp.broadcast_arrays(a, b)
        extra = a.ndim - cond.ndim
        return jnp.where(cond.reshape(cond.shape + (1,) * extra), a, b)

    def leaf_nbytes(self, leaf) -> int:
        # leading (P,) axis: one rank's slice is 1/P of the array
        return int(np.prod(leaf.shape[1:], dtype=np.int64)) * leaf.dtype.itemsize


@dataclasses.dataclass(frozen=True)
class ShardMapComm(Comm):
    """SPMD inside ``shard_map``: exchanges lower to ``collective-permute``."""

    n_ranks: int
    axis: str

    def ranks(self):
        return lax.axis_index(self.axis)

    def take(self, host_vec):
        arr = jnp.asarray(np.asarray(host_vec))
        assert arr.shape[0] == self.n_ranks
        return arr[lax.axis_index(self.axis)]

    def exchange(self, x, perm: Sequence[Pair]):
        def go(leaf):
            if not perm:
                return jnp.zeros_like(leaf)
            return lax.ppermute(leaf, self.axis, [tuple(p) for p in perm])

        return jax.tree.map(go, x)

    def bwhere(self, cond, a, b):
        return jnp.where(cond, a, b)

    def leaf_nbytes(self, leaf) -> int:
        # SPMD: the leaf is already one rank's local block
        return int(np.prod(leaf.shape, dtype=np.int64)) * leaf.dtype.itemsize
