"""Per-round communication counters for the collective engine.

:class:`InstrumentedComm` wraps any :class:`~repro.collective.comm.Comm`
backend and records, for every ``exchange`` the engine issues, the number of
point-to-point messages and the payload bytes they carry.  Because the
engine's routing is host-planned (static per plan), the counters are
populated at trace time and are exact even when the collective itself runs
under ``jax.jit`` — the recorded traffic is the traffic the plan commits to.

This is the measurement hook the benchmark subsystem
(:mod:`repro.bench`) uses to report *observed* comm volume next to the
*planned* volume from :meth:`~repro.collective.plan.Plan.message_count` /
:meth:`~repro.collective.plan.Plan.bytes_on_wire`; the two are asserted to
agree in tests, so a planner change that silently alters wire traffic trips
the regression gate.

Accounting note: the *general* executor exchanges ``(payload, validity)``
tuples, so observed bytes include one validity byte (bool) per message on
top of the payload — ``observed == plan.bytes_on_wire(...) +
plan.message_count()`` for a single-leaf payload of matching shape.  The
fault-free fast path ships the payload alone (validity is host-proven), so
there ``observed == plan.bytes_on_wire(...)`` exactly; symmetric combiners
(``gram_sum``) pack to the n(n+1)/2 triangle on either path, priced by
``bytes_on_wire(symmetric=True)``.
"""
from __future__ import annotations

import dataclasses

import jax

from .comm import Comm

__all__ = ["CommStats", "InstrumentedComm"]


@dataclasses.dataclass
class CommStats:
    """Cumulative + per-round exchange counters."""

    per_round: list[dict] = dataclasses.field(default_factory=list)

    @property
    def rounds(self) -> int:
        return len(self.per_round)

    @property
    def messages(self) -> int:
        return sum(r["messages"] for r in self.per_round)

    @property
    def payload_bytes(self) -> int:
        return sum(r["payload_bytes"] for r in self.per_round)

    def record(self, messages: int, payload_bytes: int) -> None:
        self.per_round.append(
            {"messages": messages, "payload_bytes": payload_bytes}
        )

    def reset(self) -> None:
        self.per_round.clear()

    def as_dict(self) -> dict:
        return {
            "rounds": self.rounds,
            "messages": self.messages,
            "payload_bytes": self.payload_bytes,
        }


@dataclasses.dataclass(frozen=True)
class InstrumentedComm(Comm):
    """Counting proxy around a concrete comm backend.

    ``stats`` accumulates across calls; use :meth:`CommStats.reset` (or a
    fresh wrapper) between measurements.
    """

    inner: Comm
    stats: CommStats = dataclasses.field(default_factory=CommStats)

    @property
    def n_ranks(self) -> int:  # type: ignore[override]
        return self.inner.n_ranks

    def ranks(self):
        return self.inner.ranks()

    def take(self, host_vec):
        return self.inner.take(host_vec)

    def bwhere(self, cond, a, b):
        return self.inner.bwhere(cond, a, b)

    def leaf_nbytes(self, leaf) -> int:
        return self.inner.leaf_nbytes(leaf)

    def exchange(self, x, perm):
        per_msg = sum(self.inner.leaf_nbytes(leaf) for leaf in jax.tree.leaves(x))
        self.stats.record(len(perm), len(perm) * per_msg)
        return self.inner.exchange(x, perm)
