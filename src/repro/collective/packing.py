"""Symmetric wire packing for butterfly payloads.

``gram_sum`` payloads are symmetric (…, n, n) matrices, so only the upper
triangle — n(n+1)/2 elements — needs to cross the wire.
:meth:`repro.collective.plan.Plan.bytes_on_wire(symmetric=True)` has priced
that encoding since PR 1; this module makes the engine actually *ship* it:
:func:`pack_sym` flattens the upper triangle before every exchange and
:func:`unpack_sym` mirrors it back on receipt, so the planned and observed
byte counts agree (hard-gated in ``repro.bench.cases.comm_volume``).

The round trip is exact for symmetric inputs: off-diagonal entries are
copied (never recomputed), and the diagonal is selected with a ``where``
rather than reconstructed arithmetically, so zero-filled non-receiver slots
and NaN-poisoned invalid slots survive bit-for-bit.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = ["pack_sym", "unpack_sym", "packable"]


def packable(leaf) -> bool:
    """Is this payload leaf a batched square matrix we can pack?"""
    return leaf.ndim >= 2 and leaf.shape[-1] == leaf.shape[-2]


def pack_sym(x):
    """(…, n, n) symmetric → (…, n(n+1)/2) upper triangle, row-major."""
    n = x.shape[-1]
    iu, ju = np.triu_indices(n)
    return x[..., iu, ju]


def unpack_sym(v, n: int):
    """Inverse of :func:`pack_sym`: (…, n(n+1)/2) → symmetric (…, n, n)."""
    iu, ju = np.triu_indices(n)
    upper = jnp.zeros(v.shape[:-1] + (n, n), v.dtype).at[..., iu, ju].set(v)
    return jnp.where(
        jnp.eye(n, dtype=bool), upper, upper + jnp.swapaxes(upper, -1, -2)
    )
