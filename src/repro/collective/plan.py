"""Step-by-step communication plans for the four butterfly/tree variants.

A :class:`Plan` is computed on the host (numpy) from the mesh size and a
:class:`~repro.collective.faults.FaultSpec`.  It holds, per butterfly level:

  * ``perm_rounds``  — the ``(src, dst)`` pairs of each communication round.
    XLA's ``collective-permute`` forbids duplicate sources, so when one
    replica must serve several starved ranks (Replace multicast) the
    planner decomposes the logical permutation into rounds with unique
    sources.  In the fault-free case every variant needs exactly one round.
  * ``restore_rounds`` — Self-Healing only: the replica→respawned-rank state
    transfers performed after the exchange of that level (paper Alg. 5).
  * ``valid_after``   — the host-side prediction of which ranks hold a
    correct partial value after the level completes.  The JAX execution
    threads the same validity dynamically; tests assert the two agree.

Plans are *combiner-agnostic*: the same routing drives the QR combine of
TSQR and every ``ft_allreduce`` combiner (sum/mean/max/gram_sum) — the
paper's redundancy argument only needs the combine to be associative.

This mirrors how a real TPU runtime reacts to failures: routes are recomputed
at step boundaries from the device-health vector (the ULFM "error return +
findReplica" of the paper, hoisted to the step boundary — see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .faults import NEVER, FaultSpec

__all__ = [
    "Step",
    "Plan",
    "make_plan",
    "ilog2",
    "leaf_bytes",
    "payload_numel",
    "VARIANTS",
]

Pair = tuple[int, int]


def ilog2(p: int) -> int:
    s = p.bit_length() - 1
    if p <= 0 or (1 << s) != p:
        raise ValueError(
            f"butterfly collectives require a power-of-two rank count, got {p}"
        )
    return s


def payload_numel(n_cols: int, symmetric: bool = False) -> int:
    """Elements per exchanged (n, n) payload.

    ``symmetric=True`` accounts for packed storage of a symmetric matrix
    (Gram payloads): n(n+1)/2 instead of n² — what the engine actually
    ships for ``wire_symmetric`` combiners since the
    :mod:`repro.collective.packing` codec (the comm_volume bench hard-gates
    the observed agreement).  (Triangular R factors admit the same packing;
    that saving is not modeled — ``qr_combine`` is priced square.)
    """
    if symmetric:
        return n_cols * (n_cols + 1) // 2
    return n_cols * n_cols


def leaf_bytes(
    rows: int, cols: int, itemsize: int = 4, symmetric: bool = False
) -> int:
    """Wire bytes of one payload leaf.  Rectangular leaves ship dense
    (rows × cols); symmetric leaves (which must be square) ship the
    n(n+1)/2 packed triangle the engine's per-leaf codec produces."""
    if symmetric:
        if rows != cols:
            raise ValueError(
                f"symmetric leaves must be square, got ({rows}, {cols})"
            )
        return payload_numel(cols, symmetric=True) * itemsize
    return rows * cols * itemsize


@dataclasses.dataclass(frozen=True, eq=False)
class Step:
    level: int
    perm_rounds: tuple[tuple[Pair, ...], ...]
    restore_rounds: tuple[tuple[Pair, ...], ...]
    # Host-side predictions (numpy bool, shape (P,)):
    valid_after: np.ndarray      # holds a correct partial value after this level
    respawned: np.ndarray        # ranks respawned at the end of this level

    # Steps hold numpy fields, so the dataclass-generated __eq__/__hash__
    # are unusable (ambiguous array truth / unhashable arrays).  A value
    # signature restores both, which lets plans key jit/LRU caches.
    @functools.cached_property
    def _sig(self) -> tuple:
        return (
            self.level,
            self.perm_rounds,
            self.restore_rounds,
            self.valid_after.tobytes(),
            self.respawned.tobytes(),
        )

    def __eq__(self, other) -> bool:
        return isinstance(other, Step) and self._sig == other._sig

    def __hash__(self) -> int:
        return hash(self._sig)

    @property
    def n_messages(self) -> int:
        return sum(len(r) for r in self.perm_rounds) + sum(
            len(r) for r in self.restore_rounds
        )

    @property
    def n_rounds(self) -> int:
        return len(self.perm_rounds) + len(self.restore_rounds)


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    variant: str
    n_ranks: int
    n_steps: int
    death: np.ndarray            # (P,) effective death vector consumed
    steps: tuple[Step, ...]
    final_valid: np.ndarray      # (P,) who holds the final value

    # -- value identity (hashable-static: plans key jit/LRU caches) ---------
    @functools.cached_property
    def _sig(self) -> tuple:
        return (
            self.variant,
            self.n_ranks,
            self.n_steps,
            self.death.tobytes(),
            self.steps,
            self.final_valid.tobytes(),
        )

    def __eq__(self, other) -> bool:
        return isinstance(other, Plan) and self._sig == other._sig

    def __hash__(self) -> int:
        return hash(self._sig)

    @functools.cached_property
    def is_fault_free(self) -> bool:
        """Fast-path eligibility, computed once per plan (the panel loop
        fires several collectives per panel — re-walking every step on every
        call was pure host overhead): one perm-round per step, no restore
        rounds, no deaths during the collective, every rank valid throughout
        (excludes ``tree``, whose senders go invalid by design)."""
        if not bool(self.final_valid.all()):
            return False
        if self.n_steps and bool((self.death < self.n_steps).any()):
            return False
        for step in self.steps:
            if len(step.perm_rounds) != 1 or step.restore_rounds:
                return False
            if not bool(step.valid_after.all()):
                return False
        return True

    # -- communication accounting (benchmarks/comm_volume.py) --------------
    def message_count(self) -> int:
        return sum(s.n_messages for s in self.steps)

    def round_count(self) -> int:
        """Serial communication rounds — the latency proxy."""
        return sum(max(1, s.n_rounds) for s in self.steps)

    def bytes_on_wire(
        self, n_cols: int, itemsize: int = 4, *, symmetric: bool = False
    ) -> int:
        """Total payload bytes moved by the plan.

        ``symmetric=True`` prices the n(n+1)/2 packed encoding available to
        symmetric payloads (``gram_sum``); the default n² is what a square
        ship costs.  benchmarks/comm_volume.py reports both.
        """
        payload = payload_numel(n_cols, symmetric) * itemsize
        return self.message_count() * payload

    def bytes_on_wire_stacked(self, leaves) -> int:
        """Exact wire bytes for a stacked / multi-leaf payload.

        ``leaves`` is a sequence of per-leaf specs ``(rows, cols, itemsize,
        symmetric)``; each message carries every leaf, with symmetric leaves
        priced packed and rectangular leaves dense — what the engine's
        per-leaf codec actually ships for a
        :class:`~repro.collective.combiners.StackedCombiner` payload (the
        ``comm_volume`` and ``overlap`` bench cases hard-gate the observed
        agreement).  The single-leaf square case reduces to
        :meth:`bytes_on_wire`.
        """
        per_message = sum(leaf_bytes(*spec) for spec in leaves)
        return self.message_count() * per_message


# ---------------------------------------------------------------------------
# Round decomposition: unique sources per round (no multicast on ICI).
# ---------------------------------------------------------------------------

def _split_rounds(pairs: list[Pair]) -> tuple[tuple[Pair, ...], ...]:
    """Split (src, dst) pairs into rounds with unique sources.

    Destinations are unique by construction (each rank receives once per
    level).  Sources repeat only when a replica serves several starved
    ranks; those go to later rounds.
    """
    if not pairs:
        return ()
    rounds: list[list[Pair]] = []
    used: list[set[int]] = []
    for src, dst in pairs:
        for i, srcs in enumerate(used):
            if src not in srcs:
                rounds[i].append((src, dst))
                srcs.add(src)
                break
        else:
            rounds.append([(src, dst)])
            used.append({src})
    return tuple(tuple(r) for r in rounds)


# ---------------------------------------------------------------------------
# Variant planners.  Each walks the algorithm in numpy, producing both the
# routing and the validity prediction (the robustness oracle).
# ---------------------------------------------------------------------------

def _plan_tree(p: int, death: np.ndarray) -> tuple[list[Step], np.ndarray]:
    """Paper Alg. 1 — the baseline reduction tree.  Zero redundancy."""
    n_steps = ilog2(p)
    valid = death > 0
    steps: list[Step] = []
    for s in range(n_steps):
        alive = death > s
        ok = valid & alive
        pairs: list[Pair] = []
        new_valid = np.zeros(p, dtype=bool)
        for r in range(0, p, 2 << s):
            snd, rcv = r + (1 << s), r
            pairs.append((snd, rcv))          # pattern is fault-oblivious
            new_valid[rcv] = ok[rcv] & ok[snd]
        steps.append(
            Step(s, _split_rounds(pairs), (), new_valid, np.zeros(p, bool))
        )
        valid = new_valid
    return steps, valid


def _plan_redundant(p: int, death: np.ndarray) -> tuple[list[Step], np.ndarray]:
    """Paper Alg. 2 — butterfly exchange; dependents of dead ranks go invalid."""
    n_steps = ilog2(p)
    ranks = np.arange(p)
    valid = death > 0
    steps: list[Step] = []
    for s in range(n_steps):
        buddy = ranks ^ (1 << s)
        pairs = [(int(r), int(r ^ (1 << s))) for r in range(p)]
        ok = valid & (death > s)
        new_valid = ok & ok[buddy]
        steps.append(
            Step(s, _split_rounds(pairs), (), new_valid, np.zeros(p, bool))
        )
        valid = new_valid
    return steps, valid


def _route_level(
    p: int, s: int, ok: np.ndarray
) -> tuple[list[Pair], np.ndarray]:
    """Fault-aware routing for one butterfly level (Replace, Alg. 3).

    Every live+valid rank ``r`` needs the partial value of its buddy *block*
    ``(r >> s) ^ 1``; any live+valid member of that block is a replica
    (``findReplica``).  Natural buddies pair up when both are healthy —
    in the fault-free case this reproduces the plain butterfly exactly.
    Replicas are load-balanced round-robin so the number of serial rounds
    is ``ceil(starved / live_replicas)`` per block.
    """
    pairs: list[Pair] = []
    received = np.zeros(p, dtype=bool)
    width = 1 << s
    # Group requesters by source block.
    for block_lo in range(0, p, width):
        block = block_lo >> s
        req_lo = (block ^ 1) << s
        requesters = [r for r in range(req_lo, req_lo + width) if ok[r]]
        donors = [m for m in range(block_lo, block_lo + width) if ok[m]]
        if not requesters:
            continue
        if not donors:
            continue  # starved: no copy of this block's value exists
        donor_set = set(donors)
        # Natural pairs first: r's XOR-buddy serves r when healthy.
        rest: list[int] = []
        for r in requesters:
            nat = r ^ width
            if nat in donor_set:
                pairs.append((nat, r))
                received[r] = True
            else:
                rest.append(r)
        for i, r in enumerate(rest):
            src = donors[i % len(donors)]
            pairs.append((src, r))
            received[r] = True
    return pairs, received


def _plan_replace(p: int, death: np.ndarray) -> tuple[list[Step], np.ndarray]:
    """Paper Alg. 3 — reroute to a replica of the dead buddy."""
    n_steps = ilog2(p)
    valid = death > 0
    steps: list[Step] = []
    for s in range(n_steps):
        ok = valid & (death > s)
        pairs, received = _route_level(p, s, ok)
        new_valid = ok & received
        steps.append(
            Step(s, _split_rounds(pairs), (), new_valid, np.zeros(p, bool))
        )
        valid = new_valid
    return steps, valid


def _plan_selfhealing(p: int, death: np.ndarray) -> tuple[list[Step], np.ndarray]:
    """Paper Alg. 4–6 — reroute like Replace, then respawn dead ranks from a
    replica at the end of each level (``spawnNew`` + Alg. 5 restart)."""
    n_steps = ilog2(p)
    eff_death = death.copy()          # respawn resets a rank's death to NEVER
    valid = eff_death > 0
    steps: list[Step] = []
    for s in range(n_steps):
        ok = valid & (eff_death > s)
        pairs, received = _route_level(p, s, ok)
        new_valid = ok & received
        # --- respawn: every currently-dead rank gets a fresh process whose
        # state is restored from a live replica inside its 2^(s+1) block,
        # which holds exactly the post-level-s partial value the dead rank
        # needs.
        respawned = np.zeros(p, dtype=bool)
        restore: list[Pair] = []
        width2 = 2 << s
        for blk_lo in range(0, p, width2):
            dead = [
                r for r in range(blk_lo, blk_lo + width2) if eff_death[r] <= s
            ]
            donors = [
                m for m in range(blk_lo, blk_lo + width2) if new_valid[m]
            ]
            if not dead or not donors:
                continue
            for i, r in enumerate(dead):
                restore.append((donors[i % len(donors)], r))
                respawned[r] = True
        eff_death = eff_death.copy()
        eff_death[respawned] = NEVER
        new_valid = new_valid | respawned
        steps.append(
            Step(s, _split_rounds(pairs), _split_rounds(restore), new_valid, respawned)
        )
        valid = new_valid
    return steps, valid


_PLANNERS = {
    "tree": _plan_tree,
    "redundant": _plan_redundant,
    "replace": _plan_replace,
    "selfhealing": _plan_selfhealing,
}

VARIANTS = tuple(_PLANNERS)


@functools.lru_cache(maxsize=512)
def _make_plan_cached(variant: str, n_ranks: int, spec: FaultSpec) -> Plan:
    death = spec.death_vector(n_ranks)
    n_steps = ilog2(n_ranks)
    steps, final_valid = _PLANNERS[variant](n_ranks, death)
    # Ranks that die after the last exchange but "during" the algorithm do
    # not exist in this model: death values >= n_steps mean "never".
    return Plan(
        variant=variant,
        n_ranks=n_ranks,
        n_steps=n_steps,
        death=death,
        steps=tuple(steps),
        final_valid=final_valid,
    )


def make_plan(
    variant: str,
    n_ranks: int,
    fault_spec: FaultSpec | None = None,
) -> Plan:
    """Host-plan the collective.  Memoized on ``(variant, n_ranks, spec)``:
    the panel loop requests the same fault-free plan for every collective of
    every panel, and callers key jit caches on the (shared, hashable) plan
    object."""
    if variant not in _PLANNERS:
        raise ValueError(f"unknown variant {variant!r}; choose from {VARIANTS}")
    return _make_plan_cached(variant, n_ranks, fault_spec or FaultSpec.none())
