"""Fault-tolerant butterfly collectives (the paper's machinery, generalized).

The paper's core insight — redundant computation in a communication-avoiding
butterfly buys fault tolerance — is not specific to the QR combiner: the
plan/route/validity machinery applies to any combine that is associative
over contiguous index blocks.  This package is that machinery, extracted
into one subsystem:

  * :mod:`~repro.collective.comm`      — the two execution backends
    (``SimComm`` single-device simulation, ``ShardMapComm`` SPMD/ppermute);
  * :mod:`~repro.collective.faults`    — the fail-stop fault model and the
    paper's 2^s − 1 tolerance accounting;
  * :mod:`~repro.collective.plan`      — host-side routing for the four
    variants (tree / redundant / replace / selfhealing) + wire accounting;
  * :mod:`~repro.collective.combiners` — the pluggable combine algebra
    (``qr_combine``, ``sum``, ``mean``, ``max``, ``gram_sum``, and the
    ``stacked`` family fusing several reductions under one plan);
  * :mod:`~repro.collective.engine`    — ``execute_plan`` / ``ft_allreduce``,
    the plan executor with validity threading and self-healing restores.

Consumers: :mod:`repro.core.tsqr` (QR-combiner instantiation),
:mod:`repro.optim.powersgd` (orthogonalization + Gram reductions),
:mod:`repro.checkpoint.replicated` (plan-derived buddy placement), and
:mod:`repro.runtime.trainer` (BLANK-mode gradient all-reduce).
See DESIGN.md §"Collective engine".
"""
from .combiners import (
    COMBINERS,
    Combiner,
    GramSumCombiner,
    MaxCombiner,
    MeanCombiner,
    QRCombiner,
    StackedCombiner,
    SumCombiner,
    get_combiner,
    posdiag,
    qr_r,
    stacked,
)
from .coded import (
    CodedCombiner,
    CodedPlan,
    coded_allreduce,
    coded_allreduce_jit,
    coded_weights,
    encode_parity,
    execute_coded,
    make_coded_plan,
    reconstruction_tol,
)
from .comm import Comm, ShardMapComm, SimComm
from .engine import (
    execute_plan,
    ft_allreduce,
    ft_allreduce_jit,
    plan_is_fault_free,
    recover_payload,
    replica_fetch,
)
from .faults import (
    NEVER,
    FaultSpec,
    sample_within_tolerance,
    tolerance,
    total_tolerance,
    within_tolerance,
)
from .instrument import CommStats, InstrumentedComm
from .packing import pack_sym, unpack_sym
from .plan import VARIANTS, Plan, Step, ilog2, leaf_bytes, make_plan, payload_numel

__all__ = [
    "COMBINERS",
    "CodedCombiner",
    "CodedPlan",
    "Comm",
    "CommStats",
    "Combiner",
    "FaultSpec",
    "GramSumCombiner",
    "InstrumentedComm",
    "MaxCombiner",
    "MeanCombiner",
    "NEVER",
    "Plan",
    "QRCombiner",
    "ShardMapComm",
    "SimComm",
    "StackedCombiner",
    "Step",
    "SumCombiner",
    "VARIANTS",
    "coded_allreduce",
    "coded_allreduce_jit",
    "coded_weights",
    "encode_parity",
    "execute_coded",
    "execute_plan",
    "ft_allreduce",
    "ft_allreduce_jit",
    "get_combiner",
    "ilog2",
    "leaf_bytes",
    "make_coded_plan",
    "make_plan",
    "pack_sym",
    "payload_numel",
    "plan_is_fault_free",
    "posdiag",
    "reconstruction_tol",
    "recover_payload",
    "replica_fetch",
    "stacked",
    "unpack_sym",
    "qr_r",
    "sample_within_tolerance",
    "tolerance",
    "total_tolerance",
    "within_tolerance",
]
