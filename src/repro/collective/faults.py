"""Fault model for simulated fail-stop rank failures (Coti 2015, §II/III).

The paper's ULFM failure model: a process fails (fail-stop); peers detect the
failure when a communication with it returns an error.  On TPU there is no
intra-step error return — XLA is fail-stop at slice granularity — so we model
failures as a *death vector* adjudicated at butterfly-step boundaries:

  ``death[r] = k``  means rank ``r`` fails at the ENTRY of butterfly exchange
  ``k`` (it completed exchanges ``0..k-1``, and is gone for exchange ``k``).
  ``k >= n_steps`` (canonically ``NEVER``) means the rank never fails during
  the collective.

This is exactly the granularity at which a real TPU runtime observes failures
(a device/host drops out between steps), and it is the granularity at which
the paper's own robustness accounting is stated ("no more than 1 process has
failed by the end of step 1, no more than 3 by the end of step 2, ...").

The model is combiner-agnostic: the same death vector drives the QR
butterfly of :mod:`repro.core.tsqr` and every ``ft_allreduce`` combiner in
:mod:`repro.collective.engine`.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterable, Mapping

import numpy as np

NEVER: int = 1 << 30

__all__ = [
    "NEVER",
    "FaultSpec",
    "sample_within_tolerance",
    "tolerance",
    "total_tolerance",
    "within_tolerance",
]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A set of simulated failures.

    ``deaths`` are fail-stop ``(rank, death_step)`` pairs — each rank dies at
    most once; ``death_step`` is the exchange index at whose *entry* the rank
    fails (0-based).  Two further fault kinds exist for schemes that can act
    on them (today: the coded-redundancy planner,
    :func:`repro.collective.coded.make_coded_plan`):

      * ``corrupt`` — ranks whose payload suffers silent data corruption
        (SDC): the rank participates normally and does not know it is wrong.
        The **butterfly planners ignore this field by design** — replication
        is oblivious to SDC, a corrupted replica propagates silently — which
        is exactly the blind spot checksum coding closes (Bosilca-style
        ABFT, arXiv:0806.3121): the coded plan quarantines the declared
        rank's contribution, reconstructs its true value from parity, and
        *verifies* the raw payload against the reconstruction.
      * ``slow`` — straggling ranks: alive, but their contribution would
        arrive late.  The butterfly has no choice but to await them (also
        ignored there); the coded plan excludes them from the gather and
        reconstructs their contribution from parity instead of waiting.

    The three rank sets must be pairwise disjoint (a dead rank has no
    payload to corrupt or delay).
    """

    deaths: tuple[tuple[int, int], ...] = ()
    corrupt: tuple[int, ...] = ()
    slow: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        ranks = [r for r, _ in self.deaths]
        if len(ranks) != len(set(ranks)):
            raise ValueError(f"a rank may die at most once, got {self.deaths}")
        for r, s in self.deaths:
            if r < 0 or s < 0:
                raise ValueError(f"negative rank/step in {self.deaths}")
        for kind in ("corrupt", "slow"):
            rs = getattr(self, kind)
            if len(rs) != len(set(rs)):
                raise ValueError(f"duplicate ranks in {kind}={rs}")
            if any(r < 0 for r in rs):
                raise ValueError(f"negative rank in {kind}={rs}")
        dead = set(ranks)
        overlap = (dead & set(self.corrupt)) | (dead & set(self.slow)) | (
            set(self.corrupt) & set(self.slow)
        )
        if overlap:
            raise ValueError(
                f"ranks {sorted(overlap)} appear in more than one fault kind; "
                "deaths/corrupt/slow must be disjoint"
            )

    # -- constructors ------------------------------------------------------
    @classmethod
    def of(
        cls,
        deaths: Mapping[int, int] | Iterable[tuple[int, int]] = (),
        *,
        corrupt: Iterable[int] = (),
        slow: Iterable[int] = (),
    ) -> "FaultSpec":
        """From ``{rank: step}`` or ``[(rank, step), ...]`` deaths, plus
        optional ``corrupt`` / ``slow`` rank sets."""
        if isinstance(deaths, Mapping):
            items = tuple(sorted(deaths.items()))
        else:
            items = tuple(sorted(deaths))
        return cls(items, tuple(sorted(corrupt)), tuple(sorted(slow)))

    @classmethod
    def from_events(cls, events: Mapping[int, Iterable[int]]) -> "FaultSpec":
        """From ``{step: [ranks that die at entry of that step]}``."""
        deaths: dict[int, int] = {}
        for step, ranks in events.items():
            for r in ranks:
                if r in deaths:
                    raise ValueError(f"rank {r} dies twice")
                deaths[r] = step
        return cls.of(deaths)

    @classmethod
    def none(cls) -> "FaultSpec":
        return cls(())

    # -- views -------------------------------------------------------------
    def death_vector(self, n_ranks: int) -> np.ndarray:
        """``(P,) int64``; ``NEVER`` where the rank does not die."""
        vec = np.full((n_ranks,), NEVER, dtype=np.int64)
        for r, s in self.deaths:
            if r >= n_ranks:
                raise ValueError(f"rank {r} out of range for P={n_ranks}")
            vec[r] = s
        return vec

    def cumulative_by_entry(self, step: int) -> int:
        """Number of ranks dead at the entry of exchange ``step``."""
        return sum(1 for _, s in self.deaths if s <= step)

    def new_at(self, step: int) -> int:
        return sum(1 for _, s in self.deaths if s == step)

    @property
    def n_failures(self) -> int:
        return len(self.deaths)

    def __bool__(self) -> bool:  # truthy iff any fault of any kind
        return bool(self.deaths or self.corrupt or self.slow)


# ---------------------------------------------------------------------------
# Robustness accounting (paper §III-B3 / C3 / D3)
# ---------------------------------------------------------------------------

def tolerance(variant: str, step: int) -> int:
    """Failures tolerated *at the entry of exchange ``step``* (cumulative for
    redundant/replace; per-step for selfhealing).  Paper: ``2^s - 1`` where
    ``s`` counts *completed* exchanges, i.e. at entry of exchange ``step``
    there are ``2^step`` copies of every live intermediate.
    """
    if variant == "tree":
        return 0
    if variant in ("redundant", "replace", "selfhealing"):
        return (1 << step) - 1
    raise ValueError(f"unknown variant {variant!r}")


def total_tolerance(variant: str, n_steps: int) -> int:
    """Worst-case total failures tolerated over the whole collective."""
    if variant == "tree":
        return 0
    if variant in ("redundant", "replace"):
        # Cumulative bound is binding at every prefix; the total worst case
        # is the bound at the last step: 2^(S-1) - 1.
        return (1 << (n_steps - 1)) - 1 if n_steps > 0 else 0
    if variant == "selfhealing":
        # 2^s - 1 fresh failures tolerated at each step s (respawn resets).
        return sum((1 << s) - 1 for s in range(n_steps))
    raise ValueError(f"unknown variant {variant!r}")


def within_tolerance(variant: str, spec: FaultSpec, n_steps: int) -> bool:
    """Is ``spec`` within the *guaranteed-survival* bound for ``variant``?

    A reproduction finding (EXPERIMENTS.md §Paper-validation): the paper's
    ``2^s − 1`` claim is a *data-existence* argument (2^s copies exist at
    step s).  For **Replace**/**Self-Healing**, rerouting/respawn converts
    data existence into progress, so the paper's cumulative (resp.
    per-step) bound is exactly right.  For **Redundant** — no rerouting —
    invalidity *cascades*: a rank dead at entry of exchange k invalidates
    its whole dependency coset ``d ⊕ span{2^k, ..., 2^{S-1}}`` (a 2^{-k}
    fraction of all ranks).  The paper's bound holds when all failures
    strike at one step; across steps the tight sufficient condition is the
    union-bound measure  Σ_k n_k · 2^{-k} < 1  (n_k = failures at entry of
    exchange k), which reduces to 2^s − 1 in the single-step case.
    """
    if variant == "tree":
        return spec.n_failures == 0
    if variant == "redundant":
        measure = sum(2.0 ** (-s) for _, s in spec.deaths if s < n_steps)
        return measure < 1.0
    if variant == "replace":
        return all(
            spec.cumulative_by_entry(s) <= tolerance(variant, s)
            for s in range(n_steps)
        )
    if variant == "selfhealing":
        return all(spec.new_at(s) <= tolerance(variant, s) for s in range(n_steps))
    raise ValueError(f"unknown variant {variant!r}")


def sample_within_tolerance(
    variant: str, n_ranks: int, n_steps: int, rng: np.random.Generator
) -> FaultSpec:
    """One random single-rank fail-stop death guaranteed within ``variant``'s
    survival bound — the serving layer's mid-flight fault injector draws from
    this so every injected death is *recoverable* (a batch whose fault
    exceeded tolerance could not be re-served from replicas at all).

    For ``redundant`` the union-bound measure ``2^{-s} < 1`` forces the death
    to strike at exchange entry ``s ≥ 1`` (at entry of exchange 0 only one
    copy of each local factor exists); ``replace``/``selfhealing`` tolerate
    ``2^s − 1 ≥ 1`` deaths from step 1 as well.  ``tree`` tolerates nothing —
    asking for a tolerable death is a caller error.
    """
    if variant == "tree":
        raise ValueError(
            "variant 'tree' has zero fault tolerance; there is no "
            "within-tolerance death to sample"
        )
    if n_steps < 2:
        raise ValueError(
            f"n_steps={n_steps}: a single-exchange butterfly has no step "
            "with a replica to recover from (need P >= 4)"
        )
    rank = int(rng.integers(0, n_ranks))
    step = int(rng.integers(1, n_steps))
    spec = FaultSpec.of({rank: step})
    assert within_tolerance(variant, spec, n_steps)
    return spec
