"""Benchmark runner: execute registered cases, time them, emit the document.

``run_cases`` resolves each case's tier parameters, performs ``warmup``
discarded calls plus ``repeats`` timed calls, folds the percentile timing
summary into the case's metrics as warn-gated ``time_*`` entries, and
writes a schema-validated ``BENCH_<UTC timestamp>.json`` stamped with the
git SHA, jax version and backend.  Case outcomes:

* returns metrics          → ``status: ok``
* raises ``SkipCase``      → ``status: skipped`` (never fails the run)
* raises ``BenchFailure``  → ``status: error`` **and** the run exits
  non-zero — measured-invariant violations are loud
* any other exception      → ``status: error`` + non-zero exit
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from . import schema
from .registry import BenchCase, BenchFailure, SkipCase, cases_for

__all__ = ["git_sha", "run_cases", "write_doc"]


def git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except OSError:
        return None


def _environment(tier: str) -> dict:
    import platform

    import jax

    return {
        "schema_version": schema.SCHEMA_VERSION,
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "n_devices": jax.device_count(),
        "tier": tier,
    }


def _timing_metrics(samples_s: list[float]) -> dict:
    us = np.asarray(samples_s) * 1e6
    out = {
        "time_mean_us": float(us.mean()),
        "time_p50_us": float(np.percentile(us, 50)),
        "time_p90_us": float(np.percentile(us, 90)),
        "time_min_us": float(us.min()),
    }
    return {
        k: schema.Metric(v, gate="warn", direction="lower", unit="us")
        for k, v in out.items()
    }


def _run_one(case: BenchCase, tier: str, verbose: bool = True) -> dict:
    kwargs = case.kwargs(tier)
    entry: dict = {"params": kwargs}
    if verbose:
        print(f"[bench] {case.name} "
              f"({', '.join(f'{k}={v}' for k, v in kwargs.items()) or 'no params'})",
              flush=True)
    try:
        for _ in range(case.warmup):
            case.fn(**kwargs)
        samples, result = [], None
        for _ in range(case.repeats):
            t0 = time.perf_counter()
            result = case.fn(**kwargs)
            samples.append(time.perf_counter() - t0)
    except SkipCase as e:
        entry.update(status="skipped", skip_reason=str(e) or "skipped")
        if verbose:
            print(f"[bench]   skipped: {e}", flush=True)
        return entry
    except BenchFailure as e:
        entry.update(status="error", error=f"invariant violated: {e}")
        print(f"[bench]   FAILED: {e}", file=sys.stderr, flush=True)
        return entry
    except Exception as e:  # noqa: BLE001 — recorded, fails the run
        entry.update(status="error", error=f"{type(e).__name__}: {e}")
        print(f"[bench]   ERROR: {entry['error']}", file=sys.stderr, flush=True)
        return entry
    metrics = {name: schema.metric_to_json(m) for name, m in dict(result).items()}
    metrics.update(
        {k: schema.metric_to_json(m) for k, m in _timing_metrics(samples).items()}
    )
    entry.update(status="ok", metrics=metrics)
    if verbose:
        print(f"[bench]   ok: {len(metrics)} metrics, "
              f"mean {np.mean(samples) * 1e3:.1f} ms over {case.repeats} "
              f"repeat(s)", flush=True)
    return entry


def run_cases(
    tier: str,
    *,
    only: tuple[str, ...] | None = None,
    registry=None,
    verbose: bool = True,
) -> dict:
    """Run all cases for ``tier``; return the (validated) document."""
    cases = cases_for(tier, only=only, registry=registry)
    if not cases:
        raise ValueError(f"no bench cases registered for tier {tier!r}")
    doc = _environment(tier)
    doc["cases"] = {c.name: _run_one(c, tier, verbose=verbose) for c in cases}
    return schema.validate(doc)


def write_doc(doc: dict, *, out: str | None = None,
              out_dir: str = "results/bench") -> str:
    """Write ``doc`` to ``out`` or ``out_dir/BENCH_<timestamp>.json``."""
    if out is None:
        stamp = time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
        out = os.path.join(out_dir, f"BENCH_{stamp}.json")
    parent = os.path.dirname(out)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return out
