"""Baseline comparison and regression gating.

``python -m repro.bench compare baseline.json new.json [--tolerance 0.05]``
exits non-zero when a **hard**-gated metric regresses:

* the two documents are different tiers, or a case's parameters changed —
  the verdicts would be apples-to-oranges, so the comparison refuses and
  asks for a deliberate baseline refresh;
* a case present (and ``ok``) in the baseline is missing, skipped or
  errored in the new run — coverage regression;
* a hard metric disappears;
* a hard metric moves the wrong way past the tolerance:
  ``direction: higher`` → regression when ``new < old·(1−tol)``;
  ``direction: lower``  → regression when ``new > old·(1+tol)``;
  ``direction: exact``  → ints/bools must be equal, floats must agree to
  the relative tolerance.

Warn-gated metrics (timings on shared runners) use ``--timing-tolerance``
and only print warnings, unless ``--strict-timing`` promotes them.  A
per-metric ``tolerance`` recorded in the document overrides the CLI value.
Improvements and metrics new in the new run are reported as notes.
"""
from __future__ import annotations

import dataclasses
import json
import math

from . import schema

__all__ = ["Comparison", "compare_docs", "compare_files", "load"]


@dataclasses.dataclass
class Comparison:
    failures: list[str] = dataclasses.field(default_factory=list)
    warnings: list[str] = dataclasses.field(default_factory=list)
    notes: list[str] = dataclasses.field(default_factory=list)

    def exit_code(self, strict_timing: bool = False) -> int:
        if self.failures:
            return 1
        if strict_timing and self.warnings:
            return 1
        return 0

    def report(self) -> str:
        lines = []
        for f in self.failures:
            lines.append(f"FAIL  {f}")
        for w in self.warnings:
            lines.append(f"WARN  {w}")
        for n in self.notes:
            lines.append(f"note  {n}")
        if not self.failures:
            lines.append(
                "OK    no hard regressions"
                + (f" ({len(self.warnings)} warning(s))" if self.warnings else "")
            )
        return "\n".join(lines)


def load(path: str) -> dict:
    with open(path) as f:
        return schema.validate(json.load(f))


def _is_exact_kind(v) -> bool:
    return isinstance(v, bool) or (
        isinstance(v, (int, float)) and float(v).is_integer()
    )


def _regressed(old, new, direction: str, tol: float) -> bool:
    if isinstance(old, bool) or isinstance(new, bool):
        return bool(old) != bool(new)
    old, new = float(old), float(new)
    if not math.isfinite(new):
        return True
    scale = max(abs(old), 1e-12)
    if direction == "higher":
        return new < old - tol * scale
    if direction == "lower":
        return new > old + tol * scale
    # exact: integral values must match exactly; floats to tolerance
    if _is_exact_kind(old) and _is_exact_kind(new):
        return old != new
    return abs(new - old) > tol * scale


def compare_docs(
    old: dict,
    new: dict,
    *,
    tolerance: float = 0.05,
    timing_tolerance: float = 0.50,
) -> Comparison:
    cmp = Comparison()
    if old.get("jax_version") != new.get("jax_version"):
        cmp.notes.append(
            f"jax {old.get('jax_version')} → {new.get('jax_version')}"
        )
    if old.get("tier") != new.get("tier"):
        # different tiers run different parameters: every hard verdict
        # below would be apples-to-oranges, so refuse up front
        cmp.failures.append(
            f"tier mismatch: baseline is {old.get('tier')!r}, new run is "
            f"{new.get('tier')!r} — compare runs of the same tier"
        )
        return cmp
    for cname, ocase in old["cases"].items():
        ncase = new["cases"].get(cname)
        path = f"case {cname}"
        if ncase is None:
            if ocase["status"] == "ok":
                cmp.failures.append(f"{path}: present in baseline, missing now")
            else:
                cmp.notes.append(f"{path}: non-ok in baseline, missing now")
            continue
        if ocase["status"] != "ok":
            if ocase["status"] == "skipped" and ncase["status"] == "ok":
                cmp.notes.append(f"{path}: newly running (was skipped)")
            continue
        if ncase["status"] != "ok":
            detail = ncase.get("skip_reason") or ncase.get("error") or ""
            cmp.failures.append(
                f"{path}: was ok, now {ncase['status']} ({detail})"
            )
            continue
        if ocase.get("params") != ncase.get("params"):
            # metrics were measured under different knobs — a stale
            # baseline, not a regression; demand a deliberate refresh
            cmp.failures.append(
                f"{path}: params changed {ocase.get('params')} → "
                f"{ncase.get('params')} — refresh benchmarks/baseline.json"
            )
            continue
        ometrics, nmetrics = ocase.get("metrics", {}), ncase.get("metrics", {})
        for mname, om in ometrics.items():
            mpath = f"{cname}.{mname}"
            nm = nmetrics.get(mname)
            hard = om["gate"] == "hard"
            if nm is None:
                (cmp.failures if hard else cmp.warnings).append(
                    f"{mpath}: metric missing"
                )
                continue
            tol = om.get("tolerance")
            if tol is None:
                tol = tolerance if hard else timing_tolerance
            if _regressed(om["value"], nm["value"], om["direction"], tol):
                msg = (f"{mpath}: {om['value']} → {nm['value']} "
                       f"(direction={om['direction']}, tol={tol:g})")
                (cmp.failures if hard else cmp.warnings).append(msg)
        for mname in nmetrics.keys() - ometrics.keys():
            cmp.notes.append(f"{cname}.{mname}: new metric")
    for cname in new["cases"].keys() - old["cases"].keys():
        cmp.notes.append(f"case {cname}: new case (no baseline)")
    return cmp


def compare_files(old_path: str, new_path: str, **kw) -> Comparison:
    return compare_docs(load(old_path), load(new_path), **kw)
