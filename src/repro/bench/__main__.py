"""``python -m repro.bench`` — run / compare / list.

``run`` forces a multi-device host platform (default 8 simulated CPU
devices via ``XLA_FLAGS``) *before* jax is imported, so the trainer-level
fault scenarios (SHRINK / REBUILD / BLANK over a real data axis) execute
against a genuine multi-replica mesh even on a laptop.  ``compare`` and
``list`` never import jax.
"""
from __future__ import annotations

import argparse
import os
import sys

__all__ = ["main"]

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def _force_devices(n: int) -> None:
    if n <= 0:
        return
    if "jax" in sys.modules:
        # too late to change the platform; scenarios will skip if starved
        print(f"[bench] jax already imported; cannot force {n} host devices",
              file=sys.stderr)
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICE_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={n}".strip()


def _cmd_run(args) -> int:
    _force_devices(args.devices)
    # imports deferred until after the device-count env var is set
    from . import cases  # noqa: F401  — registers the benchmark cases
    from . import runner

    doc = runner.run_cases(args.tier, only=tuple(args.only) or None)
    path = runner.write_doc(doc, out=args.out, out_dir=args.out_dir)
    bad = {n: c for n, c in doc["cases"].items() if c["status"] == "error"}
    print(f"[bench] wrote {path}")
    if bad:
        for n, c in bad.items():
            print(f"[bench] case {n} errored: {c['error']}", file=sys.stderr)
        return 1
    return 0


def _cmd_compare(args) -> int:
    from . import compare

    cmp = compare.compare_files(
        args.baseline, args.new,
        tolerance=args.tolerance, timing_tolerance=args.timing_tolerance,
    )
    print(cmp.report())
    return cmp.exit_code(strict_timing=args.strict_timing)


def _cmd_list(args) -> int:
    from . import cases  # noqa: F401
    from .registry import REGISTRY

    for c in sorted(REGISTRY.values(), key=lambda c: c.name):
        tags = f" [{','.join(c.tags)}]" if c.tags else ""
        print(f"{c.name:<18} tiers={','.join(c.tiers)}{tags}")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="machine-readable benchmarks + fault-scenario sweeps",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("run", help="run registered cases, write BENCH_*.json")
    rp.add_argument("--tier", default="smoke", choices=("smoke", "full"))
    rp.add_argument("--only", nargs="*", default=(),
                    help="run only these case names")
    rp.add_argument("--out", default=None,
                    help="explicit output path (default: timestamped)")
    rp.add_argument("--out-dir", default="results/bench")
    rp.add_argument("--devices", type=int, default=8,
                    help="forced host device count for trainer scenarios "
                         "(0 = leave XLA_FLAGS alone)")
    rp.set_defaults(fn=_cmd_run)

    cp = sub.add_parser("compare", help="gate a new run against a baseline")
    cp.add_argument("baseline")
    cp.add_argument("new")
    cp.add_argument("--tolerance", type=float, default=0.05,
                    help="relative tolerance for hard metrics")
    cp.add_argument("--timing-tolerance", type=float, default=0.50,
                    help="relative tolerance for warn (timing) metrics")
    cp.add_argument("--strict-timing", action="store_true",
                    help="promote timing warnings to failures")
    cp.set_defaults(fn=_cmd_compare)

    lp = sub.add_parser("list", help="list registered cases")
    lp.set_defaults(fn=_cmd_list)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
