"""Machine-readable benchmark + fault-scenario subsystem (``repro.bench``).

The paper's claims are quantitative — how many failures each semantics
tolerates and at what communication cost — so benchmarks and
fault-injection sweeps are first-class, reproducible artifacts here:

  * :mod:`~repro.bench.registry`  — decorator-registered cases with tiers,
    tags and per-tier parameters;
  * :mod:`~repro.bench.runner`    — warmup/repeat/percentile timing,
    writes versioned ``BENCH_<timestamp>.json`` documents;
  * :mod:`~repro.bench.schema`    — the document schema + gate metadata
    (``hard`` robustness/comm metrics vs ``warn`` timings);
  * :mod:`~repro.bench.compare`   — baseline comparator; exits non-zero on
    hard-metric regression (the CI gate);
  * :mod:`~repro.bench.scenarios` — declarative fault schedules driving
    ``ft_allreduce``/``execute_plan`` and the trainer's
    SHRINK/REBUILD/BLANK paths;
  * :mod:`~repro.bench.cases`     — the migrated ``benchmarks/*`` cases.

CLI: ``python -m repro.bench run --tier smoke``, ``... compare old new``,
``... list``.  See DESIGN.md §5 and README.md.

This module intentionally imports neither jax nor the case modules —
``compare`` must work in a bare environment and ``run`` must be able to
set ``XLA_FLAGS`` before jax loads.
"""
from .registry import REGISTRY, BenchFailure, SkipCase, bench_case, cases_for
from .schema import SCHEMA_VERSION, Metric, SchemaError, validate

__all__ = [
    "REGISTRY",
    "BenchFailure",
    "Metric",
    "SCHEMA_VERSION",
    "SchemaError",
    "SkipCase",
    "bench_case",
    "cases_for",
    "validate",
]
