"""Versioned machine-readable benchmark document (``BENCH_<timestamp>.json``).

Every run of ``python -m repro.bench run`` writes one document:

.. code-block:: json

    {
      "schema_version": 1,
      "created": "2026-07-27T12:34:56Z",
      "git_sha": "59d2844",            // null outside a git checkout
      "jax_version": "0.4.37",
      "backend": "cpu",                // jax.default_backend()
      "platform": "Linux-...",
      "python": "3.10.12",
      "n_devices": 8,
      "tier": "smoke",
      "cases": {
        "<case>": {
          "status": "ok" | "skipped" | "error",
          "params": {...},             // the tier's kwargs, as run
          "skip_reason": "...",        // skipped only
          "error": "...",              // error only
          "metrics": {
            "<metric>": {
              "value": 42,             // number or bool
              "gate": "hard" | "warn", // regression policy (see compare)
              "direction": "higher" | "lower" | "exact",
              "unit": "us",            // optional, informational
              "tolerance": 0.05        // optional per-metric rel. override
            }
          }
        }
      }
    }

Gate policy (enforced by :mod:`repro.bench.compare`): ``hard`` metrics —
robustness counts, comm volume, tolerated-failure numbers — fail the
comparison on regression; ``warn`` metrics — wall-clock timings on shared
CI runners — only print a warning unless ``--strict-timing``.  Direction
``exact`` means the value is deterministic (message counts, survivor
counts, booleans) and must match the baseline (to within the float
tolerance for non-integral values).

The schema is validated on write and on compare, so a malformed producer
fails its own CI run rather than poisoning the baseline.
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Any

__all__ = ["SCHEMA_VERSION", "Metric", "SchemaError", "metric_to_json", "validate"]

SCHEMA_VERSION = 1

_STATUSES = ("ok", "skipped", "error")
_GATES = ("hard", "warn")
_DIRECTIONS = ("higher", "lower", "exact")


class SchemaError(ValueError):
    """A benchmark document that does not conform to the schema."""


@dataclasses.dataclass(frozen=True)
class Metric:
    """One gated measurement.

    Bare numbers returned by a case are wrapped as informational
    ``Metric(value, gate="warn", direction="exact")`` by the runner; cases
    that want hard gating construct :class:`Metric` explicitly.
    """

    value: float | int | bool
    gate: str = "hard"          # "hard" | "warn"
    direction: str = "exact"    # "higher" | "lower" | "exact"
    unit: str = ""
    tolerance: float | None = None   # per-metric relative tolerance override

    def __post_init__(self):
        if self.gate not in _GATES:
            raise SchemaError(f"bad gate {self.gate!r}")
        if self.direction not in _DIRECTIONS:
            raise SchemaError(f"bad direction {self.direction!r}")


def metric_to_json(m: "Metric | float | int | bool") -> dict:
    if not isinstance(m, Metric):
        m = Metric(m, gate="warn", direction="exact")
    out: dict[str, Any] = {
        "value": bool(m.value) if isinstance(m.value, (bool,)) else m.value,
        "gate": m.gate,
        "direction": m.direction,
    }
    if m.unit:
        out["unit"] = m.unit
    if m.tolerance is not None:
        out["tolerance"] = float(m.tolerance)
    return out


def _fail(path: str, msg: str):
    raise SchemaError(f"{path}: {msg}")


def _check_metric(path: str, m: Any):
    if not isinstance(m, dict):
        _fail(path, "metric must be an object")
    v = m.get("value")
    if not isinstance(v, (bool, numbers.Real)):
        _fail(path, f"value must be a number or bool, got {type(v).__name__}")
    if m.get("gate") not in _GATES:
        _fail(path, f"gate must be one of {_GATES}, got {m.get('gate')!r}")
    if m.get("direction") not in _DIRECTIONS:
        _fail(path, f"direction must be one of {_DIRECTIONS}")
    tol = m.get("tolerance")
    if tol is not None and not (isinstance(tol, numbers.Real) and tol >= 0):
        _fail(path, "tolerance must be a non-negative number")
    extra = set(m) - {"value", "gate", "direction", "unit", "tolerance"}
    if extra:
        _fail(path, f"unknown metric keys {sorted(extra)}")


def validate(doc: dict) -> dict:
    """Validate ``doc`` against the schema; returns it unchanged."""
    if not isinstance(doc, dict):
        raise SchemaError("document must be an object")
    if doc.get("schema_version") != SCHEMA_VERSION:
        _fail("schema_version",
              f"expected {SCHEMA_VERSION}, got {doc.get('schema_version')!r}")
    for key in ("created", "jax_version", "backend", "tier"):
        if not isinstance(doc.get(key), str) or not doc[key]:
            _fail(key, "required non-empty string")
    if doc.get("git_sha") is not None and not isinstance(doc["git_sha"], str):
        _fail("git_sha", "must be a string or null")
    if not isinstance(doc.get("n_devices"), int) or doc["n_devices"] < 1:
        _fail("n_devices", "must be a positive int")
    cases = doc.get("cases")
    if not isinstance(cases, dict) or not cases:
        _fail("cases", "must be a non-empty object")
    for name, case in cases.items():
        path = f"cases.{name}"
        if not isinstance(case, dict):
            _fail(path, "case must be an object")
        status = case.get("status")
        if status not in _STATUSES:
            _fail(path, f"status must be one of {_STATUSES}, got {status!r}")
        if status == "skipped" and not case.get("skip_reason"):
            _fail(path, "skipped case needs a skip_reason")
        if status == "error" and not case.get("error"):
            _fail(path, "errored case needs an error message")
        metrics = case.get("metrics", {})
        if not isinstance(metrics, dict):
            _fail(path, "metrics must be an object")
        if status == "ok" and not metrics:
            _fail(path, "ok case must report at least one metric")
        for mname, m in metrics.items():
            _check_metric(f"{path}.metrics.{mname}", m)
    return doc
