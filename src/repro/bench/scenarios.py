"""Declarative fault-scenario engine (DESIGN.md §5).

Scenario diversity beyond single-round Monte-Carlo: a scenario is a small
declarative schedule — which rank/replica fails at which butterfly step or
training step — executed deterministically and distilled into hard-gated
metrics.  Two scenario kinds:

* :class:`CollectiveScenario` — a sequence of :class:`ReduceRound`\\ s,
  each one ``ft_allreduce`` invocation over a
  :class:`~repro.collective.comm.SimComm` with (a) *masked* replicas
  (BLANK semantics: the rank participates but its contribution is zeroed)
  and (b) mid-reduce *deaths* (``{rank: butterfly_step}``, the paper's
  fail-stop model).  Survivor values are checked against the dense
  reduction of the masked inputs, and comm volume is measured through
  :class:`~repro.collective.instrument.InstrumentedComm`.

* :class:`TrainerScenario` — a :class:`~repro.runtime.trainer.FaultEvent`
  schedule driven through a real (tiny) :class:`Trainer` on a
  ``(data, model)`` mesh, exercising the SHRINK / REBUILD / BLANK
  semantics end to end; assertions read the trainer's structured
  ``fault_stats`` counters.  Needs enough (simulated) devices — the bench
  CLI forces 8 host devices; under-provisioned environments skip.

* :class:`BlockedQRScenario` — a :class:`~repro.qr.blocked.
  PanelFaultSchedule` driven through the general-matrix blocked QR
  (:mod:`repro.qr.blocked`): deaths during a panel's TSQR reduction or its
  trailing-update (W) butterfly, evaluated per panel against the variant's
  guarantee, with the one-trailing-sweep-per-panel HBM model measured
  through :mod:`repro.kernels.traffic`.

The stock :data:`SCENARIOS` sweep covers the scenario families the
single-round Monte-Carlo misses: **correlated** block wipes, **cascading**
step-after-step failures, **fail-during-rebuild** (a second failure while
the first rollback is still replaying), **BLANK-under-repeat** (masking +
mid-reduce faults across repeated reductions), and the per-panel blocked-QR
families (**death during panel k**, **death during the trailing update**,
**cascading panels**).
"""
from __future__ import annotations

import dataclasses
import tempfile
from collections.abc import Mapping

import numpy as np

from repro.bench.registry import BenchFailure, SkipCase, bench_case
from repro.bench.schema import Metric

__all__ = [
    "BlockedQRScenario",
    "CollectiveScenario",
    "ReduceRound",
    "TrainerScenario",
    "case",
    "get_scenarios",
    "run_blocked_qr_scenario",
    "run_collective_scenario",
    "run_scenario",
    "run_trainer_scenario",
]


# ---------------------------------------------------------------------------
# Scenario formats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReduceRound:
    """One all-reduce invocation inside a repeated-reduction scenario.

    ``corrupt`` / ``slow`` are only actionable under the coded scheme
    (``CollectiveScenario.scheme="coded"``): corrupted ranks have their
    *observed* payload silently perturbed (the rank does not know), and
    straggling ranks are excluded from the gather — both contributions are
    reconstructed from parity, and corruptions are flagged by checksum
    verification.  The butterfly planners ignore both fields by design.
    """

    deaths: tuple[tuple[int, int], ...] = ()   # (rank, butterfly step)
    masked: tuple[int, ...] = ()               # BLANK-masked replicas
    corrupt: tuple[int, ...] = ()              # silent data corruption (SDC)
    slow: tuple[int, ...] = ()                 # stragglers


@dataclasses.dataclass(frozen=True)
class CollectiveScenario:
    name: str
    p: int
    variant: str
    rounds: tuple[ReduceRound, ...] = (ReduceRound(),)
    op: str = "sum"
    scheme: str = "butterfly"                  # "butterfly" | "coded"
    parity: int = 2                            # checksum ranks (coded only)
    description: str = ""

    kind = "collective"


@dataclasses.dataclass(frozen=True)
class TrainerScenario:
    name: str
    on_failure: str                      # blank | shrink | rebuild
    events: tuple = ()                   # FaultEvent schedule
    data_width: int = 4
    model_width: int = 1
    steps: int = 8
    ckpt_every: int = 3
    buddy_levels: int = 1
    arch: str = "olmo-1b"                # any configs/ registry name
    optimizer: str = "adamw"             # adamw | powersgd | orthosgd | lowrank
    n_layers: int = 2
    expect: Mapping[str, int] = dataclasses.field(default_factory=dict)
    description: str = ""

    kind = "trainer"


@dataclasses.dataclass(frozen=True)
class BlockedQRScenario:
    """Deaths scheduled into a general-matrix blocked QR.

    ``panel_deaths`` / ``update_deaths`` map panel index →
    ``((rank, butterfly_step), …)`` for that panel's TSQR reduction (phase
    1) resp. its trailing-update W butterfly (phase 3).
    """

    name: str
    p: int
    variant: str
    m_local: int = 64
    n: int = 24
    panel_width: int = 8
    panel_deaths: tuple[tuple[int, tuple[tuple[int, int], ...]], ...] = ()
    update_deaths: tuple[tuple[int, tuple[tuple[int, int], ...]], ...] = ()
    description: str = ""

    kind = "blocked"


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------

def _run_coded_scenario(sc: CollectiveScenario, seed: int = 0) -> dict:
    """Coded-scheme executor: deaths, stragglers, and *injected* silent
    corruption (the observed payload is perturbed; parity still encodes the
    distribution-time truth) per round, with checksum-detection and
    wire-accounting hard gates."""
    import jax.numpy as jnp

    from repro.collective import (
        FaultSpec,
        InstrumentedComm,
        SimComm,
        coded_allreduce,
        make_coded_plan,
        reconstruction_tol,
    )

    rng = np.random.default_rng(seed)
    comm = InstrumentedComm(SimComm(sc.p + sc.parity))
    metrics: dict[str, Metric] = {}
    all_match = True
    all_survived = True
    all_detected = True
    honest = True
    expect_msgs = expect_bytes = 0
    for i, rnd in enumerate(sc.rounds):
        spec = FaultSpec.of(
            dict(rnd.deaths), corrupt=rnd.corrupt, slow=rnd.slow
        )
        plan = make_coded_plan(sc.p, sc.parity, spec)
        x = rng.normal(size=(sc.p, 4, 4)).astype(np.float32)
        x[list(rnd.masked)] = 0.0                  # BLANK: zero contribution
        observed = x.copy()
        observed[list(rnd.corrupt)] *= 3.0         # inject the SDC
        val, valid, det = coded_allreduce(
            jnp.asarray(x), comm, op=sc.op, plan=plan,
            observed=jnp.asarray(observed),
        )
        valid = np.asarray(valid)[: sc.p]
        det = np.asarray(det)[: sc.p]
        expect = x.sum(0)      # truth: erased contributions reconstructed
        tol = reconstruction_tol(np.float32)
        holders = np.nonzero(valid)[0]
        match = bool(holders.size) and all(
            np.allclose(np.asarray(val)[r], expect, rtol=tol, atol=tol)
            for r in holders
        )
        in_tol = plan.recoverable
        metrics[f"round{i}_survivors"] = Metric(
            int(valid.sum()), gate="hard", direction="exact"
        )
        metrics[f"round{i}_within_tolerance"] = Metric(
            in_tol, gate="hard", direction="exact"
        )
        if in_tol:                                 # guarantee applies
            all_match &= match
            all_survived &= bool(valid.any())
            all_detected &= bool(
                (np.flatnonzero(det) == np.asarray(rnd.corrupt)).all()
            )
        else:                                      # honest degradation
            honest &= not valid.any() and not match
        expect_msgs += plan.message_count()
        expect_bytes += plan.bytes_on_wire(4, 4)
    metrics["values_match"] = Metric(all_match, gate="hard", direction="exact")
    metrics["survived"] = Metric(all_survived, gate="hard", direction="exact")
    metrics["corruption_detected"] = Metric(
        all_detected, gate="hard", direction="exact"
    )
    metrics["honest_degradation"] = Metric(
        honest, gate="hard", direction="exact"
    )
    metrics["messages"] = Metric(
        comm.stats.messages, gate="hard", direction="exact"
    )
    metrics["wire_matches_plan"] = Metric(
        comm.stats.messages == expect_msgs
        and comm.stats.payload_bytes == expect_bytes,
        gate="hard", direction="exact",
    )
    metrics["payload_bytes"] = Metric(
        comm.stats.payload_bytes, gate="hard", direction="exact", unit="B"
    )
    return metrics


def run_collective_scenario(sc: CollectiveScenario, seed: int = 0) -> dict:
    """Execute every round; return metric dict (unprefixed names)."""
    import jax.numpy as jnp

    from repro.collective import (
        FaultSpec,
        InstrumentedComm,
        SimComm,
        ft_allreduce,
        ilog2,
        make_plan,
        within_tolerance,
    )

    if sc.scheme == "coded":
        return _run_coded_scenario(sc, seed)
    if any(rnd.corrupt or rnd.slow for rnd in sc.rounds):
        raise ValueError(
            f"scenario {sc.name}: corrupt/slow rounds need scheme='coded' "
            "(the butterfly planners ignore both fault kinds by design)"
        )
    rng = np.random.default_rng(seed)
    comm = InstrumentedComm(SimComm(sc.p))
    n_steps = ilog2(sc.p)
    metrics: dict[str, Metric] = {}
    all_match = True
    all_survived = True
    for i, rnd in enumerate(sc.rounds):
        spec = FaultSpec.of(dict(rnd.deaths))
        plan = make_plan(sc.variant, sc.p, spec)
        x = rng.normal(size=(sc.p, 4, 4)).astype(np.float32)
        x[list(rnd.masked)] = 0.0                      # BLANK: zero contribution
        val, valid = ft_allreduce(jnp.asarray(x), comm, op=sc.op, plan=plan)
        valid = np.asarray(valid)
        expect = x.sum(0)                              # full reduction over P
        holders = np.nonzero(valid)[0]
        match = bool(holders.size) and all(
            np.allclose(np.asarray(val)[r], expect, rtol=1e-5, atol=1e-5)
            for r in holders
        )
        in_tol = within_tolerance(sc.variant, spec, n_steps)
        metrics[f"round{i}_survivors"] = Metric(
            int(valid.sum()), gate="hard", direction="exact"
        )
        if in_tol:                                     # guarantee applies
            all_match &= match
            all_survived &= bool(valid.any())
        metrics[f"round{i}_within_tolerance"] = Metric(
            in_tol, gate="hard", direction="exact"
        )
    metrics["values_match"] = Metric(all_match, gate="hard", direction="exact")
    metrics["survived"] = Metric(all_survived, gate="hard", direction="exact")
    metrics["messages"] = Metric(
        comm.stats.messages, gate="hard", direction="exact"
    )
    metrics["comm_rounds"] = Metric(
        comm.stats.rounds, gate="hard", direction="exact"
    )
    metrics["payload_bytes"] = Metric(
        comm.stats.payload_bytes, gate="hard", direction="exact", unit="B"
    )
    return metrics


def run_blocked_qr_scenario(sc: BlockedQRScenario, seed: int = 0) -> dict:
    """Run the blocked QR under the death schedule; metric dict.

    Hard-gates: survivors match the host prediction, every strict
    survivor's R equals the dense oracle whenever the schedule is within
    the variant's per-panel tolerance, and the trailing block is swept
    exactly once per panel (the fused-pipeline HBM claim).
    """
    import jax.numpy as jnp

    from repro.kernels import traffic
    from repro.qr import PanelFaultSchedule, QRConfig, factorize

    rng = np.random.default_rng(seed)
    blocks = rng.standard_normal((sc.p, sc.m_local, sc.n)).astype(np.float32)
    sched = PanelFaultSchedule.of(
        panel={k: dict(deaths) for k, deaths in sc.panel_deaths},
        update={k: dict(deaths) for k, deaths in sc.update_deaths},
    )
    with traffic.track_traffic() as t:
        res = factorize(
            jnp.asarray(blocks),
            QRConfig(panel_width=sc.panel_width, variant=sc.variant),
            faults=sched,
        )
    in_tol = all(rep.within_tolerance for rep in res.reports)
    valid = np.asarray(res.valid)
    expect = np.ones(sc.p, dtype=bool)
    for rep in res.reports:
        expect &= rep.plan_r.final_valid
        if rep.plan_w is not None:
            expect &= rep.plan_w.final_valid
    from repro.core import ref

    truth = ref.qr_r(blocks.reshape(-1, sc.n).astype(np.float64))
    scale = max(1.0, np.abs(truth).max())
    holders = np.flatnonzero(valid)
    match = bool(holders.size) and all(
        np.abs(np.asarray(res.r)[r] - truth).max() / scale < 5e-4
        for r in holders
    )
    if in_tol and not match:
        raise BenchFailure(
            f"scenario {sc.name}: within-tolerance schedule but survivor R "
            "does not match the dense QR"
        )
    sweeps = t.sweeps_of("panel_cross", "trailing_update")
    if sweeps != res.n_panels:
        raise BenchFailure(
            f"scenario {sc.name}: {sweeps} trailing-block sweeps for "
            f"{res.n_panels} panels — the 1-sweep-per-panel claim failed"
        )
    return {
        "survivors": Metric(int(valid.sum()), gate="hard", direction="exact"),
        "survivors_match_plan": Metric(
            bool((valid == expect).all()), gate="hard", direction="exact"
        ),
        "within_tolerance": Metric(in_tol, gate="hard", direction="exact"),
        "values_match": Metric(match, gate="hard", direction="exact"),
        "recovered": Metric(
            sum(rep.recovered_r + rep.recovered_w for rep in res.reports),
            gate="hard", direction="exact",
        ),
        "n_panels": Metric(res.n_panels, gate="hard", direction="exact"),
        "trailing_sweeps": Metric(sweeps, gate="hard", direction="exact"),
        "sweeps_per_panel": Metric(
            sweeps / res.n_panels, gate="hard", direction="exact"
        ),
    }


def run_trainer_scenario(sc: TrainerScenario, ckpt_dir: str | None = None) -> dict:
    """Drive a tiny Trainer through the event schedule; metric dict.

    Raises :class:`~repro.bench.registry.SkipCase` when the host has too
    few devices — anything else (I/O errors included) propagates and fails
    the run loudly.
    """
    import jax

    n_needed = sc.data_width * sc.model_width
    if jax.device_count() < n_needed:
        raise SkipCase(
            f"needs {n_needed} devices, have {jax.device_count()} "
            "(run via `python -m repro.bench run`, which forces 8)"
        )
    from repro.compat import make_mesh
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(sc.arch).smoke(n_layers=sc.n_layers)
    mesh = make_mesh((sc.data_width, sc.model_width), ("data", "model"))
    own_dir = ckpt_dir is None
    ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix=f"bench_{sc.name}_")
    tcfg = TrainerConfig(
        steps=sc.steps, log_every=10**9, ckpt_every=sc.ckpt_every,
        ckpt_dir=ckpt_dir, optimizer=sc.optimizer,
        on_failure=sc.on_failure, buddy_levels=sc.buddy_levels, seed=0,
    )
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=32, global_batch=2 * sc.data_width,
        family=cfg.family,
        enc_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    tr = Trainer(cfg, tcfg, mesh, dc)
    p, o = tr.init_state()
    try:
        tr.run(p, o, fault_schedule=tuple(sc.events))
    finally:
        if own_dir:
            import shutil

            shutil.rmtree(ckpt_dir, ignore_errors=True)
    losses = [m["loss"] for m in tr.metrics_log]
    metrics: dict[str, Metric] = {
        "completed_final_step": Metric(
            int(tr.metrics_log[-1]["step"]), gate="hard", direction="exact"
        ),
        "loss_finite": Metric(
            bool(np.isfinite(losses).all()), gate="hard", direction="exact"
        ),
        "final_replicas": Metric(
            int(tr.n_replicas), gate="hard", direction="exact"
        ),
    }
    for key, want in sc.expect.items():
        got = int(tr.fault_stats[key])
        metrics[f"stat_{key}"] = Metric(got, gate="hard", direction="exact")
        if got != want:
            raise BenchFailure(
                f"scenario {sc.name}: fault_stats[{key!r}] = {got}, "
                f"schedule expects {want} (events: "
                + "; ".join(tr.events_log[-6:]) + ")"
            )
    return metrics


def run_scenario(sc, **kw) -> dict:
    if sc.kind == "collective":
        return run_collective_scenario(sc, **kw)
    if sc.kind == "blocked":
        return run_blocked_qr_scenario(sc, **kw)
    return run_trainer_scenario(sc, **kw)


# ---------------------------------------------------------------------------
# The stock sweep
# ---------------------------------------------------------------------------

def _stock_scenarios() -> tuple:
    from repro.runtime.trainer import FaultEvent

    return (
        # Correlated: one 4-rank failure domain (a host) dies at once.  At
        # entry of exchange 3 there are 2^3 copies of every intermediate, so
        # Replace reroutes around the wiped block within tolerance.
        CollectiveScenario(
            name="correlated_block_wipe", p=16, variant="replace",
            rounds=(ReduceRound(deaths=((8, 3), (9, 3), (10, 3), (11, 3))),),
            description="ranks 8-11 (one failure domain) die at entry of "
                        "exchange 3; replace reroutes, 12 survivors",
        ),
        # Cascading: failures arriving at successive exchanges; Self-Healing
        # respawns between steps so every rank ends holding the result.
        CollectiveScenario(
            name="cascading_failures", p=16, variant="selfhealing",
            rounds=(ReduceRound(deaths=((1, 1), (6, 2), (9, 2), (12, 3))),),
            description="1 death at step 1, two at step 2, one at step 3 — "
                        "within the per-step 2^s−1 budget at every step",
        ),
        # BLANK under repeat: three successive reductions with a growing
        # masked set and mid-reduce deaths of the masked ranks — the
        # collective analogue of the trainer's blank semantics.
        CollectiveScenario(
            name="blank_under_repeat", p=8, variant="redundant",
            rounds=(
                ReduceRound(),
                ReduceRound(masked=(2,), deaths=((2, 2),)),
                ReduceRound(masked=(2, 5), deaths=((5, 1),)),
            ),
            description="repeated reductions; masked replicas contribute "
                        "zero, and also die mid-reduce within tolerance",
        ),
        # Straggler reconstruction: two slow ranks are excluded from the
        # coded gather and their contributions reconstructed from parity —
        # the reduction completes without awaiting them (the butterfly has
        # no choice but to wait).
        CollectiveScenario(
            name="straggler_reconstruction", p=8, variant="redundant",
            scheme="coded", parity=2,
            rounds=(ReduceRound(slow=(2, 5)),),
            description="ranks 2 and 5 straggle; the coded plan excludes "
                        "them from the gather and decodes both from the 2 "
                        "parity lanes — no waiting, values exact",
        ),
        # Silent corruption detected: a rank's observed payload is
        # perturbed (it participates normally, unaware); the coded plan
        # quarantines it, reconstructs the true contribution from parity,
        # and checksum-verifies the raw payload — replication would have
        # propagated the corruption silently.
        CollectiveScenario(
            name="silent_corruption_detected", p=8, variant="redundant",
            scheme="coded", parity=2,
            rounds=(ReduceRound(corrupt=(3,)), ReduceRound(corrupt=(1, 6))),
            description="SDC injected on ranks 3, then 1 and 6; detection "
                        "flags exactly the corrupted ranks and the result "
                        "matches the uncorrupted truth",
        ),
        # Over-parity death: more simultaneous deaths than parity lanes —
        # beyond the erasure budget.  Honest degradation: zero survivors,
        # NaN payloads, no silent garbage (and a recovered follow-up round
        # shows the same world succeeding within budget).
        CollectiveScenario(
            name="over_parity_death", p=8, variant="redundant",
            scheme="coded", parity=2,
            rounds=(
                ReduceRound(deaths=((1, 0), (4, 0), (6, 1))),
                ReduceRound(deaths=((1, 0), (4, 0))),
            ),
            description="3 deaths exceed the c=2 erasure budget (round 0: "
                        "all-invalid, no garbage); 2 deaths decode fine "
                        "(round 1)",
        ),
        # Fail during rebuild: disk-rollback REBUILD (no buddy store), and a
        # second replica fails while the first rollback is still replaying.
        TrainerScenario(
            name="fail_during_rebuild", on_failure="rebuild",
            buddy_levels=0, steps=10, ckpt_every=3,
            events=(
                FaultEvent(step=5, kind="fail", replica=0),
                FaultEvent(step=5, kind="fail", replica=1),
            ),
            expect={"failures": 2, "rollbacks": 2},
            description="replica 0 dies at step 5 → rollback to ckpt 3; "
                        "replica 1 dies when the replay re-reaches step 5",
        ),
        # Buddy-pair wipe: both members of an XOR buddy pair die in the same
        # step — the first recovers diskless from its buddy, the second finds
        # its only replica gone and must fall back to the disk rollback.
        TrainerScenario(
            name="buddy_pair_wipe", on_failure="rebuild",
            buddy_levels=1, steps=8, ckpt_every=3,
            events=(
                FaultEvent(step=5, kind="fail", replica=0),
                FaultEvent(step=5, kind="fail", replica=1),
            ),
            expect={"failures": 2, "buddy_restores": 1, "rollbacks": 1},
            description="replicas 0 and 1 (level-1 buddies) die together; "
                        "first recovers diskless, second needs the disk",
        ),
        # Blocked QR, death during panel k: two ranks die inside panel 1's
        # TSQR butterfly; Replace reroutes to replicas within the cumulative
        # 2^s−1 budget and the panel's R stays exact on every survivor.
        BlockedQRScenario(
            name="panel_death_midsweep", p=8, variant="replace",
            m_local=48, n=20, panel_width=6,
            panel_deaths=((1, ((3, 1), (6, 2))),),
            description="ranks 3 and 6 die at exchanges 1 and 2 of panel 1's "
                        "TSQR; replace reroutes, R exact on all 6 survivors",
        ),
        # Blocked QR, death during the trailing update: the W butterfly of
        # panel 0 loses a rank; the redundant variant's coset goes invalid
        # but survivors hold the exact block row, and the dead rank's W is
        # restored from a replica so later panels stay clean.
        BlockedQRScenario(
            name="death_during_trailing_update", p=8, variant="redundant",
            m_local=48, n=20, panel_width=6,
            update_deaths=((0, ((5, 1),)),),
            description="rank 5 dies during panel 0's trailing-update "
                        "reduction; its step-1 coset invalidates, replica "
                        "fetch re-arms the pipeline",
        ),
        # Blocked QR, cascading panels: a fresh death in each of the first
        # three panels; self-healing respawns within every butterfly so all
        # ranks stay valid through the whole factorization.
        BlockedQRScenario(
            name="cascading_panels", p=8, variant="selfhealing",
            m_local=48, n=20, panel_width=6,
            panel_deaths=((0, ((1, 1),)), (1, ((6, 2),)), (2, ((3, 1),))),
            description="one death per panel across panels 0-2, each within "
                        "the per-step budget; selfhealing keeps all 8 valid",
        ),
        # SHRINK then REBUILD: elastic round trip through the mesh layer.
        TrainerScenario(
            name="shrink_then_rebuild", on_failure="shrink",
            steps=8, ckpt_every=0,
            events=(
                FaultEvent(step=3, kind="fail", replica=1),
                FaultEvent(step=6, kind="rejoin"),
            ),
            expect={"failures": 1, "shrinks": 1, "rejoins": 1},
            description="lose a replica at step 3 (mesh 4→2), replacement "
                        "hardware rejoins at step 6 (mesh 2→4)",
        ),
    )


_CACHE: list = []


def get_scenarios() -> tuple:
    """The stock sweep (built lazily: FaultEvent's module imports jax)."""
    if not _CACHE:
        _CACHE.append(_stock_scenarios())
    return _CACHE[0]


def case(include_trainer: bool = True, seed: int = 0):
    metrics: dict[str, Metric] = {}
    n_run = 0
    for sc in get_scenarios():
        if sc.kind == "trainer" and not include_trainer:
            continue
        try:
            sub = run_scenario(
                sc,
                **({"seed": seed} if sc.kind in ("collective", "blocked")
                   else {}),
            )
        except SkipCase as e:       # too few devices; real errors propagate
            metrics[f"{sc.name}.skipped"] = Metric(
                True, gate="warn", direction="exact"
            )
            print(f"[bench]   scenario {sc.name} skipped: {e}")
            continue
        n_run += 1
        for k, m in sub.items():
            metrics[f"{sc.name}.{k}"] = m
    metrics["n_scenarios_run"] = Metric(n_run, gate="hard", direction="higher")
    return metrics


bench_case(
    "fault_scenarios",
    tags=("robustness", "scenarios"),
    params={
        "smoke": {"include_trainer": True, "seed": 0},
        "full": {"include_trainer": True, "seed": 0},
    },
)(case)
