"""Decorator-based benchmark case registry.

A *case* is a function returning ``{metric_name: Metric | number}``; the
:func:`bench_case` decorator attaches its tiers, tags, per-tier parameters
and timing policy and records it in :data:`REGISTRY`.  The runner
(:mod:`repro.bench.runner`) resolves the tier's kwargs, times the call
(warmup + repeats, percentile summary → ``time_*`` warn-gated metrics) and
assembles the schema document.

Cases signal environmental impossibility (missing artifacts, too few
devices) by raising :class:`SkipCase`, and a *measured property violation*
— e.g. the paper's within-tolerance survival guarantee failing — by
raising :class:`BenchFailure`, which fails the whole run loudly (non-zero
exit) rather than burying the violation in a metric nobody reads.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable, Mapping

__all__ = [
    "BenchCase",
    "BenchFailure",
    "REGISTRY",
    "SkipCase",
    "TIERS",
    "bench_case",
    "cases_for",
]

TIERS = ("smoke", "full")


class SkipCase(Exception):
    """Raised by a case that cannot run in this environment."""


class BenchFailure(Exception):
    """Raised by a case whose measured invariant is violated (loud failure)."""


@dataclasses.dataclass(frozen=True)
class BenchCase:
    name: str
    fn: Callable[..., Mapping]
    tiers: tuple[str, ...]
    tags: tuple[str, ...]
    params: Mapping[str, Mapping]    # tier -> kwargs for fn
    warmup: int
    repeats: int

    def kwargs(self, tier: str) -> dict:
        return dict(self.params.get(tier, {}))


REGISTRY: dict[str, BenchCase] = {}


def bench_case(
    name: str,
    *,
    tiers: tuple[str, ...] = TIERS,
    tags: tuple[str, ...] = (),
    params: Mapping[str, Mapping] | None = None,
    warmup: int = 0,
    repeats: int = 1,
    registry: dict[str, BenchCase] | None = None,
):
    """Register a benchmark case.

    ``params`` maps tier name → kwargs the runner passes to the case
    function for that tier (missing tier → no kwargs).  ``warmup`` calls
    are discarded; ``repeats`` timed calls feed the percentile summary.
    ``registry`` overrides the global table (tests use private ones).
    """
    bad = set(tiers) - set(TIERS)
    if bad:
        raise ValueError(f"unknown tiers {sorted(bad)}; choose from {TIERS}")

    def deco(fn):
        table = REGISTRY if registry is None else registry
        if name in table:
            raise ValueError(f"duplicate bench case {name!r}")
        table[name] = BenchCase(
            name=name,
            fn=fn,
            tiers=tuple(tiers),
            tags=tuple(tags),
            params=dict(params or {}),
            warmup=warmup,
            repeats=max(1, repeats),
        )
        return fn

    return deco


def cases_for(
    tier: str,
    *,
    only: tuple[str, ...] | None = None,
    registry: dict[str, BenchCase] | None = None,
) -> list[BenchCase]:
    table = REGISTRY if registry is None else registry
    if only:
        missing = set(only) - set(table)
        if missing:
            raise KeyError(
                f"unknown bench case(s) {sorted(missing)}; "
                f"known: {sorted(table)}"
            )
    out = [
        c for c in table.values()
        if tier in c.tiers and (not only or c.name in only)
    ]
    return sorted(out, key=lambda c: c.name)
