"""PowerSGD-TSQR gradient compression: bytes over the data axis vs dense
all-reduce, and reconstruction quality vs rank (the paper-integration
benchmark, DESIGN.md §3.1).  Reconstruction error and compression ratio
are hard-gated (deterministic seeds; a quality regression in the
compressor is a real bug), per-call wall-clock is warn-gated.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.registry import bench_case
from repro.bench.schema import Metric
from repro.collective import SimComm
from repro.optim import powersgd

__all__ = ["case", "main", "run"]


def _psum_id(x):
    return x


def _psum_model(x):
    return jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)


def run(ranks=(2, 8, 32, 128), p_model: int = 8, m_loc: int = 256,
        n: int = 1024, spectrum: int = 256, iters: int = 3):
    key = jax.random.key(0)
    rows = []
    # synthetic gradient with decaying spectrum (realistic for LM grads)
    spectrum = min(spectrum, p_model * m_loc, n)
    u, _ = np.linalg.qr(
        np.random.default_rng(0).standard_normal((p_model * m_loc, spectrum))
    )
    v, _ = np.linalg.qr(np.random.default_rng(1).standard_normal((n, spectrum)))
    sv = np.logspace(0, -3, spectrum)
    g = jnp.asarray((u * sv) @ v.T, jnp.float32).reshape(p_model, m_loc, n)
    g_norm = float(jnp.linalg.norm(g))
    comm = SimComm(p_model)
    for rank in ranks:
        cfg = powersgd.PowerSGDConfig(rank=rank, error_feedback=False)
        state = powersgd.init_state(key, (m_loc, n), cfg, leading=(p_model,))
        fn = jax.jit(lambda gg, st: powersgd.compress_grad(
            gg, st, comm, cfg=cfg, psum_data=_psum_id,
            psum_model=_psum_model, n_data=1)[:2])
        (g_hat, state) = fn(g, state)
        # one power-iteration refinement (warm basis), as in training
        (g_hat, state) = fn(g, state)
        jax.block_until_ready(g_hat)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(g, state)
            jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        err = float(jnp.linalg.norm(g - g_hat)) / g_norm
        dense = 4 * p_model * m_loc * n
        comp = 4 * rank * (p_model * m_loc + n)
        rows.append({
            "rank": rank, "rel_error": err,
            "bytes_dense": dense, "bytes_compressed": comp,
            "compression_x": dense / comp, "us_per_call": us,
        })
    return rows


def case(**kw):
    rows = run(**kw)
    metrics = {}
    for r in rows:
        k = r["rank"]
        metrics[f"rel_error_r{k}"] = Metric(
            r["rel_error"], gate="hard", direction="lower", tolerance=0.10
        )
        metrics[f"compression_x_r{k}"] = Metric(
            r["compression_x"], gate="hard", direction="higher", tolerance=0.01
        )
        metrics[f"us_per_call_r{k}"] = Metric(
            r["us_per_call"], gate="warn", direction="lower", unit="us"
        )
    return metrics


bench_case(
    "powersgd",
    tags=("timing", "compression", "powersgd"),
    params={
        "smoke": {"ranks": (2, 8, 32), "p_model": 4, "m_loc": 128,
                  "n": 512, "spectrum": 128, "iters": 2},
        "full": {"ranks": (2, 8, 32, 128), "p_model": 8, "m_loc": 256,
                 "n": 1024, "spectrum": 256, "iters": 3},
    },
)(case)


def main():
    print("# powersgd-tsqr: data-axis bytes + reconstruction vs rank")
    print("rank,rel_error,bytes_dense,bytes_compressed,compression_x,us_per_call")
    rows = run()
    for r in rows:
        print(f"{r['rank']},{r['rel_error']:.4f},{r['bytes_dense']},"
              f"{r['bytes_compressed']},{r['compression_x']:.1f},"
              f"{r['us_per_call']:.0f}")
    return rows


if __name__ == "__main__":
    main()
