"""Paper §III — communication accounting: baseline TSQR vs the redundant
variants, now reported per combiner.  The paper's core claim quantified:
the butterfly doubles message *count* but (a) the exchanges are full-duplex
pairs (same serial rounds = same latency on full-duplex ICI) and (b) buys
2^s-copy redundancy.  Also reports the failure-time overhead of Replace
(extra serial rounds when replicas multicast) and Self-Healing (restore
transfers).

Wire volume depends on the combiner's payload: ``qr_combine`` ships square
(n, n) R factors; ``gram_sum`` payloads are symmetric and the engine ships
them packed (n(n+1)/2, via ``repro.collective.packing``) — both numbers are
reported (``bytes`` square, ``bytes_packed`` symmetric).

The registered case additionally *executes* the plans through
:class:`~repro.collective.instrument.InstrumentedComm` and gates on the
observed-vs-planned agreement — covering the fault-free fast path (payload
only), the general executor (+1 validity byte per message), the packed
symmetric wire, and faulty plans with restore rounds — so an engine or
planner change that silently alters real wire traffic (not just the
accounting) trips CI.
"""
from __future__ import annotations

import numpy as np

from repro.bench.registry import bench_case
from repro.bench.schema import Metric
from repro.collective import COMBINERS, FaultSpec, get_combiner, make_plan

# Combiners whose wire volume we report (ft_allreduce ops + the TSQR combine).
_OPS = ("qr_combine", "sum", "mean", "max", "gram_sum")

__all__ = ["case", "main", "run"]


def _row(p, variant, failures, plan, op, n_cols, itemsize):
    comb = get_combiner(op)
    sq = plan.bytes_on_wire(n_cols, itemsize)
    packed = plan.bytes_on_wire(n_cols, itemsize, symmetric=True)
    return {
        "P": p, "variant": variant, "failures": failures, "combiner": comb.name,
        "messages": plan.message_count(),
        "rounds": plan.round_count(),
        "bytes": sq,
        # symmetric payloads (gram_sum) can ship packed; square ones cannot
        "bytes_packed": packed if comb.wire_symmetric else sq,
    }


def run(n_cols: int = 32, itemsize: int = 4, ops=_OPS):
    rows = []
    for p in (4, 16, 64, 256, 512):
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            plan = make_plan(variant, p)
            for op in ops:
                rows.append(_row(p, variant, 0, plan, op, n_cols, itemsize))
    # failure-time behavior at P=16: kill 3 ranks within tolerance
    spec = FaultSpec.of({3: 1, 9: 2, 12: 2})
    for variant in ("redundant", "replace", "selfhealing"):
        plan = make_plan(variant, 16, spec)
        for op in ops:
            rows.append(_row(16, variant, 3, plan, op, n_cols, itemsize))
    return rows


def _observed_matches_plan(p: int, n_cols: int) -> bool:
    """Execute each plan with counting comms; compare to the planner's
    accounting.  Fault-free plans ride the engine's fast path, which ships
    the payload alone (``bytes_on_wire`` exactly); the general executor
    (forced, and under faults) adds 1 validity byte per message; symmetric
    ``gram_sum`` payloads ship packed (``bytes_on_wire(symmetric=True)``)."""
    import jax.numpy as jnp

    from repro.collective import (
        FaultSpec,
        InstrumentedComm,
        SimComm,
        execute_plan,
        plan_is_fault_free,
    )

    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(p, n_cols, n_cols)).astype(np.float32)
    )
    sym = jnp.einsum("pmi,pmj->pij", x, x)      # symmetric gram payloads

    def observed(payload, plan, op, fast):
        ic = InstrumentedComm(SimComm(p))
        execute_plan(payload, ic, plan, op, fast=fast)
        return ic.stats

    for variant in ("tree", "redundant", "replace", "selfhealing"):
        plan = make_plan(variant, p)
        # fault-free auto dispatch: payload only on the wire
        st = observed(x, plan, "sum", None)
        expect = plan.bytes_on_wire(n_cols, 4)
        if plan_is_fault_free(plan):
            if st.payload_bytes != expect:
                return False
        else:  # tree never takes the fast path: validity rides along
            if st.payload_bytes != expect + plan.message_count():
                return False
        if st.messages != plan.message_count():
            return False
        if st.rounds != plan.round_count():
            return False
        # forced general path: + 1 validity byte per message
        st = observed(x, plan, "sum", False)
        if st.payload_bytes != expect + plan.message_count():
            return False
        # packed symmetric wire: what bytes_on_wire(symmetric=True) prices
        st = observed(sym, plan, "gram_sum", None)
        packed = plan.bytes_on_wire(n_cols, 4, symmetric=True)
        if plan_is_fault_free(plan):
            if st.payload_bytes != packed:
                return False
        elif st.payload_bytes != packed + plan.message_count():
            return False
    # under faults the general executor runs (restore rounds included)
    spec = FaultSpec.of({3: 1, 5: 2})
    for variant in ("redundant", "replace", "selfhealing"):
        plan = make_plan(variant, p, spec)
        st = observed(x, plan, "sum", None)
        if st.messages != plan.message_count():
            return False
        if st.rounds != plan.round_count():
            return False
        expect = plan.bytes_on_wire(n_cols, 4) + plan.message_count()
        if st.payload_bytes != expect:
            return False
    return _observed_matches_plan_stacked(p, n_cols)


def _observed_matches_plan_stacked(p: int, n_cols: int) -> bool:
    """Stacked / mixed multi-leaf payloads: per-leaf wire packing.

    A ``stacked("gram_sum", "sum")`` payload must ship its symmetric leaf
    packed and its rectangular leaf dense in the *same* message — the old
    all-or-nothing ``wire_symmetric`` rule shipped every leaf dense the
    moment any leaf was rectangular.  Gated against
    ``Plan.bytes_on_wire_stacked``, which prices exactly that per-leaf
    encoding; ``gram_sum`` over a mixed pytree must also pack only the
    leaves that qualify."""
    import jax.numpy as jnp

    from repro.collective import (
        InstrumentedComm,
        SimComm,
        execute_plan,
        plan_is_fault_free,
        stacked,
    )

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(p, n_cols, n_cols)).astype(np.float32))
    sym = jnp.einsum("pmi,pmj->pij", x, x)
    rect = jnp.asarray(
        rng.normal(size=(p, n_cols, 2 * n_cols)).astype(np.float32)
    )

    def observed(payload, plan, op, fast):
        ic = InstrumentedComm(SimComm(p))
        execute_plan(payload, ic, plan, op, fast=fast)
        return ic.stats

    fused = stacked("gram_sum", "sum")
    leaves = [(n_cols, n_cols, 4, True), (n_cols, 2 * n_cols, 4, False)]
    for variant in ("tree", "redundant", "replace", "selfhealing"):
        plan = make_plan(variant, p)
        expect = plan.bytes_on_wire_stacked(leaves)
        # stacked payload, auto dispatch (fast path for fault-free plans)
        st = observed((sym, rect), plan, fused, None)
        validity = 0 if plan_is_fault_free(plan) else plan.message_count()
        if st.payload_bytes != expect + validity:
            return False
        if st.messages != plan.message_count():
            return False
        # forced general path: + 1 validity byte per message
        st = observed((sym, rect), plan, fused, False)
        if st.payload_bytes != expect + plan.message_count():
            return False
        # plain gram_sum over a mixed pytree packs exactly the square leaf
        st = observed({"g": sym, "c": rect}, plan, "gram_sum", None)
        if st.payload_bytes != expect + validity:
            return False
    return True


def case(n_cols: int = 32, itemsize: int = 4, observe_p: int = 16):
    rows = run(n_cols=n_cols, itemsize=itemsize)
    by = {(r["P"], r["variant"], r["failures"], r["combiner"]): r for r in rows}
    hard = dict(gate="hard", direction="exact")
    metrics = {}
    for p in (16, 512):
        tree = by[(p, "tree", 0, "qr_combine")]
        red = by[(p, "redundant", 0, "qr_combine")]
        metrics[f"tree_messages_P{p}"] = Metric(tree["messages"], **hard)
        metrics[f"redundant_messages_P{p}"] = Metric(red["messages"], **hard)
        # the paper's latency story: redundancy is round-neutral on the wire
        metrics[f"latency_parity_P{p}"] = Metric(
            red["rounds"] == tree["rounds"], **hard
        )
    metrics["redundant_bytes_P16"] = Metric(
        by[(16, "redundant", 0, "qr_combine")]["bytes"], **hard, unit="B"
    )
    metrics["gram_packed_bytes_P16"] = Metric(
        by[(16, "redundant", 0, "gram_sum")]["bytes_packed"], **hard, unit="B"
    )
    # failure-time overhead at P=16, f=3 (within tolerance)
    for variant in ("replace", "selfhealing"):
        base = by[(16, variant, 0, "sum")]
        f3 = by[(16, variant, 3, "sum")]
        metrics[f"{variant}_extra_rounds_f3"] = Metric(
            f3["rounds"] - base["rounds"], gate="hard", direction="lower"
        )
        metrics[f"{variant}_extra_messages_f3"] = Metric(
            f3["messages"] - base["messages"], gate="hard", direction="lower"
        )
    metrics["observed_matches_plan"] = Metric(
        _observed_matches_plan(observe_p, n_cols), **hard
    )
    return metrics


bench_case(
    "comm_volume",
    tags=("comm", "accounting"),
    params={
        "smoke": {"n_cols": 32, "itemsize": 4, "observe_p": 16},
        "full": {"n_cols": 32, "itemsize": 4, "observe_p": 64},
    },
)(case)


def main():
    print("# comm volume per combiner: messages / serial rounds / bytes "
          "(n=32, f32; bytes_packed = symmetric n(n+1)/2 encoding)")
    print("P,variant,failures,combiner,messages,rounds,bytes,bytes_packed")
    for r in run():
        print(f"{r['P']},{r['variant']},{r['failures']},{r['combiner']},"
              f"{r['messages']},{r['rounds']},{r['bytes']},{r['bytes_packed']}")
    # structural claims from the paper, asserted
    for p in (16, 256):
        tree = make_plan("tree", p)
        red = make_plan("redundant", p)
        assert red.message_count() == p * int(np.log2(p))
        assert tree.message_count() == p - 1
        assert red.round_count() == tree.round_count()   # wire-latency-neutral
    # packed-symmetric accounting: n(n+1)/2 vs n² for the Gram butterfly
    n = 32
    plan = make_plan("redundant", 16)
    assert plan.bytes_on_wire(n, symmetric=True) * (2 * n) \
        == plan.bytes_on_wire(n) * (n + 1)
    assert get_combiner("gram_sum").wire_symmetric
    assert not get_combiner("qr_combine").wire_symmetric
    assert set(_OPS) <= set(COMBINERS)
    return run()


if __name__ == "__main__":
    main()
