"""Coded-redundancy frontier: overhead vs tolerated failures; hard-gated.

The acceptance claims of the coded scheme (DESIGN.md §12), as numbers on
the 4096×512 acceptance shape (p=8 ranks × 512 local rows × 512 cols):

  * **c deaths tolerated** — a coded plan with ``c`` Cauchy parity ranks
    survives ``c`` *simultaneous* deaths struck at distribution time
    (step 0 — before the butterfly would have made a single copy), every
    data rank ends valid, and the reconstructed R matches the fault-free
    R within the documented fp bound
    (:func:`~repro.collective.coded.reconstruction_tol`).
  * **SDC detected** — an injected silent corruption (the rank
    participates normally, unaware) is quarantined, reconstructed from
    parity, and *flagged* by checksum verification — exactly the failure
    class replication propagates silently.
  * **wire bytes exact** — traffic observed through
    :class:`~repro.collective.instrument.InstrumentedComm` equals
    ``CodedPlan.message_count()`` / ``bytes_on_wire()`` to the byte, for
    the fault-free, death, and corruption runs alike (no validity bytes,
    no hidden traffic).
  * **overhead strictly below the butterfly** at equal tolerated-failure
    count: with ``c = 2^(S-1) − 1`` (= the redundant butterfly's total
    tolerance for P = 2^S), the coded plan moves strictly fewer payload
    units — (P−1)(1+ℓ) + ℓ + (W−1) fault-free vs the butterfly's
    P·log₂P full replicas.

Honest degradation rides along: ``c + 1`` simultaneous deaths exceed the
erasure budget and must yield zero valid ranks and NaN payloads — never
silent garbage.
"""
from __future__ import annotations

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "run"]


def run(p: int = 8, m_local: int = 512, n: int = 512, parity: int = 3,
        seed: int = 0) -> dict:
    """Measure the coded scheme's guarantees and wire frontier; raw dict."""
    import jax.numpy as jnp

    from repro.collective import (
        FaultSpec,
        InstrumentedComm,
        SimComm,
        execute_coded,
        make_coded_plan,
        make_plan,
        reconstruction_tol,
        total_tolerance,
    )
    from repro.qr import QRConfig, factorize

    rng = np.random.default_rng(seed)
    blocks = rng.standard_normal((p, m_local, n)).astype(np.float32)
    a = jnp.asarray(blocks)
    tol = reconstruction_tol(np.float32)

    # -- fault-free butterfly reference (the value oracle) ------------------
    ref = factorize(a, QRConfig(panel_width=None))
    r_ref = np.asarray(ref.r)[0]
    scale = max(1.0, float(np.abs(r_ref).max()))

    # -- c simultaneous step-0 deaths through the driver --------------------
    dead = tuple(int(r) for r in rng.choice(p, size=parity, replace=False))
    cfg = QRConfig(panel_width=None, redundancy="coded", parity=parity)
    res_d = factorize(a, cfg, faults=FaultSpec.of({r: 0 for r in dead}))
    deaths_all_valid = bool(np.asarray(res_d.valid).all())
    death_err = float(
        np.abs(np.asarray(res_d.r)[0] - r_ref).max() / scale
    )

    # -- collective-level runs with byte-exact wire instrumentation ---------
    comb = QRConfig(panel_width=None).factorizer().combiner()

    def coded_run(spec, observed=None):
        comm = InstrumentedComm(SimComm(p + parity))
        plan = make_coded_plan(p, parity, spec)
        val, valid, det = execute_coded(
            a, comm, plan, comb, observed=observed
        )
        return plan, comm.stats, (
            np.asarray(val), np.asarray(valid), np.asarray(det)
        )

    victim = int(rng.integers(p))
    observed = blocks.copy()
    observed[victim] *= 2.0                          # the silent corruption
    runs = {
        "fault_free": coded_run(None),
        "deaths": coded_run(FaultSpec.of({r: 0 for r in dead})),
        "corrupt": coded_run(
            FaultSpec.of(corrupt=(victim,)), observed=jnp.asarray(observed)
        ),
    }
    wire_exact = all(
        stats.messages == plan.message_count()
        and stats.payload_bytes == plan.bytes_on_wire(n, 4)
        for plan, stats, _ in runs.values()
    )
    _, _, (val_c, valid_c, det_c) = runs["corrupt"]
    detected_exact = bool(
        (np.flatnonzero(det_c[:p]) == np.array([victim])).all()
    )
    corrupt_err = float(np.abs(val_c[0] - r_ref).max() / scale)
    corrupt_valid = bool(valid_c[:p].all())

    # -- honest degradation: parity + 1 deaths exceed the budget ------------
    over = tuple(int(r) for r in range(parity + 1))
    _, _, (val_o, valid_o, _) = coded_run(FaultSpec.of({r: 0 for r in over}))
    honest = bool(not valid_o.any() and np.isnan(val_o).all())

    # -- the frontier: payload units at equal tolerated-failure count -------
    plan_ff = make_coded_plan(p, parity, None)
    bfly = make_plan("redundant", p)
    bfly_tol = total_tolerance("redundant", bfly.n_steps)
    coded_units = plan_ff.payload_units()
    bfly_units = bfly.message_count()       # one full payload per message
    return {
        "p": p, "m_local": m_local, "n": n, "parity": parity,
        "deaths_all_valid": deaths_all_valid,
        "death_err": death_err,
        "reconstruction_tol": tol,
        "wire_exact": wire_exact,
        "detected_exact": detected_exact,
        "corrupt_err": corrupt_err,
        "corrupt_valid": corrupt_valid,
        "honest_degradation": honest,
        "tolerated_coded": parity,
        "tolerated_butterfly": bfly_tol,
        "coded_payload_units": coded_units,
        "butterfly_payload_units": bfly_units,
        "coded_wire_bytes": plan_ff.bytes_on_wire(n, 4),
        "butterfly_wire_bytes": bfly.bytes_on_wire(n, 4),
    }


def case(p: int = 8, m_local: int = 512, n: int = 512, parity: int = 3):
    rows = run(p=p, m_local=m_local, n=n, parity=parity)
    if not rows["deaths_all_valid"] or rows["death_err"] > rows[
        "reconstruction_tol"
    ]:
        raise BenchFailure(
            f"{parity} parity ranks failed to tolerate {parity} "
            f"simultaneous step-0 deaths (all_valid="
            f"{rows['deaths_all_valid']}, rel err {rows['death_err']:.2e} "
            f"vs bound {rows['reconstruction_tol']:.2e})"
        )
    if not rows["detected_exact"] or rows["corrupt_err"] > rows[
        "reconstruction_tol"
    ]:
        raise BenchFailure(
            "silent corruption was not detected-and-reconstructed "
            f"(detected_exact={rows['detected_exact']}, rel err "
            f"{rows['corrupt_err']:.2e})"
        )
    if not rows["wire_exact"]:
        raise BenchFailure(
            "observed wire traffic deviates from CodedPlan.bytes_on_wire / "
            "message_count — the exact-accounting contract failed"
        )
    if not rows["honest_degradation"]:
        raise BenchFailure(
            f"{parity + 1} deaths exceeded the erasure budget but did not "
            "degrade honestly (expected zero valid ranks + NaN payloads)"
        )
    if rows["tolerated_coded"] < rows["tolerated_butterfly"]:
        raise BenchFailure(
            f"frontier compared at unequal tolerance: coded tolerates "
            f"{rows['tolerated_coded']}, butterfly "
            f"{rows['tolerated_butterfly']}"
        )
    if not rows["coded_payload_units"] < rows["butterfly_payload_units"]:
        raise BenchFailure(
            f"coded overhead ({rows['coded_payload_units']} payload units) "
            f"is not strictly below the butterfly's "
            f"({rows['butterfly_payload_units']}) at tolerance "
            f">= {rows['tolerated_butterfly']}"
        )
    hard = dict(gate="hard", direction="exact")
    return {
        "deaths_all_valid": Metric(rows["deaths_all_valid"], **hard),
        "detected_exact": Metric(rows["detected_exact"], **hard),
        "corrupt_valid": Metric(rows["corrupt_valid"], **hard),
        "wire_exact": Metric(rows["wire_exact"], **hard),
        "honest_degradation": Metric(rows["honest_degradation"], **hard),
        "tolerated_coded": Metric(rows["tolerated_coded"], **hard),
        "tolerated_butterfly": Metric(rows["tolerated_butterfly"], **hard),
        "coded_payload_units": Metric(rows["coded_payload_units"], **hard),
        "butterfly_payload_units": Metric(
            rows["butterfly_payload_units"], **hard
        ),
        "overhead_ratio": Metric(
            rows["coded_payload_units"] / rows["butterfly_payload_units"],
            gate="hard", direction="lower",
        ),
        "coded_wire_bytes": Metric(
            rows["coded_wire_bytes"], **hard, unit="B"
        ),
        "butterfly_wire_bytes": Metric(
            rows["butterfly_wire_bytes"], **hard, unit="B"
        ),
        "death_err": Metric(
            rows["death_err"], gate="warn", direction="lower"
        ),
        "corrupt_err": Metric(
            rows["corrupt_err"], gate="warn", direction="lower"
        ),
    }


bench_case(
    "coded",
    tags=("robustness", "coded", "comm"),
    params={
        "smoke": {"p": 8, "m_local": 64, "n": 32, "parity": 3},
        # the acceptance shape: 4096×512 over 8 ranks, c = 3 = the
        # redundant butterfly's total tolerance for P = 8
        "full": {"p": 8, "m_local": 512, "n": 512, "parity": 3},
    },
)(case)
