"""Autotuner honesty + GPU/backend coverage — hard-gated end to end.

The autotuner's promise (DESIGN.md §13) decomposes into claims this case
can gate without trusting a clock:

  * **legal** — the winner is sublane-aligned for its backend, inside the
    accumulator budget, and drawn from the candidate set
    (:func:`repro.kernels.autotune.entry_legal`);
  * **persisted** — the table round-trips through the schema-versioned
    JSON under ``results/autotune/`` and re-validates on load;
  * **reproducible** — re-running winner selection over the *persisted*
    per-candidate measurements re-picks the same ``block_rows``
    (:func:`~repro.kernels.autotune.select_winner` is deterministic: min
    median time, ties to the smaller height);
  * **honest** — for every tuned kernel, running the tuned config through
    the ``ops`` wrappers observes *exactly* the predicted committed HBM
    bytes and dispatch count (``direction: exact`` — the tuner prices with
    the same byte model :mod:`repro.kernels.traffic` records, so any drift
    is a modeling bug, not noise);
  * **retrace-free** — the second call of every tuned-config wrapper
    performs zero new traces (tuned knobs are static jit keys resolved at
    the Python level).

Wall-clock p50s for the tuned vs default ``block_rows`` ride along
warn-gated (shared CI runners are too noisy to gate timing hard; the CI
backend is the interpreter anyway, where block height barely moves the
needle — the *accounting* gates are what hold on every backend).

The case installs the freshly tuned table for its own verification and
**clears it before returning**: later cases in the same bench process
(the serving planner's hard-gated decisions, the dispatch guard) must see
the untuned defaults they were baselined against.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]

KERNELS = ("gram", "apply_right", "fused_apply_gram", "trailing_update")


def run(m: int = 2048, n: int = 64, reps: int = 3,
        out_dir: str | None = None) -> dict:
    """Tune the (m, n) shape-class, persist + reload the table, and verify
    every hard claim; returns the raw measurements."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune as at
    from repro.kernels import dispatch as _dispatch
    from repro.kernels import ops, traffic
    from repro.kernels.backend import DEFAULT_BLOCK_ROWS, pick_block_rows
    from repro.kernels.backend import resolve_backend

    backend = resolve_backend(None)
    out_dir = out_dir or at.DEFAULT_OUT_DIR
    try:
        doc = at.tune([(m, n)], KERNELS, reps=reps, out_dir=out_dir)
        path = os.path.join(out_dir, f"{doc['backend']}.json")
        reloaded = at.load_table(path)          # schema re-validates
        entries = reloaded["entries"]

        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)
        w = jnp.asarray(rng.standard_normal((n, n)) / n, dtype=jnp.float32)
        b = at.trailing_panel_width(n)
        q = jnp.asarray(rng.standard_normal((m, b)), dtype=jnp.float32)
        wt = jnp.asarray(rng.standard_normal((b, n)) / n, dtype=jnp.float32)
        calls = {
            "gram": lambda: ops.gram(a, use_pallas=True),
            "apply_right": lambda: ops.apply_right(a, w, use_pallas=True),
            "fused_apply_gram": lambda: ops.fused_apply_gram(
                a, w, use_pallas=True
            ),
            "trailing_update": lambda: ops.trailing_update(
                a, q, wt, next_width=b, use_pallas=True
            ),
        }

        accounting = {}
        for kernel in KERNELS:
            e = entries[
                at.entry_key(kernel, backend.kind, "float32",
                             at.shape_class(m, n))
            ]
            calls[kernel]()                     # trace with the tuned key
            with traffic.track_traffic() as t:
                calls[kernel]()                 # the measured (warm) call
            rec = next(r for r in t.records if r["op"] == kernel)
            accounting[kernel] = {
                "block_rows": e["block_rows"],
                "predicted_read_bytes": e["predicted_read_bytes"],
                "observed_read_bytes": rec["read_bytes"],
                "predicted_write_bytes": e["predicted_write_bytes"],
                "observed_write_bytes": rec["write_bytes"],
                "predicted_dispatches": e["predicted_dispatches"],
                "observed_dispatches": rec["dispatches"],
                "warm_traces": rec["traces"],
            }

        g_entry = entries[
            at.entry_key("gram", backend.kind, "float32",
                         at.shape_class(m, n))
        ]
        default_br = pick_block_rows(m, DEFAULT_BLOCK_ROWS,
                                     sublane=backend.sublane)

        def p50_us(fn):
            with traffic.suppress(), _dispatch.suppress():
                jax.block_until_ready(fn())
                samples = []
                for _ in range(max(1, reps)):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn())
                    samples.append(time.perf_counter() - t0)
            return float(np.median(samples)) * 1e6

        us_tuned = p50_us(lambda: ops.gram(a, use_pallas=True))
        us_default = p50_us(
            lambda: ops.gram(a, use_pallas=True, block_rows=default_br)
        )

        return {
            "m": m, "n": n, "backend": backend.kind, "arch": backend.arch,
            "path": path,
            "n_entries": len(entries),
            "winners_legal": all(
                at.entry_legal(e) for e in entries.values()
            ),
            "winners_reproducible": all(
                at.select_winner(e) == e["block_rows"]
                for e in entries.values()
            ),
            "accounting": accounting,
            "tuned_block_rows": g_entry["block_rows"],
            "default_block_rows": default_br,
            "us_gram_tuned": us_tuned,
            "us_gram_default": us_default,
            "machine": reloaded["machine"],
        }
    finally:
        # later cases in this process are baselined against the untuned
        # defaults (planner decisions, retrace guard) — never leak a table
        at.clear()


def case(m: int = 2048, n: int = 64, reps: int = 3):
    rows = run(m=m, n=n, reps=reps)
    if not rows["winners_legal"]:
        raise BenchFailure("autotuner selected an illegal winner "
                           "(misaligned, over-budget, or off-candidate)")
    if not rows["winners_reproducible"]:
        raise BenchFailure(
            "winner selection is not reproducible from the persisted "
            "per-candidate measurements"
        )
    metrics = {
        "n_entries": Metric(rows["n_entries"], gate="hard",
                            direction="exact"),
        "winners_legal": Metric(1, gate="hard", direction="exact"),
        "winners_reproducible": Metric(1, gate="hard", direction="exact"),
        "artifact_validates": Metric(1, gate="hard", direction="exact"),
    }
    for kernel, acc in rows["accounting"].items():
        for field in ("read_bytes", "write_bytes", "dispatches"):
            if acc[f"predicted_{field}"] != acc[f"observed_{field}"]:
                raise BenchFailure(
                    f"{kernel}: predicted {field} "
                    f"{acc[f'predicted_{field}']} != observed "
                    f"{acc[f'observed_{field}']} at tuned "
                    f"block_rows={acc['block_rows']}"
                )
        if acc["warm_traces"]:
            raise BenchFailure(
                f"{kernel}: warm tuned-config call performed "
                f"{acc['warm_traces']} new traces (expected 0)"
            )
        metrics[f"{kernel}_hbm_read_bytes"] = Metric(
            acc["observed_read_bytes"], gate="hard", direction="exact",
            unit="B",
        )
        metrics[f"{kernel}_hbm_write_bytes"] = Metric(
            acc["observed_write_bytes"], gate="hard", direction="exact",
            unit="B",
        )
        metrics[f"{kernel}_warm_traces"] = Metric(
            acc["warm_traces"], gate="hard", direction="exact"
        )
    metrics.update({
        "us_gram_tuned": Metric(
            rows["us_gram_tuned"], gate="warn", direction="lower", unit="us"
        ),
        "us_gram_default": Metric(
            rows["us_gram_default"], gate="warn", direction="lower",
            unit="us",
        ),
        "speedup_vs_default": Metric(
            rows["us_gram_default"] / max(rows["us_gram_tuned"], 1e-9),
            gate="warn", direction="higher",
        ),
    })
    return metrics


bench_case(
    "autotune",
    tags=("autotune", "kernels", "backend"),
    params={
        "smoke": {"m": 1024, "n": 32, "reps": 2},
        "full": {"m": 16384, "n": 128, "reps": 5},
    },
)(case)


def main():
    rows = run()
    print(f"# autotune: backend={rows['backend']} arch={rows['arch']} "
          f"→ {rows['path']}")
    print("kernel,block_rows,pred_read,obs_read,pred_write,obs_write,"
          "warm_traces")
    for kernel, acc in rows["accounting"].items():
        print(f"{kernel},{acc['block_rows']},{acc['predicted_read_bytes']},"
              f"{acc['observed_read_bytes']},{acc['predicted_write_bytes']},"
              f"{acc['observed_write_bytes']},{acc['warm_traces']}")
    print(f"gram p50: tuned {rows['us_gram_tuned']:.0f}us "
          f"(block_rows={rows['tuned_block_rows']}) vs default "
          f"{rows['us_gram_default']:.0f}us "
          f"(block_rows={rows['default_block_rows']})")
    return rows


if __name__ == "__main__":
    main()
