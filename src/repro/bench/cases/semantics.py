"""Paper Figs. 3-5 as a table: who holds the final R under each variant ×
failure scenario (the three semantics made concrete), P=4 exactly as in
the paper's walkthrough plus richer P=8 scenarios.  The registered case
gates on the worked example's holder counts and on the whole table's
holder total, so any planner change that shifts a semantics row trips CI.
"""
from __future__ import annotations

from repro.bench.registry import bench_case
from repro.bench.schema import Metric
from repro.collective import FaultSpec, make_plan

__all__ = ["SCENARIOS", "case", "main", "run"]


SCENARIOS = [
    ("fault_free", 4, {}),
    ("fig3-5: P2 dies end of step 1", 4, {2: 1}),
    ("two deaths in tolerance", 8, {5: 1, 2: 2}),
    ("block wipe (beyond tolerance)", 8, {2: 1, 3: 1}),
    ("early death (step 0)", 8, {3: 0}),
]


def run():
    rows = []
    for name, p, deaths in SCENARIOS:
        spec = FaultSpec.of(deaths)
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            plan = make_plan(variant, p, spec)
            holders = "".join("1" if v else "0" for v in plan.final_valid)
            rows.append({
                "scenario": name, "P": p, "variant": variant,
                "holders": holders,
                "n_holders": int(plan.final_valid.sum()),
            })
    return rows


def case():
    rows = run()
    hard = dict(gate="hard", direction="exact")
    fig = {
        r["variant"]: r["n_holders"]
        for r in rows if r["scenario"].startswith("fig3-5")
    }
    metrics = {
        "n_scenarios": Metric(len(rows) // 4, **hard),
        # the paper's worked example: P=4, rank 2 dies at end of step 1
        "fig35_holders_redundant": Metric(fig["redundant"], **hard),
        "fig35_holders_replace": Metric(fig["replace"], **hard),
        "fig35_holders_selfhealing": Metric(fig["selfhealing"], **hard),
        # whole-table fingerprint: total holders across scenarios × variants
        "total_holders": Metric(sum(r["n_holders"] for r in rows), **hard),
    }
    return metrics


bench_case("semantics", tags=("robustness", "paper-figures"))(case)


def main():
    print("# failure semantics: per-rank holders of the final R (1=holds)")
    print("scenario,P,variant,holders,n_holders")
    for r in run():
        print(f"\"{r['scenario']}\",{r['P']},{r['variant']},{r['holders']},"
              f"{r['n_holders']}")
    # paper's worked example, asserted:
    spec = FaultSpec.of({2: 1})
    assert list(make_plan("redundant", 4, spec).final_valid) == [False, True, False, True]
    assert list(make_plan("replace", 4, spec).final_valid) == [True, True, False, True]
    assert make_plan("selfhealing", 4, spec).final_valid.all()
    return run()


if __name__ == "__main__":
    main()
