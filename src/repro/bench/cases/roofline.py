"""Roofline analysis — reads the dry-run JSONs and derives the three terms
per (arch × shape) cell on the single-pod mesh (EXPERIMENTS.md §Roofline).

  compute    = HLO_FLOPs/device        / 197e12  (bf16 peak, TPU v5e)
  memory     = HLO_bytes/device        / 819e9   (HBM bw)
  collective = collective_bytes/device / 50e9    (per-link ICI, conservative
               single-link figure; result-shape bytes of every collective in
               the partitioned HLO, async pairs deduped)

HLO FLOP/byte totals come from the unrolled accounting extrapolation
(``accounting.extrapolated``) because XLA's HloCostAnalysis counts scan
bodies once (see launch/dryrun.py).  MODEL_FLOPS = 6·N·D (train) or 2·N·D
(prefill/decode), N = non-embedding (dense) / active (MoE) params — the
MODEL/HLO ratio exposes remat recompute, causal-masking waste, capacity
overprovisioning and padding.

The registered case also models the CQR2 kernel pipeline's HBM terms —
fused (2 tall sweeps for R, 3 + Q₁ write for full Q) vs unfused (4 sweeps,
2 tall writes) at reference TSQR shapes: pure bytes/bandwidth arithmetic,
so it runs everywhere and the fused/unfused ratio is hard-gated.  The
dry-run half skips cleanly when no artifacts exist (the CI smoke tier);
when they do exist it reports cell counts and per-cell roofline fractions
(warn-gated — artifact sets evolve).
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.bench.registry import bench_case
from repro.bench.schema import Metric

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

__all__ = ["advice", "analyze_record", "case", "cqr2_rows", "load_all",
           "main", "markdown_table", "tuned_markdown", "tuned_tables"]

# Reference tall-skinny shapes for the CQR2 HBM model (per-rank panels of
# the production TSQR: m_local × n at bf16).
CQR2_SHAPES = ((1 << 20, 128), (1 << 22, 256), (1 << 24, 512))


def cqr2_rows(shapes=CQR2_SHAPES, dtype: str = "bfloat16",
              hbm_bw: float = HBM_BW) -> list[dict]:
    """HBM-traffic model of CholeskyQR2, fused vs unfused pipelines.

    The coefficients are *measured*, not restated: each pipeline runs at two
    small probe heights under :func:`repro.kernels.traffic.track_traffic`
    (the same traffic notes the hard-gated ``kernels`` case gates), and the
    exact affine-in-m byte totals are extrapolated to the target shape.  A
    pipeline change (say, a variant growing a third sweep) therefore shows
    up here automatically rather than leaving stale constants behind.
    Expected shape of the result: unfused ≈ 4 panel reads + 2 panel writes,
    fused full-Q ≈ 3 + 2, fused R-only = exactly 2 reads and no tall write.
    """
    import jax.numpy as jnp

    from repro.kernels import ops, traffic

    dt = jnp.dtype(dtype)
    pipelines = {
        "unfused": lambda a: ops.cholesky_qr2(a, fused=False),
        "fused_q": lambda a: ops.cholesky_qr2(a),
        "fused_r": lambda a: ops.cholesky_qr2_r(a),
    }

    def measured(m, n, run):
        with traffic.track_traffic() as t:
            run(jnp.zeros((m, n), dt))      # traffic depends on shapes only
        return t.read_bytes + t.write_bytes

    rows = []
    for m, n in shapes:
        m1, m2 = 2 * n, 4 * n               # cheap probes; totals affine in m
        by = {}
        for name, run in pipelines.items():
            b1, b2 = measured(m1, n, run), measured(m2, n, run)
            by[name] = b1 + (b2 - b1) * (m - m1) // (m2 - m1)
        rows.append({
            "m": m, "n": n,
            "unfused_bytes": by["unfused"],
            "fused_q_bytes": by["fused_q"],
            "fused_r_bytes": by["fused_r"],
            "unfused_s": by["unfused"] / hbm_bw,
            "fused_q_s": by["fused_q"] / hbm_bw,
            "fused_r_s": by["fused_r"] / hbm_bw,
            "speedup_r": by["unfused"] / by["fused_r"],
            "speedup_q": by["unfused"] / by["fused_q"],
        })
    return rows


def active_params(cfg) -> tuple[int, int]:
    """(total_non_embedding, active_non_embedding) parameter counts."""
    import jax

    from repro.models import api

    specs = api.param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    total = active = 0
    for path, leaf in flat:
        name = str(path[-1])
        size = int(np.prod(leaf.shape))
        if "embed" in str(path):
            continue
        total += size
        if "we_" in name:                # routed experts
            active += int(size * cfg.top_k / max(cfg.n_experts, 1))
        else:
            active += size
    return total, active


def model_flops(cfg, kind: str, global_batch: int, seq: int) -> float:
    _, n_active = active_params(cfg)
    if kind == "train":
        return 6.0 * n_active * global_batch * seq
    if kind == "prefill":
        return 2.0 * n_active * global_batch * seq
    return 2.0 * n_active * global_batch        # decode: 1 token/row


def structural_memory_bytes(cfg, rec) -> float:
    """Per-device HBM traffic model for one step.

    XLA's ``bytes accessed`` counts logical operand bytes per op with no
    fusion awareness (~100× HBM on CPU-lowered modules), so the memory
    term uses a structural model instead:

      train:   3× params (fwd read, bwd read, update write) + 4× Adam
               moments (m,v read+write, f32) + 2× activation carries
               (save + consume), all per device;
      prefill: 1× params + activations + KV-cache write;
      decode:  1× params + full cache read + state/cache write.

    The HLO figure is still recorded as ``hlo_bytes_dev`` for reference.
    """
    import numpy as np

    from repro.launch.shardings import param_bytes as pb

    n_model = 16
    n_data = rec["n_devices"] // n_model
    kind = rec["kind"]
    params_total = pb(cfg)
    b_loc = max(rec["global_batch"] // n_data, 1)
    s = rec["seq_len"]
    act_carry = (
        cfg.n_layers * b_loc * s * cfg.d_model * 2
        / (n_model if rec.get("seq_parallel") else 1)
        / max(rec.get("microbatches", 1), 1)
    )
    if kind == "train":
        # FSDP still reads the whole model per device per step (gathered
        # slices stream through); moments stay sharded
        params_traffic = 3 * (params_total / n_model)
        opt_traffic = 4 * params_total * 4 / rec["n_devices"]
        return params_traffic + opt_traffic + 2 * act_carry * rec.get("microbatches", 1)
    if kind == "prefill":
        kv = 2 * cfg.n_layers * b_loc * min(s, 10**9) * cfg.n_kv_heads * cfg.d_head * 2
        kv /= n_model
        return params_total / n_model + act_carry + kv
    # decode: one token per row
    cache_bytes = 0.0
    try:
        from repro.models import api

        specs = api.decode_cache_specs(cfg, rec["global_batch"], s)
        cache_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in __import__("jax").tree.leaves(specs)
        ) / rec["n_devices"]
    except Exception:
        pass
    return params_total / n_model + 2 * cache_bytes


def analyze_record(rec: dict) -> dict | None:
    from repro.configs.base import get_config

    if rec.get("kind") == "tsqr" or rec.get("mesh") != "16x16":
        return None
    cfg = get_config(rec["arch"])
    n_dev = rec["n_devices"]
    ext = rec.get("accounting", {}).get("extrapolated", {})
    flops_dev = ext.get("cost.flops", rec["cost"].get("flops", 0.0))
    bytes_dev = structural_memory_bytes(cfg, rec)
    hlo_bytes_dev = ext.get(
        "cost.bytes accessed", rec["cost"].get("bytes accessed", 0.0)
    )
    coll_dev = ext.get("coll.total_bytes", rec["collectives"]["total_bytes"])
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, rec["kind"], rec["global_batch"], rec["seq_len"])
    ratio = mf / (flops_dev * n_dev) if flops_dev else 0.0
    bound = max(terms.values())
    frac = (mf / n_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * n_dev,
        "hlo_bytes_dev": hlo_bytes_dev,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "hbm_gb": rec["memory"].get("total_hbm_bytes", 0) / 1e9,
        "microbatches": rec.get("microbatches", 1),
        "seq_parallel": rec.get("seq_parallel", False),
        "gather_axis": rec.get("gather_axis"),
    }


def load_all(dirpath: str = "results/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*_single.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row:
            out.append(row)
    return out


def advice(row: dict) -> str:
    d = row["dominant"]
    if d == "compute" and row["useful_ratio"] < 0.5:
        return "compute-bound but <50% useful: cut remat recompute / causal-dense waste"
    if d == "compute":
        return "compute-bound: good; push MXU utilization via layout/fusion"
    if d == "memory":
        return "HBM-bound: fuse elementwise chains, widen arithmetic intensity"
    return "collective-bound: reshard (EP/SP), overlap collectives with compute"


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac | HBM GB/dev | notes |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_frac']:.2f} | {r['hbm_gb']:.1f} | "
                 f"{advice(r)} |\n")
    return hdr + body


def tuned_tables(dirpath: str | None = None) -> list[dict]:
    """Every valid persisted autotune table under ``results/autotune/``
    (skipping stale-schema files — they must be re-tuned, not re-read)."""
    from repro.kernels import autotune as at

    dirpath = dirpath or at.DEFAULT_OUT_DIR
    docs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        try:
            docs.append(at.load_table(path))
        except (at.AutotuneError, json.JSONDecodeError, OSError):
            continue
    return docs


def tuned_markdown(docs: list[dict]) -> str:
    """The tuned-model report section: measured machine constants and the
    per-entry roofline predictions next to the timed winners, plus the
    CQR2 HBM model re-priced at the *measured* bandwidth."""
    out = "\n## Tuned kernel model (results/autotune/, DESIGN.md §13)\n\n"
    for doc in docs:
        mc = doc["machine"]
        out += (f"backend **{doc['backend']}** (arch `{doc['arch']}`): "
                f"measured bw {mc['mem_bw_bytes_per_s']:.3e} B/s, "
                f"peak {mc['flops_per_s']:.3e} flop/s\n\n")
        out += ("| kernel | shape class | block_rows | floor | fuse | "
                "predicted s | measured s |\n"
                "|---|---|---|---|---|---|---|\n")
        for _, e in sorted(doc["entries"].items()):
            out += (f"| {e['kernel']} | {e['shape_class']} | "
                    f"{e['block_rows']} | {e['gemm_width_floor']} | "
                    f"{e['fuse_want_q']} | {e['predicted_s']:.3e} | "
                    f"{e['measured_s']:.3e} |\n")
        out += (
            "\nCQR2 HBM model at the measured bandwidth "
            "(fused R-only vs unfused):\n\n"
            "| shape | unfused s | fused-R s | speedup |\n|---|---|---|---|\n"
        )
        for r in cqr2_rows(hbm_bw=mc["mem_bw_bytes_per_s"]):
            out += (f"| {r['m']}x{r['n']} | {r['unfused_s']:.3e} | "
                    f"{r['fused_r_s']:.3e} | {r['speedup_r']:.2f} |\n")
        out += "\n"
    return out


def case(dirpath: str = "results/dryrun"):
    # -- CQR2 kernel-pipeline HBM model: runs everywhere, ratio hard-gated --
    metrics = {}
    for r in cqr2_rows():
        key = f"m{r['m']}_n{r['n']}"
        metrics[f"cqr2_speedup_r_{key}"] = Metric(
            r["speedup_r"], gate="hard", direction="higher"
        )
        metrics[f"cqr2_fused_r_hbm_s_{key}"] = Metric(
            r["fused_r_s"], gate="warn", direction="lower", unit="s"
        )
        metrics[f"cqr2_unfused_hbm_s_{key}"] = Metric(
            r["unfused_s"], gate="warn", direction="lower", unit="s"
        )
    # -- dry-run roofline cells: need the artifacts ------------------------
    rows = load_all(dirpath)
    if not rows:
        metrics["n_cells"] = Metric(0, gate="warn", direction="higher")
        return metrics
    metrics["n_cells"] = Metric(len(rows), gate="warn", direction="higher")
    for r in rows:
        key = f"{r['arch']}_{r['shape']}_{r['kind']}"
        metrics[f"roofline_frac_{key}"] = Metric(
            r["roofline_frac"], gate="warn", direction="higher"
        )
        metrics[f"useful_ratio_{key}"] = Metric(
            r["useful_ratio"], gate="warn", direction="higher"
        )
    return metrics


bench_case("roofline", tags=("roofline", "dryrun"))(case)


def main():
    print("# CQR2 HBM roofline (bf16 panels): fused vs unfused pipeline")
    print("m,n,unfused_s,fused_q_s,fused_r_s,speedup_q,speedup_r")
    for r in cqr2_rows():
        print(f"{r['m']},{r['n']},{r['unfused_s']:.4e},{r['fused_q_s']:.4e},"
              f"{r['fused_r_s']:.4e},{r['speedup_q']:.2f},{r['speedup_r']:.2f}")
    rows = load_all()
    print("# roofline terms per (arch x shape), single-pod 16x16")
    print("arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_frac,hbm_gb_dev")
    for r in rows:
        print(f"{r['arch']},{r['shape']},{r['kind']},{r['compute_s']:.4e},"
              f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_frac']:.3f},{r['hbm_gb']:.1f}")
    os.makedirs("results", exist_ok=True)
    docs = tuned_tables()
    with open("results/roofline.md", "w") as f:
        f.write(markdown_table(rows))
        if docs:
            f.write(tuned_markdown(docs))
    return rows


if __name__ == "__main__":
    main()
