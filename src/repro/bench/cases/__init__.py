"""Registered benchmark cases — the migrated ``benchmarks/*`` modules.

Importing this package registers every case (including the fault-scenario
sweep) in :data:`repro.bench.registry.REGISTRY`; the CLI does so lazily
after pinning the host device count.  The old ``benchmarks/*.py`` entry
points remain as thin shims over these modules.
"""
from .. import scenarios  # noqa: F401  — registers fault_scenarios
from . import (  # noqa: F401
    autotune,
    coded,
    comm_volume,
    dispatch,
    general_qr,
    kernels,
    overlap,
    powersgd,
    robustness,
    roofline,
    semantics,
    serving,
    training,
    tsqr_scaling,
)
