"""QR-as-a-service under load — throughput, latency, fault re-serve; hard-gated.

The serving claims of DESIGN.md §11 are *numbers*:

  * every drained bucket launches exactly **one** batched device dispatch
    (``blocked_qr_batched`` under the hood — the PR 5 contract, now on the
    serving path);
  * warm serving performs **zero** new traces across the whole bucket set
    after :meth:`~repro.serve.QRServer.prewarm` (the shape buckets are the
    compile classes; a mixed-shape stream must never retrace);
  * a request whose batch hits an injected mid-flight death is re-served —
    never dropped — through the replica-recovering general driver, and its
    factor is **bit-identical** to a fault-free re-run of the same padded
    request (within-tolerance survivors compute identical arithmetic and
    ``replica_fetch`` copies exact values);
  * the cost model's per-bucket decisions (panel width, local-R variant,
    max batch) are deterministic — recorded as hard-gated metrics so the
    planner cannot drift silently.

Sustained throughput and p50/p99 service latency over the heavy
mixed-shape stream ride along warn-gated per the wall-clock policy.
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]


def _stream(buckets, p, n_requests: int, seed: int) -> list[np.ndarray]:
    """A deterministic heavy mixed-shape request stream: shapes cycle over
    the buckets and jitter within each bucket's admission region."""
    rng = np.random.default_rng(seed)
    mats = []
    for i in range(n_requests):
        spec = buckets[i % len(buckets)]
        n = int(rng.integers(max(2, spec.n_pad // 2), spec.n_pad + 1))
        k = spec.n_pad - n
        m = int(rng.integers(n, spec.m_pad - k + 1))
        mats.append(rng.standard_normal((m, n)).astype(np.float32))
    return mats


def run(
    p: int = 4,
    n_requests: int = 24,
    fault_period: int = 3,
    max_batch_cap: int = 6,
    seed: int = 0,
) -> dict:
    """Serve a mixed-shape stream with periodic mid-flight deaths; return
    the raw serving numbers."""
    import dataclasses

    import jax.numpy as jnp

    from repro.kernels import dispatch as disp
    from repro.qr.api import Pipeline, factorize
    from repro.serve import (
        BucketSpec,
        CostModel,
        PeriodicFaultInjector,
        QRServer,
    )
    from repro.serve.buckets import block_rows, extract_r, pad_request

    buckets = (BucketSpec(256, 32), BucketSpec(512, 64))
    model = CostModel(max_batch_cap=max_batch_cap)
    injector = PeriodicFaultInjector.sampled(
        fault_period, variant="redundant", p=p, seed=seed
    )
    server = QRServer(
        buckets, p=p, model=model, fault_injector=injector
    )

    prewarm = server.prewarm()
    mats = _stream(buckets, p, n_requests, seed)

    t0_traces = disp.trace_count()
    t0 = time.perf_counter()
    responses = server.serve(mats)
    wall_s = time.perf_counter() - t0
    warm_traces = disp.trace_count() - t0_traces

    # -- numerics: every response reproduces numpy's R (sign-normalized) ----
    max_rel_err = 0.0
    for resp, a in zip(responses, mats):
        r_np = np.linalg.qr(a, mode="r")
        sign = np.sign(np.diag(r_np))
        sign[sign == 0] = 1.0
        r_ref = (r_np.T * sign).T
        err = float(
            np.abs(resp.r - r_ref).max() / max(1.0, np.abs(r_ref).max())
        )
        max_rel_err = max(max_rel_err, err)

    # -- fault re-serve fidelity: bitwise vs a fault-free re-run ------------
    reserved = [r for r in responses if r.served_via == "reserved"]
    reserve_bitwise = True
    for resp in reserved:
        a = mats[resp.rid]
        cfg = dataclasses.replace(
            server.configs[resp.bucket], pipeline=Pipeline.OFF
        )
        ref = factorize(
            jnp.asarray(block_rows(pad_request(a, resp.bucket), p)), cfg
        )
        r_ref = extract_r(np.asarray(ref.r[0]), a.shape[1])
        reserve_bitwise &= bool(np.array_equal(resp.r, r_ref))

    lat_us = np.array([r.latency_s for r in responses]) * 1e6
    stats = server.stats
    per_bucket = {
        spec: sum(1 for r in responses if r.bucket == spec)
        for spec in server.buckets
    }
    return {
        "p": p,
        "n_requests": n_requests,
        "responses": len(responses),
        "prewarm_traces": sum(prewarm.values()),
        "warm_traces": int(warm_traces),
        "drains": stats.drains,
        "faulted_drains": stats.faulted_drains,
        "reserved": stats.reserved,
        "filler_slots": stats.filler_slots,
        "dispatches_per_drain_max": max(stats.dispatches_per_drain),
        "dispatches_per_drain_min": min(stats.dispatches_per_drain),
        "requests_per_bucket": [per_bucket[s] for s in server.buckets],
        "reserve_bitwise": reserve_bitwise,
        "max_rel_err": max_rel_err,
        "throughput_req_per_s": len(responses) / wall_s,
        "latency_p50_us": float(np.percentile(lat_us, 50)),
        "latency_p99_us": float(np.percentile(lat_us, 99)),
        "planner": server.planner_decisions(),
    }


def case(
    p: int = 4,
    n_requests: int = 24,
    fault_period: int = 3,
    max_batch_cap: int = 6,
    seed: int = 0,
):
    rows = run(
        p=p, n_requests=n_requests, fault_period=fault_period,
        max_batch_cap=max_batch_cap, seed=seed,
    )
    if rows["responses"] != rows["n_requests"]:
        raise BenchFailure(
            f"served {rows['responses']} of {rows['n_requests']} requests — "
            "the serving contract is that no request is ever dropped"
        )
    if rows["warm_traces"] != 0:
        raise BenchFailure(
            f"{rows['warm_traces']} new trace(s) while serving a warm "
            "mixed-shape stream — the bucket set must be the complete set "
            "of compile classes after prewarm"
        )
    if (rows["dispatches_per_drain_max"] != 1
            or rows["dispatches_per_drain_min"] != 1):
        raise BenchFailure(
            "a drained bucket launched "
            f"{rows['dispatches_per_drain_max']} batched dispatch(es) — "
            "continuous batching must cost exactly one program per drain"
        )
    if rows["faulted_drains"] < 1 or rows["reserved"] < 1:
        raise BenchFailure(
            "the injected-fault path never fired "
            f"(faulted_drains={rows['faulted_drains']}) — the re-serve "
            "contract was not exercised"
        )
    if not rows["reserve_bitwise"]:
        raise BenchFailure(
            "a re-served request's factor differs bitwise from a "
            "fault-free re-run — replica recovery must be exact"
        )
    if rows["max_rel_err"] > 1e-3:
        raise BenchFailure(
            f"served factors deviate from numpy QR by "
            f"{rows['max_rel_err']:.2e} rel (tolerance 1e-3)"
        )
    hard = dict(gate="hard", direction="exact")
    out = {
        # THE serving claims
        "warm_traces": Metric(rows["warm_traces"], **hard),
        "dispatches_per_drain_max": Metric(
            rows["dispatches_per_drain_max"], **hard
        ),
        "reserve_bitwise": Metric(rows["reserve_bitwise"], **hard),
        "responses": Metric(rows["responses"], **hard),
        # deterministic serving-run shape (seeded stream + injector)
        "drains": Metric(rows["drains"], **hard),
        "faulted_drains": Metric(rows["faulted_drains"], **hard),
        "reserved": Metric(rows["reserved"], **hard),
        "filler_slots": Metric(rows["filler_slots"], **hard),
        # numerics + timings (platform-dependent → warn)
        "max_rel_err": Metric(
            rows["max_rel_err"], gate="warn", direction="lower"
        ),
        "prewarm_traces": Metric(
            rows["prewarm_traces"], gate="warn", direction="lower"
        ),
        "throughput_req_per_s": Metric(
            rows["throughput_req_per_s"], gate="warn", direction="higher",
            unit="req/s",
        ),
        "latency_p50_us": Metric(
            rows["latency_p50_us"], gate="warn", direction="lower", unit="us"
        ),
        "latency_p99_us": Metric(
            rows["latency_p99_us"], gate="warn", direction="lower", unit="us"
        ),
    }
    # bucket routing + the cost model's audited decisions, hard-gated so
    # neither the router nor the planner can drift silently
    for i, count in enumerate(rows["requests_per_bucket"]):
        out[f"bucket{i}_requests"] = Metric(count, **hard)
    for i, plan in enumerate(rows["planner"]):
        out[f"planner_b{i}_panel_width"] = Metric(plan["panel_width"], **hard)
        out[f"planner_b{i}_max_batch"] = Metric(plan["max_batch"], **hard)
        out[f"planner_b{i}_local_r_householder"] = Metric(
            plan["local_r"] == "jnp", **hard
        )
    return out


bench_case(
    "serving",
    tags=("qr", "serving", "throughput", "faults"),
    params={
        "smoke": {"p": 4, "n_requests": 24, "fault_period": 3,
                  "max_batch_cap": 6},
        # heavy stream: more riders per drain, more faulted drains
        "full": {"p": 4, "n_requests": 96, "fault_period": 4,
                 "max_batch_cap": 8},
    },
)(case)


def main(argv: list[str] | None = None) -> int:
    print("# QR serving: bucketed continuous batching with fault re-serve")
    rows = run()
    planner = rows.pop("planner")
    for k, v in rows.items():
        print(f"{k}: {v}")
    print("planner decisions:")
    for plan in planner:
        print(f"  {plan}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
