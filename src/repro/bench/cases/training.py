"""The training loop as ONE fault-tolerant compiled program — hard-gated.

The closing claim of ROADMAP item 3 (DESIGN.md §14), measured four ways:

  * **one dispatch per warm train step** — PowerSGD's butterfly reductions
    + FT-TSQR and OrthoSGD's FT-CQR2 Gram butterflies are traced *inline*
    into the jitted step, so a warm step launches exactly one XLA program
    (``train_step``) and adds zero traces;
  * **zero retraces across elastic recovery** — a shrink→rebuild round
    trip compiles one program per mesh *equivalence class* (two total),
    and a post-rebuild step — plus an explicit ``rebuild_mesh`` of the
    template — adds **zero** new traces: the rebuilt mesh hits the same
    jit cache entry as the original (``compat.mesh_fingerprint``);
  * **loss parity with the non-FT baseline** — the same optimizer with
    every in-step collective replaced by its dense equivalent
    (``ft_grad_allreduce=False, ft_in_step=False``) must land within
    ``PARITY_TOL`` relative on the final loss: the butterfly changes fp
    association order, never the mathematics;
  * **the model zoo survives the stock fault scenarios** — MoE / SSM
    (smoke; + hybrid / multimodal at full tier) through elastic
    shrink→rebuild, cascading failures, and BLANK-under-repeat, with
    survivor/recovery counters hard-gated via ``Trainer.fault_stats``.

Needs ≥ 4 simulated devices (the bench CLI forces 8); skips otherwise.
"""
from __future__ import annotations

import shutil
import tempfile

import numpy as np

from repro.bench.registry import BenchFailure, SkipCase, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "PARITY_TOL"]

# FT vs dense-baseline final-loss tolerance.  Both runs do the same
# mathematics; the butterfly only reassociates fp sums (per-replica
# value_and_grad + tree combine vs one fused reduction), which over a
# handful of optimizer steps stays well inside 1e-3 relative.
PARITY_TOL = 5e-3

_DATA_WIDTH = 4


def _mk(arch="olmo-1b", optimizer="adamw", *, n_layers=1, steps=6,
        on_failure="blank", ft=True, seed=0, ckpt_dir=None):
    from repro.compat import make_mesh
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(arch).smoke(n_layers=n_layers)
    mesh = make_mesh((_DATA_WIDTH, 1), ("data", "model"))
    tcfg = TrainerConfig(
        steps=steps, log_every=10**9, ckpt_every=0, optimizer=optimizer,
        on_failure=on_failure, ckpt_dir=ckpt_dir or tempfile.mkdtemp(
            prefix="bench_training_"),
        ft_grad_allreduce=ft, ft_in_step=ft, seed=seed,
    )
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=32, global_batch=2 * _DATA_WIDTH,
        family=cfg.family,
        enc_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
        d_model=cfg.d_model,
    )
    return Trainer(cfg, tcfg, mesh, dc), dc


def _one_dispatch_warm(optimizer: str) -> dict:
    """Train 2 steps, then measure a warm third step."""
    from repro.data.pipeline import SyntheticCorpus
    from repro.kernels import dispatch as disp

    tr, dc = _mk(optimizer=optimizer, steps=2)
    try:
        p, o = tr.init_state()
        p, o = tr.run(p, o)
        batch = tr._device_batch(SyntheticCorpus(dc).batch(7))
        before = disp.trace_count("train_step")
        with disp.track_dispatch() as d:
            p, o, metrics = tr.step_fn(p, o, batch)
        return {
            "trace_delta": disp.trace_count("train_step") - before,
            "dispatches": d.dispatches.get("train_step", 0),
            "total_dispatches": d.n_dispatches,
            "loss": float(metrics["loss"]),
        }
    finally:
        shutil.rmtree(tr.tcfg.ckpt_dir, ignore_errors=True)


def _loss_parity(optimizer: str, steps: int) -> dict:
    losses = {}
    for ft in (True, False):
        tr, _ = _mk(optimizer=optimizer, steps=steps, ft=ft)
        try:
            p, o = tr.init_state()
            tr.run(p, o)
            series = [m["loss"] for m in tr.metrics_log]
            if not np.isfinite(series).all():
                raise BenchFailure(
                    f"{optimizer} ({'FT' if ft else 'baseline'}) produced "
                    f"non-finite losses: {series}"
                )
            losses[ft] = series
        finally:
            shutil.rmtree(tr.tcfg.ckpt_dir, ignore_errors=True)
    final_ft, final_base = losses[True][-1], losses[False][-1]
    rel = abs(final_ft - final_base) / max(abs(final_base), 1e-9)
    return {"final_ft": final_ft, "final_base": final_base, "rel": rel}


def _elastic_zero_retrace(optimizer: str) -> dict:
    """Shrink→rebuild under real events: one trace per mesh class, and a
    rebuilt mesh (plus an extra explicit rebuild) re-uses the warm cache."""
    import time

    from repro.data.pipeline import SyntheticCorpus
    from repro.kernels import dispatch as disp
    from repro.runtime.elastic import rebuild_mesh
    from repro.runtime.trainer import FaultEvent

    tr, dc = _mk(optimizer=optimizer, steps=8, on_failure="shrink")
    try:
        p, o = tr.init_state()
        before = disp.trace_count("train_step")
        t0 = time.perf_counter()
        p, o = tr.run(p, o, fault_schedule=(
            FaultEvent(step=3, kind="fail", replica=1),
            FaultEvent(step=6, kind="rejoin"),
        ))
        wall = time.perf_counter() - t0
        traces_run = disp.trace_count("train_step") - before
        # the template mesh rebuilt once more, plus a warm step on it,
        # must not compile anything
        before = disp.trace_count("train_step")
        p, o = tr._remesh(p, o, rebuild_mesh(tr._template_mesh))
        batch = tr._device_batch(SyntheticCorpus(dc).batch(11))
        with disp.track_dispatch() as d:
            p, o, _ = tr.step_fn(p, o, batch)
        losses = [m["loss"] for m in tr.metrics_log]
        return {
            "traces_across_elastic": traces_run,
            "post_rebuild_trace_delta": disp.trace_count("train_step") - before,
            "post_rebuild_dispatches": d.n_dispatches,
            "step_cache_entries": len(tr._step_cache),
            "fault_stats": dict(tr.fault_stats),
            "loss_finite": bool(np.isfinite(losses).all()),
            "steps_per_sec": tr.tcfg.steps / wall,
        }
    finally:
        shutil.rmtree(tr.tcfg.ckpt_dir, ignore_errors=True)


def _zoo_scenarios(archs: tuple) -> dict:
    """The stock elastic / cascading / BLANK-under-repeat schedules, per
    model-zoo architecture, via the declarative scenario engine."""
    from repro.bench.scenarios import TrainerScenario, run_trainer_scenario
    from repro.runtime.trainer import FaultEvent

    out = {}
    for arch in archs:
        slug = arch.split("-")[0]
        schedules = (
            TrainerScenario(
                name=f"{slug}_elastic", on_failure="shrink",
                arch=arch, n_layers=1, steps=8, ckpt_every=0,
                events=(FaultEvent(step=3, kind="fail", replica=1),
                        FaultEvent(step=6, kind="rejoin")),
                expect={"failures": 1, "shrinks": 1, "rejoins": 1},
            ),
            TrainerScenario(
                name=f"{slug}_cascading", on_failure="blank",
                arch=arch, n_layers=1, steps=8, ckpt_every=0,
                events=(FaultEvent(step=2, kind="fail", replica=1),
                        FaultEvent(step=4, kind="fail", replica=2),
                        FaultEvent(step=6, kind="recover", replica=1),
                        FaultEvent(step=6, kind="recover", replica=2)),
                expect={"failures": 2, "recoveries": 2, "masked_steps": 4},
            ),
            TrainerScenario(
                name=f"{slug}_blank_repeat", on_failure="blank",
                arch=arch, n_layers=1, steps=8, ckpt_every=0,
                events=(FaultEvent(step=2, kind="fail", replica=1),
                        FaultEvent(step=4, kind="recover", replica=1),
                        FaultEvent(step=5, kind="fail", replica=2),
                        FaultEvent(step=7, kind="recover", replica=2)),
                expect={"failures": 2, "recoveries": 2, "masked_steps": 4},
            ),
        )
        for sc in schedules:
            for k, m in run_trainer_scenario(sc).items():
                out[f"{sc.name}.{k}"] = m
    return out


def case(archs: tuple = ("qwen2-moe-a2.7b", "mamba2-2.7b"),
         parity_steps: int = 6) -> dict:
    import jax

    if jax.device_count() < _DATA_WIDTH:
        raise SkipCase(
            f"needs {_DATA_WIDTH} devices, have {jax.device_count()} "
            "(run via `python -m repro.bench run`, which forces 8)"
        )
    hard = dict(gate="hard", direction="exact")
    metrics: dict[str, Metric] = {}

    # -- one dispatch per warm train step, both FT optimizers ---------------
    for opt in ("powersgd", "orthosgd"):
        w = _one_dispatch_warm(opt)
        if w["trace_delta"] != 0 or w["total_dispatches"] != 1:
            raise BenchFailure(
                f"{opt}: warm train step traced {w['trace_delta']}x and "
                f"launched {w['total_dispatches']} program(s) — must be "
                "0 traces / 1 dispatch"
            )
        metrics[f"{opt}.warm_trace_delta"] = Metric(w["trace_delta"], **hard)
        metrics[f"{opt}.warm_dispatches"] = Metric(
            w["total_dispatches"], **hard
        )

    # -- loss parity: FT collectives vs dense baseline ----------------------
    for opt in ("powersgd", "orthosgd"):
        pr = _loss_parity(opt, parity_steps)
        if pr["rel"] > PARITY_TOL:
            raise BenchFailure(
                f"{opt}: FT final loss {pr['final_ft']:.6f} deviates from "
                f"dense baseline {pr['final_base']:.6f} by {pr['rel']:.2e} "
                f"rel (tolerance {PARITY_TOL:.0e})"
            )
        metrics[f"{opt}.loss_parity_ok"] = Metric(True, **hard)
        metrics[f"{opt}.loss_parity_rel"] = Metric(
            pr["rel"], gate="warn", direction="lower"
        )

    # -- elastic shrink→rebuild: zero warm retraces -------------------------
    el = _elastic_zero_retrace("powersgd")
    if el["traces_across_elastic"] != 2:
        raise BenchFailure(
            f"elastic run compiled {el['traces_across_elastic']} train-step "
            "programs — must be exactly 2 (one per mesh equivalence class)"
        )
    if el["post_rebuild_trace_delta"] != 0 or el["post_rebuild_dispatches"] != 1:
        raise BenchFailure(
            "a rebuilt template mesh did not hit the warm jit cache "
            f"(traces {el['post_rebuild_trace_delta']}, dispatches "
            f"{el['post_rebuild_dispatches']})"
        )
    for k, want in (("failures", 1), ("shrinks", 1), ("rejoins", 1)):
        if el["fault_stats"][k] != want:
            raise BenchFailure(
                f"elastic run fault_stats[{k!r}] = {el['fault_stats'][k]}, "
                f"expected {want}"
            )
    metrics["elastic.traces_across_elastic"] = Metric(
        el["traces_across_elastic"], **hard
    )
    metrics["elastic.post_rebuild_trace_delta"] = Metric(
        el["post_rebuild_trace_delta"], **hard
    )
    metrics["elastic.mesh_classes_compiled"] = Metric(
        el["step_cache_entries"], **hard
    )
    metrics["elastic.loss_finite"] = Metric(el["loss_finite"], **hard)
    metrics["elastic.steps_per_sec"] = Metric(
        el["steps_per_sec"], gate="warn", direction="higher", unit="steps/s"
    )

    # -- model zoo under the stock fault schedules --------------------------
    metrics.update(_zoo_scenarios(tuple(archs)))
    return metrics


bench_case(
    "training",
    tags=("robustness", "training", "compile"),
    params={
        "smoke": {"archs": ("qwen2-moe-a2.7b", "mamba2-2.7b"),
                  "parity_steps": 6},
        "full": {"archs": ("qwen2-moe-a2.7b", "mamba2-2.7b",
                           "zamba2-7b", "qwen2-vl-72b"),
                 "parity_steps": 8},
    },
)(case)
