"""Paper §III-B3/C3/D3 — survival probability vs failure count per variant.

For each variant and each number of injected failures f, run Monte-Carlo
fault placements (uniform over ranks × steps) and report the survival
fraction plus the guarantee boundary (2^s − 1).  Survival =
  tree:        rank 0 valid;
  redundant:   ≥1 rank holds the final R;
  replace:     every live rank holds the final R;
  selfhealing: every rank (incl. respawned) holds the final R.

The registered case distills the sweep into gated metrics: the largest
failure count per variant for which every *within-tolerance* placement
survived (the paper's guarantee, as a number CI can watch), plus
Self-Healing's theoretical worst-case total tolerance.  A guarantee
violation raises :class:`~repro.bench.registry.BenchFailure` — the run
fails loudly instead of hiding it behind a hardcoded ``guarantee_holds=1``
(the old ``benchmarks/run.py`` bug).
"""
from __future__ import annotations

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric
from repro.collective import FaultSpec, make_plan, total_tolerance, within_tolerance

__all__ = ["case", "main", "run", "survival"]


def survival(variant: str, plan, death) -> bool:
    if variant == "tree":
        return bool(plan.final_valid[0])
    if variant == "redundant":
        return bool(plan.final_valid.any())
    if variant == "replace":
        alive = death >= (1 << 30)
        return bool((plan.final_valid | ~alive).all() and plan.final_valid.any())
    return bool(plan.final_valid.all())


def run(p: int = 16, trials: int = 400, seed: int = 0):
    rng = np.random.default_rng(seed)
    log_p = int(np.log2(p))
    rows = []
    for variant in ("tree", "redundant", "replace", "selfhealing"):
        for f in range(0, p):
            ok = 0
            ok_in_tol = tot_in_tol = 0
            for _ in range(trials):
                ranks = rng.choice(p, size=f, replace=False)
                steps = rng.integers(0, log_p, size=f)
                spec = FaultSpec.of({int(r): int(s) for r, s in zip(ranks, steps)})
                plan = make_plan(variant, p, spec)
                s = survival(variant, plan, spec.death_vector(p))
                ok += s
                if within_tolerance(variant, spec, log_p):
                    tot_in_tol += 1
                    ok_in_tol += s
            rows.append({
                "variant": variant, "failures": f,
                "survival_rate": ok / trials,
                "in_tolerance_rate": (ok_in_tol / tot_in_tol) if tot_in_tol else None,
            })
            if ok == 0 and f > p // 2:
                break
    return rows


def _guarantee_violations(rows) -> list[dict]:
    return [r for r in rows if r["in_tolerance_rate"] not in (None, 1.0)]


def case(p: int = 16, trials: int = 400, seed: int = 0):
    rows = run(p=p, trials=trials, seed=seed)
    bad = _guarantee_violations(rows)
    if bad:
        raise BenchFailure(
            "within-tolerance survival < 1.0 (the paper's guarantee broke): "
            + "; ".join(
                f"{r['variant']} f={r['failures']} "
                f"rate={r['in_tolerance_rate']:.3f}" for r in bad
            )
        )
    metrics = {"guarantee_holds": Metric(True, gate="hard", direction="exact")}
    for variant in ("tree", "redundant", "replace", "selfhealing"):
        vr = [r for r in rows if r["variant"] == variant]
        guaranteed = [
            r["failures"] for r in vr if r["in_tolerance_rate"] == 1.0
        ]
        metrics[f"guaranteed_max_f_{variant}"] = Metric(
            max(guaranteed, default=0), gate="hard", direction="higher"
        )
    # the worst-case count the scheme guarantees by theory (the number the
    # old harness computed then dropped for a hardcoded string)
    metrics["selfhealing_total_tolerance"] = Metric(
        total_tolerance("selfhealing", int(np.log2(p))),
        gate="hard", direction="higher",
    )
    return metrics


bench_case(
    "robustness",
    tags=("robustness", "monte-carlo"),
    params={
        "smoke": {"p": 16, "trials": 150, "seed": 0},
        "full": {"p": 16, "trials": 400, "seed": 0},
    },
)(case)


def main(csv: bool = True):
    rows = run()
    print("# robustness: survival vs injected failures (P=16, MC=400)")
    print("variant,failures,survival_rate,within_tolerance_survival")
    for r in rows:
        it = "" if r["in_tolerance_rate"] is None else f"{r['in_tolerance_rate']:.3f}"
        print(f"{r['variant']},{r['failures']},{r['survival_rate']:.3f},{it}")
    # the paper's guarantee: within tolerance, survival is ALWAYS 1.0
    bad = _guarantee_violations(rows)
    assert not bad, bad
    return rows


if __name__ == "__main__":
    main()
