"""CQR2 kernel pipeline — HBM-bytes-moved model + wall time, hard-gated.

The fused pipeline's claim (DESIGN.md §Kernels) is a *number*: the TSQR
local QR (CholeskyQR2's R factor) streams the tall operand over HBM exactly
**2** times, versus the seed's 4 (which also wrote two tall intermediates
it then discarded).  This case measures that with the trace-time traffic
model of :mod:`repro.kernels.traffic` — every ``ops``-level kernel call
reports the bytes its BlockSpecs commit to moving — and hard-gates:

  * ``tall_sweeps_fused`` (== 2) and ``tall_sweeps_unfused`` (== 4);
  * the exact read/write byte totals of both pipelines (deterministic
    functions of the shape — ``direction: exact``);
  * the fused/unfused byte ratio (``direction: lower``);
  * numerical safety: the fused R must match the unfused R and the fused Q
    must be orthonormal to CQR2 tolerance — violations raise
    :class:`~repro.bench.registry.BenchFailure`, not a buried metric.

Wall-clock timings for both pipelines ride along warn-gated (shared CI
runners are too noisy to gate timing hard).
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]

ORTHO_TOL = 3e-5          # the existing CQR2 test tolerance (f32)


def run(m: int = 4096, n: int = 64, use_pallas: bool = True,
        iters: int = 3) -> dict:
    """Execute fused vs unfused CQR2 under the traffic tracker; return the
    raw model numbers, timings, and numerical-safety measurements."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, traffic

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)

    with traffic.track_traffic() as t_fused:
        r_fused = ops.cholesky_qr2_r(a, use_pallas=use_pallas)
    with traffic.track_traffic() as t_unfused:
        q_unfused, r_unfused = ops.cholesky_qr2(
            a, use_pallas=use_pallas, fused=False
        )
    q_fused, r_full = ops.cholesky_qr2(a, use_pallas=use_pallas)

    ortho = float(
        jnp.abs(q_fused.T @ q_fused - jnp.eye(n, dtype=jnp.float32)).max()
    )
    r_dev = float(
        jnp.abs(r_fused - r_unfused).max() / jnp.abs(r_unfused).max()
    )
    r_consistent = bool(jnp.array_equal(r_fused, r_full))

    def clock(fn):
        fn()  # warmup / compile
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / iters * 1e6

    us_fused = clock(lambda: ops.cholesky_qr2_r(a, use_pallas=use_pallas))
    us_unfused = clock(
        lambda: ops.cholesky_qr2(a, use_pallas=use_pallas, fused=False)[1]
    )
    return {
        "m": m, "n": n,
        "fused": t_fused.as_dict(),
        "unfused": t_unfused.as_dict(),
        "fused_total_bytes": t_fused.total_bytes,
        "unfused_total_bytes": t_unfused.total_bytes,
        "ortho_err": ortho,
        "r_rel_dev": r_dev,
        "r_consistent": r_consistent,
        "us_fused_r": us_fused,
        "us_unfused_r": us_unfused,
    }


def case(m: int = 4096, n: int = 64, iters: int = 3):
    rows = run(m=m, n=n, use_pallas=True, iters=iters)
    if rows["ortho_err"] > ORTHO_TOL:
        raise BenchFailure(
            f"fused CQR2 orthogonality {rows['ortho_err']:.2e} exceeds "
            f"tolerance {ORTHO_TOL:.0e}"
        )
    if not rows["r_consistent"]:
        raise BenchFailure("cholesky_qr2_r disagrees with cholesky_qr2(a)[1]")
    if rows["r_rel_dev"] > 1e-5:
        raise BenchFailure(
            f"fused R deviates from unfused R by {rows['r_rel_dev']:.2e}"
        )
    hard = dict(gate="hard", direction="exact")
    return {
        # THE claim: 2 sweeps fused vs 4 unfused, bytes priced exactly
        "tall_sweeps_fused": Metric(rows["fused"]["tall_sweeps"], **hard),
        "tall_sweeps_unfused": Metric(rows["unfused"]["tall_sweeps"], **hard),
        "hbm_read_bytes_fused": Metric(
            rows["fused"]["read_bytes"], **hard, unit="B"
        ),
        "hbm_read_bytes_unfused": Metric(
            rows["unfused"]["read_bytes"], **hard, unit="B"
        ),
        "hbm_write_bytes_fused": Metric(
            rows["fused"]["write_bytes"], **hard, unit="B"
        ),
        "hbm_write_bytes_unfused": Metric(
            rows["unfused"]["write_bytes"], **hard, unit="B"
        ),
        "hbm_bytes_ratio": Metric(
            rows["fused_total_bytes"] / rows["unfused_total_bytes"],
            gate="hard", direction="lower",
        ),
        # the numerical claim is enforced above (BenchFailure past
        # ORTHO_TOL); the recorded value is near-epsilon fp noise that
        # shifts with jax/XLA versions, so it only warns on drift
        "ortho_err": Metric(rows["ortho_err"], gate="warn", direction="lower"),
        "us_fused_r": Metric(
            rows["us_fused_r"], gate="warn", direction="lower", unit="us"
        ),
        "us_unfused_r": Metric(
            rows["us_unfused_r"], gate="warn", direction="lower", unit="us"
        ),
    }


bench_case(
    "kernels",
    tags=("kernels", "hbm", "timing"),
    params={
        "smoke": {"m": 2048, "n": 32, "iters": 2},
        "full": {"m": 65536, "n": 128, "iters": 5},
    },
)(case)


def main():
    print("# CQR2 HBM traffic model: fused (R-only, 2 sweeps) vs unfused "
          "(seed, 4 sweeps)")
    print("m,n,pipeline,tall_sweeps,read_B,write_B,us_per_call")
    out = []
    for m, n in ((4096, 64), (65536, 128)):
        rows = run(m=m, n=n)
        print(f"{m},{n},fused,{rows['fused']['tall_sweeps']},"
              f"{rows['fused']['read_bytes']},{rows['fused']['write_bytes']},"
              f"{rows['us_fused_r']:.0f}")
        print(f"{m},{n},unfused,{rows['unfused']['tall_sweeps']},"
              f"{rows['unfused']['read_bytes']},"
              f"{rows['unfused']['write_bytes']},{rows['us_unfused_r']:.0f}")
        out.append(rows)
    return out


if __name__ == "__main__":
    main()
