"""One butterfly per panel — fused stacked-payload reduction, hard-gated.

The paper's communication-avoiding story meets the ABFT story in the
per-panel collectives: the panel-R butterfly and the ``W = R^-T ΣA_p^T A_t``
sum butterfly ride the *same* routing plan, so fusing them into one
collective over a stacked ``(R, C)`` payload halves the per-panel serial
rounds from ``2·log2 P`` to ``log2 P`` while the replica copies keep
protecting *both* results (one ``replica_fetch`` restores the pair).
DESIGN.md §10 derives the model this case gates:

  * **rounds** — the fused driver spends exactly ``K·log2 P`` collective
    rounds on panel reductions (one butterfly per panel, the last panel's
    R-only reduction included) vs the two-butterfly driver's
    ``(2K−1)·log2 P``; both numbers are hard-gated exactly;
  * **wire bytes** — fusion halves rounds and messages, *not* payload:
    the stacked wire bytes must equal the split drivers' total exactly,
    and the engine-observed bytes of a fused panel reduction must equal
    ``Plan.bytes_on_wire_stacked`` to the byte (hard; measured through
    :class:`~repro.collective.instrument.InstrumentedComm`);
  * **overlap** — the double-buffered schedule issues panel k+1's fused
    reduction before panel k's trailing sweep; all ``K−1`` steady-state
    panels overlap (``fuse="off"`` reports 0 — the serialized baseline);
  * **compilation model** — the fused pipeline stays ONE device program,
    zero warm retraces, and matches the eager two-butterfly driver to fp
    tolerance (hard), with bitwise identity recorded warn-gated under the
    bench CLI's multi-device CPU host per the policy in
    :mod:`repro.bench.cases.dispatch` (tier-1 enforces bitwise on its
    single-device runners);
  * **p50** — fused vs two-butterfly wall clock rides along warn-gated;
    the full tier runs the acceptance shape 4096×512 (P=8, b=128).
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]

EAGER_TOL = 1e-5          # rel. agreement of fused pipeline vs eager driver


def _bitwise(x, y) -> bool:
    return bool((np.asarray(x) == np.asarray(y)).all())


def _stacked_wire_exact(p: int, b: int, n_trail: int) -> bool:
    """Execute the fused panel combiner through counting comms on every
    fault-free variant; the observed payload bytes must equal
    ``Plan.bytes_on_wire_stacked`` over the two dense leaves (R is shipped
    square, C rectangular) — plus 1 validity byte per message off the fast
    path — and rounds/messages must match the plan's accounting."""
    import jax.numpy as jnp

    from repro.collective import (
        InstrumentedComm,
        SimComm,
        execute_plan,
        make_plan,
        plan_is_fault_free,
    )
    from repro.qr.panel import FUSED_PANEL_COMBINER

    rng = np.random.default_rng(2)
    r_loc = jnp.asarray(rng.standard_normal((p, b, b)).astype(np.float32))
    c_loc = jnp.asarray(
        rng.standard_normal((p, b, n_trail)).astype(np.float32)
    )
    leaves = [(b, b, 4, False), (b, n_trail, 4, False)]
    for variant in ("tree", "redundant", "replace", "selfhealing"):
        plan = make_plan(variant, p)
        expect = plan.bytes_on_wire_stacked(leaves)
        ic = InstrumentedComm(SimComm(p))
        execute_plan((r_loc, c_loc), ic, plan, FUSED_PANEL_COMBINER, fast=None)
        validity = 0 if plan_is_fault_free(plan) else plan.message_count()
        if ic.stats.payload_bytes != expect + validity:
            return False
        if ic.stats.messages != plan.message_count():
            return False
        if ic.stats.rounds != plan.round_count():
            return False
    return True


def run(p: int = 4, m_local: int = 160, n: int = 96, panel_width: int = 32,
        use_pallas: bool = True, repeats: int = 9) -> dict:
    """Measure rounds / wire bytes / overlap / traces for the fused and
    two-butterfly drivers; return the raw numbers."""
    import jax.numpy as jnp

    from repro.kernels import dispatch as disp
    from repro.kernels import traffic
    from repro.qr import blocked_qr_sim
    from repro.qr.blocked import PIPELINE_NAME, _compiled_sim_pipeline

    # Deterministic cold-call counts regardless of what ran earlier in this
    # process (see repro.bench.cases.dispatch).
    _compiled_sim_pipeline.cache_clear()

    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((p, m_local, n)).astype(np.float32))
    kw = dict(panel_width=panel_width, compute_q=True, use_pallas=use_pallas)
    k_panels = -(-n // panel_width)
    log_p = int(np.log2(p))

    # -- eager two-butterfly reference: the fp/bitwise oracle ---------------
    eager = blocked_qr_sim(a, pipeline="off", fuse="off", **kw)

    # -- fused pipeline: cold call, rounds/overlap/wire accounting ----------
    t0 = disp.trace_count(PIPELINE_NAME)
    with disp.track_dispatch() as d_cold, traffic.track_traffic() as t_fused:
        fused = blocked_qr_sim(a, pipeline="on", fuse="auto", **kw)
    traces_first = disp.trace_count(PIPELINE_NAME) - t0

    # -- warm repeat: zero new traces ---------------------------------------
    t0 = disp.trace_count(PIPELINE_NAME)
    with disp.track_dispatch() as d_warm:
        warm = blocked_qr_sim(a, pipeline="on", fuse="auto", **kw)
    traces_second = disp.trace_count(PIPELINE_NAME) - t0

    # -- two-butterfly pipeline (fuse="off"): the pre-fusion baseline -------
    with disp.track_dispatch() as d_split, traffic.track_traffic() as t_split:
        split = blocked_qr_sim(a, pipeline="on", fuse="off", **kw)

    scale = float(np.abs(np.asarray(eager.r)).max())

    # -- warn-gated wall clock: fused vs two-butterfly (both warm; on the
    # simulated comm the rounds saving is latency the sim does not model,
    # so parity here is expected — the hard-gated round counts carry the
    # claim, the p50s record that fusion costs nothing in compute).
    # Samples are interleaved so ambient drift (GC, other cases' memory
    # pressure in a full-tier run) hits both schedules equally. ----------
    def sample_us(fn):
        t = time.perf_counter()
        fn().r.block_until_ready()
        return (time.perf_counter() - t) * 1e6

    fused_s, split_s = [], []
    for _ in range(max(1, repeats)):
        fused_s.append(sample_us(
            lambda: blocked_qr_sim(a, pipeline="on", fuse="auto", **kw)))
        split_s.append(sample_us(
            lambda: blocked_qr_sim(a, pipeline="on", fuse="off", **kw)))
    time_fused = float(np.percentile(fused_s, 50))
    time_split = float(np.percentile(split_s, 50))

    return {
        "p": p, "m_local": m_local, "n": n, "panel_width": panel_width,
        "n_panels": k_panels, "log2_p": log_p,
        "rounds_fused": t_fused.rounds_of("panel_reduce"),
        "rounds_split": t_split.rounds_of("panel_reduce"),
        "rounds_fused_expected": k_panels * log_p,
        "rounds_split_expected": (2 * k_panels - 1) * log_p,
        "overlapped_fused": t_fused.overlapped,
        "overlapped_split": t_split.overlapped,
        "wire_bytes_fused": t_fused.wire_bytes_of("panel_reduce"),
        "wire_bytes_split": t_split.wire_bytes_of("panel_reduce"),
        "traces_first": traces_first,
        "traces_second": traces_second,
        "dispatches_fused": d_cold.dispatches[PIPELINE_NAME],
        "dispatches_warm": d_warm.dispatches[PIPELINE_NAME],
        "dispatches_split": d_split.dispatches[PIPELINE_NAME],
        "stacked_wire_exact": _stacked_wire_exact(
            p, panel_width, max(n - panel_width, panel_width)),
        "bit_identical_eager": (
            _bitwise(fused.r, eager.r) and _bitwise(fused.valid, eager.valid)
            and _bitwise(fused.q, eager.q)
        ),
        "bit_identical_split": (
            _bitwise(fused.r, split.r) and _bitwise(fused.q, split.q)
        ),
        "bit_identical_warm": (
            _bitwise(fused.r, warm.r) and _bitwise(fused.q, warm.q)
        ),
        "eager_rel_err": float(
            np.abs(np.asarray(fused.r) - np.asarray(eager.r)).max() / scale
        ),
        "valid_identical": _bitwise(fused.valid, eager.valid),
        "time_fused_p50_us": time_fused,
        "time_split_p50_us": time_split,
        "fused_speedup": time_split / max(time_fused, 1e-9),
    }


def case(p: int = 4, m_local: int = 160, n: int = 96, panel_width: int = 32,
         use_pallas: bool = True):
    rows = run(p=p, m_local=m_local, n=n, panel_width=panel_width,
               use_pallas=use_pallas)
    k, lg = rows["n_panels"], rows["log2_p"]
    if rows["rounds_fused"] != rows["rounds_fused_expected"]:
        raise BenchFailure(
            f"fused driver spent {rows['rounds_fused']} collective rounds on "
            f"panel reductions; one butterfly per panel demands exactly "
            f"K·log2 P = {k}·{lg} = {rows['rounds_fused_expected']}"
        )
    if rows["rounds_split"] != rows["rounds_split_expected"]:
        raise BenchFailure(
            f"two-butterfly driver spent {rows['rounds_split']} rounds; "
            f"expected (2K−1)·log2 P = {rows['rounds_split_expected']}"
        )
    if rows["wire_bytes_fused"] != rows["wire_bytes_split"]:
        raise BenchFailure(
            "fusion must conserve payload bytes (it halves rounds, not "
            f"volume): fused {rows['wire_bytes_fused']} B vs split "
            f"{rows['wire_bytes_split']} B"
        )
    if not rows["stacked_wire_exact"]:
        raise BenchFailure(
            "engine-observed stacked wire bytes deviate from "
            "Plan.bytes_on_wire_stacked — the pricing model is wrong"
        )
    if rows["overlapped_fused"] != k - 1 or rows["overlapped_split"] != 0:
        raise BenchFailure(
            f"overlap accounting: fused {rows['overlapped_fused']} (expected "
            f"K−1 = {k - 1}), split {rows['overlapped_split']} (expected 0)"
        )
    if rows["eager_rel_err"] > EAGER_TOL or not rows["valid_identical"]:
        raise BenchFailure(
            "the fused pipeline deviates from the eager two-butterfly "
            f"driver by {rows['eager_rel_err']:.2e} rel (tolerance "
            f"{EAGER_TOL:.0e}; valid identical: {rows['valid_identical']})"
        )
    if not rows["bit_identical_warm"]:
        raise BenchFailure("a warm fused repeat changed the result bits")
    if rows["traces_second"] != 0:
        raise BenchFailure(
            f"{rows['traces_second']} new trace(s) on a repeat call — the "
            "fused pipeline broke the zero-retrace contract"
        )
    if rows["dispatches_fused"] != 1:
        raise BenchFailure(
            f"the fused pipeline launched {rows['dispatches_fused']} "
            "programs; fusion must not break the one-dispatch contract"
        )
    hard = dict(gate="hard", direction="exact")
    return {
        # THE claims: one butterfly per panel, payload conserved, overlap on
        "rounds_per_panel_fused": Metric(rows["rounds_fused"] // k, **hard),
        "rounds_fused": Metric(rows["rounds_fused"], **hard),
        "rounds_split": Metric(rows["rounds_split"], **hard),
        "wire_bytes_fused": Metric(rows["wire_bytes_fused"], **hard,
                                   unit="B"),
        "wire_bytes_conserved": Metric(
            rows["wire_bytes_fused"] == rows["wire_bytes_split"], **hard
        ),
        "stacked_wire_exact": Metric(rows["stacked_wire_exact"], **hard),
        "overlapped_panels": Metric(rows["overlapped_fused"], **hard),
        "overlapped_split": Metric(rows["overlapped_split"], **hard),
        # compilation model survives fusion
        "n_traces_total": Metric(
            rows["traces_first"] + rows["traces_second"], **hard
        ),
        "n_traces_second_call": Metric(rows["traces_second"], **hard),
        "dispatches_per_call": Metric(rows["dispatches_fused"], **hard),
        "valid_identical": Metric(rows["valid_identical"], **hard),
        # bitwise: hard in tier-1 on single-device runners; warn here under
        # the forced multi-device CPU host (repro.bench.cases.dispatch doc)
        "bit_identical_eager": Metric(
            rows["bit_identical_eager"], gate="warn", direction="exact"
        ),
        "bit_identical_split": Metric(
            rows["bit_identical_split"], gate="warn", direction="exact"
        ),
        "eager_rel_err": Metric(
            rows["eager_rel_err"], gate="warn", direction="lower"
        ),
        # context + warn-gated wall clock
        "n_panels": Metric(rows["n_panels"], **hard),
        "time_fused_p50_us": Metric(
            rows["time_fused_p50_us"], gate="warn", direction="lower",
            unit="us",
        ),
        "time_split_p50_us": Metric(
            rows["time_split_p50_us"], gate="warn", direction="lower",
            unit="us",
        ),
        "fused_speedup": Metric(
            rows["fused_speedup"], gate="warn", direction="higher", unit="x",
        ),
    }


bench_case(
    "overlap",
    tags=("qr", "blocked", "comm", "fusion", "throughput"),
    params={
        "smoke": {"p": 4, "m_local": 160, "n": 96, "panel_width": 32},
        # the acceptance shape: 4096×512, panel width 128, 8 ranks
        "full": {"p": 8, "m_local": 512, "n": 512, "panel_width": 128},
    },
)(case)


def main(argv: list[str] | None = None) -> int:
    print("# fused stacked-payload panel reduction: rounds / bytes / overlap")
    for k, v in run().items():
        print(f"{k}: {v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
