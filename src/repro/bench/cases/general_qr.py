"""General-matrix blocked QR — numerics, guarantees, HBM model; hard-gated.

The panel-pipeline claim (DESIGN.md §8) is a *number*: the right-looking
blocked QR touches the trailing block exactly **once per panel** — the
prime cross-product sweep plus one fused update sweep per non-final panel
(:mod:`repro.kernels.trailing_update`), with each panel's Gram and cross
products arriving from the previous update's lookahead accumulator.  This
case measures that with the trace-time traffic model of
:mod:`repro.kernels.traffic` and hard-gates:

  * ``trailing_sweeps`` == ``n_panels`` and ``sweeps_per_panel`` == 1;
  * the exact trailing-path read/write byte totals (deterministic
    functions of the shape — ``direction: exact``);
  * numerical safety: R must match the dense ``np.linalg.qr`` oracle to
    fp32 tolerance and Q must reconstruct A — violations raise
    :class:`~repro.bench.registry.BenchFailure`, not a buried metric;
  * the per-variant failure guarantee: a within-tolerance death schedule
    injected mid-factorization leaves the host-predicted survivor count,
    every survivor holding the exact R;
  * the single-program discipline (DESIGN.md §9): the fault-free
    factorization launches exactly **one** device program, and the B=8
    batched shape ("B independent user matrices, one dispatch") launches
    one program for the whole batch with every element matching the dense
    oracle.

Wall-clock timings ride along warn-gated (shared CI runners are noisy).
The full tier runs the acceptance shape: 4096×512 at panel width 128.
"""
from __future__ import annotations

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]

R_TOL = 5e-4              # fp32 tolerance vs the f64 dense oracle

GUARANTEE_SPECS = {
    # one death at entry of exchange 1 — within tolerance for every
    # redundant variant at any power-of-two p ≥ 2
    "redundant": {1: 1},
    "replace": {1: 1},
    "selfhealing": {1: 1},
}


def run(p: int = 4, m_local: int = 128, n: int = 96, panel_width: int = 32,
        use_pallas: bool = True, batch: int = 8) -> dict:
    """Execute the blocked QR under the traffic tracker; return the raw
    model numbers and numerical measurements."""
    import jax.numpy as jnp

    from repro.collective import FaultSpec, within_tolerance
    from repro.kernels import dispatch as disp
    from repro.kernels import traffic
    from repro.qr import PanelFaultSchedule, blocked_qr_batched, blocked_qr_sim
    from repro.qr.blocked import PIPELINE_NAME

    from repro.core import ref

    rng = np.random.default_rng(0)
    blocks = rng.standard_normal((p, m_local, n)).astype(np.float32)
    a = jnp.asarray(blocks)
    truth = ref.qr_r(blocks.reshape(-1, n).astype(np.float64))
    scale = np.abs(truth).max()

    with traffic.track_traffic() as t:
        res = blocked_qr_sim(
            a, panel_width=panel_width, compute_q=True, use_pallas=use_pallas
        )
    r_err = float(np.abs(np.asarray(res.r)[0] - truth).max() / scale)
    q = np.asarray(res.q).reshape(-1, n)
    recon_err = float(
        np.abs(q @ np.asarray(res.r)[0] - blocks.reshape(-1, n)).max() / scale
    )
    ortho_err = float(np.abs(q.T @ q - np.eye(n)).max())
    trailing = [r for r in t.records
                if r["op"] in ("panel_cross", "pad_cross", "trailing_update")]

    # -- batched throughput shape: B independent user matrices, ONE dispatch
    ab = rng.standard_normal((batch, p, m_local, n)).astype(np.float32)
    ab[0] = blocks
    with disp.track_dispatch() as d:
        bres = blocked_qr_batched(
            jnp.asarray(ab), panel_width=panel_width, use_pallas=use_pallas
        )
    batched_dispatches = int(d.dispatches[PIPELINE_NAME])
    batched_err = float(
        np.abs(np.asarray(bres.r)[0, 0] - truth).max() / scale
    )

    # -- per-variant guarantee: within-tolerance deaths mid-factorization --
    mid_panel = res.n_panels // 2
    survivors = {}
    for variant, deaths in GUARANTEE_SPECS.items():
        spec = FaultSpec.of(deaths)
        n_steps = res.reports[0].plan_r.n_steps
        if not within_tolerance(variant, spec, n_steps):
            raise BenchFailure(
                f"{variant}: guarantee spec {deaths} is outside tolerance "
                f"at p={p} — the case's precondition is broken"
            )
        fres = blocked_qr_sim(
            a, panel_width=panel_width, variant=variant,
            faults=PanelFaultSchedule.of(panel={mid_panel: spec}),
            use_pallas=use_pallas,
        )
        valid = np.asarray(fres.valid)
        ok = bool(valid.size) and all(
            np.abs(np.asarray(fres.r)[r] - truth).max() / scale < R_TOL
            for r in np.flatnonzero(valid)
        )
        survivors[variant] = {
            "survivors": int(valid.sum()),
            "match": ok,
            "expected": int(fres.reports[mid_panel].plan_r.final_valid.sum()),
        }
    return {
        "p": p, "m_local": m_local, "n": n, "panel_width": panel_width,
        "n_panels": res.n_panels,
        "trailing_sweeps": t.sweeps_of(
            "panel_cross", "pad_cross", "trailing_update"
        ),
        "trailing_read_bytes": sum(r["read_bytes"] for r in trailing),
        "trailing_write_bytes": sum(r["write_bytes"] for r in trailing),
        "dispatches": t.dispatches,
        "r_err": r_err,
        "recon_err": recon_err,
        "ortho_err": ortho_err,
        "batch": batch,
        "batched_dispatches": batched_dispatches,
        "batched_r_err": batched_err,
        "survivors": survivors,
    }


def case(p: int = 4, m_local: int = 128, n: int = 96, panel_width: int = 32,
         use_pallas: bool = True, batch: int = 8):
    rows = run(p=p, m_local=m_local, n=n, panel_width=panel_width,
               use_pallas=use_pallas, batch=batch)
    if rows["r_err"] > R_TOL:
        raise BenchFailure(
            f"blocked R deviates from the dense QR by {rows['r_err']:.2e} "
            f"(tolerance {R_TOL:.0e})"
        )
    if rows["recon_err"] > R_TOL:
        raise BenchFailure(
            f"Q·R reconstruction error {rows['recon_err']:.2e} exceeds "
            f"{R_TOL:.0e}"
        )
    if rows["trailing_sweeps"] != rows["n_panels"]:
        raise BenchFailure(
            f"{rows['trailing_sweeps']} trailing-block sweeps for "
            f"{rows['n_panels']} panels — the 1-sweep-per-panel claim failed"
        )
    if rows["dispatches"] != 1:
        raise BenchFailure(
            f"the fault-free factorization launched {rows['dispatches']} "
            "programs — the single-dispatch pipeline claim failed"
        )
    if rows["batched_dispatches"] != 1:
        raise BenchFailure(
            f"the B={rows['batch']} batched factorization launched "
            f"{rows['batched_dispatches']} programs instead of 1"
        )
    if rows["batched_r_err"] > R_TOL:
        raise BenchFailure(
            f"batched R deviates from the dense QR by "
            f"{rows['batched_r_err']:.2e} (tolerance {R_TOL:.0e})"
        )
    hard = dict(gate="hard", direction="exact")
    metrics = {
        # THE claim: trailing block touched once per panel, bytes exact,
        # the whole fault-free factorization one device dispatch
        "n_panels": Metric(rows["n_panels"], **hard),
        "trailing_sweeps": Metric(rows["trailing_sweeps"], **hard),
        "sweeps_per_panel": Metric(
            rows["trailing_sweeps"] / rows["n_panels"], **hard
        ),
        "trailing_read_bytes": Metric(
            rows["trailing_read_bytes"], **hard, unit="B"
        ),
        "trailing_write_bytes": Metric(
            rows["trailing_write_bytes"], **hard, unit="B"
        ),
        "dispatches": Metric(rows["dispatches"], **hard),
        "batched_b": Metric(rows["batch"], **hard),
        "batched_dispatches": Metric(rows["batched_dispatches"], **hard),
        "batched_r_err": Metric(
            rows["batched_r_err"], gate="warn", direction="lower"
        ),
        # enforced above via BenchFailure; recorded values only warn on
        # drift (near-epsilon fp noise shifts with jax/XLA versions)
        "r_err": Metric(rows["r_err"], gate="warn", direction="lower"),
        "recon_err": Metric(rows["recon_err"], gate="warn", direction="lower"),
        "ortho_err": Metric(rows["ortho_err"], gate="warn", direction="lower"),
    }
    for variant, s in rows["survivors"].items():
        if not s["match"]:
            raise BenchFailure(
                f"{variant}: within-tolerance deaths but a survivor's R "
                "does not match the dense QR"
            )
        if s["survivors"] != s["expected"]:
            raise BenchFailure(
                f"{variant}: {s['survivors']} survivors, host plan "
                f"predicts {s['expected']}"
            )
        metrics[f"survivors_{variant}"] = Metric(s["survivors"], **hard)
    return metrics


bench_case(
    "general_qr",
    tags=("qr", "blocked", "robustness", "hbm"),
    params={
        "smoke": {"p": 4, "m_local": 128, "n": 96, "panel_width": 32},
        # the acceptance shape: 4096×512, panel width 128, 8 ranks
        "full": {"p": 8, "m_local": 512, "n": 512, "panel_width": 128},
    },
)(case)


def main():
    print("# blocked QR: trailing-block HBM sweeps (1 per panel) + survival")
    print("p,m_local,n,panel_width,n_panels,trailing_sweeps,r_err,recon_err")
    out = []
    for kw in ({"p": 4, "m_local": 128, "n": 96, "panel_width": 32},
               {"p": 8, "m_local": 512, "n": 512, "panel_width": 128,
                "use_pallas": False}):
        rows = run(**kw)
        print(f"{rows['p']},{rows['m_local']},{rows['n']},"
              f"{rows['panel_width']},{rows['n_panels']},"
              f"{rows['trailing_sweeps']},{rows['r_err']:.2e},"
              f"{rows['recon_err']:.2e}")
        out.append(rows)
    return out


if __name__ == "__main__":
    main()
