"""TSQR wall-clock microbenchmark (CPU, SimComm backend): variant × P ×
local-QR implementation.  The absolute numbers are CPU-simulation times;
the *relative* cost of redundancy (redundant ≈ tree despite 2× messages —
extra QRs land on otherwise-idle ranks) is the paper's Fig. 1/2 story.

Two registered cases: ``tsqr_scaling`` sweeps variant × P, and
``tsqr_local_qr`` sweeps the local-QR implementations (jnp / CholeskyQR2 /
the Pallas kernel).  All timing metrics are warn-gated — shared CI runners
are too noisy to gate wall-clock hard.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.registry import bench_case
from repro.bench.schema import Metric
from repro.core import ref
from repro.qr import QRConfig, factorize

__all__ = ["bench_one", "case_local_qr", "case_scaling", "main"]


def bench_one(variant: str, p: int, m_loc: int, n: int, local_qr: str,
              iters: int = 5) -> float:
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(ref.random_tall_skinny(rng, p, m_loc, n))
    cfg = QRConfig(variant=variant, local_r=local_qr)
    fn = jax.jit(lambda a: factorize(a, cfg).r)
    fn(blocks).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(blocks).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def case_scaling(ps=(4, 16, 64), m_loc: int = 256, n: int = 32, iters: int = 5):
    metrics = {}
    for p in ps:
        us = {}
        for variant in ("tree", "redundant"):
            us[variant] = bench_one(variant, p, m_loc, n, "jnp", iters=iters)
            metrics[f"us_{variant}_P{p}"] = Metric(
                us[variant], gate="warn", direction="lower", unit="us"
            )
        # the paper's story: redundancy ≈ free (ratio near 1 on idle ranks)
        metrics[f"redundant_overhead_P{p}"] = Metric(
            us["redundant"] / us["tree"], gate="warn", direction="lower"
        )
    return metrics


def case_local_qr(p: int = 16, m_loc: int = 512, n: int = 64, iters: int = 5,
                  impls=("jnp", "cqr2", "cqr2_pallas")):
    metrics = {}
    for lq in impls:
        us = bench_one("redundant", p, m_loc, n, lq, iters=iters)
        metrics[f"us_{lq}"] = Metric(us, gate="warn", direction="lower", unit="us")
    return metrics


bench_case(
    "tsqr_scaling",
    tags=("timing", "tsqr"),
    params={
        "smoke": {"ps": (4, 16), "m_loc": 128, "n": 16, "iters": 2},
        "full": {"ps": (4, 16, 64), "m_loc": 256, "n": 32, "iters": 5},
    },
)(case_scaling)

bench_case(
    "tsqr_local_qr",
    tags=("timing", "tsqr", "kernels"),
    params={
        "smoke": {"p": 16, "m_loc": 256, "n": 32, "iters": 2},
        "full": {"p": 16, "m_loc": 512, "n": 64, "iters": 5},
    },
)(case_local_qr)


def main():
    print("# tsqr scaling (SimComm on CPU): us_per_call")
    print("variant,P,m_local,n,local_qr,us_per_call")
    rows = []
    for p in (4, 16, 64):
        for variant in ("tree", "redundant"):
            us = bench_one(variant, p, 256, 32, "jnp")
            rows.append((variant, p, 256, 32, "jnp", us))
            print(f"{variant},{p},256,32,jnp,{us:.0f}")
    for lq in ("jnp", "cqr2", "cqr2_pallas"):
        us = bench_one("redundant", 16, 512, 64, lq)
        rows.append(("redundant", 16, 512, 64, lq, us))
        print(f"redundant,16,512,64,{lq},{us:.0f}")
    return rows


if __name__ == "__main__":
    main()
