"""Single-program blocked QR — trace & dispatch counts; hard-gated.

The compilation-model claim (DESIGN.md §9) is a *number*, twice over:

  * the fault-free blocked QR runs as **one** jitted device program —
    ``dispatches_per_call == 1`` and *constant in the panel count* (the
    eager per-panel driver launches O(K) programs and re-traces every
    shrinking trailing width);
  * repeated calls are **zero-retrace** — ``n_traces == 1`` after a repeat
    call with identical shapes (the jit caches are module-level and keyed
    on ``(plan, combiner, treedef, shapes)``).

Both are measured with the counters in :mod:`repro.kernels.dispatch` and
hard-gated, alongside the semantic floor that makes the pipeline shippable:
its ``(Q, R, valid)`` must match the eager driver exactly (to fp tolerance
— hard), and the B-matrix batched program must launch once and agree with
the per-matrix runs likewise.  Bit-identity is the *stronger* contract the
tier-1 suite enforces on its single-device runners (tests/test_pipeline.py,
incl. the hypothesis sweep); this case runs under the bench CLI's forced
multi-device CPU host, where XLA re-shards large GEMM reductions by output
shape, so the padded-width program can differ from the shrinking-width
eager program in the last ulp of a deep reduction (DESIGN.md §9) — the
case records ``bit_identical_eager`` warn-gated and hard-gates the fp
bound plus warm-repeat determinism instead.  Wall-clock p50s for pipeline
vs eager ride along warn-gated per the existing policy.

``python -m repro.bench.cases.dispatch --guard`` runs the standalone
retrace guard CI uses in tier-1: every guarded entry point is called twice
with identical statics and the process exits non-zero if the second call
performs any new trace.
"""
from __future__ import annotations

import time

import numpy as np

from repro.bench.registry import BenchFailure, bench_case
from repro.bench.schema import Metric

__all__ = ["case", "main", "run"]

BATCH_TOL = 1e-5          # rel. agreement of the batched program's R


def _bitwise(x, y) -> bool:
    return bool((np.asarray(x) == np.asarray(y)).all())


def run(p: int = 4, m_local: int = 160, n: int = 96, panel_width: int = 32,
        batch: int = 8, use_pallas: bool = True, repeats: int = 3) -> dict:
    """Measure traces/dispatches for the pipeline, the eager driver, the
    batched program and the jitted collective; return the raw numbers."""
    import jax.numpy as jnp

    from repro.collective import SimComm, ft_allreduce_jit
    from repro.kernels import dispatch as disp
    from repro.qr import blocked_qr_batched, blocked_qr_sim
    from repro.qr.blocked import PIPELINE_NAME, _compiled_sim_pipeline

    # Make the cold-call measurement deterministic regardless of what ran
    # earlier in this process (warmup repeats, other cases touching the
    # same shape): drop the cached compiles so the first call below traces
    # exactly once and the repeat exactly zero times.
    _compiled_sim_pipeline.cache_clear()

    rng = np.random.default_rng(7)
    blocks = rng.standard_normal((p, m_local, n)).astype(np.float32)
    a = jnp.asarray(blocks)
    kw = dict(panel_width=panel_width, compute_q=True, use_pallas=use_pallas)

    # -- eager reference: O(K) dispatches, the bit-identity oracle ----------
    with disp.track_dispatch() as d_eager:
        eager = blocked_qr_sim(a, pipeline="off", **kw)

    # -- pipeline: cold call traces once, launches once ---------------------
    t0 = disp.trace_count(PIPELINE_NAME)
    with disp.track_dispatch() as d_cold:
        cold = blocked_qr_sim(a, pipeline="on", **kw)
    traces_first = disp.trace_count(PIPELINE_NAME) - t0

    # -- warm repeat: zero new traces, same single launch -------------------
    t0 = disp.trace_count(PIPELINE_NAME)
    with disp.track_dispatch() as d_warm:
        warm = blocked_qr_sim(a, pipeline="on", **kw)
    traces_second = disp.trace_count(PIPELINE_NAME) - t0

    # -- K-independence: half the panel width → double the panels, still 1 --
    with disp.track_dispatch() as d_half:
        half = blocked_qr_sim(a, pipeline="on", panel_width=panel_width // 2,
                              compute_q=True, use_pallas=use_pallas)

    # -- batched: B matrices, one launch ------------------------------------
    ab = rng.standard_normal((batch, p, m_local, n)).astype(np.float32)
    ab[0] = blocks
    with disp.track_dispatch() as d_batch:
        batched = blocked_qr_batched(
            jnp.asarray(ab), panel_width=panel_width, use_pallas=use_pallas
        )
    scale = float(np.abs(np.asarray(cold.r)).max())
    batch_err = float(
        np.abs(np.asarray(batched.r)[0] - np.asarray(cold.r)).max() / scale
    )

    # -- the compiled collective itself is retrace-proof too ----------------
    x = jnp.asarray(rng.standard_normal((p, 16)).astype(np.float32))
    comm = SimComm(p)
    ft_allreduce_jit(x, comm, op="sum")
    t0 = disp.trace_count("ft_allreduce")
    ft_allreduce_jit(x, comm, op="sum")
    allreduce_retrace = disp.trace_count("ft_allreduce") - t0

    # -- warn-gated wall clock: pipeline vs eager (both warm by now) --------
    def p50_us(fn):
        samples = []
        for _ in range(max(1, repeats)):
            t = time.perf_counter()
            fn().r.block_until_ready()
            samples.append((time.perf_counter() - t) * 1e6)
        return float(np.percentile(samples, 50))

    time_pipeline = p50_us(lambda: blocked_qr_sim(a, pipeline="on", **kw))
    time_eager = p50_us(lambda: blocked_qr_sim(a, pipeline="off", **kw))

    return {
        "p": p, "m_local": m_local, "n": n, "panel_width": panel_width,
        "batch": batch, "n_panels": cold.n_panels,
        "traces_first": traces_first,
        "traces_second": traces_second,
        "dispatches_cold": d_cold.dispatches[PIPELINE_NAME],
        "dispatches_warm": d_warm.dispatches[PIPELINE_NAME],
        "dispatches_half_width": d_half.dispatches[PIPELINE_NAME],
        "n_panels_half_width": half.n_panels,
        "dispatches_batched": d_batch.dispatches[PIPELINE_NAME],
        "eager_kernel_dispatches": d_eager.n_dispatches,
        "bit_identical_eager": (
            _bitwise(cold.r, eager.r) and _bitwise(cold.valid, eager.valid)
            and _bitwise(cold.q, eager.q)
        ),
        "eager_rel_err": float(
            np.abs(np.asarray(cold.r) - np.asarray(eager.r)).max() / scale
        ),
        "valid_identical": _bitwise(cold.valid, eager.valid),
        "bit_identical_warm": (
            _bitwise(cold.r, warm.r) and _bitwise(cold.q, warm.q)
        ),
        "batch_rel_err": batch_err,
        "allreduce_retrace": allreduce_retrace,
        "time_pipeline_p50_us": time_pipeline,
        "time_eager_p50_us": time_eager,
    }


def case(p: int = 4, m_local: int = 160, n: int = 96, panel_width: int = 32,
         batch: int = 8, use_pallas: bool = True):
    rows = run(p=p, m_local=m_local, n=n, panel_width=panel_width,
               batch=batch, use_pallas=use_pallas)
    if rows["eager_rel_err"] > BATCH_TOL or not rows["valid_identical"]:
        raise BenchFailure(
            "the scan-compiled pipeline deviates from the eager per-panel "
            f"driver by {rows['eager_rel_err']:.2e} rel "
            f"(tolerance {BATCH_TOL:.0e}; valid identical: "
            f"{rows['valid_identical']})"
        )
    if not rows["bit_identical_warm"]:
        raise BenchFailure("a warm pipeline repeat changed the result bits")
    if rows["traces_second"] != 0:
        raise BenchFailure(
            f"{rows['traces_second']} new trace(s) on a repeat call with "
            "identical shapes — the zero-retrace contract failed"
        )
    if rows["dispatches_cold"] != 1 or rows["dispatches_half_width"] != 1:
        raise BenchFailure(
            "the pipeline launched more than one program "
            f"(K={rows['n_panels']}: {rows['dispatches_cold']}, "
            f"K={rows['n_panels_half_width']}: "
            f"{rows['dispatches_half_width']}) — dispatch count must be "
            "constant in the panel count"
        )
    if rows["batch_rel_err"] > BATCH_TOL:
        raise BenchFailure(
            f"batched element deviates from the single-matrix pipeline by "
            f"{rows['batch_rel_err']:.2e} (tolerance {BATCH_TOL:.0e})"
        )
    hard = dict(gate="hard", direction="exact")
    return {
        # THE claims: one trace total after a repeat, one launch per call,
        # constant in K, one launch for the whole batch
        "n_traces_total": Metric(
            rows["traces_first"] + rows["traces_second"], **hard
        ),
        "n_traces_second_call": Metric(rows["traces_second"], **hard),
        "dispatches_per_call": Metric(rows["dispatches_cold"], **hard),
        "dispatches_half_panel_width": Metric(
            rows["dispatches_half_width"], **hard
        ),
        "dispatches_batched": Metric(rows["dispatches_batched"], **hard),
        "batched_b": Metric(rows["batch"], **hard),
        "allreduce_retrace": Metric(rows["allreduce_retrace"], **hard),
        "valid_identical": Metric(rows["valid_identical"], **hard),
        # bitwise holds on single-device CPU and TPU; multi-device CPU
        # hosts reshard deep GEMM reductions by shape (see module doc) —
        # recorded, warn-gated; the fp bound above is the hard gate
        "bit_identical_eager": Metric(
            rows["bit_identical_eager"], gate="warn", direction="exact"
        ),
        "eager_rel_err": Metric(
            rows["eager_rel_err"], gate="warn", direction="lower"
        ),
        # context + warn-gated comparisons
        "n_panels": Metric(rows["n_panels"], **hard),
        "eager_kernel_dispatches": Metric(
            rows["eager_kernel_dispatches"], gate="warn", direction="lower"
        ),
        "batch_rel_err": Metric(
            rows["batch_rel_err"], gate="warn", direction="lower"
        ),
        "time_pipeline_p50_us": Metric(
            rows["time_pipeline_p50_us"], gate="warn", direction="lower",
            unit="us",
        ),
        "time_eager_p50_us": Metric(
            rows["time_eager_p50_us"], gate="warn", direction="lower",
            unit="us",
        ),
    }


bench_case(
    "dispatch",
    tags=("qr", "blocked", "compile", "throughput"),
    params={
        "smoke": {"p": 4, "m_local": 160, "n": 96, "panel_width": 32,
                  "batch": 8},
        # the acceptance shape: 4096×512, panel width 128, 8 ranks, B=8
        "full": {"p": 8, "m_local": 512, "n": 512, "panel_width": 128,
                 "batch": 8},
    },
)(case)


# ---------------------------------------------------------------------------
# Standalone retrace guard (CI tier-1 step)
# ---------------------------------------------------------------------------

def guard() -> int:
    """Call every guarded entry point twice with identical statics; return
    the number of entry points that re-traced on the second call."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.collective import FaultSpec, SimComm, ft_allreduce_jit
    from repro.kernels import dispatch as disp
    from repro.kernels import ops as kops
    from repro.qr import (
        QRConfig,
        blocked_qr_batched,
        blocked_qr_shard_map,
        blocked_qr_sim,
        factorize,
        tsqr_gram_shard_map,
        tsqr_shard_map,
    )

    _cfg_coded = QRConfig(panel_width=None, redundancy="coded", parity=2)
    _spec_coded = FaultSpec.of({1: 0})
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((4, 96, 40)).astype(np.float32))
    ab = jnp.asarray(
        rng.standard_normal((2, 4, 96, 40)).astype(np.float32)
    )
    flat = jnp.asarray(rng.standard_normal((128, 24)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    checks = [
        ("blocked_qr_pipeline",
         lambda: blocked_qr_sim(a, panel_width=12, pipeline="on")),
        # fused (stacked-payload) and two-butterfly pipelines compile into
        # distinct cached programs — guard both schedules
        ("blocked_qr_pipeline",
         lambda: blocked_qr_sim(a, panel_width=12, pipeline="on",
                                fuse="on")),
        ("blocked_qr_pipeline",
         lambda: blocked_qr_sim(a, panel_width=12, pipeline="on",
                                fuse="off")),
        ("blocked_qr_pipeline",
         lambda: blocked_qr_batched(ab, panel_width=12)),
        ("blocked_qr_pipeline",
         lambda: blocked_qr_shard_map(
             flat, mesh=mesh, axis="x", panel_width=8)),
        ("blocked_qr_pipeline",
         lambda: blocked_qr_shard_map(
             flat, mesh=mesh, axis="x", panel_width=8, fuse="off")),
        ("tsqr_shard_map",
         lambda: tsqr_shard_map(flat, mesh=mesh, axis="x")),
        ("tsqr_gram_shard_map",
         lambda: tsqr_gram_shard_map(flat, mesh=mesh, axis="x")),
        ("ft_allreduce",
         lambda: ft_allreduce_jit(x, SimComm(4), op="sum")),
        # coded warm paths: fault-free and faulted plans compile into
        # distinct cached programs keyed on (config, plan) — guard both
        ("tsqr_coded",
         lambda: factorize(a, _cfg_coded)),
        ("tsqr_coded",
         lambda: factorize(a, _cfg_coded, faults=_spec_coded)),
        ("kernel:trailing_update",
         lambda: kops.trailing_update(
             flat, flat[:, :8], jnp.zeros((8, 24), jnp.float32),
             next_width=8, use_pallas=True)),
    ]
    # ShardMapComm: the cached SPMD butterfly (shard_map over a real mesh
    # axis) is retrace-proof too — keyed on (mesh-class, plan, combiner).
    if jax.device_count() >= 4:
        from repro.collective import ShardMapComm
        from repro.compat import make_mesh as _make_mesh

        smesh = _make_mesh((4,), ("x",))
        checks.append(
            ("ft_allreduce",
             lambda: ft_allreduce_jit(
                 x, ShardMapComm(4, "x"), op="sum", mesh=smesh)),
        )
    failures = 0
    for name, fn in checks:
        fn()                                     # warm (may trace)
        before = disp.trace_count(name)
        fn()                                     # must not trace again
        delta = disp.trace_count(name) - before
        status = "ok" if delta == 0 else f"RETRACED x{delta}"
        print(f"[retrace-guard] {name}: {status}")
        failures += delta != 0

    # Serving warm path: after prewarm + one mixed-shape pass (batched
    # drains AND the fault re-serve fallback), a second pass over the whole
    # bucket set must add zero traces of ANY kind — the shape buckets are
    # the complete set of compile classes.
    from repro.serve import (
        BucketSpec,
        CostModel,
        PeriodicFaultInjector,
        QRServer,
    )

    server = QRServer(
        (BucketSpec(64, 8), BucketSpec(128, 16)),
        p=4,
        model=CostModel(max_batch_cap=2),
        fault_injector=PeriodicFaultInjector.sampled(
            2, variant="redundant", p=4
        ),
    )
    server.prewarm()
    mats = [
        rng.standard_normal(s).astype(np.float32)
        for s in ((40, 6), (120, 14), (56, 8), (96, 12))
    ]
    server.serve(mats)                           # warm (may trace)
    before = disp.trace_count()
    server.serve(mats)                           # must not trace again
    delta = disp.trace_count() - before
    status = "ok" if delta == 0 else f"RETRACED x{delta}"
    print(f"[retrace-guard] serving:warm_stream: {status}")
    failures += delta != 0

    # Jitted train-step warm path: both FT optimizers, plus a step after an
    # elastic rebuild of the template mesh — the rebuilt mesh must hit the
    # same jit cache entry as the original (zero traces for all three warm
    # calls together).  Degrades to a 1-wide data axis on starved hosts.
    import shutil
    import tempfile

    from repro.compat import make_mesh
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.runtime.elastic import rebuild_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    width = 4 if jax.device_count() >= 4 else 1
    cfg_t = get_config("olmo-1b").smoke(n_layers=1)
    dc = DataConfig(vocab=cfg_t.vocab, seq_len=16, global_batch=2 * width)
    for opt in ("powersgd", "orthosgd"):
        tmp = tempfile.mkdtemp(prefix="guard_train_")
        try:
            tr = Trainer(
                cfg_t,
                TrainerConfig(steps=2, log_every=10**9, ckpt_every=0,
                              optimizer=opt, ckpt_dir=tmp),
                make_mesh((width, 1), ("data", "model")), dc,
            )
            p, o = tr.init_state()
            p, o, _ = tr.step_fn(p, o, tr._device_batch(
                SyntheticCorpus(dc).batch(0)))        # warm (traces once)
            before = disp.trace_count("train_step")
            p, o, _ = tr.step_fn(p, o, tr._device_batch(
                SyntheticCorpus(dc).batch(1)))        # must not trace
            p, o = tr._remesh(p, o, rebuild_mesh(tr._template_mesh))
            p, o, _ = tr.step_fn(p, o, tr._device_batch(
                SyntheticCorpus(dc).batch(2)))        # nor after rebuild
            delta = disp.trace_count("train_step") - before
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        status = "ok" if delta == 0 else f"RETRACED x{delta}"
        print(f"[retrace-guard] train_step:{opt}: {status}")
        failures += delta != 0

    # Tuned-config warm paths: installing an autotune table changes the
    # resolved block_rows (a static jit key) for its shape-classes, so the
    # first tuned call may trace — but repeats must not, whether the tuned
    # height comes from the installed table (kernel wrapper + pipeline
    # lookup) or from an explicit ``QRConfig.block_rows``.  A scripted
    # timer keeps the tuning itself deterministic and instant.
    from repro.kernels import autotune as at

    ticks = iter(range(1, 1 << 20))
    at.tune([(96, 40)], ("gram", "trailing_update"),
            timer=lambda: next(ticks) * 1e-4, reps=1, measure_top=2,
            out_dir=None)
    try:
        tuned_checks = [
            ("kernel:gram",
             lambda: kops.gram(a[0], use_pallas=True)),
            ("blocked_qr_pipeline",
             lambda: factorize(a, QRConfig(panel_width=12))),
            ("blocked_qr_pipeline",
             lambda: factorize(a, QRConfig(panel_width=12, block_rows=16))),
        ]
        for name, fn in tuned_checks:
            fn()                                 # warm under the new key
            before = disp.trace_count(name)
            fn()                                 # must not trace again
            delta = disp.trace_count(name) - before
            status = "ok" if delta == 0 else f"RETRACED x{delta}"
            print(f"[retrace-guard] tuned:{name}: {status}")
            failures += delta != 0
    finally:
        at.clear()                               # never leak tuned state
    return failures


def main(argv: list[str] | None = None) -> int:
    import sys

    args = sys.argv[1:] if argv is None else argv
    if "--guard" in args:
        failures = guard()
        if failures:
            print(f"[retrace-guard] {failures} entry point(s) re-traced",
                  file=sys.stderr)
        return 1 if failures else 0
    print("# blocked QR single-program dispatch/trace accounting")
    rows = run()
    for k, v in rows.items():
        print(f"{k}: {v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
