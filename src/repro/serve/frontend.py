"""The QR serving front-end: continuous batching with fault re-serve.

``QRServer`` is the first consumer of the unified :func:`repro.qr.api.
factorize` facade.  The request lifecycle:

  1. **Bucket** — an ``(m, n)`` request routes to the cheapest configured
     :class:`~repro.serve.buckets.BucketSpec` admitting it and queues
     there (identity-extension padding, see :mod:`repro.serve.buckets`).
  2. **Drain** — when a bucket's queue reaches its planned ``max_batch``
     (or on :meth:`QRServer.flush`), the batch is topped up to exactly
     ``max_batch`` with identity fillers, row-blocked, and shipped through
     the batched scan pipeline: B factorizations, ONE device dispatch
     (hard-gated by the ``serving`` bench case).
  3. **Re-serve on fault** — if the fault injector strikes a drain
     mid-flight, the batched result is treated as lost and every real
     request of that batch is *re-served*, matrix-by-matrix, through the
     eager general driver with the actual death schedule; the butterfly's
     replica copies restore the lost factors
     (:func:`~repro.collective.engine.replica_fetch`), so the re-served
     factors are bit-identical to a fault-free re-run of the same padded
     request (the ``serving`` bench gates this too).  Requests are never
     dropped.
  4. **Pre-warm** — :meth:`QRServer.prewarm` drains one filler batch per
     bucket through the batched pipeline and runs one eager fallback
     factorization per bucket, so warm serving performs ZERO new traces
     across the whole bucket set (extends the CI retrace guard).

Per-bucket panel width, local-R variant and ``max_batch`` come from the
deterministic cost model in :mod:`repro.serve.planner`; the decisions are
exposed via :meth:`QRServer.planner_decisions` for the bench artifact.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections.abc import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch as _dispatch
from repro.qr.api import Pipeline, QRConfig, factorize
from repro.qr.blocked import PIPELINE_NAME, PanelFaultSchedule

from .buckets import (
    BucketSpec,
    block_rows,
    bucket_for,
    default_buckets,
    extract_r,
    filler_matrix,
    pad_request,
    validate_buckets,
)
from .planner import BucketPlan, CostModel, plan_bucket

__all__ = [
    "PeriodicFaultInjector",
    "QRRequest",
    "QRResponse",
    "QRServer",
    "ServerStats",
]


@dataclasses.dataclass(frozen=True)
class QRRequest:
    """One factorization request: a single (m, n) matrix."""

    rid: int
    a: np.ndarray


@dataclasses.dataclass
class QRResponse:
    """The served factor and its provenance.

    ``served_via`` — ``"batched"`` (rode a one-dispatch bucket drain) or
    ``"reserved"`` (its drain hit an injected fault and it was re-served
    through the eager general driver with replica recovery).
    """

    rid: int
    r: np.ndarray
    bucket: BucketSpec
    served_via: str
    drain_index: int
    latency_s: float


@dataclasses.dataclass
class ServerStats:
    """Serving-run counters the bench case gates on."""

    served: int = 0
    reserved: int = 0
    drains: int = 0
    faulted_drains: int = 0
    filler_slots: int = 0
    dispatches_per_drain: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PeriodicFaultInjector:
    """Deterministic mid-flight death source: strikes every ``period``-th
    drain with a within-tolerance single-rank death (drawn once from
    :func:`repro.collective.faults.sample_within_tolerance`, so the batch
    is always re-servable from replicas)."""

    def __init__(
        self,
        period: int,
        schedule: PanelFaultSchedule,
    ):
        if period <= 0:
            raise ValueError(f"period must be positive, got {period}")
        if not schedule:
            raise ValueError("injector needs a non-empty fault schedule")
        self.period = period
        self.schedule = schedule

    @classmethod
    def sampled(
        cls, period: int, *, variant: str, p: int, panel: int = 0, seed: int = 0
    ) -> "PeriodicFaultInjector":
        """Death sampled within ``variant``'s tolerance for a P-rank
        butterfly, scheduled into panel ``panel``'s reduction."""
        import math

        from repro.collective.faults import sample_within_tolerance

        spec = sample_within_tolerance(
            variant, p, int(math.log2(p)), np.random.default_rng(seed)
        )
        return cls(period, PanelFaultSchedule.of(panel={panel: spec}))

    def __call__(
        self, spec: BucketSpec, drain_index: int
    ) -> PanelFaultSchedule | None:
        if (drain_index + 1) % self.period == 0:
            return self.schedule
        return None


@dataclasses.dataclass
class _Entry:
    request: QRRequest
    t_submit: float
    future: asyncio.Future | None = None


class QRServer:
    """Shape-bucketed continuous batching over the batched QR pipeline.

    ``fault_injector`` is any ``(bucket, drain_index) ->
    PanelFaultSchedule | None`` callable (see
    :class:`PeriodicFaultInjector`); ``None`` serves fault-free.
    """

    def __init__(
        self,
        buckets: Iterable[BucketSpec] | None = None,
        *,
        p: int = 4,
        variant: str = "redundant",
        reorth: int = 1,
        model: CostModel | None = None,
        fault_injector=None,
    ):
        self.buckets = tuple(sorted(buckets or default_buckets()))
        validate_buckets(self.buckets, p)
        self.p = p
        self.fault_injector = fault_injector
        self.plans: dict[BucketSpec, BucketPlan] = {
            spec: plan_bucket(spec, p, model) for spec in self.buckets
        }
        self.configs: dict[BucketSpec, QRConfig] = {
            spec: QRConfig(
                panel_width=plan.panel_width,
                local_r=plan.local_r,
                variant=variant,
                reorth=reorth,
            )
            for spec, plan in self.plans.items()
        }
        self._queues: dict[BucketSpec, list[_Entry]] = {
            spec: [] for spec in self.buckets
        }
        self._drain_index = 0
        self._next_rid = 0
        self.stats = ServerStats()
        self.prewarm_traces: dict | None = None

    # -- planning surface ---------------------------------------------------

    def bucket_of(self, m: int, n: int) -> BucketSpec:
        return bucket_for(self.buckets, m, n)

    def planner_decisions(self) -> list[dict]:
        """The cost model's per-bucket choices, for the bench artifact."""
        return [self.plans[spec].as_dict() for spec in self.buckets]

    # -- warmup -------------------------------------------------------------

    def prewarm(self) -> dict:
        """Compile every warm-path program up front: one filler drain per
        bucket through the batched pipeline plus one eager general-driver
        run per bucket (the re-serve fallback's kernel shapes are fixed by
        the bucket geometry, so this covers the fault path too).  Returns
        the per-phase trace counts; after this, serving any stream over
        the bucket set performs zero new traces."""
        t0 = _dispatch.trace_count()
        for spec in self.buckets:
            batch = self._filler_batch(spec)
            res = factorize(jnp.asarray(batch), self.configs[spec])
            jax.block_until_ready(res.r)
        t_batched = _dispatch.trace_count()
        for spec in self.buckets:
            blocks = block_rows(filler_matrix(spec), self.p)
            cfg = dataclasses.replace(
                self.configs[spec], pipeline=Pipeline.OFF
            )
            res = factorize(jnp.asarray(blocks), cfg)
            jax.block_until_ready(res.r)
        t_end = _dispatch.trace_count()
        self.prewarm_traces = {
            "batched_pipeline": t_batched - t0,
            "eager_fallback": t_end - t_batched,
        }
        return self.prewarm_traces

    def _filler_batch(self, spec: BucketSpec) -> np.ndarray:
        fill = block_rows(filler_matrix(spec), self.p)
        return np.broadcast_to(
            fill, (self.plans[spec].max_batch,) + fill.shape
        ).copy()

    # -- request intake -----------------------------------------------------

    def submit(self, a: np.ndarray, *, rid: int | None = None,
               future: asyncio.Future | None = None) -> list[QRResponse]:
        """Queue one request; returns the responses (for the whole batch)
        if this submission filled its bucket and triggered a drain, else
        an empty list.  Continuous batching: callers keep submitting and
        collect completions as they come, then :meth:`flush` the tail."""
        a = np.asarray(a, dtype=np.float32)
        if a.ndim != 2:
            raise ValueError(
                f"a request is one (m, n) matrix, got shape {a.shape}"
            )
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        spec = self.bucket_of(*a.shape)
        entry = _Entry(
            QRRequest(rid=rid, a=a), t_submit=time.perf_counter(),
            future=future,
        )
        queue = self._queues[spec]
        queue.append(entry)
        if len(queue) >= self.plans[spec].max_batch:
            return self._drain(spec)
        return []

    async def submit_async(self, a: np.ndarray) -> QRResponse:
        """Async intake: resolves with this request's own response when its
        bucket drains (batch completion resolves every rider's future)."""
        fut = asyncio.get_running_loop().create_future()
        self.submit(a, future=fut)
        return await fut

    def flush(self) -> list[QRResponse]:
        """Drain every non-empty bucket queue (short batches are topped up
        with fillers — the drained program is always the same shape)."""
        out: list[QRResponse] = []
        for spec in self.buckets:
            if self._queues[spec]:
                out.extend(self._drain(spec))
        return out

    # -- the drain ----------------------------------------------------------

    def _drain(self, spec: BucketSpec) -> list[QRResponse]:
        entries = self._queues[spec]
        self._queues[spec] = []
        plan, config = self.plans[spec], self.configs[spec]
        idx = self._drain_index
        self._drain_index += 1
        fill = plan.max_batch - len(entries)
        mats = [pad_request(e.request.a, spec) for e in entries]
        mats += [filler_matrix(spec)] * fill
        batch = np.stack([block_rows(m, self.p) for m in mats])
        fault = (
            self.fault_injector(spec, idx) if self.fault_injector else None
        )
        with _dispatch.track_dispatch() as d:
            res = factorize(jnp.asarray(batch), config)
            jax.block_until_ready(res.r)
        self.stats.drains += 1
        self.stats.filler_slots += fill
        self.stats.dispatches_per_drain.append(
            int(d.dispatches[PIPELINE_NAME])
        )
        if fault:
            # Mid-flight death: the batched program has no validity
            # machinery, so the whole drain is lost — re-serve every real
            # request through the replica-recovering general driver.
            self.stats.faulted_drains += 1
            responses = [
                self._reserve(e, spec, config, fault, idx) for e in entries
            ]
        else:
            r_batch = np.asarray(res.r)
            done = time.perf_counter()
            responses = [
                QRResponse(
                    rid=e.request.rid,
                    r=extract_r(r_batch[i, 0], e.request.a.shape[1]),
                    bucket=spec,
                    served_via="batched",
                    drain_index=idx,
                    latency_s=done - e.t_submit,
                )
                for i, e in enumerate(entries)
            ]
        self.stats.served += len(responses)
        for e, resp in zip(entries, responses):
            if e.future is not None and not e.future.done():
                e.future.set_result(resp)
        return responses

    def _reserve(
        self,
        entry: _Entry,
        spec: BucketSpec,
        config: QRConfig,
        fault: PanelFaultSchedule,
        idx: int,
    ) -> QRResponse:
        """Serve one request of a faulted batch through the eager general
        driver, injecting the actual death; replica recovery makes the
        result bit-identical to a fault-free run of the same padded
        request (within-tolerance survivors compute identical arithmetic
        and ``replica_fetch`` copies exact values)."""
        blocks = block_rows(pad_request(entry.request.a, spec), self.p)
        res = factorize(jnp.asarray(blocks), config, faults=fault)
        if not res.recoverable:
            raise RuntimeError(
                f"injected fault {fault} exceeded tolerance on {spec}; "
                "the injector must sample within-tolerance deaths"
            )
        self.stats.reserved += 1
        return QRResponse(
            rid=entry.request.rid,
            r=extract_r(np.asarray(res.r[0]), entry.request.a.shape[1]),
            bucket=spec,
            served_via="reserved",
            drain_index=idx,
            latency_s=time.perf_counter() - entry.t_submit,
        )

    # -- convenience --------------------------------------------------------

    def serve(self, matrices: Sequence[np.ndarray]) -> list[QRResponse]:
        """Serve a whole stream synchronously (submit all + flush), returning
        responses sorted by request id (submission order)."""
        out: list[QRResponse] = []
        for a in matrices:
            out.extend(self.submit(a))
        out.extend(self.flush())
        return sorted(out, key=lambda r: r.rid)
