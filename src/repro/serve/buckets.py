"""Shape buckets: the serving layer's compile classes.

A heterogeneous request stream would retrace the jitted pipeline once per
distinct shape — the opposite of the zero-retrace contract.  The fix
generalizes the pipeline's own padding trick (DESIGN.md §9 pads every
*panel* to the maximal width and masks the dead columns) up one level: pad
every *request* into one of a small, fixed set of ``(m_pad, n_pad)``
compile classes, so the whole stream is served by a handful of compiled
programs that are all pre-warmed at startup.

**Identity-extension padding.**  Zero-padding the columns would hand the
blocked driver a rank-deficient matrix — every panel Gram containing a pad
column would be singular and its lookahead Cholesky NaN.  Instead a
request ``A`` of shape ``(m, n)`` is embedded as::

    [ A      0   ]      k = n_pad − n  pad columns
    [ 0      I_k ]      k  pad rows carrying an identity
    [ 0      0   ]      remaining row padding

The pad columns have unit norm, are exactly orthogonal to the real
columns (disjoint row support), and the padded matrix's R factor is
``[[R_A, 0], [0, I_k]]`` up to roundoff — so the caller's factor is the
top-left ``(n, n)`` block of the padded result and the pad never
perturbs it beyond ordinary fp reassociation.  The embedding needs
``m + k ≤ m_pad``, which :meth:`BucketSpec.admits` enforces.

Buckets also fix the *batch* geometry: a drain always ships exactly
``max_batch`` matrices (short drains are topped up with identity
fillers), so every drain of a bucket is the same compiled program and a
re-served request's arithmetic is independent of whatever else rode its
batch.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterable, Sequence

import numpy as np

__all__ = [
    "BucketSpec",
    "bucket_for",
    "default_buckets",
    "extract_r",
    "filler_matrix",
    "pad_request",
]


@dataclasses.dataclass(frozen=True, order=True)
class BucketSpec:
    """One compile class: requests served through this bucket are padded to
    ``(m_pad, n_pad)`` and row-blocked over the server's P simulated ranks.
    """

    m_pad: int
    n_pad: int

    def __post_init__(self) -> None:
        if self.m_pad < self.n_pad or self.n_pad <= 0:
            raise ValueError(
                f"bucket must be tall-or-square with positive width, got "
                f"({self.m_pad}, {self.n_pad})"
            )

    @property
    def area(self) -> int:
        return self.m_pad * self.n_pad

    def admits(self, m: int, n: int) -> bool:
        """Can an ``(m, n)`` request be identity-extended into this bucket?
        Needs ``n ≤ n_pad`` columns and room for the ``k = n_pad − n``
        identity rows under the real rows."""
        k = self.n_pad - n
        return 0 < n <= self.n_pad and 0 < m and m + k <= self.m_pad


def default_buckets() -> tuple[BucketSpec, ...]:
    """A small power-of-two ladder covering tall-and-skinny request mixes."""
    return (
        BucketSpec(256, 32),
        BucketSpec(512, 64),
        BucketSpec(1024, 128),
    )


def bucket_for(
    buckets: Iterable[BucketSpec], m: int, n: int
) -> BucketSpec:
    """The cheapest (smallest padded area) bucket admitting ``(m, n)``."""
    fits = [b for b in buckets if b.admits(m, n)]
    if not fits:
        raise ValueError(
            f"no bucket admits a ({m}, {n}) request; configured buckets: "
            f"{sorted(buckets)} (each needs n <= n_pad and "
            "m + (n_pad - n) <= m_pad)"
        )
    return min(fits, key=lambda b: (b.area, b.n_pad, b.m_pad))


def pad_request(a: np.ndarray, spec: BucketSpec) -> np.ndarray:
    """Identity-extend ``a`` to the bucket's ``(m_pad, n_pad)`` canvas."""
    m, n = a.shape
    if not spec.admits(m, n):
        raise ValueError(f"{spec} does not admit a ({m}, {n}) request")
    k = spec.n_pad - n
    out = np.zeros((spec.m_pad, spec.n_pad), dtype=np.float32)
    out[:m, :n] = a
    if k:
        out[m:m + k, n:] = np.eye(k, dtype=np.float32)
    return out


def filler_matrix(spec: BucketSpec) -> np.ndarray:
    """The batch top-up payload: a padded identity (orthonormal columns, so
    its R is exactly I — numerically inert, never rank-deficient)."""
    return np.eye(spec.m_pad, spec.n_pad, dtype=np.float32)


def extract_r(r_pad: np.ndarray, n: int) -> np.ndarray:
    """The request's factor out of the padded result: the pad columns land
    in the trailing ``k`` rows/columns of ``R_pad``, so the caller's R is
    the top-left ``(n, n)`` block."""
    return np.asarray(r_pad)[..., :n, :n]


def block_rows(a_pad: np.ndarray, p: int) -> np.ndarray:
    """Row-block a padded ``(m_pad, n_pad)`` matrix over P simulated ranks
    → ``(P, m_local, n_pad)``."""
    m_pad, n_pad = a_pad.shape
    if m_pad % p:
        raise ValueError(f"m_pad={m_pad} not divisible by P={p} ranks")
    return a_pad.reshape(p, m_pad // p, n_pad)


def validate_buckets(buckets: Sequence[BucketSpec], p: int) -> None:
    """Server-startup validation: every bucket must row-block over P."""
    seen = set()
    for spec in buckets:
        if spec in seen:
            raise ValueError(f"duplicate bucket {spec}")
        seen.add(spec)
        if spec.m_pad % p:
            raise ValueError(
                f"{spec}: m_pad must be divisible by P={p} simulated ranks"
            )
