"""Cost-aware bucket planning: panel width, local-R variant, batch size.

The dispatcher's knobs should come from a model, not from hardcoded
defaults — the same shape-wise planning idea as torchrec's
``EmbeddingPerfEstimator`` (perf = comms + compute + HBM sweeps per
shard), instantiated on this repo's own accounting:

  * **HBM bytes** mirror ``repro.qr.blocked._note_pipeline`` exactly —
    the prime sweep (``pad_cross``) plus ``K − 1`` fused trailing sweeps
    at the padded maximal width, the quantities the roofline report and
    the ``general_qr`` bench case gate.
  * **Collective rounds** mirror ``repro.kernels.dispatch.note_rounds``:
    the fused schedule ships ONE stacked butterfly per panel, so a
    factorization costs ``K · log₂P`` serial rounds (Langou's
    single-reduce ideal per panel, PR 6's hard gate).
  * **Dispatch overhead** is amortized by continuous batching: the scan
    pipeline launches one program per *drain*, so per matrix it costs
    ``overhead / B``.

Every quantity is a pure function of ``(bucket, P, CostModel)`` — no
clocks, no measurements — so planning is deterministic, the serving bench
can hard-gate the recorded decisions, and the decision table in the bench
artifact is auditable after the fact.
"""
from __future__ import annotations

import dataclasses
import math

from repro.qr.blocked import panel_widths

from .buckets import BucketSpec

__all__ = [
    "BucketPlan",
    "CostModel",
    "plan_bucket",
]

_F32 = 4  # serving payload itemsize


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Machine constants the planner prices against (defaults are
    order-of-magnitude host-CPU figures; a deployment would calibrate them
    from the roofline report, which measures exactly these quantities)."""

    mem_bw_bytes_per_s: float = 4.0e10
    flops_per_s: float = 2.0e11
    dispatch_overhead_s: float = 5.0e-5
    round_latency_s: float = 5.0e-6
    # Continuous-batching limits: padded payload bytes a drain may occupy,
    # and a cap keeping per-request queueing latency bounded.
    batch_bytes_budget: int = 1 << 28
    max_batch_cap: int = 16
    panel_width_candidates: tuple[int, ...] = (8, 16, 32, 64, 128)

    @classmethod
    def tuned(cls, **overrides) -> "CostModel":
        """A model fed from the installed autotune table's *measured*
        machine constants (bandwidth and peak from the tuner's probes)
        instead of the static defaults.  With no table installed this is
        exactly ``CostModel()`` — deterministic tests and artifacts are
        unchanged until a deployment actually tunes.  Scheduling knobs
        (budgets, caps, candidates) keep their defaults unless overridden;
        planning stays a pure function of its inputs — the tuned constants
        are part of those inputs, recorded in the bench artifact."""
        from repro.kernels import autotune as _autotune

        mc = _autotune.machine_constants() or {}
        kw = {}
        if mc.get("mem_bw_bytes_per_s"):
            kw["mem_bw_bytes_per_s"] = float(mc["mem_bw_bytes_per_s"])
        if mc.get("flops_per_s"):
            kw["flops_per_s"] = float(mc["flops_per_s"])
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """The planner's decision for one bucket, with its audit trail."""

    spec: BucketSpec
    panel_width: int
    local_r: str
    max_batch: int
    predicted_matrix_s: float     # per-matrix service time at full batch
    predicted_drain_s: float      # one drained batch, dispatch included
    candidates: tuple[tuple[int, str, float, bool], ...]
    # ^ every (panel_width, local_r, predicted_matrix_s, admissible) scored

    def as_dict(self) -> dict:
        return {
            "bucket": [self.spec.m_pad, self.spec.n_pad],
            "panel_width": self.panel_width,
            "local_r": self.local_r,
            "max_batch": self.max_batch,
            "predicted_matrix_s": self.predicted_matrix_s,
            "predicted_drain_s": self.predicted_drain_s,
            "candidates": [list(c) for c in self.candidates],
        }


def _pipeline_bytes(
    p: int, m_local: int, n: int, widths: tuple[int, ...]
) -> int:
    """HBM bytes of one scan-pipeline factorization — the same per-sweep
    formulas ``_note_pipeline`` records (prime + K−1 trailing sweeps at the
    padded maximal trailing width)."""
    b, k_panels = widths[0], len(widths)
    n_pad = b * k_panels
    total = p * m_local * n * _F32                      # prime read
    total += p * (m_local * n_pad * _F32 + b * n_pad * _F32)  # prime write
    nt = n_pad - b
    per_sweep = p * (
        m_local * nt * _F32 + m_local * b * _F32 + b * nt * _F32  # reads
        + m_local * nt * _F32 + b * nt * _F32                     # writes
    )
    return total + (k_panels - 1) * per_sweep


def _pipeline_flops(m: int, n: int, widths: tuple[int, ...]) -> float:
    """Leading-order flop count: the trailing GEMM pair (2mn² form W +
    2mn² apply) dominates; panel-local work is O(mnb)."""
    b = widths[0]
    return 4.0 * m * n * n + 2.0 * m * n * b


def _local_r_extra_bytes(
    local_r: str, p: int, m_local: int, widths: tuple[int, ...]
) -> int:
    """``chol`` derives every panel R from the lookahead Gram accumulated
    inside the trailing sweep — zero extra bytes.  A Householder local QR
    (``jnp``) re-reads each m×b panel once more."""
    if local_r == "chol":
        return 0
    return sum(p * m_local * b * _F32 for b in widths)


def _score(
    spec: BucketSpec,
    p: int,
    panel_width: int,
    local_r: str,
    max_batch: int,
    model: CostModel,
) -> float:
    """Predicted per-matrix service time for one (width, local-R) choice."""
    m_local = spec.m_pad // p
    widths = panel_widths(spec.n_pad, panel_width)
    hbm = _pipeline_bytes(p, m_local, spec.n_pad, widths)
    hbm += _local_r_extra_bytes(local_r, p, m_local, widths)
    flops = _pipeline_flops(spec.m_pad, spec.n_pad, widths)
    # Roofline: sweeps and math overlap on real hardware — take the max —
    # while the K·log₂P serial butterfly rounds are latency-bound and
    # additive (they sit on the critical path between sweeps).
    t_roof = max(hbm / model.mem_bw_bytes_per_s, flops / model.flops_per_s)
    t_rounds = len(widths) * math.ceil(math.log2(p)) * model.round_latency_s
    return t_roof + t_rounds + model.dispatch_overhead_s / max_batch


def plan_bucket(
    spec: BucketSpec,
    p: int,
    model: CostModel | None = None,
    *,
    rank_deficient_inputs: bool = True,
) -> BucketPlan:
    """Pick ``(panel_width, local_r, max_batch)`` for one bucket.

    ``max_batch`` is budget-driven (padded payload bytes per drain, capped
    for latency); width and local-R minimize the predicted per-matrix time
    over the candidate grid, ties broken toward the wider panel (fewer
    butterflies).  Deterministic: equal inputs always produce the equal
    plan, which lets the serving bench hard-gate the recorded decisions.

    ``rank_deficient_inputs`` (the serving default) marks the Cholesky
    local factorizations *inadmissible*: identity-extension padding leaves
    a request's pad columns exactly zero on most ranks, so a per-rank
    local Gram is singular and its Cholesky NaN.  The Householder local QR
    is safe — rank-deficient local R factors still carry the exact local
    Gram, which the butterfly's stacked combines sum back to the
    (nonsingular) global Gram.  Inadmissible candidates stay in the audit
    table (``admissible=False``) so the cost comparison remains visible.
    """
    model = model or CostModel.tuned()
    matrix_bytes = spec.area * _F32
    max_batch = max(
        1, min(model.max_batch_cap, model.batch_bytes_budget // matrix_bytes)
    )
    m_local = spec.m_pad // p
    cand_widths = [
        b for b in model.panel_width_candidates
        if b <= spec.n_pad and b <= m_local
    ] or [min(spec.n_pad, m_local)]
    scored = []
    for b in cand_widths:
        for local_r in ("chol", "jnp"):
            admissible = not (rank_deficient_inputs and local_r == "chol")
            scored.append((
                b, local_r,
                _score(spec, p, b, local_r, max_batch, model), admissible,
            ))
    best = min(
        (c for c in scored if c[3]), key=lambda c: (c[2], -c[0])
    )
    t_matrix = best[2]
    return BucketPlan(
        spec=spec,
        panel_width=best[0],
        local_r=best[1],
        max_batch=max_batch,
        predicted_matrix_s=t_matrix,
        predicted_drain_s=t_matrix * max_batch,
        candidates=tuple(scored),
    )
