"""QR-as-a-service: shape-bucketed continuous batching over the repo's
fault-tolerant factorization pipelines (DESIGN.md §11).

The ROADMAP's north star is serving heavy traffic, and PR 5/6 built the
machinery a serving path needs — the one-dispatch batched scan pipeline,
zero-retrace cached compiles, and replica-fetch recovery.  This package
drives them under load:

  * :mod:`repro.serve.buckets`  — shape buckets (compile classes) and the
    identity-extension request padding.
  * :mod:`repro.serve.planner`  — the deterministic cost model picking
    panel width, local-R variant and max batch size per bucket.
  * :mod:`repro.serve.frontend` — :class:`QRServer`: async intake,
    continuous batching, pre-warm, and fault re-serve (requests whose
    batch hits an injected mid-flight death are re-served through the
    replica-recovering general driver, never dropped).

The hard-gated ``serving`` bench case measures throughput, p50/p99
latency, one dispatch per drain, zero warm retraces, and bitwise
re-serve fidelity over a mixed-shape stream with injected deaths.
"""
from .buckets import (
    BucketSpec,
    bucket_for,
    default_buckets,
    extract_r,
    filler_matrix,
    pad_request,
)
from .frontend import (
    PeriodicFaultInjector,
    QRRequest,
    QRResponse,
    QRServer,
    ServerStats,
)
from .planner import BucketPlan, CostModel, plan_bucket

__all__ = [
    "BucketPlan",
    "BucketSpec",
    "CostModel",
    "PeriodicFaultInjector",
    "QRRequest",
    "QRResponse",
    "QRServer",
    "ServerStats",
    "bucket_for",
    "default_buckets",
    "extract_r",
    "filler_matrix",
    "pad_request",
    "plan_bucket",
]
