"""Elastic mesh management: SHRINK / REBUILD at the device level.

``shrink_mesh`` halves the data axis (power-of-two widths keep the
collective butterfly well-formed and the collectives balanced) and returns a
mesh over the surviving device subset; state is re-sharded by the trainer
via device_put.  ``rebuild_mesh`` re-creates the original topology once
replacement hardware is available (REBUILD semantics).

Mesh construction goes through :mod:`repro.compat` so the module imports on
jax versions without ``jax.sharding.AxisType`` (plain ``Mesh(...)`` kwargs).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import mesh_from_devices

__all__ = ["shrink_mesh", "rebuild_mesh"]


def _axis_index(mesh: Mesh, name: str) -> int:
    return mesh.axis_names.index(name)


def shrink_mesh(mesh: Mesh, drop_replicas: int = 1) -> Mesh | None:
    """Return a mesh with the data axis halved (dropping ≥ drop_replicas),
    or None if no further shrink is possible."""
    if "data" not in mesh.axis_names:
        return None
    ax = _axis_index(mesh, "data")
    d = mesh.devices.shape[ax]
    new_d = d // 2
    while new_d > 0 and d - new_d < drop_replicas:
        new_d //= 2
    if new_d < 1:
        return None
    take = [slice(None)] * mesh.devices.ndim
    take[ax] = slice(0, new_d)
    devs = mesh.devices[tuple(take)]
    return mesh_from_devices(devs, mesh.axis_names)


def rebuild_mesh(template_mesh: Mesh) -> Mesh:
    """REBUILD: re-instantiate the full original topology (replacement
    devices joined).  On real fleets this waits for the scheduler; here the
    devices never physically left.  The trainer drives this via the
    ``"rejoin"`` :class:`~repro.runtime.trainer.FaultEvent` (the inverse of
    an elastic shrink), which the fault-scenario benchmarks schedule to
    exercise shrink→rebuild round trips."""
    return mesh_from_devices(template_mesh.devices, template_mesh.axis_names)
