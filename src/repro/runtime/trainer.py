"""Fault-tolerant training runtime.

Exposes the paper's three failure semantics at training-step granularity
(DESIGN.md §2 — the step boundary is where a TPU fleet adjudicates health):

  * ``rebuild``  (Self-Healing / REBUILD): the lost replica's state is
    restored — from the in-memory buddy store when a replica exists
    (diskless path, zero I/O), else from the latest disk checkpoint — and
    the step is retried at full width.
  * ``shrink``   (Replace / SHRINK): the mesh is rebuilt without the lost
    replicas' devices; state is resharded onto the smaller mesh and the
    run continues at reduced width (elastic scaling).
  * ``blank``    (Redundant / BLANK): the dead replica's rows are masked
    out of the loss (weight 0) and the gradient rescales over survivors;
    width is restored when the replica returns.  With >1 replicas the
    gradient combine itself runs through the collective engine's
    :func:`~repro.collective.engine.ft_allreduce` (redundant butterfly,
    ``sum`` combiner) over the explicit replica axis, so the reduction
    inherits the paper's 2^s − 1 mid-reduce tolerance instead of relying
    on a fault-oblivious mesh all-reduce.

Failures are injected via a schedule of :class:`FaultEvent` — this CPU
container has no real failing hosts, so the runtime consumes simulated
health transitions exactly where a real deployment consumes its health
service.  Straggler mitigation: a step-time EMA flags outliers; in
``blank`` mode flagged replicas are masked for the step (drop-straggler
gradient), otherwise they are only logged.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.replicated import BuddyStore
from repro.collective import SimComm, ft_allreduce, make_plan
from repro.compat import mesh_fingerprint
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.kernels import dispatch as _dispatch
from repro.models import api
from repro.models.partitioning import param_shardings
from repro.models.sharding import batch_axes, mesh_context
from repro.optim import adamw, lowrank, orthosgd, powersgd

__all__ = [
    "TrainerConfig",
    "FaultEvent",
    "Trainer",
    "ft_replica_grad",
    "replica_grads",
]


def replica_grads(loss_fn, params, batch, n_replicas: int):
    """Per-replica losses and gradients over the trainer's replica layout.

    ``batch`` rows are split into ``n_replicas`` contiguous slices and
    per-replica gradients taken with vmap; liveness derives from the
    ``loss_weight`` mask (an all-zero slice — failed or dropped-straggler
    replica masked by ``Trainer._mask_for`` — is dead).  Returns
    ``(losses (R,), grads with leading (R,) axis, live (R,) bool,
    n_live f32 ≥ 1)`` — the raw material both the BLANK gradient combine
    (:func:`ft_replica_grad`) and the in-step PowerSGD round
    (:func:`repro.optim.powersgd.compress_mean_grad`) reduce over.
    """
    rep = jax.tree.map(
        lambda x: x.reshape((n_replicas, x.shape[0] // n_replicas) + x.shape[1:]),
        batch,
    )
    losses, grads = jax.vmap(
        lambda b: jax.value_and_grad(loss_fn)(params, b)
    )(rep)
    live = rep["loss_weight"].reshape(n_replicas, -1).sum(-1) > 0
    n_live = jnp.maximum(live.sum(), 1).astype(jnp.float32)
    return losses, grads, live, n_live


def mask_replica_tree(tree, live, n_replicas: int):
    """Zero every dead replica's slice of each leading-(R,) leaf."""

    def mask(g):
        m = live.reshape((n_replicas,) + (1,) * (g.ndim - 1))
        return g * m.astype(g.dtype)

    return jax.tree.map(mask, tree)


def ft_replica_grad(loss_fn, params, batch, n_replicas: int, fault_spec=None):
    """BLANK-semantics gradient combine over an explicit replica axis.

    ``batch`` rows are split into ``n_replicas`` contiguous slices (the
    trainer's replica layout), per-replica gradients are taken with vmap,
    dead replicas — identified by an all-zero ``loss_weight`` slice, i.e.
    failed or dropped-straggler replicas masked by ``Trainer._mask_for`` —
    are zeroed, and the survivor gradients are combined with
    :func:`~repro.collective.engine.ft_allreduce` (redundant butterfly,
    ``sum`` combiner) on a :class:`~repro.collective.comm.SimComm` whose
    rank axis is the replica axis.  ``fault_spec`` injects mid-reduce rank
    failures for robustness testing.

    Returns ``(loss, grads)`` where both are means over *live* replicas.

    Note the cost model: this materializes per-replica gradient trees
    (R× the fused path's peak gradient memory) — it is the fault-tolerance
    demonstration path; set ``TrainerConfig.ft_grad_allreduce=False`` to
    keep the fused mesh all-reduce.
    """
    # Host plan first: the combined gradient must be read from a slot the
    # planner certifies valid (slot 0 is NOT guaranteed to survive an
    # in-tolerance fault — e.g. {2: 1} on R=4 invalidates rank 0's coset).
    plan = make_plan("redundant", n_replicas, fault_spec)
    if not plan.final_valid.any():
        raise ValueError(
            "fault_spec exceeds the butterfly's tolerance: no replica slot "
            f"holds the combined gradient (final_valid={plan.final_valid})"
        )
    slot = int(np.argmax(plan.final_valid))

    losses, grads, live, n_live = replica_grads(
        loss_fn, params, batch, n_replicas
    )
    summed, _ = ft_allreduce(
        mask_replica_tree(grads, live, n_replicas),
        SimComm(n_replicas), op="sum", plan=plan,
    )
    grads = jax.tree.map(lambda g: g[slot] / n_live, summed)
    loss = jnp.where(live, losses, 0.0).sum() / n_live
    return loss, grads


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str              # "fail" | "recover" | "straggle" | "rejoin"
    replica: int = 0       # data-parallel replica index (unused for rejoin)
    duration: int = 1      # steps (straggle)


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 50
    log_every: int = 10
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 2
    microbatches: int = 1
    on_failure: str = "blank"          # blank | shrink | rebuild
    optimizer: str = "adamw"           # adamw | powersgd | orthosgd | lowrank
    lr: float = 3e-4
    # PowerSGD / low-rank compression rank, and the shard count for the
    # in-step fault-tolerant CQR2 (orthosgd/lowrank Gram butterflies).
    opt_rank: int = 8
    qr_shards: int = 4
    # Route the optimizer's in-step collectives (PowerSGD reductions +
    # TSQR, CQR2 Gram sums) through the fault-tolerant butterfly; False is
    # the dense parity baseline (plain sums, GSPMD CQR2).
    ft_in_step: bool = True
    straggler_factor: float = 3.0
    drop_stragglers: bool = True
    buddy_levels: int = 1              # 2^levels in-memory replicas
    # BLANK mode: combine gradients with the fault-tolerant butterfly
    # (ft_replica_grad).  Costs R× peak gradient memory vs the fused mesh
    # all-reduce — disable to keep the fused path.
    ft_grad_allreduce: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg, tcfg: TrainerConfig, mesh, data_cfg: DataConfig,
                 opt_cfg: adamw.AdamWConfig | None = None):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.data_cfg = data_cfg
        # warmup must fit inside the run: smoke/short runs would otherwise
        # never leave the ramp (default warmup 100 ≫ a 10-step run).
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            lr=tcfg.lr, total_steps=tcfg.steps,
            warmup=min(100, max(1, tcfg.steps // 10)),
        )
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.n_replicas = self._mesh_replicas(mesh)
        # buddy_levels=0 disables the diskless store entirely (forces the
        # disk-rollback REBUILD path — fault-scenario sweeps rely on this).
        self.buddies = BuddyStore(max(2, 1 << (self.n_replicas - 1).bit_length())) \
            if self.n_replicas > 1 and tcfg.buddy_levels > 0 else None
        self.alive = np.ones(self.n_replicas, dtype=bool)
        self.straggling = np.zeros(self.n_replicas, dtype=np.int64)
        self.metrics_log: list[dict] = []
        self.events_log: list[str] = []
        # Structured counters consumed by the fault-scenario benchmarks
        # (repro.bench.scenarios) — the machine-readable twin of events_log.
        self.fault_stats: dict[str, int] = {
            "failures": 0, "recoveries": 0, "straggles": 0, "rollbacks": 0,
            "buddy_restores": 0, "shrinks": 0, "rejoins": 0, "masked_steps": 0,
        }
        # REBUILD-to-full-width target: the topology we started with.
        self._template_mesh = mesh
        # Compiled-step cache keyed on the mesh *equivalence class*
        # (compat.mesh_fingerprint): an elastic shrink→rebuild cycle ends on
        # a mesh fingerprinting identically to the template, so _build
        # restores the original jitted step — same jit cache entry, zero
        # retraces (DESIGN.md §14).
        self._step_cache: dict = {}
        self._build(mesh)

    # ------------------------------------------------------------------
    @staticmethod
    def _mesh_replicas(mesh):
        n = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n *= mesh.shape[a]
        return n

    def _build(self, mesh):
        """(Re)create shardings + jitted step for the current mesh.

        Cached per mesh equivalence class: a rebuilt mesh over the same
        devices (``rebuild_mesh`` re-instantiates the template) restores
        the previously compiled step instead of re-jitting — the warm jit
        cache entry survives every shrink→rebuild round trip.
        """
        self.mesh = mesh
        fp = mesh_fingerprint(mesh)
        cached = self._step_cache.get(fp)
        if cached is not None:
            (self.param_spec_tree, self.param_shardings, self.opt_shardings,
             self.batch_sharding, self.step_fn, self.ft_grad_allreduce,
             self._opt_init) = cached
            return
        cfg = self.model_cfg
        with mesh_context(mesh):
            from repro.launch.shardings import sanitize_specs

            pspecs = api.param_specs(cfg)
            self.param_spec_tree = sanitize_specs(
                param_shardings(pspecs), pspecs, mesh
            )
            self.param_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), self.param_spec_tree
            )
            opt_specs = adamw.state_shardings(
                self.param_spec_tree, pspecs, mesh, zero1_axis=batch_axes(mesh)
            )
            self.opt_shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), opt_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            ba = batch_axes(mesh)
            self.batch_sharding = {
                "tokens": NamedSharding(mesh, P(ba)),
                "labels": NamedSharding(mesh, P(ba)),
                "loss_weight": NamedSharding(mesh, P(ba)),
            }
            if cfg.family == "encdec":
                self.batch_sharding["frames"] = NamedSharding(mesh, P(ba))
            if cfg.family == "vlm":
                self.batch_sharding["positions"] = NamedSharding(mesh, P(None, ba))

        tcfg, opt_cfg = self.tcfg, self.opt_cfg
        n_rep = self.n_replicas
        # An explicit replica axis is available when the batch splits into
        # power-of-two contiguous replica slices.  (vlm batches carry a
        # non-leading batch axis and stay on the fused path.)
        use_rep = (
            tcfg.ft_grad_allreduce
            and n_rep > 1
            and (n_rep & (n_rep - 1)) == 0
            and cfg.family != "vlm"
            # per-replica slices are microbatched by loss_over_micro; only
            # the trivial split is guaranteed divisible for any batch shape
            and tcfg.microbatches == 1
        )
        # BLANK semantics: the gradient combine itself routes through the
        # fault-tolerant butterfly.
        use_ft = use_rep and tcfg.on_failure == "blank"
        self.ft_grad_allreduce = use_ft
        if use_ft:
            self.events_log.append(
                f"gradient all-reduce: ft_allreduce over {n_rep} replicas"
            )

        def loss_over_micro(p, b):
            if tcfg.microbatches == 1:
                return api.loss_fn(p, b, cfg)
            splits = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches) + x.shape[1:]),
                b,
            )

            def micro(acc, mb):
                return acc + api.loss_fn(p, mb, cfg) / tcfg.microbatches, None

            total, _ = jax.lax.scan(micro, 0.0, splits)
            return total

        def combined_grads(params, batch):
            if use_ft:
                return ft_replica_grad(loss_over_micro, params, batch, n_rep)
            return jax.value_and_grad(loss_over_micro)(params, batch)

        step_fn, self._opt_init, extra_opt_specs = self._make_optimizer_step(
            cfg, tcfg, opt_cfg, n_rep, use_rep, combined_grads,
            loss_over_micro, pspecs,
        )
        if extra_opt_specs is not None:
            with mesh_context(mesh):
                self.opt_shardings = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), extra_opt_specs,
                    is_leaf=lambda x: isinstance(x, P),
                )

        with mesh_context(mesh):
            jitted = jax.jit(
                step_fn,
                in_shardings=(self.param_shardings, self.opt_shardings,
                              self.batch_sharding),
                out_shardings=(self.param_shardings, self.opt_shardings, None),
                donate_argnums=(0, 1),
            )

        def step(params, opt_state, batch, _jit=jitted):
            _dispatch.note_dispatch("train_step")
            return _jit(params, opt_state, batch)

        self.step_fn = step
        self._step_cache[fp] = (
            self.param_spec_tree, self.param_shardings, self.opt_shardings,
            self.batch_sharding, self.step_fn, self.ft_grad_allreduce,
            self._opt_init,
        )

    # ------------------------------------------------------------------
    def _make_optimizer_step(self, cfg, tcfg, opt_cfg, n_rep, use_rep,
                             combined_grads, loss_over_micro, pspecs):
        """Per-optimizer jit body + state init + (optional) state specs.

        Every body starts with ``note_trace("train_step")`` — the CI
        retrace guard and the ``training`` bench case pin one trace per
        mesh equivalence class and one dispatch per warm step.  The
        orthogonalization work (PowerSGD butterfly TSQR, OrthoSGD/low-rank
        FT-CQR2) is traced *inline*, so the whole train step is ONE
        compiled program.
        """
        opt = tcfg.optimizer

        if opt == "adamw":

            def step_fn(params, opt_state, batch):
                _dispatch.note_trace("train_step")
                loss, grads = combined_grads(params, batch)
                new_p, new_o, om = adamw.update(opt_cfg, params, grads, opt_state)
                return new_p, new_o, {"loss": loss, **om}

            return step_fn, adamw.init, None

        shards = tcfg.qr_shards if tcfg.ft_in_step else 0

        if opt == "orthosgd":
            ocfg = orthosgd.OrthoSGDConfig(lr=tcfg.lr, ft_shards=shards)

            def step_fn(params, opt_state, batch):
                _dispatch.note_trace("train_step")
                loss, grads = combined_grads(params, batch)
                new_p, new_o = orthosgd.update(ocfg, params, grads, opt_state)
                om = {"grad_norm": adamw.global_norm(grads),
                      "lr": jnp.float32(ocfg.lr)}
                return new_p, new_o, {"loss": loss, **om}

            ad = adamw.state_shardings(
                self.param_spec_tree, pspecs, self.mesh,
                zero1_axis=batch_axes(self.mesh),
            )
            return step_fn, orthosgd.init, {"m": ad["m"], "step": P()}

        if opt == "lowrank":
            lcfg = lowrank.LowRankConfig(
                lr=tcfg.lr, rank=tcfg.opt_rank,
                min_dim=max(2 * tcfg.opt_rank, 16), ft_shards=shards,
            )

            def step_fn(params, opt_state, batch):
                _dispatch.note_trace("train_step")
                loss, grads = combined_grads(params, batch)
                new_p, new_o = lowrank.update(lcfg, params, grads, opt_state)
                om = {"grad_norm": adamw.global_norm(grads),
                      "lr": jnp.float32(lcfg.lr)}
                return new_p, new_o, {"loss": loss, **om}

            opt_init = partial(lowrank.init, cfg=lcfg)
            opt_struct = jax.eval_shape(opt_init, pspecs)
            return step_fn, opt_init, jax.tree.map(lambda _: P(), opt_struct)

        if opt != "powersgd":
            raise ValueError(f"unknown optimizer {opt!r}")

        pcfg = powersgd.PowerSGDConfig(rank=tcfg.opt_rank, error_feedback=False)
        ft = tcfg.ft_in_step and use_rep
        comm = SimComm(n_rep) if ft else None
        plan = make_plan(pcfg.variant, n_rep, None) if ft else None
        slot = int(np.argmax(plan.final_valid)) if ft else 0

        def eligible(shape):
            return len(shape) == 2 and min(shape) > pcfg.rank

        def step_fn(params, opt_state, batch):
            _dispatch.note_trace("train_step")
            if use_rep:
                losses, g_rep, live, n_live = replica_grads(
                    loss_over_micro, params, batch, n_rep
                )
                g_rep = mask_replica_tree(g_rep, live, n_rep)
                loss = jnp.where(live, losses, 0.0).sum() / n_live
            else:
                loss, g = jax.value_and_grad(loss_over_micro)(params, batch)
                g_rep = jax.tree.map(lambda x: x[None], g)
                n_live = jnp.float32(1.0)
            flat, tdef = jax.tree.flatten(g_rep)
            qs = opt_state["q"]
            ghat: list = [None] * len(flat)
            new_q = list(qs)
            rest_idx = []
            for i, gi in enumerate(flat):
                if eligible(gi.shape[1:]):
                    ghat[i], new_q[i] = powersgd.compress_mean_grad(
                        gi, qs[i], cfg=pcfg, comm=comm, plan=plan,
                        n_live=n_live, ft=ft,
                    )
                else:
                    rest_idx.append(i)
            # every uncompressed leaf rides ONE butterfly (tree payload)
            if rest_idx:
                rest = [flat[i] for i in rest_idx]
                if ft:
                    summed, _ = ft_allreduce(rest, comm, op="sum", plan=plan)
                    rest_mean = [s[slot] / n_live for s in summed]
                else:
                    rest_mean = [x.sum(0) / n_live for x in rest]
                for i, gm in zip(rest_idx, rest_mean):
                    ghat[i] = gm
            grads = tdef.unflatten(ghat)
            new_p, new_inner, om = adamw.update(
                opt_cfg, params, grads, opt_state["inner"]
            )
            return new_p, {"inner": new_inner, "q": tuple(new_q)}, \
                {"loss": loss, **om}

        seed = tcfg.seed
        rank = pcfg.rank

        def opt_init(params):
            leaves = jax.tree.leaves(params)
            keys = jax.random.split(jax.random.key(seed), max(len(leaves), 1))
            qs = tuple(
                jax.random.normal(k, (p.shape[1], rank), jnp.float32)
                if eligible(p.shape) else jnp.zeros((0,), jnp.float32)
                for k, p in zip(keys, leaves)
            )
            return {"inner": adamw.init(params), "q": qs}

        ad = adamw.state_shardings(
            self.param_spec_tree, pspecs, self.mesh,
            zero1_axis=batch_axes(self.mesh),
        )
        n_leaves = len(jax.tree.leaves(pspecs))
        q_specs = tuple(P() for _ in range(n_leaves))
        return step_fn, opt_init, {"inner": ad, "q": q_specs}

    # ------------------------------------------------------------------
    def init_state(self, key=None):
        key = key if key is not None else jax.random.key(self.tcfg.seed)
        with mesh_context(self.mesh):
            params = jax.jit(
                partial(api.init, cfg=self.model_cfg),
                out_shardings=self.param_shardings,
            )(key)
            opt_state = jax.jit(
                self._opt_init, out_shardings=self.opt_shardings
            )(params)
        return params, opt_state

    # ------------------------------------------------------------------
    def _mask_for(self, rows: int) -> np.ndarray:
        """Per-row loss weight from replica health (BLANK semantics)."""
        w = np.ones(rows, np.float32)
        per = rows // self.n_replicas
        dead = ~self.alive
        if self.tcfg.drop_stragglers:
            dead = dead | (self.straggling > 0)
        if dead.any():
            self.fault_stats["masked_steps"] += 1
        for r in np.nonzero(dead)[0]:
            w[r * per : (r + 1) * per] = 0.0
        alive_frac = max(w.mean(), 1e-6)
        return w / alive_frac

    def _device_batch(self, host_batch):
        rows = host_batch["tokens"].shape[0]
        hb = dict(host_batch, loss_weight=self._mask_for(rows))
        return {
            k: jax.device_put(v, self.batch_sharding[k]) for k, v in hb.items()
        }

    # ------------------------------------------------------------------
    def run(self, params, opt_state, *, start_step: int = 0,
            fault_schedule: tuple[FaultEvent, ...] = (),
            on_step: Callable | None = None):
        corpus = SyntheticCorpus(self.data_cfg)
        events = sorted(fault_schedule, key=lambda e: e.step)
        fired: set[int] = set()
        ema = None
        step = start_step
        while step < self.tcfg.steps:
            # --- consume health transitions for this step (once each:
            # after a REBUILD rollback the step counter passes the event's
            # step again — re-firing it would loop forever) ---------------
            for i, ev in enumerate(events):
                if ev.step == step and i not in fired:
                    fired.add(i)
                    params, opt_state, step = self._handle_event(
                        ev, params, opt_state, step
                    )
            t0 = time.perf_counter()
            batch = self._device_batch(corpus.batch(step))
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            # --- straggler detector --------------------------------------
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            slow = dt > self.tcfg.straggler_factor * ema
            if slow:
                self.events_log.append(f"step {step}: straggler ({dt:.3f}s vs {ema:.3f}s)")
            self.straggling = np.maximum(self.straggling - 1, 0)
            metrics.update(step=step, wall=dt)
            self.metrics_log.append(metrics)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                print(f"[train] step={step} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} wall={dt:.2f}s")
            if self.tcfg.ckpt_every and step and step % self.tcfg.ckpt_every == 0:
                self._checkpoint(step, params, opt_state)
            if on_step:
                on_step(step, params, metrics)
            step += 1
        self.ckpt.wait()
        return params, opt_state

    # ------------------------------------------------------------------
    def _checkpoint(self, step, params, opt_state):
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       meta={"arch": self.model_cfg.name}, block=False)
        if self.buddies is not None:
            # ZeRO-1 shard ownership: replica r owns its optimizer slice.
            # Host-simulated diskless copy: one logical shard per replica.
            shards = {
                r: {"step": step}
                for r in range(self.n_replicas) if self.alive[r]
            }
            self.buddies.checkpoint(step, shards, levels=self.tcfg.buddy_levels)
        self.events_log.append(f"step {step}: checkpoint")

    def _handle_event(self, ev: FaultEvent, params, opt_state, step):
        if ev.kind == "straggle":
            self.straggling[ev.replica] = ev.duration
            self.fault_stats["straggles"] += 1
            self.events_log.append(f"step {step}: replica {ev.replica} straggling")
            return params, opt_state, step
        if ev.kind == "recover":
            self.alive[ev.replica] = True
            self.fault_stats["recoveries"] += 1
            if self.buddies is not None:
                self.buddies.respawn(ev.replica)
            self.events_log.append(f"step {step}: replica {ev.replica} recovered")
            return params, opt_state, step
        if ev.kind == "rejoin":
            params, opt_state = self._rejoin(params, opt_state)
            return params, opt_state, step
        assert ev.kind == "fail"
        self.alive[ev.replica] = False
        self.fault_stats["failures"] += 1
        if self.buddies is not None:
            self.buddies.fail(ev.replica)
        mode = self.tcfg.on_failure
        self.events_log.append(
            f"step {step}: replica {ev.replica} FAILED → {mode}"
        )
        if mode == "blank":
            return params, opt_state, step          # masked out by _mask_for
        if mode == "rebuild":
            # Diskless first: a live buddy replica of the lost shard means
            # no rollback at all (the paper's Self-Healing semantics);
            # otherwise restore the latest disk checkpoint.
            restored = None
            if self.buddies is not None:
                try:
                    ck_step, _ = self.buddies.recover(ev.replica)
                    restored = step  # in-memory state is current: no rollback
                    self.fault_stats["buddy_restores"] += 1
                    self.events_log.append(
                        f"step {step}: replica {ev.replica} restored from buddy "
                        f"(ckpt step {ck_step}, no rollback)"
                    )
                except KeyError:
                    pass
            # Drain the async save thread BEFORE probing for a checkpoint: a
            # failure arriving a step or two after a non-blocking save must
            # not race the manifest write and silently skip the rollback.
            if restored is None:
                self.ckpt.wait()
            if restored is None and self.ckpt.latest_step() is not None:
                tpl = jax.tree.map(np.asarray, jax.device_get(
                    {"params": params, "opt": opt_state}))
                state, meta = self.ckpt.restore(tpl)
                with mesh_context(self.mesh):
                    params = jax.device_put(state["params"], self.param_shardings)
                    opt_state = jax.device_put(state["opt"], self.opt_shardings)
                step = int(meta["step"]) + 1
                self.fault_stats["rollbacks"] += 1
                self.events_log.append(
                    f"rollback to checkpoint step {meta['step']}"
                )
            self.alive[ev.replica] = True            # respawned
            if self.buddies is not None:
                self.buddies.respawn(ev.replica)
            return params, opt_state, step
        if mode == "shrink":
            params, opt_state = self._shrink(params, opt_state, ev.replica)
            return params, opt_state, step
        raise ValueError(mode)

    def _shrink(self, params, opt_state, dead_replica: int):
        """Elastic SHRINK: rebuild the mesh without the dead replica's
        devices and reshard live state onto it."""
        from repro.compat import mesh_from_devices
        from repro.runtime.elastic import shrink_mesh

        # shrink_mesh keeps the leading data-axis slice, so rotate the dead
        # replica's devices to the tail first — the surviving mesh must not
        # contain the failed hardware.
        mesh = self.mesh
        if "data" in mesh.axis_names:
            ax = mesh.axis_names.index("data")
            d = mesh.devices.shape[ax]
            if 0 <= dead_replica < d:
                order = [i for i in range(d) if i != dead_replica] + [dead_replica]
                mesh = mesh_from_devices(
                    np.take(mesh.devices, order, axis=ax), mesh.axis_names
                )
        new_mesh = shrink_mesh(mesh, drop_replicas=1)
        if new_mesh is None:
            self.events_log.append("shrink impossible (data axis exhausted) — blanking")
            return params, opt_state
        params, opt_state = self._remesh(params, opt_state, new_mesh)
        self.fault_stats["shrinks"] += 1
        self.events_log.append(
            f"elastic shrink → mesh {dict(zip(new_mesh.axis_names, new_mesh.devices.shape))}"
        )
        return params, opt_state

    def _rejoin(self, params, opt_state):
        """Elastic REBUILD: replacement devices are back — re-instantiate the
        original template topology and reshard live state onto it (the
        inverse of :meth:`_shrink`; a ``"rejoin"`` :class:`FaultEvent`)."""
        from repro.runtime.elastic import rebuild_mesh

        full = rebuild_mesh(self._template_mesh)
        if full.devices.shape == self.mesh.devices.shape:
            self.events_log.append("rejoin: already at full width — no-op")
            return params, opt_state
        params, opt_state = self._remesh(params, opt_state, full)
        self.fault_stats["rejoins"] += 1
        self.events_log.append(
            f"elastic rebuild → mesh {dict(zip(full.axis_names, full.devices.shape))}"
        )
        return params, opt_state

    def _remesh(self, params, opt_state, new_mesh):
        """Move live state onto ``new_mesh`` and rebuild the jitted step."""
        host = jax.device_get({"params": params, "opt": opt_state})
        self.n_replicas = self._mesh_replicas(new_mesh)
        self.alive = np.ones(self.n_replicas, dtype=bool)
        self.straggling = np.zeros(self.n_replicas, dtype=np.int64)
        self._build(new_mesh)
        with mesh_context(new_mesh):
            params = jax.device_put(host["params"], self.param_shardings)
            opt_state = jax.device_put(host["opt"], self.opt_shardings)
        return params, opt_state
