"""Pallas TPU kernel: panel-streamed right-multiply Q = A @ W.

The second hot-spot of CholeskyQR2: forming Q = A·R⁻¹ once the small
triangular factor is inverted.  Same streaming structure as the Gram
kernel — A row-panels stream HBM→VMEM, the (n, k) right operand is resident
in VMEM for the whole sweep, and each output panel is written exactly once
(index_map i → (i, 0), no revisits).  Accumulation is f32 on the MXU;
the result is cast back to A's dtype on the way out.

Edge tiles need no masking here: an out-of-bounds input row produces an
out-of-bounds output row, which Pallas discards on the partial final block
write.  No padded copy of A or W ever hits HBM (the seed padded both to
lane multiples before every call).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import autotune as _autotune
from .backend import pick_block_rows, resolve_backend
from .dispatch import note_trace

__all__ = ["apply_right"]


def _apply_kernel(a_ref, w_ref, o_ref):
    o_ref[...] = lax.dot_general(
        a_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def apply_right(a, w, *, block_rows: int | None = None,
                interpret: bool | None = None):
    """A (m, n) @ W (n, k) → (m, k) in A's dtype, f32 accumulation.

    ``interpret=None`` auto-detects the backend (compiled on TPU/GPU,
    interpreted elsewhere); ``block_rows=None`` consults the installed
    autotune table at trace time (see :func:`repro.kernels.gram.gram`).
    """
    note_trace("kernel:apply_right")
    be = resolve_backend(interpret)
    m, n = a.shape
    n2, k = w.shape
    assert n == n2, (a.shape, w.shape)
    block_rows = _autotune.resolve_block_rows(
        "apply_right", m, n, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.apply_right(a, w, block_rows=block_rows, interpret=False)
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    return pl.pallas_call(
        _apply_kernel,
        grid=(pl.cdiv(m, block_rows),),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), a.dtype),
        interpret=be.interpret,
    )(a, w)
