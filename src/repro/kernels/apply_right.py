"""Pallas TPU kernel: panel-streamed right-multiply Q = A @ W.

The second hot-spot of CholeskyQR2: forming Q = A·R⁻¹ once the small
triangular factor is inverted.  Same streaming structure as the Gram
kernel — A row-panels stream HBM→VMEM, the (n, k) right operand is resident
in VMEM for the whole sweep, and each output panel is written exactly once
(index_map i → (i, 0), no revisits).  Accumulation is f32 on the MXU;
the result is cast back to A's dtype on the way out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["apply_right"]

_LANE = 128


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def _apply_kernel(a_ref, w_ref, o_ref):
    o_ref[...] = lax.dot_general(
        a_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def apply_right(a, w, *, block_rows: int = 1024, interpret: bool = True):
    """A (m, n) @ W (n, k) → (m, k) in A's dtype, f32 accumulation."""
    m, n = a.shape
    n2, k = w.shape
    assert n == n2, (a.shape, w.shape)
    n_pad = _ceil_to(max(n, 1), _LANE)
    k_pad = _ceil_to(max(k, 1), _LANE)
    block_rows = max(_LANE, min(block_rows, _ceil_to(m, _LANE)))
    m_pad = _ceil_to(m, block_rows)
    a_pad = jnp.pad(a, ((0, m_pad - m), (0, n_pad - n)))
    w_pad = jnp.pad(w, ((0, n_pad - n), (0, k_pad - k)))
    out = pl.pallas_call(
        _apply_kernel,
        grid=(m_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((n_pad, k_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), a.dtype),
        interpret=interpret,
    )(a_pad, w_pad)
    return out[:m, :k]
