"""Pallas TPU kernel: blocked Gram matrix G = AᵀA for tall-skinny A.

This is the FLOP hot-spot of the TPU-native local QR (CholeskyQR2,
DESIGN.md §2): for A (m, n) with m ≫ n, the Gram product is ~m·n² MACs while
everything downstream (Cholesky, small inverse) is O(n³).  The kernel streams
row-panels of A HBM→VMEM and accumulates the (n, n) Gram block in VMEM across
the sequential TPU grid, so A is read exactly once and the accumulator never
leaves VMEM.

Tiling:
  * grid = (m_pad / block_rows,) — sequential row sweep ("arbitrary"
    dimension semantics: the accumulation is order-independent).
  * A panel  BlockSpec (block_rows, n_pad), index_map i → (i, 0).
  * G output BlockSpec (n_pad, n_pad), index_map i → (0, 0): a constant
    output block revisited by every grid step = the VMEM accumulator.
  * n is zero-padded to the 128-lane boundary and m to the row-block size;
    zero rows/columns contribute nothing to AᵀA, so padding is exact, and
    the MXU sees native (8·k × 128·j) tiles.

VMEM budget at defaults (block_rows=1024, n≤512, bf16 in / f32 acc):
1 MiB panel + 1 MiB accumulator — comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["gram", "DEFAULT_BLOCK_ROWS"]

DEFAULT_BLOCK_ROWS = 1024
_LANE = 128


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def _gram_kernel(a_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    o_ref[...] += lax.dot_general(
        a, a, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram(a, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True):
    """G = AᵀA, float32.  a: (m, n); returns (n, n).

    ``interpret=True`` (the default in this CPU container) runs the kernel
    body in the Pallas interpreter; on a TPU runtime pass ``interpret=False``
    for the compiled Mosaic kernel.
    """
    m, n = a.shape
    n_pad = _ceil_to(max(n, 1), _LANE)
    block_rows = max(_LANE, min(block_rows, _ceil_to(m, _LANE)))
    m_pad = _ceil_to(m, block_rows)
    a_pad = jnp.pad(a, ((0, m_pad - m), (0, n_pad - n)))
    out = pl.pallas_call(
        _gram_kernel,
        grid=(m_pad // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(a_pad)
    return out[:n, :n]
