"""Pallas TPU kernel: blocked Gram matrix G = AᵀA for tall-skinny A.

This is the FLOP hot-spot of the TPU-native local QR (CholeskyQR2,
DESIGN.md §2, adaptation #2): for A (m, n) with m ≫ n, the Gram product is
~m·n² MACs while everything downstream (Cholesky, small inverse) is O(n³).
The kernel streams row-panels of A HBM→VMEM and accumulates the (n, n) Gram
block in VMEM across the sequential TPU grid, so A is read exactly once and
the accumulator never leaves VMEM.

Tiling:
  * grid = (⌈m / block_rows⌉,) — sequential row sweep ("arbitrary"
    dimension semantics: the accumulation is order-independent).
  * A panel  BlockSpec (block_rows, n), index_map i → (i, 0).
  * G output BlockSpec (n, n), index_map i → (0, 0): a constant output
    block revisited by every grid step = the VMEM accumulator.
  * Edge tiles are handled **in-kernel**: when ``block_rows ∤ m`` the last
    panel's out-of-bounds rows are zeroed against a row-index iota before
    the matmul, so zero rows contribute nothing to AᵀA.  No padded copy of
    A is ever materialized in HBM (the seed ``jnp.pad``-ed A to lane/block
    multiples before every call — a full extra HBM round-trip); sub-lane n
    is padded by Mosaic inside VMEM only.

VMEM budget at defaults (block_rows=1024, n≤512, bf16 in / f32 acc):
1 MiB panel + 1 MiB accumulator — comfortably inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import autotune as _autotune
from .backend import DEFAULT_BLOCK_ROWS, pick_block_rows, resolve_backend
from .dispatch import note_trace

__all__ = [
    "gram",
    "DEFAULT_BLOCK_ROWS",
    "pick_block_rows",
    "mask_rows",
    "mask_cols",
]


def mask_rows(panel, grid_idx, block_rows: int, m: int):
    """Zero the out-of-bounds rows of an edge panel (no-op when blocks
    divide m exactly — the branch is static)."""
    if m % block_rows == 0:
        return panel
    rows = grid_idx * block_rows + lax.broadcasted_iota(
        jnp.int32, panel.shape, 0
    )
    return jnp.where(rows < m, panel, jnp.zeros_like(panel))


def mask_cols(block, n_valid: int):
    """Zero columns ``>= n_valid`` of a block — the column analogue of
    :func:`mask_rows`, used by the fixed-shape blocked-QR pipeline to keep
    a padded trailing block exact (no-op when the block is exactly
    ``n_valid`` wide — the branch is static)."""
    if block.shape[-1] == n_valid:
        return block
    cols = lax.broadcasted_iota(jnp.int32, block.shape, block.ndim - 1)
    return jnp.where(cols < n_valid, block, jnp.zeros_like(block))


def _gram_kernel(a_ref, o_ref, *, block_rows: int, m: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = mask_rows(a_ref[...], i, block_rows, m)
    o_ref[...] += lax.dot_general(
        a, a, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram(a, *, block_rows: int | None = None,
         interpret: bool | None = None):
    """G = AᵀA, float32.  a: (m, n); returns (n, n).

    ``interpret=None`` auto-detects the backend (compiled Mosaic kernel on
    TPU, compiled Triton on GPU, Pallas interpreter elsewhere); pass an
    explicit bool to override.  ``block_rows=None`` consults the installed
    autotune table at trace time (the resolved int is frozen into this
    shape's compiled program — callers that want table changes to take
    effect per call resolve at the Python level, as ``ops`` does, and pass
    the concrete int).
    """
    note_trace("kernel:gram")
    be = resolve_backend(interpret)
    m, n = a.shape
    block_rows = _autotune.resolve_block_rows(
        "gram", m, n, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.gram(a, block_rows=block_rows, interpret=False)
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    return pl.pallas_call(
        functools.partial(_gram_kernel, block_rows=block_rows, m=m),
        grid=(pl.cdiv(m, block_rows),),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=be.interpret,
    )(a)
