"""Trace-time HBM traffic model for the CQR2 kernel pipeline.

The fused-pipeline claim of DESIGN.md §Kernels — CholeskyQR2's R factor in
**2** HBM sweeps over the tall operand instead of the seed's 4 — is gated
as a hard benchmark metric (``repro.bench.cases.kernels``), so it needs a
measurement, not an assertion-by-construction.  Because every kernel's
routing is static (shapes known at trace time, one ``pallas_call`` per
streamed sweep), the public wrappers in :mod:`repro.kernels.ops` can report
their exact traffic as they are called: each wrapper notes the bytes it
streams from/to HBM and whether the call is a *sweep* over a tall operand
(the (m, n) panel stream; the n×n Cholesky/inverse work is not).

Usage::

    with track_traffic() as t:
        ops.cholesky_qr2_r(a, use_pallas=True)
    assert t.tall_sweeps == 2

Counting happens at Python call time in the ``ops`` wrappers (outside any
``jit``), so call the pipeline un-jitted when measuring; the model is the
same traffic a compiled TPU execution commits to, since the block streaming
is fixed by the BlockSpecs.

Scope (DESIGN.md §9): per-call accounting is exact for the sim drivers
(wrappers run per call) and for the scan-compiled blocked-QR pipeline
(whose entry point notes its own K-sweep totals).  Kernel calls made
*inside* a cached ``shard_map`` body note at trace time only — a warm
repeat of those entry points records nothing, because the body never
re-executes (that the seed noted per call there was an artifact of its
per-call ``jax.jit(shard)`` rebuild, i.e. of the retrace bug itself).
"""
from __future__ import annotations

import contextlib
import dataclasses

__all__ = ["KernelTraffic", "note", "suppress", "track_traffic"]


@dataclasses.dataclass
class KernelTraffic:
    """Accumulated per-op HBM traffic records."""

    records: list[dict] = dataclasses.field(default_factory=list)

    @property
    def tall_sweeps(self) -> int:
        """Number of HBM sweeps over a tall (panel-streamed) operand."""
        return sum(r["sweeps"] for r in self.records)

    def sweeps_of(self, *ops: str) -> int:
        """Tall sweeps attributed to the named ops only — e.g. the blocked-QR
        trailing-block accounting counts ``panel_cross`` + ``trailing_update``
        and excludes the narrow panel-local factorization sweeps."""
        wanted = set(ops)
        return sum(r["sweeps"] for r in self.records if r["op"] in wanted)

    @property
    def read_bytes(self) -> int:
        return sum(r["read_bytes"] for r in self.records)

    @property
    def write_bytes(self) -> int:
        return sum(r["write_bytes"] for r in self.records)

    @property
    def total_bytes(self) -> int:
        return self.read_bytes + self.write_bytes

    @property
    def dispatches(self) -> int:
        """Compiled-program launches recorded alongside the bytes (each
        eager kernel wrapper is one jitted call = one device dispatch; the
        scan-compiled pipeline records 1 for the whole factorization)."""
        return sum(r["dispatches"] for r in self.records)

    @property
    def traces(self) -> int:
        """New jit traces the recorded calls caused (0 on warm calls)."""
        return sum(r["traces"] for r in self.records)

    @property
    def collective_rounds(self) -> int:
        """Serial butterfly rounds committed by the recorded collectives —
        the latency proxy (one record per butterfly; the blocked drivers
        note one ``panel_reduce`` per panel plus ``reorth_reduce`` polish
        rounds, priced from the host plans)."""
        return sum(r["rounds"] for r in self.records)

    def rounds_of(self, *ops: str) -> int:
        """Collective rounds attributed to the named ops only — the
        ``overlap`` bench case gates ``rounds_of("panel_reduce")`` at
        exactly ``log P`` per panel on the fused path."""
        wanted = set(ops)
        return sum(r["rounds"] for r in self.records if r["op"] in wanted)

    @property
    def wire_bytes(self) -> int:
        """Collective payload bytes committed by the recorded reductions
        (plan-priced: packed symmetric leaves, dense rectangular leaves)."""
        return sum(r["wire_bytes"] for r in self.records)

    def wire_bytes_of(self, *ops: str) -> int:
        wanted = set(ops)
        return sum(
            r["wire_bytes"] for r in self.records if r["op"] in wanted
        )

    @property
    def overlapped(self) -> int:
        """Reductions issued against lookahead accumulators *during* the
        previous panel's trailing sweep (the double-buffered pipeline's
        comm/compute overlap depth — K−1 for a K-panel fused run, 0 for the
        serialized two-butterfly schedule)."""
        return sum(r["overlapped"] for r in self.records)

    def as_dict(self) -> dict:
        return {
            "tall_sweeps": self.tall_sweeps,
            "read_bytes": self.read_bytes,
            "write_bytes": self.write_bytes,
            "dispatches": self.dispatches,
            "traces": self.traces,
            "collective_rounds": self.collective_rounds,
            "wire_bytes": self.wire_bytes,
            "overlapped": self.overlapped,
            "ops": [r["op"] for r in self.records],
        }


_ACTIVE: list[KernelTraffic] = []
_SUPPRESS: list[bool] = []


def note(op: str, *, sweeps: int = 0, read_bytes: int = 0,
         write_bytes: int = 0, dispatches: int = 1, traces: int = 0,
         rounds: int = 0, wire_bytes: int = 0, overlapped: int = 0) -> None:
    """Record one kernel invocation into every active tracker (no-op when
    nothing is tracking — the hot path pays one list check).

    ``dispatches``/``traces`` ride alongside the bytes: a plain wrapper call
    is one compiled-program launch (default 1); callers that know better —
    the scan pipeline records its K-panel traffic as several byte records
    but a single dispatch — pass explicit counts.

    ``rounds``/``wire_bytes``/``overlapped`` account collectives: serial
    butterfly rounds the record commits, plan-priced payload bytes on the
    wire, and whether the reduction was issued against lookahead
    accumulators under the previous panel's trailing sweep.  The blocked
    drivers note one ``panel_reduce`` record per butterfly with
    ``dispatches=0, sweeps=0`` so the collective accounting never perturbs
    the HBM-sweep and single-dispatch gates.
    """
    if not _ACTIVE or _SUPPRESS:
        return
    rec = {
        "op": op,
        "sweeps": int(sweeps),
        "read_bytes": int(read_bytes),
        "write_bytes": int(write_bytes),
        "dispatches": int(dispatches),
        "traces": int(traces),
        "rounds": int(rounds),
        "wire_bytes": int(wire_bytes),
        "overlapped": int(overlapped),
    }
    for t in _ACTIVE:
        t.records.append(rec)


@contextlib.contextmanager
def track_traffic():
    """Context manager yielding a :class:`KernelTraffic` that observes every
    ``ops``-level kernel call made inside the block."""
    t = KernelTraffic()
    _ACTIVE.append(t)
    try:
        yield t
    finally:
        _ACTIVE.remove(t)


@contextlib.contextmanager
def suppress():
    """Drop :func:`note` calls inside the block.  The scan-compiled pipeline
    wraps its compiled-function invocation with this: any kernel wrapper
    reached while *tracing* the body (e.g. a ``cqr2`` local QR) would note
    once per trace instead of once per panel per call — the pipeline entry
    point notes its own exact per-call totals instead."""
    _SUPPRESS.append(True)
    try:
        yield
    finally:
        _SUPPRESS.pop()
