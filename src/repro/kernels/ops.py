"""Jit'd public wrappers over the Pallas kernels (with pure-jnp fallbacks).

``cholesky_qr2`` is the TPU-native local QR used by the TSQR variants
(DESIGN.md §2, adaptation #2): Householder panels are sequential and
VPU-bound, while CQR2 is two rounds of (Gram matmul → n×n Cholesky →
triangular inverse → panel matmul) — all MXU-shaped.  Numerically CQR2
delivers Householder-grade orthogonality for κ(A) ≲ 1/√ε per round.

Every wrapper accepts arbitrary leading batch dimensions (the SimComm
backend carries a (P,) rank axis); Pallas calls are vmapped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import apply_right as _apply_mod
from . import combine_gram as _combine_mod
from . import gram as _gram_mod
from . import ref as _ref

__all__ = [
    "gram",
    "apply_right",
    "combine_gram",
    "cholesky_qr",
    "cholesky_qr2",
    "tri_inv",
]


def _batched(fn, n_array_args):
    """Apply ``fn`` over arbitrary shared leading batch dims."""

    def wrapped(*args, **kwargs):
        arrays = args[:n_array_args]
        extra = arrays[0].ndim - 2
        if extra == 0:
            return fn(*args, **kwargs)
        f = functools.partial(fn, **kwargs)
        for _ in range(extra):
            f = jax.vmap(f)
        return f(*arrays)

    return wrapped


# -- kernel entry points (batched, pallas/jnp switchable) -------------------

def gram(a, *, use_pallas: bool = False, interpret: bool = True):
    if not use_pallas:
        return _ref.gram(a)
    return _batched(_gram_mod.gram, 1)(a, interpret=interpret)


def apply_right(a, w, *, use_pallas: bool = False, interpret: bool = True):
    if not use_pallas:
        return _ref.apply_right(a, w)
    return _batched(_apply_mod.apply_right, 2)(a, w, interpret=interpret)


def combine_gram(r1, r2, *, use_pallas: bool = False, interpret: bool = True):
    if not use_pallas:
        return _ref.combine_gram(r1, r2)
    return _batched(_combine_mod.combine_gram, 2)(r1, r2, interpret=interpret)


# -- composed ops -----------------------------------------------------------

def tri_inv(r):
    """Inverse of an upper-triangular (…, n, n) factor."""
    eye = jnp.broadcast_to(
        jnp.eye(r.shape[-1], dtype=r.dtype), r.shape
    )
    return jsl.solve_triangular(r, eye, lower=False)


def _posdiag(r):
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def cholesky_qr(a, *, use_pallas: bool = False, interpret: bool = True):
    """One CholeskyQR round.  a: (…, m, n) → (Q (…, m, n), R (…, n, n) f32)."""
    g = gram(a, use_pallas=use_pallas, interpret=interpret)
    r = jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2)  # upper, positive diag
    q = apply_right(
        a, tri_inv(r).astype(a.dtype), use_pallas=use_pallas, interpret=interpret
    )
    return q, r


def cholesky_qr2(a, *, use_pallas: bool = False, interpret: bool = True):
    """CholeskyQR2: Householder-grade orthogonality, MXU-native FLOPs."""
    q1, r1 = cholesky_qr(a, use_pallas=use_pallas, interpret=interpret)
    q, r2 = cholesky_qr(q1, use_pallas=use_pallas, interpret=interpret)
    return q, _posdiag(r2 @ r1)
