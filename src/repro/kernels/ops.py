"""Jit'd public wrappers over the Pallas kernels (with pure-jnp fallbacks).

``cholesky_qr2`` is the TPU-native local QR used by the TSQR variants
(DESIGN.md §2, adaptation #2): Householder panels are sequential and
VPU-bound, while CQR2 is two rounds of (Gram matmul → n×n Cholesky →
triangular inverse → panel matmul) — all MXU-shaped.  Numerically CQR2
delivers Householder-grade orthogonality for κ(A) ≲ 1/√ε per round.

The pipeline is **fused** (DESIGN.md §Kernels): round 1's panel apply also
accumulates round 2's Gram in VMEM (:mod:`repro.kernels.fused_apply_gram`),
so the full factorization streams the tall operand 3× instead of the seed's
4×, and the R-factor-only variant (:func:`cholesky_qr2_r` — what the TSQR
local QR actually needs) streams it exactly **2×** with no tall intermediate
ever written to HBM.  Every wrapper reports its HBM traffic to
:mod:`repro.kernels.traffic`, which the ``kernels`` bench case hard-gates.

``interpret=None`` (the default everywhere) auto-detects the backend:
compiled Mosaic kernels on TPU, the Pallas interpreter elsewhere
(:mod:`repro.kernels.backend`).  Every wrapper accepts arbitrary leading
batch dimensions (the SimComm backend carries a (P,) rank axis); Pallas
calls are vmapped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from . import apply_right as _apply_mod
from . import autotune as _autotune
from . import combine_gram as _combine_mod
from . import dispatch as _dispatch
from . import fused_apply_gram as _fused_mod
from . import gram as _gram_mod
from . import ref as _ref
from . import traffic as _traffic
from . import trailing_update as _trailing_mod
from .backend import resolve_backend

__all__ = [
    "gram",
    "apply_right",
    "fused_apply_gram",
    "combine_gram",
    "cholesky_qr",
    "cholesky_qr2",
    "cholesky_qr2_r",
    "tri_inv",
    "trailing_update",
    "panel_cross",
    "pad_cross",
]


def _batched(fn, n_array_args):
    """Apply ``fn`` over arbitrary shared leading batch dims."""

    def wrapped(*args, **kwargs):
        arrays = args[:n_array_args]
        extra = arrays[0].ndim - 2
        if extra == 0:
            return fn(*args, **kwargs)
        f = functools.partial(fn, **kwargs)
        for _ in range(extra):
            f = jax.vmap(f)
        return f(*arrays)

    return wrapped


def _nbytes(x) -> int:
    return int(x.size) * x.dtype.itemsize


def _resolve_br(op: str, a, block_rows: int | None,
                interpret: bool | None) -> int:
    """Resolve the tuned panel height **at the Python level, per call**:
    explicit caller choice > installed autotune winner for the shape-class >
    aligned default.  The concrete int becomes the kernel's static jit key,
    so installing a new tuned table takes effect immediately for its
    shape-classes and never retraces any other warm class (the retrace
    guard pins this)."""
    return _autotune.resolve_block_rows(
        op, a.shape[-2], a.shape[-1], a.dtype, explicit=block_rows,
        backend=resolve_backend(interpret),
    )


def _pre(op: str) -> int:
    """Snapshot the kernel's process-lifetime trace count before a call."""
    return _dispatch.trace_count("kernel:" + op)


def _note(op: str, t0: int, **traffic_kw) -> None:
    """Record one wrapper call: a device dispatch plus its HBM traffic, with
    the number of *new* jit traces the call caused (0 on warm calls — the
    zero-retrace contract the ``dispatch`` bench case gates)."""
    _dispatch.note_dispatch(op)
    _traffic.note(
        op, dispatches=1, traces=_dispatch.trace_count("kernel:" + op) - t0,
        **traffic_kw,
    )


# -- kernel entry points (batched, pallas/jnp switchable) -------------------

def gram(a, *, use_pallas: bool = False, interpret: bool | None = None,
         block_rows: int | None = None):
    t0 = _pre("gram")
    if use_pallas:
        out = _batched(_gram_mod.gram, 1)(
            a, interpret=interpret,
            block_rows=_resolve_br("gram", a, block_rows, interpret),
        )
    else:
        out = _ref.gram(a)
    _note("gram", t0, sweeps=1, read_bytes=_nbytes(a),
          write_bytes=_nbytes(out))
    return out


def apply_right(a, w, *, use_pallas: bool = False,
                interpret: bool | None = None,
                block_rows: int | None = None):
    t0 = _pre("apply_right")
    if use_pallas:
        out = _batched(_apply_mod.apply_right, 2)(
            a, w, interpret=interpret,
            block_rows=_resolve_br("apply_right", a, block_rows, interpret),
        )
    else:
        out = _ref.apply_right(a, w)
    _note("apply_right", t0, sweeps=1,
          read_bytes=_nbytes(a) + _nbytes(w),
          write_bytes=_nbytes(out))
    return out


def fused_apply_gram(a, w, *, use_pallas: bool = False,
                     interpret: bool | None = None, want_q: bool = True,
                     block_rows: int | None = None):
    """One tall-operand sweep: ``Q = A @ W`` and ``G' = QᵀQ`` together.

    Returns ``(q, g)`` — or just ``g`` when ``want_q=False``, in which case
    the applied panel never leaves VMEM (no tall HBM write at all).
    """
    t0 = _pre("fused_apply_gram")
    if use_pallas:
        out = _batched(_fused_mod.fused_apply_gram, 2)(
            a, w, interpret=interpret, want_q=want_q,
            block_rows=_resolve_br("fused_apply_gram", a, block_rows,
                                   interpret),
        )
    else:
        q = _ref.apply_right(a, w)
        g = _ref.gram(q)
        out = (q, g) if want_q else g
    g_out = out[1] if want_q else out
    q_bytes = _nbytes(out[0]) if want_q else 0
    _note("fused_apply_gram", t0, sweeps=1,
          read_bytes=_nbytes(a) + _nbytes(w),
          write_bytes=q_bytes + _nbytes(g_out))
    return out


def combine_gram(r1, r2, *, use_pallas: bool = False,
                 interpret: bool | None = None):
    t0 = _pre("combine_gram")
    out = (
        _batched(_combine_mod.combine_gram, 2)(r1, r2, interpret=interpret)
        if use_pallas
        else _ref.combine_gram(r1, r2)
    )
    _note("combine_gram", t0, read_bytes=_nbytes(r1) + _nbytes(r2),
          write_bytes=_nbytes(out))
    return out


# -- raw dispatchers (no traffic/dispatch notes) ----------------------------
#
# The scan-compiled blocked-QR pipeline (repro.qr.blocked) traces these
# *once* for all K panels, so noting at kernel-call time would undercount by
# K−1 on the first call and by K on every warm call; the pipeline wrapper
# notes its exact per-call totals itself instead.
#
# The jnp oracles are dispatched through module-level jits: the eager
# driver then executes the *same compiled pattern* the pipeline traces into
# its single program (XLA applies rewrites like fusing a width-1 panel's
# degenerate product into the trailing subtraction's FMA only under jit —
# op-by-op eager execution would differ from the pipeline in the last ulp),
# and the jnp path stops re-dispatching op-by-op on every panel.  They note
# traces under the same ``kernel:<op>`` keys as the Pallas kernels, so the
# per-call trace deltas in ``_note`` are honest on both kernel paths.

@functools.partial(jax.jit, static_argnames=("next_width",))
def _ref_trailing_jit(a, q, w, *, next_width: int = 0):
    _dispatch.note_trace("kernel:trailing_update")
    return _ref.trailing_update(a, q, w, next_width=next_width)


@functools.partial(jax.jit, static_argnames=("split",))
def _ref_panel_cross_jit(a, *, split: int):
    _dispatch.note_trace("kernel:panel_cross")
    return _ref.panel_cross(a, split=split)


@functools.partial(jax.jit, static_argnames=("split", "out_width"))
def _ref_pad_cross_jit(a, *, split: int, out_width: int):
    _dispatch.note_trace("kernel:pad_cross")
    return _ref.pad_cross(a, split=split, out_width=out_width)


def _trailing_update_raw(a, q, w, *, next_width: int = 0,
                         use_pallas: bool = False,
                         interpret: bool | None = None,
                         block_rows: int | None = None):
    if use_pallas:
        return _batched(_trailing_mod.trailing_update, 3)(
            a, q, w, next_width=next_width, interpret=interpret,
            block_rows=block_rows,
        )
    return _ref_trailing_jit(a, q, w, next_width=next_width)


def _panel_cross_raw(a, *, split: int, use_pallas: bool = False,
                     interpret: bool | None = None,
                     block_rows: int | None = None):
    if use_pallas:
        return _batched(_trailing_mod.panel_cross, 1)(
            a, split=split, interpret=interpret, block_rows=block_rows
        )
    return _ref_panel_cross_jit(a, split=split)


def _pad_cross_raw(a, *, split: int, out_width: int, use_pallas: bool = False,
                   interpret: bool | None = None,
                   block_rows: int | None = None):
    if use_pallas:
        return _batched(_trailing_mod.pad_cross, 1)(
            a, split=split, out_width=out_width, interpret=interpret,
            block_rows=block_rows,
        )
    return _ref_pad_cross_jit(a, split=split, out_width=out_width)


def trailing_update(a, q, w, *, next_width: int = 0, use_pallas: bool = False,
                    interpret: bool | None = None,
                    block_rows: int | None = None):
    """Blocked-QR trailing update ``A − Q W`` in **one** trailing-block
    sweep, with the next panel's cross-Gram ``S`` accumulated in the same
    pass when ``next_width > 0`` (see :mod:`repro.kernels.trailing_update`).

    Returns ``a_new`` — or ``(a_new, s)`` when ``next_width > 0``.
    """
    t0 = _pre("trailing_update")
    if use_pallas:
        block_rows = _resolve_br("trailing_update", a, block_rows, interpret)
    out = _trailing_update_raw(
        a, q, w, next_width=next_width, use_pallas=use_pallas,
        interpret=interpret, block_rows=block_rows,
    )
    a_new = out[0] if next_width else out
    s_bytes = _nbytes(out[1]) if next_width else 0
    _note("trailing_update", t0, sweeps=1,
          read_bytes=_nbytes(a) + _nbytes(q) + _nbytes(w),
          write_bytes=_nbytes(a_new) + s_bytes)
    return out


def panel_cross(a, *, split: int, use_pallas: bool = False,
                interpret: bool | None = None,
                block_rows: int | None = None):
    """Pipeline prime for blocked QR: ``S = A[:, :split]ᵀ A`` in one sweep."""
    t0 = _pre("panel_cross")
    if use_pallas:
        block_rows = _resolve_br("panel_cross", a, block_rows, interpret)
    out = _panel_cross_raw(
        a, split=split, use_pallas=use_pallas, interpret=interpret,
        block_rows=block_rows,
    )
    _note("panel_cross", t0, sweeps=1, read_bytes=_nbytes(a),
          write_bytes=_nbytes(out))
    return out


def pad_cross(a, *, split: int, out_width: int, use_pallas: bool = False,
              interpret: bool | None = None, block_rows: int | None = None):
    """Fixed-shape pipeline prime: widen A to the padded trailing width and
    compute ``S = A[:, :split]ᵀ A`` in the same single sweep.  Returns
    ``(a_pad, s)`` — see :func:`repro.kernels.trailing_update.pad_cross`."""
    t0 = _pre("pad_cross")
    if use_pallas:
        block_rows = _resolve_br("pad_cross", a, block_rows, interpret)
    out = _pad_cross_raw(
        a, split=split, out_width=out_width, use_pallas=use_pallas,
        interpret=interpret, block_rows=block_rows,
    )
    _note("pad_cross", t0, sweeps=1, read_bytes=_nbytes(a),
          write_bytes=_nbytes(out[0]) + _nbytes(out[1]))
    return out


# -- composed ops -----------------------------------------------------------

def tri_inv(r):
    """Inverse of an upper-triangular (…, n, n) factor.

    Solves against the single unbatched identity — no broadcast (…, n, n)
    identity is ever materialized; batch dims are vmapped over ``r`` only.
    Accumulation stays in ``r``'s (f32 in every CQR2 use) precision.
    """
    eye = jnp.eye(r.shape[-1], dtype=r.dtype)

    def solve(rr):
        return jsl.solve_triangular(rr, eye, lower=False)

    for _ in range(r.ndim - 2):
        solve = jax.vmap(solve)
    return solve(r)


def _posdiag(r):
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def _chol_upper(g):
    """Upper-triangular Cholesky factor of a Gram matrix (positive diag)."""
    return jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2)


def cholesky_qr(a, *, use_pallas: bool = False, interpret: bool | None = None):
    """One CholeskyQR round.  a: (…, m, n) → (Q (…, m, n), R (…, n, n) f32)."""
    g = gram(a, use_pallas=use_pallas, interpret=interpret)
    r = _chol_upper(g)
    q = apply_right(
        a, tri_inv(r).astype(a.dtype), use_pallas=use_pallas, interpret=interpret
    )
    return q, r


def cholesky_qr2(a, *, use_pallas: bool = False, interpret: bool | None = None,
                 fused: bool = True):
    """CholeskyQR2: Householder-grade orthogonality, MXU-native FLOPs.

    ``fused=True`` (default) rides :func:`fused_apply_gram`: round 1's panel
    apply accumulates round 2's Gram in the same sweep — 3 tall-operand
    sweeps (A, A, Q₁) instead of the unfused 4 (A, A, Q₁, Q₁).
    ``fused=False`` keeps the seed's two independent rounds (the bench
    baseline and the property-test reference).
    """
    if not fused:
        q1, r1 = cholesky_qr(a, use_pallas=use_pallas, interpret=interpret)
        q, r2 = cholesky_qr(q1, use_pallas=use_pallas, interpret=interpret)
        return q, _posdiag(r2 @ r1)
    g1 = gram(a, use_pallas=use_pallas, interpret=interpret)       # sweep 1
    r1 = _chol_upper(g1)
    q1, g2 = fused_apply_gram(                                     # sweep 2
        a, tri_inv(r1).astype(a.dtype),
        use_pallas=use_pallas, interpret=interpret,
    )
    r2 = _chol_upper(g2)
    q = apply_right(                                               # sweep 3
        q1, tri_inv(r2).astype(a.dtype),
        use_pallas=use_pallas, interpret=interpret,
    )
    return q, _posdiag(r2 @ r1)


def cholesky_qr2_r(a, *, use_pallas: bool = False,
                   interpret: bool | None = None):
    """CholeskyQR2, R factor only — **2 HBM sweeps** over the tall operand.

    This is the TSQR local QR (``QRCombiner.prepare``): the butterfly only
    carries R, so Q₁ is never needed.  Sweep 1 is the Gram of A; sweep 2 is
    :func:`fused_apply_gram` with ``want_q=False`` — the applied panel is
    consumed in VMEM for round 2's Gram and no tall intermediate touches
    HBM.  Bit-identical to ``cholesky_qr2(a)[1]`` (same panel boundaries,
    same cast points); the seed computed the full 4-sweep factorization and
    discarded Q.
    """
    g1 = gram(a, use_pallas=use_pallas, interpret=interpret)       # sweep 1
    r1 = _chol_upper(g1)
    g2 = fused_apply_gram(                                         # sweep 2
        a, tri_inv(r1).astype(a.dtype),
        use_pallas=use_pallas, interpret=interpret, want_q=False,
    )
    r2 = _chol_upper(g2)
    return _posdiag(r2 @ r1)
