"""Pallas **Triton** lowerings of the streaming QR kernels (GPU backend).

The TPU kernels in :mod:`gram` / :mod:`fused_apply_gram` /
:mod:`trailing_update` rely on a Mosaic-only contract: the grid is
*sequential*, so a constant output block revisited by every step
(``index_map i → (0, 0)``) is a legal VMEM accumulator.  On GPU that
contract does not exist — Pallas lowers through Triton, grid programs are
CUDA blocks running **in parallel**, and the revisited-block pattern is a
data race.  These lowerings keep the same streaming structure (one
row-panel of the tall operand per program, in-kernel row/column-iota edge
masking, no padded HBM copies) but split every reduction into the
GPU-legal two-phase shape:

  1. each program writes its **own** f32 partial block — out BlockSpec
     ``(1, n, k)`` with ``index_map i → (i, 0, 0)`` over a
     ``(grid, n, k)`` output, so no two programs touch the same memory;
  2. a ``jnp.sum(partials, axis=0)`` *outside* the ``pallas_call`` (but
     inside the caller's jit, so XLA fuses it) folds the partials.

Map-style writes (``Q`` panels, ``A_new``, the padded copy) are untouched:
each program owns its output block, which is exactly the parallel-safe
pattern.  ``combine_gram`` needs no GPU variant at all — its grid is
``(1,)``, trivially race-free on any backend.

The partial blocks are priced honestly: the autotuner's *streamed*-byte
model adds ``2·grid·n·k·4`` (the partial write + the fold's re-read) per
reduction on this backend, which is why its GPU winners lean to taller
blocks than the SMEM budget alone would suggest.  Committed operand bytes
(what the ``ops`` wrappers note) are unchanged — partials are jit-local
temporaries.

CI safety: this container has no GPU; ``interpret=None`` auto-falls back
to the Pallas interpreter whenever ``jax.default_backend() != "gpu"``, so
every kernel here is exercised numerically in CI while the compiled
resolution (``interpret=False`` reaching ``pl.pallas_call``) is pinned by
mocked-backend tests.  Block heights align to :data:`SUBLANE` = 16 rows
(half a warp — Triton block dims want power-of-two-ish multiples), not the
TPU's 8 f32 sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .backend import pick_block_rows
from .gram import mask_cols, mask_rows

__all__ = [
    "DEFAULT_BLOCK_ROWS",
    "SUBLANE",
    "apply_right",
    "fused_apply_gram",
    "gram",
    "pad_cross",
    "panel_cross",
    "trailing_update",
]

# Triton programs stage their block through shared memory/registers — far
# smaller than a TPU core's VMEM, so the untuned default panel is short.
# The autotuner's gpu-triton budget searches around this.
DEFAULT_BLOCK_ROWS = 128
SUBLANE = 16

_GRAM_DIMS = (((0,), (0,)), ((), ()))
_APPLY_DIMS = (((1,), (0,)), ((), ()))
_CROSS_DIMS = (((0,), (0,)), ((), ()))


def _resolve(m: int, block_rows: int | None, interpret: bool | None):
    """(block_rows, interpret) with the CI-safe fallback: no GPU runtime →
    interpreter, so these kernels are numerically exercised anywhere."""
    if interpret is None:
        interpret = jax.default_backend() != "gpu"
    if block_rows is None:
        block_rows = DEFAULT_BLOCK_ROWS
    return pick_block_rows(m, block_rows, sublane=SUBLANE), bool(interpret)


def _fold(partials):
    """Phase 2 of every reduction: fold the per-program partials.  Lives
    outside the pallas_call, inside the caller's jit."""
    return jnp.sum(partials, axis=0)


def _gram_kernel(a_ref, o_ref, *, block_rows: int, m: int):
    i = pl.program_id(0)
    a = mask_rows(a_ref[...], i, block_rows, m)
    o_ref[0, ...] = lax.dot_general(
        a, a, _GRAM_DIMS, preferred_element_type=jnp.float32
    )


def gram(a, *, block_rows: int | None = None, interpret: bool | None = None):
    """G = AᵀA, float32 — Triton lowering (see module docstring)."""
    m, n = a.shape
    block_rows, interpret = _resolve(m, block_rows, interpret)
    g = pl.cdiv(m, block_rows)
    partials = pl.pallas_call(
        functools.partial(_gram_kernel, block_rows=block_rows, m=m),
        grid=(g,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, n), jnp.float32),
        interpret=interpret,
    )(a)
    return _fold(partials)


def _apply_kernel(a_ref, w_ref, o_ref):
    o_ref[...] = lax.dot_general(
        a_ref[...], w_ref[...], _APPLY_DIMS,
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def apply_right(a, w, *, block_rows: int | None = None,
                interpret: bool | None = None):
    """A (m, n) @ W (n, k) → (m, k) — a pure map: every program owns its
    output block, so the TPU structure is already parallel-safe."""
    m, n = a.shape
    n2, k = w.shape
    assert n == n2, (a.shape, w.shape)
    block_rows, interpret = _resolve(m, block_rows, interpret)
    return pl.pallas_call(
        _apply_kernel,
        grid=(pl.cdiv(m, block_rows),),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), a.dtype),
        interpret=interpret,
    )(a, w)


def _fused_kernel(a_ref, w_ref, *out_refs, block_rows: int, m: int,
                  want_q: bool):
    i = pl.program_id(0)
    a = mask_rows(a_ref[...], i, block_rows, m)
    q32 = lax.dot_general(
        a, w_ref[...], _APPLY_DIMS, preferred_element_type=jnp.float32
    )
    q = q32.astype(a_ref.dtype)
    if want_q:
        out_refs[0][...] = q
    out_refs[-1][0, ...] = lax.dot_general(
        q, q, _GRAM_DIMS, preferred_element_type=jnp.float32
    )


def fused_apply_gram(a, w, *, block_rows: int | None = None,
                     interpret: bool | None = None, want_q: bool = True):
    """One-sweep fused ``Q = A @ W`` + partial ``G' = QᵀQ`` per program;
    the Gram partials fold outside the kernel."""
    m, n = a.shape
    n2, k = w.shape
    assert n == n2, (a.shape, w.shape)
    block_rows, interpret = _resolve(m, block_rows, interpret)
    g = pl.cdiv(m, block_rows)
    kernel = functools.partial(
        _fused_kernel, block_rows=block_rows, m=m, want_q=want_q
    )
    in_specs = [
        pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        pl.BlockSpec((n, k), lambda i: (0, 0)),
    ]
    gram_spec = pl.BlockSpec((1, k, k), lambda i: (i, 0, 0))
    gram_shape = jax.ShapeDtypeStruct((g, k, k), jnp.float32)
    if want_q:
        out_specs = [pl.BlockSpec((block_rows, k), lambda i: (i, 0)), gram_spec]
        out_shape = [jax.ShapeDtypeStruct((m, k), a.dtype), gram_shape]
    else:
        out_specs = [gram_spec]
        out_shape = [gram_shape]
    out = pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(a, w)
    if want_q:
        return out[0], _fold(out[1])
    return _fold(out[0])


def _update_kernel(a_ref, q_ref, w_ref, *out_refs, block_rows: int, m: int,
                   next_width: int):
    i = pl.program_id(0)
    upd = lax.dot_general(
        q_ref[...], w_ref[...], _APPLY_DIMS, preferred_element_type=jnp.float32
    )
    a_new = (a_ref[...].astype(jnp.float32) - upd).astype(a_ref.dtype)
    out_refs[0][...] = a_new
    if next_width:
        a_m = mask_rows(a_new, i, block_rows, m)
        out_refs[1][0, ...] = lax.dot_general(
            a_m[:, :next_width], a_m, _CROSS_DIMS,
            preferred_element_type=jnp.float32,
        )


def trailing_update(a, q, w, *, next_width: int = 0,
                    block_rows: int | None = None,
                    interpret: bool | None = None):
    """One-sweep ``A_new = A − Q W`` (+ lookahead ``S`` via partials)."""
    m, nt = a.shape
    m2, b = q.shape
    b2, nt2 = w.shape
    assert m == m2 and b == b2 and nt == nt2, (a.shape, q.shape, w.shape)
    assert 0 <= next_width <= nt, (next_width, nt)
    block_rows, interpret = _resolve(m, block_rows, interpret)
    g = pl.cdiv(m, block_rows)
    kernel = functools.partial(
        _update_kernel, block_rows=block_rows, m=m, next_width=next_width
    )
    in_specs = [
        pl.BlockSpec((block_rows, nt), lambda i: (i, 0)),
        pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
        pl.BlockSpec((b, nt), lambda i: (0, 0)),
    ]
    out_specs = [pl.BlockSpec((block_rows, nt), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((m, nt), a.dtype)]
    if next_width:
        out_specs.append(pl.BlockSpec((1, next_width, nt), lambda i: (i, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((g, next_width, nt), jnp.float32)
        )
    out = pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(a, q, w)
    if next_width:
        return out[0], _fold(out[1])
    return out[0]


def _cross_kernel(a_ref, s_ref, *, block_rows: int, m: int, split: int):
    i = pl.program_id(0)
    a = mask_rows(a_ref[...], i, block_rows, m)
    s_ref[0, ...] = lax.dot_general(
        a[:, :split], a, _CROSS_DIMS, preferred_element_type=jnp.float32
    )


def panel_cross(a, *, split: int, block_rows: int | None = None,
                interpret: bool | None = None):
    """Pipeline prime: ``S = A[:, :split]ᵀ A`` via per-program partials."""
    m, n = a.shape
    assert 0 < split <= n, (split, n)
    block_rows, interpret = _resolve(m, block_rows, interpret)
    g = pl.cdiv(m, block_rows)
    partials = pl.pallas_call(
        functools.partial(
            _cross_kernel, block_rows=block_rows, m=m, split=split
        ),
        grid=(g,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, split, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, split, n), jnp.float32),
        interpret=interpret,
    )(a)
    return _fold(partials)


def _pad_cross_kernel(a_ref, apad_ref, s_ref, *, block_rows: int, m: int,
                      split: int, n: int):
    i = pl.program_id(0)
    a_p = mask_cols(a_ref[...], n)
    apad_ref[...] = a_p
    a_m = mask_rows(a_p, i, block_rows, m)
    s_ref[0, ...] = lax.dot_general(
        a_m[:, :split], a_m, _CROSS_DIMS, preferred_element_type=jnp.float32
    )


def pad_cross(a, *, split: int, out_width: int,
              block_rows: int | None = None,
              interpret: bool | None = None):
    """Fixed-shape prime: widened copy + ``S`` partials in one sweep."""
    m, n = a.shape
    assert 0 < split <= n <= out_width, (split, n, out_width)
    block_rows, interpret = _resolve(m, block_rows, interpret)
    g = pl.cdiv(m, block_rows)
    a_pad, partials = pl.pallas_call(
        functools.partial(
            _pad_cross_kernel, block_rows=block_rows, m=m, split=split, n=n
        ),
        grid=(g,),
        in_specs=[pl.BlockSpec((block_rows, out_width), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, out_width), lambda i: (i, 0)),
            pl.BlockSpec((1, split, out_width), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, out_width), a.dtype),
            jax.ShapeDtypeStruct((g, split, out_width), jnp.float32),
        ],
        interpret=interpret,
    )(a)
    return a_pad, _fold(partials)
