"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against the
function of the same name here.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "gram",
    "apply_right",
    "fused_apply_gram",
    "combine_gram",
    "cholesky_qr",
    "cholesky_qr2",
    "trailing_update",
    "panel_cross",
]


def gram(a: jnp.ndarray) -> jnp.ndarray:
    """G = AᵀA accumulated in float32.  a: (..., m, n) → (..., n, n) f32."""
    a32 = a.astype(jnp.float32)
    return jnp.einsum("...mi,...mj->...ij", a32, a32)


def apply_right(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """A @ W with float32 accumulation, result in A's dtype.  w: (..., n, k)."""
    out = a.astype(jnp.float32) @ w.astype(jnp.float32)
    return out.astype(a.dtype)


def fused_apply_gram(
    a: jnp.ndarray, w: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the fused kernel: Q = A @ W and G' = QᵀQ of the *stored*
    (cast) Q — the rounding a materialized panel would carry."""
    q = apply_right(a, w)
    return q, gram(q)


def trailing_update(
    a: jnp.ndarray, q: jnp.ndarray, w: jnp.ndarray, *, next_width: int = 0
):
    """Oracle for the fused trailing update: ``A_new = A − Q W`` (f32 math,
    stored in A's dtype) and, when ``next_width > 0``, the lookahead
    ``S = A_new[:, :next_width]ᵀ A_new`` of the *stored* (cast) update."""
    upd = q.astype(jnp.float32) @ w.astype(jnp.float32)
    a_new = (a.astype(jnp.float32) - upd).astype(a.dtype)
    if not next_width:
        return a_new
    return a_new, panel_cross(a_new, split=next_width)


def panel_cross(a: jnp.ndarray, *, split: int) -> jnp.ndarray:
    """S = A[:, :split]ᵀ A accumulated in float32.  a: (..., m, n)."""
    a32 = a.astype(jnp.float32)
    return jnp.einsum("...mi,...mj->...ij", a32[..., :split], a32)


def combine_gram(r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """G = R1ᵀR1 + R2ᵀR2 in float32 — the Gram-combine of two R̃ factors."""
    return gram(r1) + gram(r2)


def _posdiag(r):
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def cholesky_qr(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One CholeskyQR round: Q = A·R⁻¹ with R = chol(AᵀA)ᵀ.

    Certified only for κ(A) ≲ 1/√ε; use :func:`cholesky_qr2` in general.
    """
    import jax.scipy.linalg as jsl

    g = gram(a)
    l = jnp.linalg.cholesky(g)
    r = l.T  # upper, positive diagonal by construction
    rinv = jsl.solve_triangular(r, jnp.eye(r.shape[-1], dtype=r.dtype), lower=False)
    q = apply_right(a, rinv)
    return q, r


def cholesky_qr2(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """CholeskyQR2 — two rounds; the TPU-native tall-skinny QR."""
    q1, r1 = cholesky_qr(a)
    q, r2 = cholesky_qr(q1)
    return q, _posdiag(r2 @ r1)
