"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against the
function of the same name here.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "gram",
    "apply_right",
    "fused_apply_gram",
    "combine_gram",
    "cholesky_qr",
    "cholesky_qr2",
    "trailing_update",
    "panel_cross",
    "pad_cross",
]


def gram(a: jnp.ndarray) -> jnp.ndarray:
    """G = AᵀA accumulated in float32.  a: (..., m, n) → (..., n, n) f32."""
    a32 = a.astype(jnp.float32)
    return jnp.einsum("...mi,...mj->...ij", a32, a32)


def apply_right(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """A @ W with float32 accumulation, result in A's dtype.  w: (..., n, k)."""
    out = a.astype(jnp.float32) @ w.astype(jnp.float32)
    return out.astype(a.dtype)


def fused_apply_gram(
    a: jnp.ndarray, w: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the fused kernel: Q = A @ W and G' = QᵀQ of the *stored*
    (cast) Q — the rounding a materialized panel would carry."""
    q = apply_right(a, w)
    return q, gram(q)


# XLA CPU lowers dots with an output dimension this narrow to mat-vec
# strategies whose accumulation order differs from the blocked GEMM used at
# wider shapes.  The blocked-QR drivers need *width-stable* per-element
# results (the fixed-shape pipeline computes at the padded maximal width,
# the eager driver at the true shrinking width — bit-identity between them
# is hypothesis-gated), so the two trailing-path oracles below pad narrow
# operands with zero columns up to this floor and slice the result back:
# values are unchanged, but every shape rides the same GEMM strategy.  The
# ``optimization_barrier`` keeps XLA's algebraic simplifier from folding
# the slice back into the dot (restoring the narrow strategy) when the
# oracle is traced into a larger program such as the scan pipeline.
_MIN_GEMM_WIDTH = 4


def min_gemm_width() -> int:
    """The effective GEMM-width floor: the static minimum above, raised (never
    lowered) by an installed autotune winner's ``gemm_width_floor``.  The
    tuner may prefer a wider pad when the roofline prior says the extra
    zero-column FLOPs are cheaper than the narrow-dot strategy switch; it can
    never go below :data:`_MIN_GEMM_WIDTH` — that floor is a bit-identity
    contract, not a tuning knob.  Consulted at trace time: a table installed
    *after* an oracle is traced does not rewrite the compiled program (the
    drivers' compile keys pin the config they were built with)."""
    from . import autotune as _autotune

    floors = [
        e.get("gemm_width_floor", _MIN_GEMM_WIDTH)
        for e in _autotune.installed().values()
    ]
    return max([_MIN_GEMM_WIDTH, *floors])


def _pad_cols(x: jnp.ndarray, min_width: int) -> jnp.ndarray:
    pad = min_width - x.shape[-1]
    if pad <= 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def trailing_update(
    a: jnp.ndarray, q: jnp.ndarray, w: jnp.ndarray, *, next_width: int = 0
):
    """Oracle for the fused trailing update: ``A_new = A − Q W`` (f32 math,
    stored in A's dtype) and, when ``next_width > 0``, the lookahead
    ``S = A_new[:, :next_width]ᵀ A_new`` of the *stored* (cast) update."""
    from repro.compat import optimization_barrier

    nt = a.shape[-1]
    w32 = w.astype(jnp.float32)
    floor = min_gemm_width()
    if nt < floor:
        wide = q.astype(jnp.float32) @ _pad_cols(w32, floor)
        upd = optimization_barrier(wide)[..., :nt]
    else:
        upd = q.astype(jnp.float32) @ w32
    a_new = (a.astype(jnp.float32) - upd).astype(a.dtype)
    if not next_width:
        return a_new
    return a_new, panel_cross(a_new, split=next_width)


def panel_cross(a: jnp.ndarray, *, split: int) -> jnp.ndarray:
    """S = A[:, :split]ᵀ A accumulated in float32.  a: (..., m, n)."""
    from repro.compat import optimization_barrier

    a32 = a.astype(jnp.float32)
    n = a.shape[-1]
    floor = min_gemm_width()
    if split >= floor and n >= floor:
        return jnp.einsum("...mi,...mj->...ij", a32[..., :split], a32)
    left = _pad_cols(a32[..., :split], floor)
    right = _pad_cols(a32, floor)
    s = jnp.einsum("...mi,...mj->...ij", left, right)
    return optimization_barrier(s)[..., :split, :n]


def pad_cross(a: jnp.ndarray, *, split: int, out_width: int):
    """Oracle for the fused pad+cross prime: widen A with zero columns to
    ``out_width`` and compute the :func:`panel_cross` of the widened copy."""
    pad = out_width - a.shape[-1]
    widths = [(0, 0)] * (a.ndim - 1) + [(0, pad)]
    a_pad = jnp.pad(a, widths)
    return a_pad, panel_cross(a_pad, split=split)


def combine_gram(r1: jnp.ndarray, r2: jnp.ndarray) -> jnp.ndarray:
    """G = R1ᵀR1 + R2ᵀR2 in float32 — the Gram-combine of two R̃ factors."""
    return gram(r1) + gram(r2)


def _posdiag(r):
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def cholesky_qr(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One CholeskyQR round: Q = A·R⁻¹ with R = chol(AᵀA)ᵀ.

    Certified only for κ(A) ≲ 1/√ε; use :func:`cholesky_qr2` in general.
    """
    import jax.scipy.linalg as jsl

    g = gram(a)
    l = jnp.linalg.cholesky(g)
    r = l.T  # upper, positive diagonal by construction
    rinv = jsl.solve_triangular(r, jnp.eye(r.shape[-1], dtype=r.dtype), lower=False)
    q = apply_right(a, rinv)
    return q, r


def cholesky_qr2(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """CholeskyQR2 — two rounds; the TPU-native tall-skinny QR."""
    q1, r1 = cholesky_qr(a)
    q, r2 = cholesky_qr(q1)
    return q, _posdiag(r2 @ r1)
