"""Pallas TPU kernels for the paper's compute hot-spot: local tall-skinny QR.

The paper's local QR (LAPACK Householder in the MPI original) is adapted to
the MXU as CholeskyQR2 (DESIGN.md §2, adaptation #2).  Four kernels:

  * :mod:`repro.kernels.gram`             — blocked G = AᵀA, VMEM accumulator;
  * :mod:`repro.kernels.apply_right`      — panel-streamed Q = A·R⁻¹;
  * :mod:`repro.kernels.fused_apply_gram` — ONE sweep: Q = A·W **and** the
    next round's G' = QᵀQ accumulated in VMEM (optionally without writing Q
    at all) — the single-sweep-per-round CQR2 pipeline;
  * :mod:`repro.kernels.combine_gram`     — fused R̃ᵀR̃ + R̃ᵀR̃ combine for the
    Gram-butterfly variant (§Perf);
  * :mod:`repro.kernels.trailing_update`  — blocked-QR trailing update
    ``A − Q W`` in ONE trailing-block sweep, with the next panel's
    cross-Gram accumulated in the same pass (DESIGN.md §8).

Edge tiles are masked in-kernel (no ``jnp.pad`` HBM round-trips), and the
execution mode auto-detects the backend (:mod:`repro.kernels.backend`):
compiled Mosaic on TPU, the Pallas interpreter elsewhere.  ``ops.py`` holds
the jit'd public wrappers (jnp fallbacks, batching, and the HBM-traffic
notes consumed by :mod:`repro.kernels.traffic`); ``ref.py`` the oracles the
tests compare against.
"""
from . import autotune, backend, dispatch, gpu, ops, ref, traffic

__all__ = ["autotune", "backend", "dispatch", "gpu", "ops", "ref", "traffic"]
