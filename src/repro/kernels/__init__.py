"""Pallas TPU kernels for the paper's compute hot-spot: local tall-skinny QR.

The paper's local QR (LAPACK Householder in the MPI original) is adapted to
the MXU as CholeskyQR2 (DESIGN.md §2, adaptation #2).  Three kernels:

  * :mod:`repro.kernels.gram`         — blocked G = AᵀA, VMEM accumulator;
  * :mod:`repro.kernels.apply_right`  — panel-streamed Q = A·R⁻¹ application;
  * :mod:`repro.kernels.combine_gram` — fused R̃ᵀR̃ + R̃ᵀR̃ combine for the
    Gram-butterfly variant (§Perf).

``ops.py`` holds the jit'd public wrappers (with pure-jnp fallbacks and
batching); ``ref.py`` the oracles the tests compare against.  Kernels are
validated in ``interpret=True`` mode on CPU; ``interpret=False`` targets the
Mosaic TPU compiler.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
