"""Pallas TPU kernels: one-sweep trailing-matrix update for blocked QR.

The right-looking blocked QR (:mod:`repro.qr.blocked`) spends its FLOPs in
the trailing update ``A_t ← A_t − Q_p (Q_pᵀ A_t)``.  Done naively that is
*two* HBM sweeps over the trailing block per panel: one reduction sweep for
``W = Q_pᵀ A_t`` and one map sweep for the subtraction.  These kernels get
it down to exactly **one** sweep per panel by a lookahead fusion:

  * :func:`trailing_update` applies ``A_new = A_t − Q_p W`` with ``W``
    *already known*, and — in the same pass, while each updated row-panel
    is still in VMEM — accumulates the next panel's cross-Gram
    ``S = A_new[:, :next_width]ᵀ A_new`` into a VMEM-resident f32
    accumulator.  ``S[:, :next_width]`` is the next panel's Gram (its local
    QR via Cholesky) and ``S[:, next_width:]`` is the next cross product
    ``A_pᵀ A_t`` (whence the next ``W = R⁻ᵀ ΣS``), so the *next* panel
    never has to re-read the trailing block at all.
  * :func:`panel_cross` primes the pipeline: one sweep over the initial
    matrix producing ``S = A[:, :split]ᵀ A`` for panel 0.
  * :func:`pad_cross` is the fixed-shape (scan-compiled) driver's prime:
    the same sweep additionally emits a copy of A widened to the padded
    maximal trailing width with in-kernel zeroed pad columns — the column
    extension of the row-iota edge masking (DESIGN.md §9).

K panels therefore cost exactly K trailing-block sweeps — 1 per panel —
which the ``general_qr`` bench case hard-gates through the
:mod:`repro.kernels.traffic` model.

Tiling mirrors the CQR2 kernels: row-panels of the tall operands stream
HBM→VMEM over a sequential grid, the small operands (``W``, the ``S``
accumulator) are VMEM-resident constant blocks, and ragged edge tiles are
masked in-kernel against a row iota (``S`` contributions) or dropped on the
partial final block write (``A_new`` rows) — no padded HBM copy is ever
materialized.  The update is computed in f32 and cast to the storage dtype
*before* feeding the ``S`` accumulator, so ``S`` is bit-identical to
``panel_cross`` re-run on the stored ``A_new`` with the same panel height.

VMEM at defaults (block_rows=1024, n_trail≤512, b≤128, f32): input panel
+ Q panel + W + updated panel + S accumulator ≈ 5 MiB — inside ~16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import autotune as _autotune
from .backend import pick_block_rows, resolve_backend
from .dispatch import note_trace
from .gram import mask_cols, mask_rows

__all__ = ["trailing_update", "panel_cross", "pad_cross"]

_CROSS_DIMS = (((0,), (0,)), ((), ()))   # (rows, b)ᵀ @ (rows, n) → (b, n)
_APPLY_DIMS = (((1,), (0,)), ((), ()))   # (rows, b) @ (b, n) → (rows, n)


def _update_kernel(a_ref, q_ref, w_ref, *out_refs, block_rows: int, m: int,
                   next_width: int):
    i = pl.program_id(0)
    upd = lax.dot_general(
        q_ref[...], w_ref[...], _APPLY_DIMS, preferred_element_type=jnp.float32
    )
    a_new = (a_ref[...].astype(jnp.float32) - upd).astype(a_ref.dtype)
    out_refs[0][...] = a_new
    if next_width:
        s_ref = out_refs[1]

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)

        a_m = mask_rows(a_new, i, block_rows, m)
        s_ref[...] += lax.dot_general(
            a_m[:, :next_width], a_m, _CROSS_DIMS,
            preferred_element_type=jnp.float32,
        )


@functools.partial(
    jax.jit, static_argnames=("next_width", "block_rows", "interpret")
)
def trailing_update(a, q, w, *, next_width: int = 0,
                    block_rows: int | None = None,
                    interpret: bool | None = None):
    """One-sweep ``A_new = A − Q W`` (+ lookahead ``S``).

    a: (m, n_t), q: (m, b), w: (b, n_t).  Returns ``A_new`` (m, n_t) in
    ``a``'s dtype — and, when ``next_width > 0``, also
    ``S = A_new[:, :next_width]ᵀ A_new`` (next_width, n_t) float32, the
    next panel's fused Gram + cross product.  ``interpret=None``
    auto-detects the backend; ``block_rows=None`` consults the installed
    autotune table at trace time (see :func:`repro.kernels.gram.gram`).
    """
    note_trace("kernel:trailing_update")
    be = resolve_backend(interpret)
    m, nt = a.shape
    m2, b = q.shape
    b2, nt2 = w.shape
    assert m == m2 and b == b2 and nt == nt2, (a.shape, q.shape, w.shape)
    assert 0 <= next_width <= nt, (next_width, nt)
    block_rows = _autotune.resolve_block_rows(
        "trailing_update", m, nt, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.trailing_update(
            a, q, w, next_width=next_width, block_rows=block_rows,
            interpret=False,
        )
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    grid = (pl.cdiv(m, block_rows),)
    kernel = functools.partial(
        _update_kernel, block_rows=block_rows, m=m, next_width=next_width
    )
    in_specs = [
        pl.BlockSpec((block_rows, nt), lambda i: (i, 0)),
        pl.BlockSpec((block_rows, b), lambda i: (i, 0)),
        pl.BlockSpec((b, nt), lambda i: (0, 0)),
    ]
    out_specs = [pl.BlockSpec((block_rows, nt), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((m, nt), a.dtype)]
    if next_width:
        out_specs.append(pl.BlockSpec((next_width, nt), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((next_width, nt), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=be.interpret,
    )(a, q, w)
    if next_width:
        return tuple(out)
    return out[0]


def _cross_kernel(a_ref, s_ref, *, block_rows: int, m: int, split: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = mask_rows(a_ref[...], i, block_rows, m)
    s_ref[...] += lax.dot_general(
        a[:, :split], a, _CROSS_DIMS, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("split", "block_rows", "interpret"))
def panel_cross(a, *, split: int, block_rows: int | None = None,
                interpret: bool | None = None):
    """Pipeline prime: ``S = A[:, :split]ᵀ A`` in one sweep, float32.

    a: (m, n) → (split, n).  ``S[:, :split]`` is panel 0's Gram,
    ``S[:, split:]`` its cross product against the trailing block.
    """
    note_trace("kernel:panel_cross")
    be = resolve_backend(interpret)
    m, n = a.shape
    assert 0 < split <= n, (split, n)
    block_rows = _autotune.resolve_block_rows(
        "panel_cross", m, n, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.panel_cross(
            a, split=split, block_rows=block_rows, interpret=False
        )
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    return pl.pallas_call(
        functools.partial(
            _cross_kernel, block_rows=block_rows, m=m, split=split
        ),
        grid=(pl.cdiv(m, block_rows),),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((split, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((split, n), jnp.float32),
        interpret=be.interpret,
    )(a)


def _pad_cross_kernel(a_ref, apad_ref, s_ref, *, block_rows: int, m: int,
                      split: int, n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    # The input block is read at the widened out_width: columns >= n are
    # out-of-bounds garbage, zeroed against a column iota — the exact
    # column analogue of the row-iota edge masking below.
    a_p = mask_cols(a_ref[...], n)
    apad_ref[...] = a_p                 # OOB rows dropped on the edge write
    a_m = mask_rows(a_p, i, block_rows, m)
    s_ref[...] += lax.dot_general(
        a_m[:, :split], a_m, _CROSS_DIMS, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("split", "out_width", "block_rows", "interpret")
)
def pad_cross(a, *, split: int, out_width: int,
              block_rows: int | None = None,
              interpret: bool | None = None):
    """Pipeline prime for the fixed-shape blocked QR: widen A to the padded
    trailing width and compute ``S = A[:, :split]ᵀ A`` in the **same** sweep.

    a: (m, n) → ``(a_pad (m, out_width) in a's dtype, s (split, out_width)
    float32)``.  Columns ``>= n`` of both outputs are exact zeros (the
    column extension of the row-iota edge masking): the scan-compiled
    driver keeps its trailing block at the maximal width ``K·b``, and zero
    pad columns ride every later sweep without perturbing the real columns
    bit-for-bit.  Compared to ``jnp.pad`` + :func:`panel_cross` this saves
    one full HBM read of the padded copy — A is streamed once, the padded
    copy and the lookahead accumulator are produced together.
    """
    note_trace("kernel:pad_cross")
    be = resolve_backend(interpret)
    m, n = a.shape
    assert 0 < split <= n <= out_width, (split, n, out_width)
    block_rows = _autotune.resolve_block_rows(
        "pad_cross", m, n, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.pad_cross(
            a, split=split, out_width=out_width, block_rows=block_rows,
            interpret=False,
        )
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    return pl.pallas_call(
        functools.partial(
            _pad_cross_kernel, block_rows=block_rows, m=m, split=split, n=n
        ),
        grid=(pl.cdiv(m, block_rows),),
        # the input block is read at the *widened* width: columns >= n are
        # out-of-bounds and masked in-kernel (mask_cols), like edge rows
        in_specs=[pl.BlockSpec((block_rows, out_width), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, out_width), lambda i: (i, 0)),
            pl.BlockSpec((split, out_width), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, out_width), a.dtype),
            jax.ShapeDtypeStruct((split, out_width), jnp.float32),
        ],
        interpret=be.interpret,
    )(a)
