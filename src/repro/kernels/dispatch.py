"""Trace- and dispatch-count accounting for the compiled hot paths.

The single-program claims of DESIGN.md §9 are *numbers*, so — like the HBM
model in :mod:`repro.kernels.traffic` — they need a measurement, not an
assertion-by-construction:

  * **traces** — how many times a guarded entry point's Python body was
    re-traced by ``jax.jit``.  Every guarded body calls :func:`note_trace`
    as its first statement; because a jitted function's Python body only
    executes while tracing, the global per-name counter increments exactly
    once per (re)compilation.  A second call with identical
    ``(plan, combiner, treedef, shapes)`` must add **zero** — that is the
    zero-retrace contract the ``dispatch`` bench case and the CI
    retrace-guard step pin.
  * **dispatches** — how many compiled XLA programs a factorization
    launches.  Each jitted-callable invocation is one device dispatch; the
    public wrappers call :func:`note_dispatch` per call (Python-level, so
    the count is exact whether or not the call hit the jit cache).  The
    scan-compiled blocked-QR pipeline dispatches **1** program per
    factorization independent of the panel count; the eager per-panel
    driver dispatches O(K).

Usage::

    with track_dispatch() as d:
        blocked_qr_sim(a, panel_width=128)
    assert d.dispatches["blocked_qr_pipeline"] == 1

    before = trace_count("blocked_qr_pipeline")
    blocked_qr_sim(a, panel_width=128)        # same shapes again
    assert trace_count("blocked_qr_pipeline") == before   # zero retrace

The global trace counters are monotonic for the life of the process (they
survive ``track_dispatch`` scopes), so retrace guards compare deltas.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses

__all__ = [
    "DispatchStats",
    "note_dispatch",
    "note_overlap",
    "note_rounds",
    "note_trace",
    "suppress",
    "trace_count",
    "track_dispatch",
]

# Monotonic per-name trace counts for the whole process (retrace guards
# compare before/after deltas; never reset).
_TRACES: collections.Counter = collections.Counter()


@dataclasses.dataclass
class DispatchStats:
    """Per-scope counters collected by :func:`track_dispatch`."""

    traces: collections.Counter = dataclasses.field(
        default_factory=collections.Counter
    )
    dispatches: collections.Counter = dataclasses.field(
        default_factory=collections.Counter
    )
    # Serial butterfly rounds per entry point (the collective latency
    # proxy) and how many of its reductions were overlapped with compute.
    rounds: collections.Counter = dataclasses.field(
        default_factory=collections.Counter
    )
    overlapped: collections.Counter = dataclasses.field(
        default_factory=collections.Counter
    )

    @property
    def n_traces(self) -> int:
        return sum(self.traces.values())

    @property
    def n_dispatches(self) -> int:
        return sum(self.dispatches.values())

    @property
    def n_rounds(self) -> int:
        return sum(self.rounds.values())

    @property
    def n_overlapped(self) -> int:
        return sum(self.overlapped.values())

    def as_dict(self) -> dict:
        return {
            "traces": dict(self.traces),
            "dispatches": dict(self.dispatches),
            "rounds": dict(self.rounds),
            "overlapped": dict(self.overlapped),
        }


_ACTIVE: list[DispatchStats] = []


def note_trace(name: str) -> None:
    """Record one (re)trace of the named entry point.  Call as the first
    statement of a jitted body — it only executes while tracing."""
    _TRACES[name] += 1
    for t in _ACTIVE:
        t.traces[name] += 1


_SUPPRESS: list[bool] = []


def note_dispatch(name: str, n: int = 1) -> None:
    """Record ``n`` compiled-program launches for the named entry point
    (no-op when nothing is tracking or inside :func:`suppress`)."""
    if not _ACTIVE or _SUPPRESS:
        return
    for t in _ACTIVE:
        t.dispatches[name] += n


def note_rounds(name: str, n: int = 1) -> None:
    """Record ``n`` serial collective (butterfly) rounds committed by the
    named entry point — one per exchange level, priced from the host plan
    (no-op when nothing is tracking or inside :func:`suppress`)."""
    if not _ACTIVE or _SUPPRESS:
        return
    for t in _ACTIVE:
        t.rounds[name] += n


def note_overlap(name: str, n: int = 1) -> None:
    """Record ``n`` reductions issued against lookahead accumulators while
    the previous panel's trailing sweep runs (the double-buffered pipeline's
    comm/compute overlap depth)."""
    if not _ACTIVE or _SUPPRESS:
        return
    for t in _ACTIVE:
        t.overlapped[name] += n


def trace_count(name: str | None = None) -> int:
    """Process-lifetime trace count — total, or for one entry point."""
    if name is None:
        return sum(_TRACES.values())
    return _TRACES[name]


@contextlib.contextmanager
def track_dispatch():
    """Context manager yielding a :class:`DispatchStats` that observes every
    guarded entry point entered inside the block."""
    t = DispatchStats()
    _ACTIVE.append(t)
    try:
        yield t
    finally:
        _ACTIVE.remove(t)


@contextlib.contextmanager
def suppress():
    """Drop :func:`note_dispatch` calls inside the block (the pipeline
    invokes its compiled function under this so wrappers reached at trace
    time don't count phantom launches).  :func:`note_trace` is *not*
    suppressed — trace counters are process-lifetime facts the retrace
    guards rely on."""
    _SUPPRESS.append(True)
    try:
        yield
    finally:
        _SUPPRESS.pop()
