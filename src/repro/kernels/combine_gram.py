"""Pallas TPU kernel: fused Gram-combine of two R̃ factors.

Used by the beyond-paper "Gram-butterfly" TSQR variant (EXPERIMENTS.md
§Perf): instead of re-factorizing the stacked ``[R̃₁; R̃₂]`` (a 2n×n
Householder QR, sequential and VPU-bound on TPU), the combine keeps Gram
form ``G = R̃₁ᵀR̃₁ + R̃₂ᵀR̃₂`` — two n×n MXU matmuls fused in one VMEM-resident
kernel, deferring the single Cholesky to the end of the butterfly.

Single-block kernel: both operands and the output live entirely in VMEM
(n ≤ 512 in every TSQR use; 3·n²·4B ≤ 3 MiB).  Operands are passed at their
natural (n, n) shape — Mosaic pads to lane tiles inside VMEM; no padded
copy is materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .backend import resolve_interpret
from .dispatch import note_trace

__all__ = ["combine_gram"]


def _combine_kernel(r1_ref, r2_ref, o_ref):
    r1 = r1_ref[...]
    r2 = r2_ref[...]
    dims = (((0,), (0,)), ((), ()))
    o_ref[...] = lax.dot_general(
        r1, r1, dims, preferred_element_type=jnp.float32
    ) + lax.dot_general(r2, r2, dims, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def combine_gram(r1, r2, *, interpret: bool | None = None):
    """G = R1ᵀR1 + R2ᵀR2, float32.  r1, r2: (n, n) → (n, n).

    ``interpret=None`` auto-detects the backend.
    """
    note_trace("kernel:combine_gram")
    interpret = resolve_interpret(interpret)
    n = r1.shape[-1]
    assert r1.shape == r2.shape == (n, n)
    return pl.pallas_call(
        _combine_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(r1, r2)
