"""Backend resolution for the Pallas kernels: one descriptor, three worlds.

The seed knew exactly two execution modes — compiled Mosaic on TPU or the
Pallas interpreter everywhere else — collapsed into a single boolean.  That
made the GPU invisible: ``jax.default_backend() == "gpu"`` silently fell
into the interpreter and the whole bench trajectory measured
interpreter-CPU.  This module replaces the boolean tri-state with a
:class:`Backend` descriptor carrying everything a kernel (or the autotuner)
needs to know about the lowering it is about to take:

  * ``kind`` — the lowering family:

      - ``"tpu-mosaic"``  — compiled Mosaic kernels (sequential grid; a
        revisited output block is a legal VMEM accumulator);
      - ``"gpu-triton"``  — compiled Triton kernels via Pallas's GPU
        lowering (grid programs run in PARALLEL; accumulators must be
        per-program partials — see :mod:`repro.kernels.gpu`);
      - ``"interpret"``   — the Pallas interpreter (XLA ops, any backend;
        the CPU test/CI path).

  * ``arch`` — the concrete device kind (``"TPU v5e"``, ``"NVIDIA H100"``,
    ``"cpu"``), the autotune-table key component.
  * ``interpret`` — the flag that reaches ``pl.pallas_call``.  An explicit
    ``True``/``False`` from the caller always wins (tests pin this); when
    it forces the interpreter although a compiled backend is available, a
    one-time warning is emitted — the silent-interpretation failure mode
    this module exists to kill.
  * ``sublane`` — the row-tile alignment quantum for ``block_rows``:
    8 on TPU (f32 sublanes), 16 on GPU (half a warp; Triton block dims
    want power-of-two multiples), 8 under the interpreter (which follows
    the TPU kernel structure).

:func:`pick_block_rows` lives here (re-exported by ``gram`` for
compatibility) because the clamp is backend-derived now: panels are never
taller than sublane-rounded ``m`` and never shorter than one sublane tile.
For tiny ``m < sublane`` panels the choice is one full sublane tile — the
kernels mask the out-of-bounds rows in-kernel against a row iota, so the
padding is compute waste (bounded by ``sublane − 1`` rows), never a
correctness hazard.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax

__all__ = [
    "Backend",
    "DEFAULT_BLOCK_ROWS",
    "KINDS",
    "default_interpret",
    "pick_block_rows",
    "resolve_backend",
    "resolve_interpret",
]

KINDS = ("tpu-mosaic", "gpu-triton", "interpret")

# The untuned streaming panel height (rows per grid step).  Re-exported by
# ``gram`` for compatibility; the autotuner treats it as the baseline
# candidate every measured search must include.
DEFAULT_BLOCK_ROWS = 1024

_TPU_SUBLANE = 8
_GPU_SUBLANE = 16


@dataclasses.dataclass(frozen=True)
class Backend:
    """One resolved kernel-execution target (see module docstring)."""

    kind: str            # "tpu-mosaic" | "gpu-triton" | "interpret"
    arch: str            # device kind of device 0, e.g. "TPU v5e" / "cpu"
    interpret: bool      # the flag that reaches pl.pallas_call
    sublane: int         # block_rows alignment quantum

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")

    @property
    def compiled(self) -> bool:
        return not self.interpret


def _arch() -> str:
    try:
        return jax.devices()[0].device_kind
    except Exception:          # uninitialized / mocked runtime
        return jax.default_backend()


# one warning per process per platform — not one per kernel call
_FORCED_WARNED: set[str] = set()


def _warn_forced_interpret(platform: str) -> None:
    if platform in _FORCED_WARNED:
        return
    _FORCED_WARNED.add(platform)
    kind = "tpu-mosaic" if platform == "tpu" else "gpu-triton"
    warnings.warn(
        f"interpret=True forces the Pallas interpreter although the "
        f"compiled {kind} backend is available on this {platform!r} "
        "runtime — kernels will execute as XLA ops, orders of magnitude "
        "below hardware speed.  Pass interpret=None (the default) to use "
        "the compiled lowering, or silence this by really meaning it "
        "(the warning fires once per process).",
        stacklevel=3,
    )


def resolve_backend(interpret: bool | None = None) -> Backend:
    """Resolve the tri-state ``interpret`` flag into a full :class:`Backend`.

    ``None`` auto-detects: compiled Mosaic on TPU, compiled Triton on GPU,
    interpreter elsewhere.  An explicit bool always wins — ``True`` on a
    compiled-capable runtime warns once (see module docstring); ``False``
    on a runtime with no compiled lowering is honored verbatim and reaches
    ``pl.pallas_call`` (where it fails at lowering — the "explicit always
    wins" contract the kernel tests pin with a mocked ``pallas_call``).
    """
    platform = jax.default_backend()
    if interpret is None:
        interpret = platform not in ("tpu", "gpu")
    else:
        interpret = bool(interpret)
        if interpret and platform in ("tpu", "gpu"):
            _warn_forced_interpret(platform)
    if not interpret and platform == "tpu":
        return Backend("tpu-mosaic", _arch(), False, _TPU_SUBLANE)
    if not interpret and platform == "gpu":
        return Backend("gpu-triton", _arch(), False, _GPU_SUBLANE)
    return Backend("interpret", _arch(), interpret, _TPU_SUBLANE)


def default_interpret() -> bool:
    """True when auto-detection lands on the interpreter (no compiled
    backend on this runtime).  Kept for compatibility — new code should
    consult :func:`resolve_backend` for the full descriptor."""
    return resolve_backend(None).interpret


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag: ``None`` → auto-detect."""
    return resolve_backend(interpret).interpret


def _ceil_to(x: int, q: int) -> int:
    return -(-x // q) * q


def pick_block_rows(m: int, block_rows: int, *,
                    sublane: int | None = None) -> int:
    """Clamp the streaming panel height to the backend's alignment quantum:
    never taller than (sublane-rounded) ``m``, never shorter than one
    sublane tile.  ``sublane=None`` derives the quantum from the
    auto-detected backend (8 TPU sublanes, 16 GPU rows); kernels that
    already resolved a :class:`Backend` pass its ``sublane`` explicitly.

    Tiny panels (``m < sublane``) get exactly one sublane tile: the
    kernels' in-kernel row-iota masking zeroes the out-of-bounds rows, so
    the cost is at most ``sublane − 1`` rows of masked compute — never an
    HBM pad round-trip, never a wrong result.
    """
    if sublane is None:
        sublane = resolve_backend(None).sublane
    return max(sublane, min(block_rows, _ceil_to(m, sublane)))
