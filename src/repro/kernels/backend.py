"""Backend auto-detection for the Pallas kernels.

The kernels in this package run in one of two modes:

  * ``interpret=False`` — the compiled Mosaic TPU kernel (the production
    path);
  * ``interpret=True``  — the Pallas interpreter, which executes the kernel
    body with XLA ops on any backend (the CPU test/CI path).

The seed hard-coded ``interpret=True`` everywhere, so the "TPU-native"
kernels silently ran interpreted even on a TPU runtime.  Every kernel entry
point now takes ``interpret: bool | None = None`` and resolves ``None``
here: compiled on TPU, interpreted elsewhere.  An explicit ``True``/``False``
always wins (tests assert the resolved flag is the one that reaches
``pl.pallas_call``).
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """True (interpreter) unless running on a real TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag: ``None`` → auto-detect."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)
