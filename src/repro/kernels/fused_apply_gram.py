"""Pallas TPU kernel: fused panel apply + next-round Gram, one HBM sweep.

The CholeskyQR2 pipeline interleaves two panel-streamed passes per round:
``Q = A @ W`` (apply) followed by ``G' = QᵀQ`` (the next round's Gram).
Running them as separate kernels streams the tall operand over HBM twice —
and the apply's output panel is *already in VMEM* when the Gram pass would
re-read it.  This kernel fuses the two: per row-panel it

  1. computes ``Q_i = A_i @ W`` on the MXU (f32 accumulation, cast to the
     storage dtype — the exact rounding a materialized Q would carry),
  2. optionally writes ``Q_i`` out (``want_q=True``), and
  3. accumulates ``G' += Q_iᵀ Q_i`` into the VMEM-resident (k, k)
     accumulator (a constant output block revisited by every grid step).

so one sweep over A yields both the applied panel and the Gram the next
round needs.  With ``want_q=False`` (the R-factor-only TSQR local QR) the
panel is consumed entirely in VMEM and never touches HBM at all — CQR2's R
comes out in **2** tall-operand sweeps instead of the seed's 4 (see
``ops.cholesky_qr2_r`` and the hard-gated ``kernels`` bench case).

Edge tiles are masked in-kernel against a row-index iota (zero rows
contribute nothing to either product); no padded copy of A is materialized
in HBM.  Because the Gram is taken of the *cast* panel with the same
``block_rows`` panel boundaries, the accumulated G' is bit-identical to
``gram(apply_right(A, W))`` from the unfused kernels.

VMEM budget at defaults (block_rows=1024, n=k≤512, bf16 in / f32 acc):
one (block_rows, n) input panel + one (block_rows, k) product panel +
the (k, k) f32 accumulator ≈ 3 MiB — well inside ~16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import autotune as _autotune
from .backend import pick_block_rows, resolve_backend
from .dispatch import note_trace
from .gram import mask_rows

__all__ = ["fused_apply_gram"]

_GRAM_DIMS = (((0,), (0,)), ((), ()))
_APPLY_DIMS = (((1,), (0,)), ((), ()))


def _fused_kernel(a_ref, w_ref, *out_refs, block_rows: int, m: int,
                  want_q: bool):
    g_ref = out_refs[-1]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    a = mask_rows(a_ref[...], i, block_rows, m)
    q32 = lax.dot_general(
        a, w_ref[...], _APPLY_DIMS, preferred_element_type=jnp.float32
    )
    q = q32.astype(a_ref.dtype)
    if want_q:
        out_refs[0][...] = q
    g_ref[...] += lax.dot_general(
        q, q, _GRAM_DIMS, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret", "want_q")
)
def fused_apply_gram(a, w, *, block_rows: int | None = None,
                     interpret: bool | None = None, want_q: bool = True):
    """One-sweep fused ``Q = A @ W`` and ``G' = QᵀQ``.

    a: (m, n), w: (n, k).  Returns ``(q, g)`` with q (m, k) in A's dtype and
    g (k, k) float32 — or just ``g`` when ``want_q=False`` (Q never leaves
    VMEM).  ``interpret=None`` auto-detects the backend; ``block_rows=None``
    consults the installed autotune table at trace time (see
    :func:`repro.kernels.gram.gram`).
    """
    note_trace("kernel:fused_apply_gram")
    be = resolve_backend(interpret)
    m, n = a.shape
    n2, k = w.shape
    assert n == n2, (a.shape, w.shape)
    block_rows = _autotune.resolve_block_rows(
        "fused_apply_gram", m, n, a.dtype, explicit=block_rows, backend=be
    )
    if be.kind == "gpu-triton":
        from . import gpu as _gpu

        return _gpu.fused_apply_gram(
            a, w, block_rows=block_rows, interpret=False, want_q=want_q
        )
    block_rows = pick_block_rows(m, block_rows, sublane=be.sublane)
    grid = (pl.cdiv(m, block_rows),)
    kernel = functools.partial(
        _fused_kernel, block_rows=block_rows, m=m, want_q=want_q
    )
    in_specs = [
        pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        pl.BlockSpec((n, k), lambda i: (0, 0)),
    ]
    gram_spec = pl.BlockSpec((k, k), lambda i: (0, 0))
    gram_shape = jax.ShapeDtypeStruct((k, k), jnp.float32)
    if want_q:
        out_specs = [pl.BlockSpec((block_rows, k), lambda i: (i, 0)), gram_spec]
        out_shape = [jax.ShapeDtypeStruct((m, k), a.dtype), gram_shape]
    else:
        out_specs = [gram_spec]
        out_shape = [gram_shape]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=be.interpret,
    )(a, w)
    if want_q:
        return tuple(out)
    return out[0]
