"""Roofline-seeded, hardware-aware autotuner for the Pallas kernels.

ROADMAP item 5: every kernel ran with fixed knobs (``block_rows=1024``,
hard-coded VMEM budgets, the fixed 4-col GEMM floor) picked for one TPU
generation.  This module searches the real knob space per
``(kernel, backend, arch, dtype, shape-class)``:

  * ``block_rows``        — sublane-aligned streaming panel heights;
  * accumulator budget    — the VMEM/SMEM bytes a candidate's working set
    may occupy (per-backend constants; candidates that overflow are
    *illegal*, not merely slow);
  * GEMM-width floor      — the narrow-dot padding width (never below
    :data:`MIN_GEMM_FLOOR` — ``ref.py`` relies on it for width-stable XLA
    GEMM strategies, a bit-identity contract);
  * ``want_q`` fusion split — whether the fused apply+Gram sweep beats the
    unfused apply-then-Gram pair for the class.

The search is **roofline-seeded**: an analytic prior prices each candidate
as ``max(streamed_HBM_bytes / measured_bandwidth, FLOPs / measured_peak)``
plus a per-grid-step overhead — the byte model is the same shape-derived
accounting :mod:`repro.kernels.traffic` records (streamed bytes add the
edge-padding waste ``⌈m/br⌉·br`` rows and, on GPU, the per-program partial
accumulators of :mod:`repro.kernels.gpu`) — so only the top few candidates
are ever measured, not a grid sweep.  Machine constants come from two tiny
probes (a streaming copy and a square matmul), injectable for tests.

Winners persist as schema-versioned JSON under ``results/autotune/`` (one
file per backend kind) with an in-process cache consulted by the ``ops``
wrappers and the blocked-QR pipelines.  The tuned ``block_rows`` is
resolved to a **concrete int at the Python level** before it becomes a
static jit key — installing a new table changes the resolution for the
affected shape-classes only, so tuning never retraces an unrelated warm
path (the ``autotune`` bench case and the CI retrace guard pin this).

Prediction honesty is hard-gated: for every tuned entry the *committed*
byte model (:func:`committed_traffic`) must equal the wrapper-level traffic
notes observed when running the tuned config, byte for byte, and the
dispatch count must match — see ``repro/bench/cases/autotune.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time

import numpy as np

from . import dispatch as _dispatch
from . import traffic as _traffic
from .backend import DEFAULT_BLOCK_ROWS, Backend, KINDS, pick_block_rows, resolve_backend

__all__ = [
    "ACCUM_BUDGET_BYTES",
    "AutotuneError",
    "AutotuneSchemaError",
    "DEFAULT_KERNELS",
    "DEFAULT_OUT_DIR",
    "MIN_GEMM_FLOOR",
    "MachineModel",
    "Prediction",
    "candidate_block_rows",
    "clear",
    "committed_traffic",
    "entry_key",
    "entry_legal",
    "generation",
    "install",
    "installed",
    "load_table",
    "lookup",
    "machine_constants",
    "main",
    "measure_machine",
    "predict",
    "resolve_block_rows",
    "save_table",
    "select_winner",
    "shape_class",
    "trailing_panel_width",
    "tune",
    "tune_kernel",
    "validate_table",
]

SCHEMA_VERSION = 1
DEFAULT_OUT_DIR = os.path.join("results", "autotune")
DEFAULT_KERNELS = ("gram", "apply_right", "fused_apply_gram",
                   "trailing_update")

# ref.py pads narrower dots to this width so XLA keeps one GEMM strategy
# across panel widths (a bit-identity contract between the eager and
# pipelined drivers) — tuner candidates below it are illegal.
MIN_GEMM_FLOOR = 4
_GEMM_FLOOR_CANDIDATES = (4, 8)

# Accumulator working-set budgets per backend kind (bytes).  Mosaic streams
# blocks through ~16 MiB/core VMEM (leave headroom for double buffering);
# the interpreter mirrors the TPU kernel structure; Triton programs stage
# their block through shared memory / registers — far smaller.
ACCUM_BUDGET_BYTES = {
    "tpu-mosaic": 12 << 20,
    "interpret": 12 << 20,
    "gpu-triton": 192 << 10,
}

_BASE_BLOCK_ROWS = (16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


class AutotuneError(ValueError):
    """An invalid tuning request or corrupt tuned table."""


class AutotuneSchemaError(AutotuneError):
    """A persisted table that does not conform to the schema (stale
    ``schema_version``, missing fields) — rejected, never half-loaded."""


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Measured machine constants the roofline prior prices against."""

    mem_bw_bytes_per_s: float
    flops_per_s: float
    step_overhead_s: float = 2e-6

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Prediction:
    """The analytic model of one (kernel, shape, config) execution.

    ``read_bytes``/``write_bytes`` are the *committed* operand bytes — the
    exact figures the ``ops`` wrappers note to :mod:`repro.kernels.traffic`
    (hard-gated equal by the ``autotune`` bench case).  ``streamed_bytes``
    adds what the grid actually moves: edge-padding waste and, on GPU, the
    partial-accumulator round trip.  ``seconds`` is the roofline prior."""

    read_bytes: int
    write_bytes: int
    dispatches: int
    streamed_bytes: int
    flops: float
    accum_bytes: int
    grid_steps: int
    seconds: float


# ---------------------------------------------------------------------------
# shape classes and keys
# ---------------------------------------------------------------------------

def shape_class(m: int, n: int) -> str:
    """Bucket ``m`` to the next power of two (panel heights are the knob —
    nearby heights share a winner); ``n`` stays exact (it is a static trace
    dimension and small)."""
    p2 = 1 << max(int(m) - 1, 0).bit_length()
    return f"m{p2}xn{int(n)}"


def entry_key(kernel: str, backend_kind: str, dtype, klass: str) -> str:
    return f"{kernel}|{backend_kind}|{np.dtype(dtype).name}|{klass}"


def trailing_panel_width(n: int) -> int:
    """The representative blocked-QR panel width for an n-wide trailing
    block — what ``trailing_update`` tuning (and its bench verification)
    factor the shape with."""
    return min(int(n), max(MIN_GEMM_FLOOR, int(n) // 4))


# ---------------------------------------------------------------------------
# the analytic model (committed + streamed traffic, flops, working set)
# ---------------------------------------------------------------------------

def committed_traffic(kernel: str, m: int, n: int, dtype,
                      *, want_q: bool = True) -> tuple[int, int, int]:
    """(read_bytes, write_bytes, dispatches) exactly as the ``ops``
    wrappers will note them — operand bytes, block-size independent."""
    it = np.dtype(dtype).itemsize
    if kernel == "gram":
        return m * n * it, n * n * 4, 1
    if kernel == "apply_right":
        return m * n * it + n * n * it, m * n * it, 1
    if kernel == "fused_apply_gram":
        w = m * n * it if want_q else 0
        return m * n * it + n * n * it, w + n * n * 4, 1
    if kernel == "trailing_update":
        b = trailing_panel_width(n)
        read = m * n * it + m * b * it + b * n * it
        return read, m * n * it + b * n * 4, 1
    raise AutotuneError(f"unknown kernel {kernel!r} (expected one of "
                        f"{DEFAULT_KERNELS})")


def predict(kernel: str, m: int, n: int, dtype, *, block_rows: int,
            machine: MachineModel, backend: Backend, want_q: bool = True,
            gemm_floor: int = MIN_GEMM_FLOOR) -> Prediction:
    """Roofline prior for one candidate (see :class:`Prediction`)."""
    it = np.dtype(dtype).itemsize
    br = pick_block_rows(m, block_rows, sublane=backend.sublane)
    g = math.ceil(m / br)
    rows = g * br                       # streamed rows incl. edge padding
    gpu = backend.kind == "gpu-triton"
    read, write, dispatches = committed_traffic(
        kernel, m, n, dtype, want_q=want_q
    )

    def partials(rows_out: int, cols_out: int) -> int:
        # per-program partial accumulators: written by the kernel, re-read
        # by the jnp.sum that folds them (repro.kernels.gpu)
        return 2 * g * rows_out * cols_out * 4 if gpu else 0

    if kernel == "gram":
        streamed = rows * n * it + n * n * 4 + partials(n, n)
        flops = 2.0 * rows * n * n
        accum = br * n * it + n * n * 4
    elif kernel == "apply_right":
        streamed = rows * n * it + n * n * it + rows * n * it
        flops = 2.0 * rows * n * n
        accum = br * n * it + n * n * it + br * n * 4
    elif kernel == "fused_apply_gram":
        streamed = (rows * n * it + n * n * it + n * n * 4
                    + (rows * n * it if want_q else 0) + partials(n, n))
        flops = 4.0 * rows * n * n
        accum = br * n * it + n * n * it + br * n * 4 + n * n * 4
    else:  # trailing_update
        b = trailing_panel_width(n)
        b_eff = max(b, gemm_floor)      # narrow dots pad to the floor
        streamed = (rows * (n + b) * it + b * n * it
                    + rows * n * it + b * n * 4 + partials(b, n))
        flops = 2.0 * rows * n * (b_eff + b)
        accum = (2 * br * n + br * b + b * n) * it + b * n * 4
    seconds = max(
        streamed / machine.mem_bw_bytes_per_s, flops / machine.flops_per_s
    ) + g * machine.step_overhead_s
    return Prediction(
        read_bytes=read, write_bytes=write, dispatches=dispatches,
        streamed_bytes=int(streamed), flops=float(flops),
        accum_bytes=int(accum), grid_steps=g, seconds=float(seconds),
    )


def candidate_block_rows(m: int, backend: Backend) -> tuple[int, ...]:
    """Sublane-aligned candidate panel heights, clamped to the shape."""
    base = set(_BASE_BLOCK_ROWS) | {backend.sublane, DEFAULT_BLOCK_ROWS}
    cands = {
        pick_block_rows(m, c, sublane=backend.sublane)
        for c in base if c >= backend.sublane
    }
    return tuple(sorted(cands))


# ---------------------------------------------------------------------------
# machine probes
# ---------------------------------------------------------------------------

def _p50(fn, timer, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())            # warm: compile outside the clock
    samples = []
    for _ in range(max(1, reps)):
        t0 = timer()
        jax.block_until_ready(fn())
        samples.append(timer() - t0)
    return float(np.median(samples))


def measure_machine(*, timer=None, reps: int = 3) -> MachineModel:
    """Measure the two roofline denominators with tiny probes: a streaming
    copy (memory bandwidth) and a square f32 matmul (sustained peak).
    ``timer`` is injectable (tests pass a scripted clock)."""
    import jax
    import jax.numpy as jnp

    timer = timer or time.perf_counter
    n_copy = 1 << 22                                   # 16 MiB of f32
    x = jnp.arange(n_copy, dtype=jnp.float32)
    copy = jax.jit(lambda v: v + 1.0)
    k = 384
    a = jnp.ones((k, k), jnp.float32)
    mm = jax.jit(lambda v: v @ v)
    with _traffic.suppress(), _dispatch.suppress():
        t_copy = max(_p50(lambda: copy(x), timer, reps), 1e-9)
        t_mm = max(_p50(lambda: mm(a), timer, reps), 1e-9)
    return MachineModel(
        mem_bw_bytes_per_s=2.0 * n_copy * 4 / t_copy,  # read + write
        flops_per_s=2.0 * k ** 3 / t_mm,
    )


# ---------------------------------------------------------------------------
# the measured search
# ---------------------------------------------------------------------------

def _kernel_runner(kernel: str, m: int, n: int, dtype, backend: Backend):
    """Build ``fn(block_rows)`` executing one dispatch of the kernel at the
    class's representative shape — also used by the bench case so tuning
    and verification run the identical op."""
    import jax.numpy as jnp

    from . import apply_right as _apply_mod
    from . import fused_apply_gram as _fused_mod
    from . import gram as _gram_mod
    from . import trailing_update as _trailing_mod

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dtype)
    interp = backend.interpret
    if kernel == "gram":
        return lambda br: _gram_mod.gram(a, block_rows=br, interpret=interp)
    if kernel == "apply_right":
        w = jnp.asarray(rng.standard_normal((n, n)) / n, dtype=dtype)
        return lambda br: _apply_mod.apply_right(
            a, w, block_rows=br, interpret=interp
        )
    if kernel == "fused_apply_gram":
        w = jnp.asarray(rng.standard_normal((n, n)) / n, dtype=dtype)
        return lambda br: _fused_mod.fused_apply_gram(
            a, w, block_rows=br, interpret=interp
        )
    b = trailing_panel_width(n)
    q = jnp.asarray(rng.standard_normal((m, b)), dtype=dtype)
    w = jnp.asarray(rng.standard_normal((b, n)) / n, dtype=dtype)
    return lambda br: _trailing_mod.trailing_update(
        a, q, w, next_width=b, block_rows=br, interpret=interp
    )


def tune_kernel(kernel: str, m: int, n: int, *, dtype="float32",
                backend: Backend | None = None,
                machine: MachineModel | None = None, timer=None,
                reps: int = 3, measure_top: int = 3) -> dict:
    """Tune one (kernel, shape-class): rank every legal candidate by the
    roofline prior, measure only the top ``measure_top`` (always including
    the pre-tuning default so the win is relative to a real baseline), and
    return the persistable entry dict."""
    backend = backend or resolve_backend(None)
    machine = machine or measure_machine(timer=timer)
    timer = timer or time.perf_counter
    dt = np.dtype(dtype)
    budget = ACCUM_BUDGET_BYTES[backend.kind]

    preds: dict[int, Prediction] = {}
    legal = []
    for c in candidate_block_rows(m, backend):
        preds[c] = predict(kernel, m, n, dt, block_rows=c, machine=machine,
                           backend=backend)
        if preds[c].accum_bytes <= budget:
            legal.append(c)
    if not legal:                        # budget smaller than any candidate:
        legal = [min(preds, key=lambda c: preds[c].accum_bytes)]
    ranked = sorted(legal, key=lambda c: (preds[c].seconds, c))
    to_measure = list(ranked[:max(1, measure_top)])
    default_br = pick_block_rows(m, DEFAULT_BLOCK_ROWS,
                                 sublane=backend.sublane)
    if default_br in legal and default_br not in to_measure:
        to_measure.append(default_br)

    run = _kernel_runner(kernel, m, n, dt, backend)
    measured: dict[int, float] = {}
    with _traffic.suppress(), _dispatch.suppress():
        for c in to_measure:
            measured[c] = _p50(lambda: run(c), timer, reps)
    winner = min(measured, key=lambda c: (measured[c], c))

    # secondary knobs, decided on the prior at the winning height
    floor = min(
        _GEMM_FLOOR_CANDIDATES,
        key=lambda f: (predict(kernel, m, n, dt, block_rows=winner,
                               machine=machine, backend=backend,
                               gemm_floor=f).seconds, f),
    )
    fused = predict("fused_apply_gram", m, n, dt, block_rows=winner,
                    machine=machine, backend=backend)
    unfused = (
        predict("apply_right", m, n, dt, block_rows=winner, machine=machine,
                backend=backend).seconds
        + predict("gram", m, n, dt, block_rows=winner, machine=machine,
                  backend=backend).seconds
    )
    win = preds[winner]
    return {
        "kernel": kernel,
        "backend": backend.kind,
        "arch": backend.arch,
        "dtype": dt.name,
        "shape_class": shape_class(m, n),
        "m": int(m),
        "n": int(n),
        "block_rows": int(winner),
        "accum_budget_bytes": int(budget),
        "gemm_width_floor": int(floor),
        "fuse_want_q": bool(fused.seconds < unfused),
        "predicted_read_bytes": win.read_bytes,
        "predicted_write_bytes": win.write_bytes,
        "predicted_dispatches": win.dispatches,
        "predicted_streamed_bytes": win.streamed_bytes,
        "predicted_flops": win.flops,
        "predicted_s": win.seconds,
        "measured_s": measured[winner],
        "candidates": [
            {
                "block_rows": int(c),
                "predicted_s": preds[c].seconds,
                "accum_bytes": preds[c].accum_bytes,
                "measured_s": measured.get(c),
            }
            for c in sorted(legal)
        ],
    }


def select_winner(entry: dict) -> int:
    """Re-select the winner from an entry's persisted measurements — the
    reproducibility contract the bench case hard-gates: same persisted
    numbers, same deterministic pick (min measured time, ties to the
    smaller height)."""
    measured = [c for c in entry["candidates"]
                if c.get("measured_s") is not None]
    if not measured:
        raise AutotuneError(
            f"entry {entry.get('kernel')}|{entry.get('shape_class')} has no "
            "measured candidates — not a tuned table"
        )
    best = min(measured, key=lambda c: (c["measured_s"], c["block_rows"]))
    return int(best["block_rows"])


def entry_legal(entry: dict) -> bool:
    """A winner is legal iff it is sublane-aligned for its backend, inside
    the accumulator budget, and drawn from the candidate set."""
    sublane = 16 if entry["backend"] == "gpu-triton" else 8
    br = entry["block_rows"]
    cands = {c["block_rows"]: c for c in entry["candidates"]}
    if br not in cands:
        return False
    aligned = br % sublane == 0 or br == entry["m"] >= sublane
    return (
        aligned
        and br >= min(sublane, entry["m"])
        and cands[br]["accum_bytes"] <= entry["accum_budget_bytes"]
        and entry["gemm_width_floor"] >= MIN_GEMM_FLOOR
    )


# ---------------------------------------------------------------------------
# persistence (schema-versioned JSON under results/autotune/)
# ---------------------------------------------------------------------------

_ENTRY_FIELDS = (
    "kernel", "backend", "arch", "dtype", "shape_class", "m", "n",
    "block_rows", "accum_budget_bytes", "gemm_width_floor", "fuse_want_q",
    "predicted_read_bytes", "predicted_write_bytes", "predicted_dispatches",
    "predicted_streamed_bytes", "predicted_flops", "predicted_s",
    "measured_s", "candidates",
)
_MACHINE_FIELDS = ("mem_bw_bytes_per_s", "flops_per_s", "step_overhead_s")


def validate_table(doc: dict) -> dict:
    """Validate a persisted table; raises :class:`AutotuneSchemaError`."""
    if not isinstance(doc, dict):
        raise AutotuneSchemaError("table must be an object")
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise AutotuneSchemaError(
            f"schema_version: expected {SCHEMA_VERSION}, got "
            f"{doc.get('schema_version')!r} — stale tables are rejected, "
            "re-run the tuner"
        )
    if doc.get("backend") not in KINDS:
        raise AutotuneSchemaError(
            f"backend: must be one of {KINDS}, got {doc.get('backend')!r}"
        )
    machine = doc.get("machine")
    if not isinstance(machine, dict):
        raise AutotuneSchemaError("machine: required object")
    for f in _MACHINE_FIELDS:
        v = machine.get(f)
        if not isinstance(v, (int, float)) or v <= 0:
            raise AutotuneSchemaError(f"machine.{f}: must be positive")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        raise AutotuneSchemaError("entries: required object")
    for key, e in entries.items():
        if not isinstance(e, dict):
            raise AutotuneSchemaError(f"entries.{key}: must be an object")
        missing = [f for f in _ENTRY_FIELDS if f not in e]
        if missing:
            raise AutotuneSchemaError(f"entries.{key}: missing {missing}")
        want = entry_key(e["kernel"], e["backend"], e["dtype"],
                         e["shape_class"])
        if key != want:
            raise AutotuneSchemaError(
                f"entries.{key}: key does not match its fields ({want})"
            )
        if not isinstance(e["candidates"], list) or not e["candidates"]:
            raise AutotuneSchemaError(
                f"entries.{key}: candidates must be a non-empty list"
            )
    return doc


def save_table(doc: dict, out_dir: str = DEFAULT_OUT_DIR) -> str:
    validate_table(doc)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{doc['backend']}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_table(path: str) -> dict:
    with open(path) as f:
        return validate_table(json.load(f))


# ---------------------------------------------------------------------------
# in-process cache
# ---------------------------------------------------------------------------

_INSTALLED: dict[str, dict] = {}
_MACHINE: dict | None = None
_GENERATION = 0


def install(doc: dict) -> int:
    """Merge a validated table into the in-process cache; returns the new
    generation.  Resolution happens per call at the Python level, so a new
    table takes effect immediately for its shape-classes and *only* its
    shape-classes (unchanged classes keep their compiled programs)."""
    global _MACHINE, _GENERATION
    validate_table(doc)
    _INSTALLED.update(doc["entries"])
    _MACHINE = dict(doc["machine"])
    _GENERATION += 1
    return _GENERATION


def installed() -> dict[str, dict]:
    return dict(_INSTALLED)


def clear() -> None:
    global _MACHINE, _GENERATION
    _INSTALLED.clear()
    _MACHINE = None
    _GENERATION += 1


def generation() -> int:
    return _GENERATION


def machine_constants() -> dict | None:
    """The installed table's measured machine constants (or None) — what
    :meth:`repro.serve.planner.CostModel.tuned` feeds the serving planner
    instead of the static defaults."""
    return dict(_MACHINE) if _MACHINE else None


def lookup(kernel: str, m: int, n: int, dtype,
           backend: Backend | None = None) -> dict | None:
    be = backend or resolve_backend(None)
    return _INSTALLED.get(entry_key(kernel, be.kind, dtype, shape_class(m, n)))


def resolve_block_rows(kernel: str, m: int, n: int, dtype, *,
                       explicit: int | None = None,
                       backend: Backend | None = None) -> int:
    """The one block_rows resolution order: explicit caller choice >
    installed tuned winner for the shape-class > the aligned default.
    Always returns a concrete, shape-clamped int — the static jit key."""
    if explicit is not None:
        return int(explicit)
    be = backend or resolve_backend(None)
    e = lookup(kernel, m, n, dtype, backend=be)
    base = e["block_rows"] if e is not None else DEFAULT_BLOCK_ROWS
    return pick_block_rows(m, base, sublane=be.sublane)


# ---------------------------------------------------------------------------
# the driver + CLI
# ---------------------------------------------------------------------------

def tune(shapes, kernels=DEFAULT_KERNELS, *, dtype="float32",
         backend: Backend | None = None, timer=None, reps: int = 3,
         measure_top: int = 3, out_dir: str | None = None,
         install_result: bool = True) -> dict:
    """Tune every (kernel × shape) cell, build the table document, install
    it in-process and (when ``out_dir``) persist it.  Returns the doc."""
    backend = backend or resolve_backend(None)
    machine = measure_machine(timer=timer)
    entries = {}
    for m, n in shapes:
        for kernel in kernels:
            e = tune_kernel(kernel, m, n, dtype=dtype, backend=backend,
                            machine=machine, timer=timer, reps=reps,
                            measure_top=measure_top)
            entries[entry_key(kernel, backend.kind, dtype,
                              e["shape_class"])] = e
    doc = {
        "schema_version": SCHEMA_VERSION,
        "backend": backend.kind,
        "arch": backend.arch,
        "machine": machine.as_dict(),
        "entries": entries,
    }
    validate_table(doc)
    if install_result:
        install(doc)
    if out_dir:
        save_table(doc, out_dir)
    return doc


def _parse_shapes(spec: str) -> tuple[tuple[int, int], ...]:
    out = []
    for part in spec.split(","):
        m, _, n = part.strip().partition("x")
        out.append((int(m), int(n)))
    return tuple(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="roofline-seeded kernel autotuner (persists winners "
                    "under results/autotune/)",
    )
    ap.add_argument("--shapes", default="4096x256,1024x64",
                    help="comma-separated MxN shape classes")
    ap.add_argument("--kernels", default=",".join(DEFAULT_KERNELS))
    ap.add_argument("--out", default=DEFAULT_OUT_DIR)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + end-to-end persistence round-trip "
                         "(the CI autotune-smoke step)")
    args = ap.parse_args(argv)
    shapes = _parse_shapes("256x32" if args.smoke else args.shapes)
    reps = 2 if args.smoke else args.reps
    doc = tune(shapes, tuple(args.kernels.split(",")), reps=reps,
               out_dir=args.out)
    path = os.path.join(args.out, f"{doc['backend']}.json")
    reloaded = load_table(path)                       # validates the schema
    bad = [k for k, e in reloaded["entries"].items()
           if select_winner(e) != e["block_rows"] or not entry_legal(e)]
    if bad:
        print(f"[autotune] ILLEGAL/IRREPRODUCIBLE winners: {bad}")
        return 1
    mc = doc["machine"]
    print(f"[autotune] backend={doc['backend']} arch={doc['arch']} "
          f"bw={mc['mem_bw_bytes_per_s']:.3e} B/s "
          f"peak={mc['flops_per_s']:.3e} flop/s")
    for key, e in sorted(reloaded["entries"].items()):
        print(f"[autotune] {key}: block_rows={e['block_rows']} "
              f"floor={e['gemm_width_floor']} fused={e['fuse_want_q']} "
              f"predicted={e['predicted_s']:.3e}s "
              f"measured={e['measured_s']:.3e}s")
    print(f"[autotune] wrote {path} ({len(reloaded['entries'])} entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
