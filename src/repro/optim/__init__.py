"""Optimizers: AdamW (+ZeRO-1), PowerSGD-TSQR gradient compression,
low-rank (GaLore-style) with distributed-CQR2 bases, QR-orthogonalized
momentum.  The latter three embed the paper's distributed tall-skinny QR
in the training loop (DESIGN.md §3)."""
from . import adamw, lowrank, orthosgd, powersgd

__all__ = ["adamw", "lowrank", "orthosgd", "powersgd"]
