"""QR-orthogonalized momentum ("Muon-flavoured" via QR, not Newton-Schulz).

For each 2D parameter: momentum M ← β·M + G; the update direction is the
orthonormal factor Q of M's tall orientation, computed with the same
distributed CholeskyQR2 the low-rank optimizer uses (Gram contraction over
the sharded dim → XLA all-reduce; the paper's butterfly is the shard_map
path).  1D params fall back to SGD+momentum.

This is the orthogonalized-momentum family (Tuddenham et al.; Muon uses a
Newton-Schulz polar iterate instead of QR — QR yields Q from M = QR, which
shares the column space; DESIGN.md §3.2 records the distinction).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .lowrank import gram_cqr2_q

__all__ = ["OrthoSGDConfig", "init", "update"]


@dataclasses.dataclass(frozen=True)
class OrthoSGDConfig:
    lr: float = 0.02
    momentum: float = 0.95
    nesterov: bool = True
    weight_decay: float = 0.0
    # >1 routes the CQR2 Gram sums through the fault-tolerant butterfly
    # over this many row shards (repro.optim.ftqr); 0/1 keeps the pure
    # GSPMD contraction.
    ft_shards: int = 0


def init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _orth_update(m, ft_shards: int = 0):
    tall = m.shape[-2] >= m.shape[-1]
    x = m if tall else jnp.swapaxes(m, -1, -2)
    if ft_shards > 1:
        from .ftqr import ft_cqr2_q

        q = ft_cqr2_q(x, ft_shards)
    else:
        q = gram_cqr2_q(x)
    q = q if tall else jnp.swapaxes(q, -1, -2)
    # Muon-style shape rescale so update RMS matches across aspect ratios
    out_scale = jnp.sqrt(jnp.maximum(m.shape[-2], m.shape[-1]) / m.shape[-1])
    return q * out_scale


def update(cfg: OrthoSGDConfig, params, grads, state):
    step = state["step"] + 1

    def one(p, g, m):
        gf = g.astype(jnp.float32)
        m_ = cfg.momentum * m + gf
        eff = gf + cfg.momentum * m_ if cfg.nesterov else m_
        if p.ndim >= 2 and min(p.shape[-2:]) >= 2:
            d = _orth_update(eff, cfg.ft_shards)
        else:
            d = eff
        newp = p.astype(jnp.float32) - cfg.lr * (d + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m_

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    out = [one(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    return (
        tdef.unflatten([o[0] for o in out]),
        {"m": tdef.unflatten([o[1] for o in out]), "step": step},
    )
