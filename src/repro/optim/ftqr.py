"""In-step fault-tolerant CholeskyQR2 for the trainer's optimizers.

:func:`repro.optim.lowrank.gram_cqr2_q` is the pure-GSPMD formulation —
the Gram contraction lowers to matmul + mesh all-reduce, which is
fault-oblivious.  This module is the paper-faithful twin: the *same*
CQR2 numerics, but every Gram sum rides the collective engine's
redundant butterfly (:func:`~repro.collective.engine.ft_allreduce`,
``gram_sum`` combiner) over an explicit shard axis, so each of the two
orthogonalization rounds inherits the 2^s − 1 mid-reduce tolerance.
The whole thing is plain traced jax — it inlines into the trainer's
jitted train step (one compiled program, zero extra dispatches).
"""
from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np

from repro.collective import SimComm, ft_allreduce, make_plan
from repro.optim.lowrank import _gram_ridge

__all__ = ["ft_cqr2_q"]


def _distribute_rows(x, shards: int):
    """(…, m, n) → (shards, …, m_loc, n) with zero-row padding.  Exact for
    CQR2: zero rows contribute nothing to the Gram and Q = A·R⁻¹ maps them
    back to zero rows."""
    *lead, m, n = x.shape
    pad = (-m) % shards
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((*lead, pad, n), x.dtype)], axis=-2
        )
    x = x.reshape(*lead, shards, (m + pad) // shards, n)
    return jnp.moveaxis(x, -3, 0)


def ft_cqr2_q(a, shards: int, plan=None):
    """CholeskyQR2 Q factor of ``a`` (…, m, n); Gram sums on the butterfly.

    Rows are split into ``shards`` contiguous blocks (the SimComm replica
    axis); each round's n×n Gram is combined with
    ``ft_allreduce(op="gram_sum")`` and read from a plan-certified slot.
    ``plan`` defaults to the fault-free redundant plan (the straight-line
    fast path); an injected :class:`~repro.collective.plan.Plan` exercises
    mid-reduce deaths.  Matches :func:`~repro.optim.lowrank.gram_cqr2_q`
    up to fp summation order, bit-for-bit when ``shards <= 1`` (dense
    fallback).
    """
    if shards <= 1:
        from repro.optim.lowrank import gram_cqr2_q

        return gram_cqr2_q(a)
    comm = SimComm(shards)
    if plan is None:
        plan = make_plan("redundant", shards, None)
    if not plan.final_valid.any():
        raise ValueError(
            "plan exceeds the butterfly's tolerance: no shard slot holds "
            f"the Gram sum (final_valid={plan.final_valid})"
        )
    slot = int(np.argmax(plan.final_valid))

    def round_(x):
        xd = _distribute_rows(x, shards)
        g_loc = jnp.einsum(
            "...mi,...mj->...ij", xd, xd, preferred_element_type=jnp.float32
        )
        g_sum, _ = ft_allreduce(g_loc, comm, op="gram_sum", plan=plan)
        r = jnp.swapaxes(jnp.linalg.cholesky(_gram_ridge(g_sum[slot])), -1, -2)
        y = jsl.solve_triangular(
            jnp.swapaxes(r, -1, -2), jnp.swapaxes(x, -1, -2), lower=True
        )
        return jnp.swapaxes(y, -1, -2)

    return round_(round_(a.astype(jnp.float32)))
