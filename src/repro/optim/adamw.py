"""AdamW with ZeRO-1 optimizer-state sharding.

Pure-pytree implementation (no optax dependency in this offline container).
Optimizer state (m, v, f32 master copy optional) carries its own sharding
specs: parameter sharding *plus* the batch axes spread over every large
tensor's first shardable dim — the ZeRO-1 layout that keeps the 12
bytes/param of Adam state off the replicated-memory budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "init", "update", "state_shardings"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr,
    }


def state_shardings(param_specs_tree, params_struct=None, mesh=None, *,
                    zero1_axis=None):
    """m/v inherit the param spec; with ``zero1_axis`` (e.g. ('data',) or
    ('pod','data')), the largest *divisible* unsharded dim of every tensor
    is additionally spread over those axes (ZeRO-1).  Shape-aware: pjit
    argument shardings require exact divisibility, so dims that don't
    divide (layer stacks, odd vocab) are left alone."""

    axes = (
        (zero1_axis,) if isinstance(zero1_axis, str) else tuple(zero1_axis or ())
    )
    div = 1
    if mesh is not None:
        for a in axes:
            div *= mesh.shape[a]

    def zero1(spec, struct=None):
        if not axes or not isinstance(spec, P):
            return spec
        parts = list(spec)
        parts += [None] * ((len(struct.shape) if struct is not None else 0) - len(parts))
        # a mesh axis may appear at most once per spec (weight-gathered
        # layouts already consume 'data')
        used: set[str] = set()
        for ax in parts:
            for name in ((ax,) if isinstance(ax, str) else (ax or ())):
                used.add(name)
        free = tuple(a for a in axes if a not in used)
        if not free:
            return P(*parts)
        fdiv = 1
        if mesh is not None:
            for a in free:
                fdiv *= mesh.shape[a]
        cand = [
            i for i, ax in enumerate(parts)
            if ax is None and (
                struct is None
                or (struct.shape[i] % fdiv == 0 and struct.shape[i] >= fdiv)
            )
        ]
        if not cand:
            return P(*parts)
        best = max(cand, key=lambda i: struct.shape[i] if struct is not None else i)
        parts[best] = free if len(free) > 1 else free[0]
        return P(*parts)

    is_leaf = lambda x: isinstance(x, P) or x is None
    if params_struct is None:
        mv = jax.tree.map(zero1, param_specs_tree, is_leaf=is_leaf)
    else:
        mv = jax.tree.map(zero1, param_specs_tree, params_struct, is_leaf=is_leaf)
    return {"m": mv, "v": mv, "step": P()}
