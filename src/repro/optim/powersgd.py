"""PowerSGD gradient compression with fault-tolerant TSQR orthogonalization.

The paper's algorithm embedded in the data-parallel gradient exchange
(DESIGN.md §3.1).  For a 2D gradient block G (rows sharded over the
*model* axis, distinct values per *data* replica), one compression round:

  1. ``P_loc = G @ Q``                       (m_loc × r, per replica)
  2. ``P̄ = psum_data(P_loc) / D``            — the only data-axis exchange of
     the left factor: r columns instead of n
  3. ``P̂, _ = FT-TSQR(P̄)`` over the **model** axis — the butterfly makes
     every model rank hold the same R (and tolerates 2^s−1 rank failures,
     paper §III-B3); Q̂ = P̄·R⁻¹ locally.  Both the QR butterfly and the
     reorthogonalization's Gram reductions ride the public collective
     engine (``repro.collective``), so every reduction in the round
     inherits the paper's tolerance.
  4. ``S_loc = Gᵀ @ P̂``; ``S̄ = psum_data(S_loc) / D`` — right-factor
     exchange, again r columns
  5. ``Ĝ = P̂ @ S̄ᵀ`` — rank-r approximation of the data-mean gradient,
     now bit-identical on every replica
  6. error feedback: ``e ← G − Ĝ`` folded into the next step's G.

Data-axis bytes per step: r·(m+n)·4 instead of m·n·4 — the PowerSGD win.
The orthogonalization collective is the paper's redundant butterfly, so a
replica loss during step 3 leaves every survivor with the factor.

This module is written against :class:`repro.collective.comm.Comm` so the
test-suite drives it on ``SimComm`` (P-leading axes) and the example
driver on ``ShardMapComm`` inside ``shard_map``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.collective import Comm, FaultSpec, QRCombiner, execute_plan, make_plan
from repro.qr.panel import form_q, local_qr_fns

__all__ = ["PowerSGDConfig", "init_state", "compress_grad"]


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 8
    error_feedback: bool = True
    variant: str = "redundant"          # which FT-TSQR drives step 3
    reorth: int = 1


def init_state(key, shape, cfg: PowerSGDConfig, leading=()):
    """Q (n, r) start basis + error buffer for a (m, n) gradient.

    ``leading`` adds SimComm rank axes; the basis is *broadcast* (every
    rank must start from the identical Q — a per-rank random basis makes
    P̄ = G·Q meaningless)."""
    m, n = shape
    q = jax.random.normal(key, (n, cfg.rank), jnp.float32)
    q = jnp.broadcast_to(q, (*leading, n, cfg.rank)) if leading else q
    e = jnp.zeros((*leading, m, n), jnp.float32) if cfg.error_feedback else None
    return {"q": q, "e": e}


def _ft_tsqr_q(p_bar, comm: Comm, cfg: PowerSGDConfig, fault_spec):
    """Orthonormalize the row-distributed P̄ via the paper's butterfly
    (public engine API: plan → execute with the QR combiner → form_q)."""
    plan = make_plan(cfg.variant, comm.n_ranks, fault_spec)
    r, valid = execute_plan(p_bar, comm, plan, QRCombiner(local_qr_fns["jnp"]))
    q, _ = form_q(p_bar, r, comm, cfg.reorth)
    return q, valid


def compress_grad(
    g, state, comm_model: Comm, *,
    cfg: PowerSGDConfig,
    psum_data,
    psum_model,
    n_data: int,
    fault_spec: FaultSpec | None = None,
):
    """One PowerSGD round.  ``g``: per-device (m_loc, n) block, distinct per
    data replica.  ``psum_data`` / ``psum_model``: axis sums (lax.psum under
    shard_map; SimComm equivalents in tests).  Returns (ĝ, new_state,
    stats) with ĝ the decompressed mean gradient.
    """
    gf = g.astype(jnp.float32)
    if cfg.error_feedback and state["e"] is not None:
        gf = gf + state["e"]
    p_loc = gf @ state["q"]                       # (m_loc, r)
    p_bar = psum_data(p_loc) / n_data
    q_hat, valid = _ft_tsqr_q(p_bar, comm_model, cfg, fault_spec)
    s_loc = jnp.swapaxes(gf, -1, -2) @ q_hat      # (n, r), partial over rows
    s_bar = psum_data(psum_model(s_loc)) / n_data  # full data+model reduction
    g_hat = q_hat @ jnp.swapaxes(s_bar, -1, -2)   # (m_loc, n)
    new_e = gf - g_hat if cfg.error_feedback else None
    new_state = {"q": s_bar, "e": new_e}
    m, n = g.shape[-2], g.shape[-1]
    stats = {
        "data_bytes_compressed": 4 * cfg.rank * (m * comm_model.n_ranks + n),
        "data_bytes_dense": 4 * m * comm_model.n_ranks * n,
        "valid": valid,
    }
    return g_hat.astype(g.dtype), new_state, stats
