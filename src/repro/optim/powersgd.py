"""PowerSGD gradient compression with fault-tolerant TSQR orthogonalization.

The paper's algorithm embedded in the data-parallel gradient exchange
(DESIGN.md §3.1).  For a 2D gradient block G (rows sharded over the
*model* axis, distinct values per *data* replica), one compression round:

  1. ``P_loc = G @ Q``                       (m_loc × r, per replica)
  2. ``P̄ = psum_data(P_loc) / D``            — the only data-axis exchange of
     the left factor: r columns instead of n
  3. ``P̂, _ = FT-TSQR(P̄)`` over the **model** axis — the butterfly makes
     every model rank hold the same R (and tolerates 2^s−1 rank failures,
     paper §III-B3); Q̂ = P̄·R⁻¹ locally.  Both the QR butterfly and the
     reorthogonalization's Gram reductions ride the public collective
     engine (``repro.collective``), so every reduction in the round
     inherits the paper's tolerance.
  4. ``S_loc = Gᵀ @ P̂``; ``S̄ = psum_data(S_loc) / D`` — right-factor
     exchange, again r columns
  5. ``Ĝ = P̂ @ S̄ᵀ`` — rank-r approximation of the data-mean gradient,
     now bit-identical on every replica
  6. error feedback: ``e ← G − Ĝ`` folded into the next step's G.

Data-axis bytes per step: r·(m+n)·4 instead of m·n·4 — the PowerSGD win.
The orthogonalization collective is the paper's redundant butterfly, so a
replica loss during step 3 leaves every survivor with the factor.

This module is written against :class:`repro.collective.comm.Comm` so the
test-suite drives it on ``SimComm`` (P-leading axes) and the example
driver on ``ShardMapComm`` inside ``shard_map``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.collective import (
    Comm,
    FaultSpec,
    QRCombiner,
    SimComm,
    execute_plan,
    ft_allreduce,
    make_plan,
)
from repro.qr.panel import form_q, local_qr_fns

__all__ = ["PowerSGDConfig", "init_state", "compress_grad", "compress_mean_grad"]


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 8
    error_feedback: bool = True
    variant: str = "redundant"          # which FT-TSQR drives step 3
    reorth: int = 1


def init_state(key, shape, cfg: PowerSGDConfig, leading=()):
    """Q (n, r) start basis + error buffer for a (m, n) gradient.

    ``leading`` adds SimComm rank axes; the basis is *broadcast* (every
    rank must start from the identical Q — a per-rank random basis makes
    P̄ = G·Q meaningless)."""
    m, n = shape
    q = jax.random.normal(key, (n, cfg.rank), jnp.float32)
    q = jnp.broadcast_to(q, (*leading, n, cfg.rank)) if leading else q
    e = jnp.zeros((*leading, m, n), jnp.float32) if cfg.error_feedback else None
    return {"q": q, "e": e}


def _ft_tsqr_q(p_bar, comm: Comm, cfg: PowerSGDConfig, fault_spec):
    """Orthonormalize the row-distributed P̄ via the paper's butterfly
    (public engine API: plan → execute with the QR combiner → form_q)."""
    plan = make_plan(cfg.variant, comm.n_ranks, fault_spec)
    r, valid = execute_plan(p_bar, comm, plan, QRCombiner(local_qr_fns["jnp"]))
    q, _ = form_q(p_bar, r, comm, cfg.reorth)
    return q, valid


def compress_grad(
    g, state, comm_model: Comm, *,
    cfg: PowerSGDConfig,
    psum_data,
    psum_model,
    n_data: int,
    fault_spec: FaultSpec | None = None,
):
    """One PowerSGD round.  ``g``: per-device (m_loc, n) block, distinct per
    data replica.  ``psum_data`` / ``psum_model``: axis sums (lax.psum under
    shard_map; SimComm equivalents in tests).  Returns (ĝ, new_state,
    stats) with ĝ the decompressed mean gradient.
    """
    gf = g.astype(jnp.float32)
    if cfg.error_feedback and state["e"] is not None:
        gf = gf + state["e"]
    p_loc = gf @ state["q"]                       # (m_loc, r)
    p_bar = psum_data(p_loc) / n_data
    q_hat, valid = _ft_tsqr_q(p_bar, comm_model, cfg, fault_spec)
    s_loc = jnp.swapaxes(gf, -1, -2) @ q_hat      # (n, r), partial over rows
    s_bar = psum_data(psum_model(s_loc)) / n_data  # full data+model reduction
    g_hat = q_hat @ jnp.swapaxes(s_bar, -1, -2)   # (m_loc, n)
    new_e = gf - g_hat if cfg.error_feedback else None
    new_state = {"q": s_bar, "e": new_e}
    m, n = g.shape[-2], g.shape[-1]
    stats = {
        "data_bytes_compressed": 4 * cfg.rank * (m * comm_model.n_ranks + n),
        "data_bytes_dense": 4 * m * comm_model.n_ranks * n,
        "valid": valid,
    }
    return g_hat.astype(g.dtype), new_state, stats


def compress_mean_grad(
    g_rep, q, *, cfg: PowerSGDConfig, comm: Comm | None = None,
    plan=None, n_live=None, ft: bool = True,
):
    """One PowerSGD round over an explicit *replica* axis, inside the jit.

    The in-train-step face of :func:`compress_grad`: ``g_rep`` is the
    (R, m, n) stack of per-replica (masked) gradients the trainer's
    ``replica_grads`` produces, ``q`` the shared (n, r) basis.  Every
    reduction over the replica axis — P̄, S̄, and the TSQR butterfly that
    orthogonalizes P̄ — rides :func:`~repro.collective.engine.ft_allreduce`
    / :func:`~repro.collective.engine.execute_plan` when ``ft`` (the
    paper's 2^s − 1 tolerance at each); ``ft=False`` is the dense parity
    baseline (plain axis sums, GSPMD CQR2).  For the FT orthogonalization
    P̄ — identical on every replica after the butterfly mean — is
    *row-distributed* over the R slots (zero-padded: exact, Q = P̄·R⁻¹
    maps zero rows to zero rows), so the butterfly replicas double as the
    TSQR ranks.  Returns ``(ĝ, new_q)`` with ĝ the (m, n) rank-r
    approximation of the live-replica mean gradient — exact when that mean
    has rank ≤ r and the basis spans its row space.

    No error feedback: per-replica residuals would cost R× gradient memory
    and break across elastic width changes (DESIGN.md §14).
    """
    from repro.optim.lowrank import gram_cqr2_q

    R, m, n = g_rep.shape
    gf = g_rep.astype(jnp.float32)
    if n_live is None:
        n_live = jnp.float32(R)
    if ft:
        if comm is None:
            comm = SimComm(R)
        if plan is None:
            plan = make_plan(cfg.variant, R, None)
        if not plan.final_valid.any():
            raise ValueError(
                "plan exceeds the butterfly's tolerance: no replica slot "
                f"holds the mean (final_valid={plan.final_valid})"
            )
        slot = int(np.argmax(plan.final_valid))

        def rep_mean(x):
            s, _ = ft_allreduce(x, comm, op="sum", plan=plan)
            return s[slot] / n_live
    else:

        def rep_mean(x):
            return x.sum(0) / n_live

    r = q.shape[-1]
    p_bar = rep_mean(gf @ q)                      # (m, r) mean left factor
    if ft:
        pad = (-m) % R
        p_pad = (
            jnp.concatenate([p_bar, jnp.zeros((pad, r), p_bar.dtype)])
            if pad else p_bar
        )
        p_dist = p_pad.reshape(R, (m + pad) // R, r)
        q_dist, _ = _ft_tsqr_q(p_dist, comm, cfg, None)
        q_hat = q_dist.reshape(m + pad, r)[:m]
    else:
        q_hat = gram_cqr2_q(p_bar)
    s_bar = rep_mean(jnp.swapaxes(gf, -1, -2) @ q_hat)   # (n, r)
    g_hat = q_hat @ jnp.swapaxes(s_bar, -1, -2)          # (m, n)
    return g_hat.astype(g_rep.dtype), s_bar
