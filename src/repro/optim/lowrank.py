"""Low-rank-projected optimizer (GaLore-style) with distributed-CQR2 bases.

The in-trainer face of the paper's technique (DESIGN.md §3.2): every
``refresh_every`` steps the projection basis of each 2D parameter's
gradient is re-orthonormalized with the *Gram-butterfly* TSQR — the pure
GSPMD formulation where the Gram contraction runs over the row-sharded
("model") dim, so XLA emits the all-reduce (the beyond-paper collective
layout; the shard_map butterfly is the paper-faithful path used by
:mod:`repro.optim.powersgd`).  Adam moments then live in the rank-r
projected space: 8·m·r bytes instead of 8·m·n.

Applied to 2D params whose smaller dim ≥ ``min_dim``; everything else
falls through to dense AdamW behavior.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["LowRankConfig", "init", "update"]


@dataclasses.dataclass(frozen=True)
class LowRankConfig:
    rank: int = 32
    refresh_every: int = 20
    min_dim: int = 256
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    scale: float = 0.25            # GaLore alpha
    # >1 routes the basis-refresh CQR2 Gram sums through the fault-tolerant
    # butterfly over this many row shards (repro.optim.ftqr); 0/1 keeps the
    # pure GSPMD contraction.
    ft_shards: int = 0


def _eligible(p):
    return p.ndim >= 2 and min(p.shape[-2:]) >= 1 and p.shape[-1] >= 1


def _orient(g):
    """Tall orientation: rows = the longer of the final two dims."""
    if g.shape[-2] >= g.shape[-1]:
        return g, False
    return jnp.swapaxes(g, -1, -2), True


def _gram_ridge(g):
    """Shifted-Cholesky regularizer: real training momenta are routinely
    rank-deficient (unseen vocab rows, dead experts, zero grads), which
    makes the exact Gram singular and ``cholesky`` return NaN.  A relative
    ridge keeps the factorization finite; the second CQR2 round restores
    orthogonality on the non-degenerate subspace, and an all-zero input
    maps to an all-zero Q instead of NaN."""
    n = g.shape[-1]
    tr = jnp.trace(g, axis1=-2, axis2=-1)[..., None, None]
    return g + (1e-6 * tr / n + 1e-30) * jnp.eye(n, dtype=g.dtype)


def gram_cqr2_q(a):
    """Distributed CholeskyQR2 Q factor, pure GSPMD: the Gram contraction
    over (sharded) rows lowers to matmul + all-reduce; the n×n work is
    replicated.  Two rounds for Householder-grade orthogonality."""
    import jax.scipy.linalg as jsl

    def round_(x):
        g = jnp.einsum("...mi,...mj->...ij", x, x,
                       preferred_element_type=jnp.float32)
        r = jnp.swapaxes(jnp.linalg.cholesky(_gram_ridge(g)), -1, -2)
        y = jsl.solve_triangular(
            jnp.swapaxes(r, -1, -2), jnp.swapaxes(x, -1, -2), lower=True
        )
        return jnp.swapaxes(y, -1, -2)

    return round_(round_(a.astype(jnp.float32)))


def _project_basis(g, rank, ft_shards: int = 0):
    """Orthonormal (n, r) right basis of g (m, n) via CQR2 of gᵀ·sketch."""
    gt, _ = _orient(jnp.swapaxes(g, -1, -2))  # (n, m)-ish; we want right basis
    # right-sketch: n×r panel = gᵀ @ (g @ Ω) is overkill here; rank-revealing
    # enough is the CQR2 of the first r columns of gᵀg's action:
    n = g.shape[-1]
    key = jax.random.key(0)
    omega = jax.random.normal(key, (*g.shape[:-2], g.shape[-2], rank), jnp.float32)
    panel = jnp.swapaxes(g, -1, -2).astype(jnp.float32) @ omega   # (n, r)
    if ft_shards > 1:
        from .ftqr import ft_cqr2_q

        return ft_cqr2_q(panel, ft_shards)                        # (n, r)
    return gram_cqr2_q(panel)                                     # (n, r)


def init(params, cfg: LowRankConfig):
    def one(p):
        if not _eligible(p) or min(p.shape[-2:]) < cfg.min_dim:
            return {
                "m": jnp.zeros(p.shape, jnp.float32),
                "v": jnp.zeros(p.shape, jnp.float32),
                "basis": None,
            }
        m, n = p.shape[-2:]
        r = min(cfg.rank, n)
        lead = p.shape[:-2]
        return {
            "m": jnp.zeros((*lead, m, r), jnp.float32),
            "v": jnp.zeros((*lead, m, r), jnp.float32),
            "basis": jnp.zeros((*lead, n, r), jnp.float32),
        }

    return {
        "per_param": jax.tree.map(one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def update(cfg: LowRankConfig, params, grads, state):
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, st):
        gf = g.astype(jnp.float32)
        if st["basis"] is None:
            m_ = cfg.b1 * st["m"] + (1 - cfg.b1) * gf
            v_ = cfg.b2 * st["v"] + (1 - cfg.b2) * gf * gf
            delta = (m_ / b1c) / (jnp.sqrt(v_ / b2c) + cfg.eps)
            newp = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
            return newp, {"m": m_, "v": v_, "basis": None}
        refresh = (step % cfg.refresh_every) == 1
        basis = jax.lax.cond(
            refresh,
            lambda: _project_basis(gf, st["basis"].shape[-1], cfg.ft_shards),
            lambda: st["basis"],
        )
        gr = gf @ basis                                  # (m, r) projected
        m_ = cfg.b1 * st["m"] + (1 - cfg.b1) * gr
        v_ = cfg.b2 * st["v"] + (1 - cfg.b2) * gr * gr
        dr = (m_ / b1c) / (jnp.sqrt(v_ / b2c) + cfg.eps)
        delta = cfg.scale * (dr @ jnp.swapaxes(basis, -1, -2))
        newp = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return newp, {"m": m_, "v": v_, "basis": basis}

    is_leaf = lambda x: isinstance(x, dict) and "basis" in x
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = jax.tree.flatten(state["per_param"], is_leaf=is_leaf)[0]
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_s = jax.tree.unflatten(
        jax.tree.structure(state["per_param"], is_leaf=is_leaf),
        [o[1] for o in out],
    )
    return new_p, {"per_param": new_s, "step": step}
