"""Deterministic, shardable synthetic data pipeline.

Production framing without a network: an index-based corpus whose
``(step, row)`` → tokens mapping is a counter-mode hash, so any worker can
materialize any shard of any step independently — the property that makes
checkpoint/restart and elastic rescaling trivial (a restored run at step k
regenerates exactly the batches a never-failed run would have seen, for
any data-parallel width).

A small background prefetcher overlaps host batch synthesis with device
compute, standing in for the input pipeline of a real cluster.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np

__all__ = ["DataConfig", "SyntheticCorpus", "Prefetcher", "make_batches"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    family: str = "dense"
    enc_frames: int = 0
    d_model: int = 0


def _counter_hash(x: np.ndarray) -> np.ndarray:
    """splitmix64 — a counter-mode PRF, vectorized."""
    x = x.astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


class SyntheticCorpus:
    """Zipf-ish token streams with enough structure for loss to decrease
    (each token weakly predicts its successor)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1):
        cfg = self.cfg
        rows = cfg.global_batch // n_shards
        row0 = shard * rows
        idx = (
            np.uint64(step) * np.uint64(cfg.global_batch * (cfg.seq_len + 1))
            + (np.arange(rows, dtype=np.uint64)[:, None] + np.uint64(row0))
            * np.uint64(cfg.seq_len + 1)
            + np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
            + np.uint64(cfg.seed) * np.uint64(0x1000003)
        )
        h = _counter_hash(idx)
        # zipf-ish marginal + repeat structure.  (The previous mixing
        # ``(zipf[t+1] + 7·zipf[t]) % V`` flattened the marginal to uniform,
        # leaving nothing a model could learn in a short run.)  Fresh tokens
        # keep the heavy-tailed Zipf marginal — a frequency bias any model
        # picks up within a few steps — and each position repeats its
        # predecessor with probability 1/2 (an independent hash bit), giving
        # an attention-learnable copy signal.  Both draws are row-local
        # functions of the counter hash, preserving determinism and shard
        # composability.
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        zipf = np.minimum(
            (cfg.vocab * (u ** 2.2)).astype(np.int64), cfg.vocab - 1
        )
        repeat = ((h >> np.uint64(3)) & np.uint64(1)).astype(bool)
        repeat[:, 0] = False                       # position 0 is always fresh
        cols = np.arange(cfg.seq_len + 1, dtype=np.int64)[None, :]
        last_fresh = np.maximum.accumulate(np.where(~repeat, cols, -1), axis=1)
        toks = np.take_along_axis(zipf, last_fresh, axis=1).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "encdec":
            fh = _counter_hash(idx[:, : cfg.enc_frames] + np.uint64(0xABCDEF))
            frames = (
                (fh >> np.uint64(11)).astype(np.float32) / float(1 << 53) - 0.5
            )
            out["frames"] = np.broadcast_to(
                frames[:, :, None], (rows, cfg.enc_frames, cfg.d_model)
            ).astype(np.float32).copy()
        if cfg.family == "vlm":
            pos = np.broadcast_to(
                np.arange(cfg.seq_len, dtype=np.int32)[None], (rows, cfg.seq_len)
            )
            out["positions"] = np.stack([pos, pos, pos])
        return out


class Prefetcher:
    """Background thread keeping ``depth`` batches ready."""

    def __init__(self, corpus: SyntheticCorpus, start_step: int = 0, depth: int = 2,
                 shard: int = 0, n_shards: int = 1):
        self._corpus = corpus
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._shard, self._n_shards = shard, n_shards
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self._corpus.batch(step, shard=self._shard, n_shards=self._n_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_batches(cfg: DataConfig, steps: int, start: int = 0):
    corpus = SyntheticCorpus(cfg)
    for s in range(start, start + steps):
        yield s, corpus.batch(s)
