"""DEPRECATED shim — communication plans live in :mod:`repro.collective.plan`.

Importing this module warns; it will be removed one release after the
panel-pipeline extraction (DESIGN.md §8).  Import from
:mod:`repro.collective` instead.
"""
import warnings

from repro.collective.plan import (  # noqa: F401
    VARIANTS,
    Plan,
    Step,
    ilog2,
    make_plan,
    payload_numel,
)

warnings.warn(
    "repro.core.plan is deprecated; import from repro.collective instead",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["Step", "Plan", "make_plan", "ilog2", "payload_numel", "VARIANTS"]
