"""Compatibility shim — communication plans moved to
:mod:`repro.collective.plan` when the fault-tolerant collective engine was
extracted.  Import from :mod:`repro.collective` in new code."""
from repro.collective.plan import (
    VARIANTS,
    Plan,
    Step,
    ilog2,
    make_plan,
    payload_numel,
)

__all__ = ["Step", "Plan", "make_plan", "ilog2", "payload_numel", "VARIANTS"]
