"""Compatibility shim — the fault model moved to
:mod:`repro.collective.faults` when the fault-tolerant collective engine was
extracted.  Import from :mod:`repro.collective` in new code."""
from repro.collective.faults import (
    NEVER,
    FaultSpec,
    tolerance,
    total_tolerance,
    within_tolerance,
)

__all__ = [
    "NEVER",
    "FaultSpec",
    "tolerance",
    "total_tolerance",
    "within_tolerance",
]
