"""DEPRECATED shim — the fault model lives in :mod:`repro.collective.faults`.

Importing this module warns; it will be removed one release after the
panel-pipeline extraction (DESIGN.md §8).  Import from
:mod:`repro.collective` instead.
"""
import warnings

from repro.collective.faults import (  # noqa: F401
    NEVER,
    FaultSpec,
    tolerance,
    total_tolerance,
    within_tolerance,
)

warnings.warn(
    "repro.core.faults is deprecated; import from repro.collective instead",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = [
    "NEVER",
    "FaultSpec",
    "tolerance",
    "total_tolerance",
    "within_tolerance",
]
