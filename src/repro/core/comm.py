"""DEPRECATED shim — the comm backends live in :mod:`repro.collective.comm`.

Importing this module warns; it will be removed one release after the
panel-pipeline extraction (DESIGN.md §8).  Import from
:mod:`repro.collective` instead.
"""
import warnings

from repro.collective.comm import Comm, ShardMapComm, SimComm  # noqa: F401

warnings.warn(
    "repro.core.comm is deprecated; import from repro.collective instead",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["Comm", "SimComm", "ShardMapComm"]
