"""Compatibility shim — the communication backends moved to
:mod:`repro.collective.comm` when the fault-tolerant collective engine was
extracted.  Import from :mod:`repro.collective` in new code."""
from repro.collective.comm import Comm, ShardMapComm, SimComm

__all__ = ["Comm", "SimComm", "ShardMapComm"]
