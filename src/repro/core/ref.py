"""Numpy oracles for the TSQR variants — ground truth for the test-suite.

Everything here is deliberately naive and sequential: plain
``np.linalg.qr`` plus an explicit walk of the reduction tree.  The JAX
implementations (sim and shard_map backends alike) must agree with these to
tolerance on every valid rank.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "posdiag",
    "qr_r",
    "qr_full",
    "tree_tsqr",
    "butterfly_tsqr",
    "random_tall_skinny",
]


def posdiag(r: np.ndarray) -> np.ndarray:
    d = np.diagonal(r, axis1=-2, axis2=-1)
    s = np.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def qr_r(a: np.ndarray) -> np.ndarray:
    """R factor with non-negative diagonal (unique for full-rank A)."""
    return posdiag(np.linalg.qr(a, mode="r"))


def qr_full(a: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    q, r = np.linalg.qr(a, mode="reduced")
    d = np.diagonal(r, axis1=-2, axis2=-1)
    s = np.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return q * s[..., None, :], r * s[..., :, None]


def tree_tsqr(blocks: np.ndarray) -> np.ndarray:
    """Paper Alg. 1 walked sequentially: blocks (P, m_local, n) → R (n, n)."""
    rs = [qr_r(b) for b in blocks]
    while len(rs) > 1:
        nxt = []
        for i in range(0, len(rs), 2):
            nxt.append(qr_r(np.concatenate([rs[i], rs[i + 1]], axis=0)))
        rs = nxt
    return rs[0]


def butterfly_tsqr(blocks: np.ndarray) -> np.ndarray:
    """Paper Alg. 2 (fault-free) walked sequentially: returns (P, n, n) —
    every rank's final R.  All slices must be identical."""
    p = blocks.shape[0]
    rs = np.stack([qr_r(b) for b in blocks])
    s = 0
    while (1 << s) < p:
        new = np.empty_like(rs)
        for r_id in range(p):
            buddy = r_id ^ (1 << s)
            lo, hi = (r_id, buddy) if (r_id >> s) & 1 == 0 else (buddy, r_id)
            new[r_id] = qr_r(np.concatenate([rs[lo], rs[hi]], axis=0))
        rs = new
        s += 1
    return rs


def random_tall_skinny(
    rng: np.random.Generator,
    p: int,
    m_local: int,
    n: int,
    dtype=np.float32,
    cond: float | None = None,
) -> np.ndarray:
    """(P, m_local, n) blocks of a full-rank tall-skinny matrix.

    ``cond`` optionally fixes the condition number (log-uniform singular
    values) — the CQR2 kernels are only certified for κ ≲ 1/√ε per round.
    """
    m = p * m_local
    a = rng.standard_normal((m, n)).astype(np.float64)
    if cond is not None:
        u, _, vt = np.linalg.svd(a, full_matrices=False)
        sv = np.logspace(0, -np.log10(cond), n)
        a = (u * sv) @ vt
    return a.reshape(p, m_local, n).astype(dtype)
