"""Fault-tolerant, communication-avoiding TSQR (Coti 2015) in JAX.

The four variants of the paper are driven by a host-computed
:class:`~repro.core.plan.Plan` and execute identically on the
:class:`~repro.core.comm.SimComm` (single device, leading (P,) axis) and
:class:`~repro.core.comm.ShardMapComm` (SPMD, ``lax.ppermute``) backends:

  * ``tree``        — Alg. 1, the baseline reduction tree (zero redundancy);
  * ``redundant``   — Alg. 2, butterfly *exchange*: both buddies combine, so
                      every intermediate R̃ exists in ``2^s`` copies;
  * ``replace``     — Alg. 3, identical fault-free, reroutes to a replica of
                      a dead buddy;
  * ``selfhealing`` — Alg. 4–6, additionally respawns dead ranks from a
                      replica at every level.

Validity bits ride along with every payload: a dead rank's contribution is
zero-filled (XLA collective-permute semantics) and flagged invalid, which is
the step-boundary analogue of ULFM's error returns.  The host plan predicts
the same validity; tests assert the two agree bit-for-bit.

The combine is ``QR([R_lo; R_hi])`` ordered by the level bit of the *block*
index so every member of a block computes an identical R (making the
butterfly a true all-reduce — every survivor ends with the same final R,
which the paper's semantics require and which lets Q be formed locally as
``A R⁻¹`` without a backward tree pass).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .comm import Comm, ShardMapComm, SimComm
from .faults import NEVER, FaultSpec
from .plan import Plan, make_plan

__all__ = [
    "TSQRResult",
    "tsqr_sim",
    "tsqr_shard_map",
    "butterfly_allreduce_sum",
    "local_qr_fns",
]


# ---------------------------------------------------------------------------
# Local QR building blocks
# ---------------------------------------------------------------------------

def _posdiag(r):
    """Normalize an upper-triangular factor to a non-negative diagonal.

    Makes the R factor unique, so every rank (and the numpy oracle) computes
    bit-comparable results.
    """
    d = jnp.diagonal(r, axis1=-2, axis2=-1)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[..., :, None]


def qr_r_jnp(a):
    """Householder QR, R factor only (LAPACK on CPU, QR-decomp HLO on TPU)."""
    return _posdiag(jnp.linalg.qr(a, mode="r"))


def qr_r_cqr2(a):
    """CholeskyQR2 R factor — the MXU-native local QR (see kernels/)."""
    from repro.kernels import ops as kops

    return kops.cholesky_qr2(a)[1]


def qr_r_cqr2_pallas(a):
    from repro.kernels import ops as kops

    return kops.cholesky_qr2(a, use_pallas=True)[1]


local_qr_fns: dict[str, Callable] = {
    "jnp": qr_r_jnp,
    "cqr2": qr_r_cqr2,
    "cqr2_pallas": qr_r_cqr2_pallas,
}


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TSQRResult:
    """Per-rank outcome of a fault-tolerant TSQR.

    ``r``      — (P, n, n) in sim / per-device (n, n) under shard_map.
    ``valid``  — who holds a correct final R (the paper's semantics).
    ``q``      — optional per-rank (m_local, n) orthonormal factor.
    ``plan``   — the communication plan that was executed (accounting).
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    plan: Plan


# ---------------------------------------------------------------------------
# The single-source butterfly/tree executor
# ---------------------------------------------------------------------------

def _execute(
    a_blocks,
    comm: Comm,
    plan: Plan,
    local_qr: Callable,
):
    """Run the plan. Returns (R, valid, d_eff) per rank."""
    r = local_qr(a_blocks)
    nan = jnp.asarray(jnp.nan, dtype=r.dtype)
    d = comm.take(plan.death)
    my = comm.ranks()
    valid = d > 0
    for step in plan.steps:
        s = step.level
        can = valid & (d > s)
        # ---- exchange (possibly several unique-source rounds) -------------
        recv_r = jnp.zeros_like(r)
        recv_v = jnp.zeros_like(can)
        for rnd in step.perm_rounds:
            rr, rv = comm.exchange((r, can), rnd)
            recv_r = recv_r + rr          # each rank receives in ≤1 round
            recv_v = recv_v | rv
        # ---- combine: order by this level's block bit ----------------------
        mine_first = ((my >> s) & 1) == 0
        lo = comm.bwhere(mine_first, r, recv_r)
        hi = comm.bwhere(mine_first, recv_r, r)
        stacked = jnp.concatenate([lo, hi], axis=-2)
        new_r = _posdiag(jnp.linalg.qr(stacked, mode="r"))
        valid = can & recv_v
        r = comm.bwhere(valid, new_r, jnp.full_like(new_r, nan))
        # ---- Self-Healing: respawn dead ranks from a replica ---------------
        if step.restore_rounds:
            for rnd in step.restore_rounds:
                rr, rv = comm.exchange((r, valid), rnd)
                got = rv & ~valid
                r = comm.bwhere(got, rr, r)
                valid = valid | got
            respawned = comm.take(step.respawned)
            d = jnp.where(respawned, jnp.asarray(NEVER, d.dtype), d)
    return r, valid


def _compute_q(a_blocks, r, comm: Comm, reorth: int):
    """Q = A·R⁻¹ locally (every survivor holds the same final R), followed by
    ``reorth`` CholeskyQR-style re-orthonormalization passes whose Gram
    reduction reuses the fault-tolerant butterfly (sum combiner).

    Requires an all-valid plan (fault-free, or self-healing within
    tolerance): Q spans *all* row-blocks, so a permanently-lost block makes
    the global Q undefined.  Entry points enforce this on the host plan.
    """
    import jax.scipy.linalg as jsl

    def solve_r(q_in, rr):
        # q = a @ rr^{-1}  ==  solve rr^T y = a^T  (rr upper → rr^T lower)
        y = jsl.solve_triangular(
            jnp.swapaxes(rr, -1, -2), jnp.swapaxes(q_in, -1, -2), lower=True
        )
        return jnp.swapaxes(y, -1, -2)

    q = solve_r(a_blocks, r)
    for _ in range(reorth):
        g = jnp.swapaxes(q, -1, -2) @ q
        g_sum = butterfly_allreduce_sum(g, comm)
        r2 = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g_sum), -1, -2))
        q = solve_r(q, r2)
        r = _posdiag(r2 @ r)
    return q, r


def butterfly_allreduce_sum(x, comm: Comm):
    """Recursive-doubling all-reduce over the same butterfly as TSQR.

    On the fault-free path this is exactly the redundant-TSQR communication
    pattern with a ``+`` combiner — the building block the optimizer layer
    (PowerSGD Gram reductions) shares with the factorization.
    """
    p = comm.n_ranks
    s_max = p.bit_length() - 1
    for s in range(s_max):
        perm = [(i, i ^ (1 << s)) for i in range(p)]
        x = x + comm.exchange(x, perm)
    return x


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def tsqr_sim(
    a_blocks,
    *,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
) -> TSQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n).

    This is the backend the test-suite and the hypothesis robustness sweeps
    drive; the algorithm body is shared with :func:`tsqr_shard_map`.
    """
    p = a_blocks.shape[0]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance); got final_valid="
            f"{plan.final_valid}"
        )
    comm = SimComm(p)
    fn = local_qr_fns[local_qr] if isinstance(local_qr, str) else local_qr
    r, valid = _execute(a_blocks, comm, plan, fn)
    q = None
    if compute_q:
        q, r = _compute_q(a_blocks, r, comm, reorth)
    return TSQRResult(r=r, valid=valid, q=q, plan=plan)


def tsqr_gram_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    reorth: int = 1,
    jit: bool = True,
):
    """Beyond-paper optimized TSQR: the **Gram butterfly** (EXPERIMENTS.md
    §Perf, cell C).

    The paper's combine is ``QR([R̃ᵢ; R̃ⱼ])`` at every butterfly level —
    log₂(P) Householder factorizations of 2n×n on the critical path, each
    sequential and VPU-bound on TPU.  This variant keeps the *same
    butterfly* (same exchanges, same 2^s-copy redundancy, same fault
    semantics — the combiner is ``+``) but carries Gram matrices:
    ``G = Σ AᵢᵀAᵢ``, one Cholesky at the end, and a CholeskyQR2 polish for
    Householder-grade orthogonality.  Per level the combine is an n×n add
    instead of an O(n³) QR; the local work is one MXU Gram matmul instead
    of a Householder panel.  Wire bytes are identical (n² per exchange —
    n(n+1)/2 with symmetric packing, left on the table).

    Numerics: κ(A)² enters the Gram, so the polish round is mandatory;
    certified for κ(A) ≲ 1/√ε like CQR2.
    """
    p = mesh.shape[axis]
    comm = ShardMapComm(p, axis)

    def body(a_blk):
        a32 = a_blk.astype(jnp.float32)
        g = jnp.einsum("mi,mj->ij", a32, a32)
        g = butterfly_allreduce_sum(g, comm)
        r = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2))
        q, r = _compute_q(a_blk, r, comm, reorth)
        return r[None], q

    shard = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    fun = jax.jit(shard) if jit else shard
    r, q = fun(a_global)
    return TSQRResult(r=r, valid=jnp.ones((p,), bool), q=q,
                      plan=make_plan("redundant", p))


def tsqr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
    jit: bool = True,
):
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Returns ``(r, valid, q)`` with r (P, n, n) — one (replicated-if-valid)
    copy per rank — valid (P,) and q (m, n) row-sharded (or None).

    The permutation plan is host-computed from ``fault_spec``; on a real
    fleet the runtime re-invokes this with a fresh plan after each health
    change (step-boundary replanning, DESIGN.md §2).
    """
    p = mesh.shape[axis]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance)"
        )
    comm = ShardMapComm(p, axis)
    fn = local_qr_fns[local_qr] if isinstance(local_qr, str) else local_qr

    def body(a_blk):
        a = a_blk  # (m_local, n)
        r, valid = _execute(a, comm, plan, fn)
        q = None
        if compute_q:
            q, r = _compute_q(a, r, comm, reorth)
        out_q = q if compute_q else jnp.zeros((0, a.shape[-1]), a.dtype)
        return r[None], valid[None], out_q

    shard = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis), P(axis)),
        check_vma=False,
    )
    fun = jax.jit(shard) if jit else shard
    r, valid, q = fun(a_global)
    return TSQRResult(
        r=r, valid=valid, q=(q if compute_q else None), plan=plan
    )
