"""Back-compat facade — the TSQR implementation moved to :mod:`repro.qr`
when the panel-pipeline layer was extracted (DESIGN.md §8).

The panel-local machinery (local QR fns, ``form_q``) now lives in
:mod:`repro.qr.panel` as the engine-agnostic
:class:`~repro.qr.panel.PanelFactorizer`, shared between the
tall-and-skinny entry points (:mod:`repro.qr.tsqr`) and the blocked
general-matrix driver (:mod:`repro.qr.blocked`).  Import from
:mod:`repro.qr` in new code; everything this module ever exported is
re-exported unchanged below.
"""
from repro.qr.panel import (  # noqa: F401
    form_q,
    local_qr_fns,
    qr_r_cqr2,
    qr_r_cqr2_pallas,
    qr_r_jnp,
    resolve_local_qr as _resolve_local_qr,
)
from repro.qr.tsqr import (  # noqa: F401
    TSQRResult,
    tsqr_gram_shard_map,
    tsqr_shard_map,
    tsqr_sim,
)

__all__ = [
    "TSQRResult",
    "tsqr_sim",
    "tsqr_shard_map",
    "tsqr_gram_shard_map",
    "form_q",
    "local_qr_fns",
]
