"""Fault-tolerant, communication-avoiding TSQR (Coti 2015) in JAX.

This module is now a thin instantiation of the generic collective engine
(:mod:`repro.collective`) with the QR combiner: the plan/route/validity
machinery, the butterfly executor, and the self-healing restore rounds all
live in :func:`repro.collective.engine.execute_plan`; this file contributes
only what is QR-specific — the local panel factorizations, the
``Q = A·R⁻¹`` formation, and the entry-point plumbing.

The four variants of the paper are driven by a host-computed
:class:`~repro.collective.plan.Plan` and execute identically on the
:class:`~repro.collective.comm.SimComm` (single device, leading (P,) axis)
and :class:`~repro.collective.comm.ShardMapComm` (SPMD, ``lax.ppermute``)
backends:

  * ``tree``        — Alg. 1, the baseline reduction tree (zero redundancy);
  * ``redundant``   — Alg. 2, butterfly *exchange*: both buddies combine, so
                      every intermediate R̃ exists in ``2^s`` copies;
  * ``replace``     — Alg. 3, identical fault-free, reroutes to a replica of
                      a dead buddy;
  * ``selfhealing`` — Alg. 4–6, additionally respawns dead ranks from a
                      replica at every level.

The combine is ``QR([R_lo; R_hi])`` ordered by the level bit of the *block*
index so every member of a block computes an identical R (making the
butterfly a true all-reduce — every survivor ends with the same final R,
which the paper's semantics require and which lets Q be formed locally as
``A R⁻¹`` without a backward tree pass).  The CholeskyQR reorthogonalization
inside :func:`form_q` reduces its Gram matrices with
:func:`~repro.collective.engine.ft_allreduce` (``gram_sum`` combiner — the
symmetric payload ships packed) over the same butterfly.

Hot-path notes (DESIGN.md §7): fault-free plans ride the engine's
straight-line fast path automatically, and the CQR2 local QRs use the
fused 2-sweep R-only pipeline (``cholesky_qr2_r``) — the butterfly only
carries R, so no tall intermediate is ever materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collective.combiners import QRCombiner, posdiag as _posdiag, qr_r
from repro.collective.comm import Comm, ShardMapComm, SimComm
from repro.collective.engine import execute_plan, ft_allreduce
from repro.collective.faults import FaultSpec
from repro.collective.plan import Plan, make_plan
from repro.compat import shard_map

__all__ = [
    "TSQRResult",
    "tsqr_sim",
    "tsqr_shard_map",
    "tsqr_gram_shard_map",
    "form_q",
    "local_qr_fns",
]


# ---------------------------------------------------------------------------
# Local QR building blocks
# ---------------------------------------------------------------------------

def qr_r_jnp(a):
    """Householder QR, R factor only (LAPACK on CPU, QR-decomp HLO on TPU)."""
    return qr_r(a)


def qr_r_cqr2(a):
    """CholeskyQR2 R factor — the MXU-native local QR (see kernels/).

    Rides the fused 2-sweep R-only pipeline: the butterfly only carries R,
    so no tall intermediate is ever materialized (the seed computed the full
    4-sweep factorization and discarded Q).
    """
    from repro.kernels import ops as kops

    return kops.cholesky_qr2_r(a)


def qr_r_cqr2_pallas(a):
    from repro.kernels import ops as kops

    return kops.cholesky_qr2_r(a, use_pallas=True)


local_qr_fns: dict[str, Callable] = {
    "jnp": qr_r_jnp,
    "cqr2": qr_r_cqr2,
    "cqr2_pallas": qr_r_cqr2_pallas,
}


def _resolve_local_qr(local_qr: str | Callable) -> Callable:
    return local_qr_fns[local_qr] if isinstance(local_qr, str) else local_qr


# ---------------------------------------------------------------------------
# Result container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TSQRResult:
    """Per-rank outcome of a fault-tolerant TSQR.

    ``r``      — (P, n, n) in sim / per-device (n, n) under shard_map.
    ``valid``  — who holds a correct final R (the paper's semantics).
    ``q``      — optional per-rank (m_local, n) orthonormal factor.
    ``plan``   — the communication plan that was executed (accounting).
    """

    r: jax.Array
    valid: jax.Array
    q: jax.Array | None
    plan: Plan


# ---------------------------------------------------------------------------
# Q formation (QR-specific; the reduction rides the generic engine)
# ---------------------------------------------------------------------------

def form_q(a_blocks, r, comm: Comm, reorth: int = 1):
    """Q = A·R⁻¹ locally (every survivor holds the same final R), followed by
    ``reorth`` CholeskyQR-style re-orthonormalization passes whose Gram
    reduction rides the fault-tolerant butterfly (``gram_sum`` combiner).

    Requires an all-valid plan (fault-free, or self-healing within
    tolerance): Q spans *all* row-blocks, so a permanently-lost block makes
    the global Q undefined.  Entry points enforce this on the host plan.
    """
    import jax.scipy.linalg as jsl

    def solve_r(q_in, rr):
        # q = a @ rr^{-1}  ==  solve rr^T y = a^T  (rr upper → rr^T lower)
        y = jsl.solve_triangular(
            jnp.swapaxes(rr, -1, -2), jnp.swapaxes(q_in, -1, -2), lower=True
        )
        return jnp.swapaxes(y, -1, -2)

    q = solve_r(a_blocks, r)
    for _ in range(reorth):
        g = jnp.swapaxes(q, -1, -2) @ q
        g_sum, _ = ft_allreduce(g, comm, op="gram_sum")
        r2 = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g_sum), -1, -2))
        q = solve_r(q, r2)
        r = _posdiag(r2 @ r)
    return q, r


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def tsqr_sim(
    a_blocks,
    *,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
) -> TSQRResult:
    """Single-device simulation: ``a_blocks`` is (P, m_local, n).

    This is the backend the test-suite and the hypothesis robustness sweeps
    drive; the algorithm body is shared with :func:`tsqr_shard_map`.
    """
    p = a_blocks.shape[0]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance); got final_valid="
            f"{plan.final_valid}"
        )
    comm = SimComm(p)
    combiner = QRCombiner(_resolve_local_qr(local_qr))
    r, valid = execute_plan(a_blocks, comm, plan, combiner)
    q = None
    if compute_q:
        q, r = form_q(a_blocks, r, comm, reorth)
    return TSQRResult(r=r, valid=valid, q=q, plan=plan)


def tsqr_gram_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    reorth: int = 1,
    jit: bool = True,
):
    """Beyond-paper optimized TSQR: the **Gram butterfly** (EXPERIMENTS.md
    §Perf, cell C).

    The paper's combine is ``QR([R̃ᵢ; R̃ⱼ])`` at every butterfly level —
    log₂(P) Householder factorizations of 2n×n on the critical path, each
    sequential and VPU-bound on TPU.  This variant keeps the *same
    butterfly* (same exchanges, same 2^s-copy redundancy, same fault
    semantics) but swaps the combiner to ``gram_sum``: it carries Gram
    matrices ``G = Σ AᵢᵀAᵢ``, one Cholesky at the end, and a CholeskyQR2
    polish for Householder-grade orthogonality.  Per level the combine is
    an n×n add instead of an O(n³) QR; the local work is one MXU Gram
    matmul instead of a Householder panel.  Wire bytes are n² per exchange
    shipped square — n(n+1)/2 with symmetric packing, which
    ``Plan.bytes_on_wire(symmetric=True)`` now prices (see
    benchmarks/comm_volume.py).

    Numerics: κ(A)² enters the Gram, so the polish round is mandatory;
    certified for κ(A) ≲ 1/√ε like CQR2.
    """
    p = mesh.shape[axis]
    comm = ShardMapComm(p, axis)

    def body(a_blk):
        a32 = a_blk.astype(jnp.float32)
        g = jnp.einsum("mi,mj->ij", a32, a32)
        g, _ = ft_allreduce(g, comm, op="gram_sum")
        r = _posdiag(jnp.swapaxes(jnp.linalg.cholesky(g), -1, -2))
        q, r = compute_q(a_blk, r, comm, reorth)
        return r[None], q

    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis)),
    )
    fun = jax.jit(shard) if jit else shard
    r, q = fun(a_global)
    return TSQRResult(r=r, valid=jnp.ones((p,), bool), q=q,
                      plan=make_plan("redundant", p))


def tsqr_shard_map(
    a_global,
    *,
    mesh,
    axis: str,
    variant: str = "redundant",
    fault_spec: FaultSpec | None = None,
    compute_q: bool = False,
    reorth: int = 1,
    local_qr: str | Callable = "jnp",
    jit: bool = True,
):
    """Production path: A (m, n) row-sharded over ``mesh`` axis ``axis``.

    Returns ``(r, valid, q)`` with r (P, n, n) — one (replicated-if-valid)
    copy per rank — valid (P,) and q (m, n) row-sharded (or None).

    The permutation plan is host-computed from ``fault_spec``; on a real
    fleet the runtime re-invokes this with a fresh plan after each health
    change (step-boundary replanning, DESIGN.md §2).
    """
    p = mesh.shape[axis]
    plan = make_plan(variant, p, fault_spec)
    if compute_q and not plan.final_valid.all():
        raise ValueError(
            "compute_q requires an all-valid plan (fault-free, or "
            "self-healing within tolerance)"
        )
    comm = ShardMapComm(p, axis)
    combiner = QRCombiner(_resolve_local_qr(local_qr))
    want_q = compute_q

    def body(a_blk):
        a = a_blk  # (m_local, n)
        r, valid = execute_plan(a, comm, plan, combiner)
        q = None
        if want_q:
            q, r = form_q(a, r, comm, reorth)
        out_q = q if want_q else jnp.zeros((0, a.shape[-1]), a.dtype)
        return r[None], valid[None], out_q

    shard = shard_map(
        body,
        mesh=mesh,
        in_specs=P(axis),
        out_specs=(P(axis), P(axis), P(axis)),
    )
    fun = jax.jit(shard) if jit else shard
    r, valid, q = fun(a_global)
    return TSQRResult(
        r=r, valid=valid, q=(q if want_q else None), plan=plan
    )
