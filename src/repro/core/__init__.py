"""Core library: fault-tolerant communication-avoiding TSQR (Coti 2015).

The paper's contribution as a composable JAX module:

  * :mod:`repro.core.tsqr`   — the four algorithm variants (tree / redundant /
    replace / self-healing) on sim and shard_map backends;
  * :mod:`repro.core.plan`   — host-side routing + robustness oracle;
  * :mod:`repro.core.faults` — the fail-stop fault model and the paper's
    tolerance accounting (2^s − 1);
  * :mod:`repro.core.comm`   — the two communication backends;
  * :mod:`repro.core.ref`    — numpy ground truth.
"""
from .comm import ShardMapComm, SimComm
from .faults import NEVER, FaultSpec, tolerance, total_tolerance, within_tolerance
from .plan import Plan, Step, make_plan
from .tsqr import (
    TSQRResult,
    butterfly_allreduce_sum,
    tsqr_shard_map,
    tsqr_sim,
)

__all__ = [
    "NEVER",
    "FaultSpec",
    "Plan",
    "Step",
    "ShardMapComm",
    "SimComm",
    "TSQRResult",
    "butterfly_allreduce_sum",
    "make_plan",
    "tolerance",
    "total_tolerance",
    "tsqr_shard_map",
    "tsqr_sim",
    "within_tolerance",
]
