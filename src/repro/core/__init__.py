"""Core library: fault-tolerant communication-avoiding TSQR (Coti 2015).

The generic plan/route/validity machinery now lives in
:mod:`repro.collective` (comm backends, fault model, planners, combiners,
and the ``execute_plan`` / ``ft_allreduce`` engine); this package keeps the
QR-combiner instantiation and the numpy ground truth:

  * :mod:`repro.core.tsqr`   — the four algorithm variants (tree / redundant /
    replace / self-healing) on sim and shard_map backends, plus Q formation;
  * :mod:`repro.core.ref`    — numpy ground truth.

The ``repro.core.plan`` / ``repro.core.faults`` / ``repro.core.comm``
deprecation stubs have been **removed** — import those names from
:mod:`repro.collective` (or from this package, which re-exports them
below).  The implementation itself lives in :mod:`repro.qr` (panel
pipeline layer) — ``repro.core.tsqr`` is a thin facade over it.
"""
from repro.collective import (
    NEVER,
    FaultSpec,
    Plan,
    ShardMapComm,
    SimComm,
    Step,
    ft_allreduce,
    make_plan,
    tolerance,
    total_tolerance,
    within_tolerance,
)

from .tsqr import (
    TSQRResult,
    form_q,
    tsqr_gram_shard_map,
    tsqr_shard_map,
    tsqr_sim,
)

__all__ = [
    "NEVER",
    "FaultSpec",
    "Plan",
    "Step",
    "ShardMapComm",
    "SimComm",
    "TSQRResult",
    "form_q",
    "ft_allreduce",
    "make_plan",
    "tolerance",
    "total_tolerance",
    "tsqr_gram_shard_map",
    "tsqr_shard_map",
    "tsqr_sim",
    "within_tolerance",
]
