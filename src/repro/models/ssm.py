"""Mamba2 (SSD — state-space duality) blocks and LM (arXiv:2405.21060).

Training uses the chunked SSD form: intra-chunk quadratic ("attention-like")
term + inter-chunk state recurrence (a ``lax.scan`` over S/chunk steps with a
(B, nh, hp, N) running state).  Decode is the O(1)-per-token recurrence —
which is why the ``long_500k`` cell runs for the SSM/hybrid archs only.

TP: heads (nh = d_inner / head_dim) shard over "model"; B/C projections are
group-shared (G groups, replicated for G=1).  No attention, no RoPE.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .sharding import constrain

__all__ = [
    "init_mamba_block", "mamba_chunked", "mamba_step", "init_ssm_state",
    "init", "forward", "loss_fn", "prefill", "decode_step", "init_decode_cache",
]


# ---------------------------------------------------------------------------
# Block params
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg, d_model: int | None = None):
    d = d_model or cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    gn = cfg.ssm_groups * cfg.ssm_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "w_z": (jax.random.normal(ks[0], (d, di)) * s).astype(dt),
        "w_x": (jax.random.normal(ks[1], (d, di)) * s).astype(dt),
        "w_bc": (jax.random.normal(ks[2], (d, 2 * gn)) * s).astype(dt),
        "w_dt": (jax.random.normal(ks[3], (d, nh)) * s).astype(dt),
        "conv_x": (jax.random.normal(ks[4], (cfg.ssm_conv, di)) * 0.1).astype(dt),
        "conv_bc": (jax.random.normal(ks[5], (cfg.ssm_conv, 2 * gn)) * 0.1).astype(dt),
        "conv_x_b": jnp.zeros((di,), dt),
        "conv_bc_b": jnp.zeros((2 * gn,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), jnp.float32),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 7), (di, d))
                  / math.sqrt(di)).astype(dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, ch), w (K, ch)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b


def _gated_norm(y, z, scale, eps=1e-6):
    """Mamba2 RMSNormGated: norm(y · silu(z)) · scale."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    y = yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + eps)
    return (y * scale).astype(z.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD (training / prefill)
# ---------------------------------------------------------------------------

def _segsum(a):
    """a (..., Q) → lower-triangular pairwise sums Σ_{j<i≤q} (..., Q, Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]        # cs[i] - cs[j]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba_chunked(xh, da, b_mat, c_mat, cfg, state0=None):
    """SSD over full sequence, chunk-parallel.

    xh (B,S,nh,hp) — dt-scaled inputs; da (B,S,nh) = dt·A (negative);
    b_mat/c_mat (B,S,G,N).  Returns (y (B,S,nh,hp), final state (B,nh,hp,N)).
    """
    bsz, s, nh, hp = xh.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = min(cfg.ssm_chunk, s)
    while s % q:          # ragged sequence (tests): largest divisor ≤ chunk
        q -= 1
    nc = s // q
    rep = nh // g

    xh = xh.reshape(bsz, nc, q, nh, hp)
    da = da.reshape(bsz, nc, q, nh).astype(jnp.float32)
    bm = b_mat.reshape(bsz, nc, q, g, n)
    cm = c_mat.reshape(bsz, nc, q, g, n)

    cs = jnp.cumsum(da, axis=2)                              # inclusive
    # ---- intra-chunk (diagonal blocks) ---------------------------------
    lmat = jnp.exp(_segsum(jnp.moveaxis(da, 3, 2)))          # (B,nc,nh,Q,Q)
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", cm, bm,
                        preferred_element_type=jnp.float32)
    scores = jnp.repeat(scores, rep, axis=2)                 # (B,nc,nh,Q,K)
    att = (scores * lmat).astype(xh.dtype)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", att, xh)
    # ---- chunk-final states ---------------------------------------------
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)               # (B,nc,Q,nh)
    states = jnp.einsum(
        "bcqgn,bcqhp,bcqh->bchpn",
        bm.astype(jnp.float32), xh.astype(jnp.float32), decay_end,
    )
    total = jnp.exp(cs[:, :, -1, :])                         # (B,nc,nh)
    # ---- inter-chunk recurrence (sequential scan over chunks) -----------
    s0 = (jnp.zeros((bsz, nh, hp, n), jnp.float32)
          if state0 is None else state0.astype(jnp.float32))

    def step(carry, inp):
        st_new, tot = inp                                    # (B,nh,hp,n),(B,nh)
        prev = carry
        nxt = prev * tot[..., None, None] + st_new
        return nxt, prev

    final, prev_states = lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,nh,hp,n)
    # ---- inter-chunk contribution ----------------------------------------
    y_off = jnp.einsum(
        "bcqgn,bchpn,bcqh->bcqhp",
        cm.astype(jnp.float32), prev_states, jnp.exp(cs),
    ).astype(xh.dtype)
    y = (y_diag + y_off).reshape(bsz, s, nh, hp)
    return y, final


def mamba_block(p, x, cfg, state=None):
    """Full block: projections + conv + SSD + gated norm.  x (B,S,d).

    Returns (y (B,S,d), carry) with carry = (ssm_state, conv tail states)
    so prefill can hand off to decode.
    """
    bsz, s, _ = x.shape
    di = p["w_x"].shape[1]
    nh = p["A_log"].shape[0]
    hp = di // nh
    gn2 = p["w_bc"].shape[1]
    g = cfg.ssm_groups
    n = cfg.ssm_state

    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    bc = x @ p["w_bc"]
    dt_raw = (x @ p["w_dt"]).astype(jnp.float32)
    z = constrain(z, "batch", None, "model")
    xin = constrain(xin, "batch", None, "model")

    xc = jax.nn.silu(_causal_conv(xin, p["conv_x"], p["conv_x_b"]))
    bcc = jax.nn.silu(_causal_conv(bc, p["conv_bc"], p["conv_bc_b"]))
    b_mat = bcc[..., : gn2 // 2].reshape(bsz, s, g, n)
    c_mat = bcc[..., gn2 // 2 :].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (B,S,nh)
    a = -jnp.exp(p["A_log"])                                 # (nh,)
    da = dt * a
    xh = xc.reshape(bsz, s, nh, hp)
    xdt = xh * dt[..., None].astype(xh.dtype)

    y, final_state = mamba_chunked(xdt, da, b_mat, c_mat, cfg, state)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = _gated_norm(y.reshape(bsz, s, di), z, p["gate_norm"])
    out = y @ p["w_out"]
    conv_tail = (xin[:, s - (cfg.ssm_conv - 1):, :], bc[:, s - (cfg.ssm_conv - 1):, :])
    return out, (final_state, conv_tail)


# ---------------------------------------------------------------------------
# Single-token decode recurrence
# ---------------------------------------------------------------------------

def init_ssm_state(cfg, batch: int, n_layers: int, d_model: int | None = None):
    d = d_model or cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    hp = cfg.ssm_head_dim
    gn = cfg.ssm_groups * cfg.ssm_state
    kc = cfg.ssm_conv - 1
    return {
        "ssm": jnp.zeros((n_layers, batch, nh, hp, cfg.ssm_state), jnp.float32),
        "conv_x": jnp.zeros((n_layers, batch, kc, di), jnp.dtype(cfg.dtype)),
        "conv_bc": jnp.zeros((n_layers, batch, kc, 2 * gn), jnp.dtype(cfg.dtype)),
    }


def mamba_step(p, x, cfg, state):
    """One-token step.  x (B,1,d); state {"ssm","conv_x","conv_bc"} slices."""
    bsz = x.shape[0]
    di = p["w_x"].shape[1]
    nh = p["A_log"].shape[0]
    hp = di // nh
    g, n = cfg.ssm_groups, cfg.ssm_state

    xt = x[:, 0]
    z = xt @ p["w_z"]
    xin = xt @ p["w_x"]
    bc = xt @ p["w_bc"]
    dt_raw = (xt @ p["w_dt"]).astype(jnp.float32)

    # conv windows: state holds the previous (K-1) raw inputs
    win_x = jnp.concatenate([state["conv_x"], xin[:, None, :]], axis=1)
    win_bc = jnp.concatenate([state["conv_bc"], bc[:, None, :]], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win_x, p["conv_x"]) + p["conv_x_b"]
    )
    bcc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win_bc, p["conv_bc"]) + p["conv_bc_b"]
    )
    b_t = bcc[:, : g * n].reshape(bsz, g, n)
    c_t = bcc[:, g * n :].reshape(bsz, g, n)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (B,nh)
    a = -jnp.exp(p["A_log"])
    da_t = jnp.exp(dt * a)                                   # (B,nh)
    xh = xc.reshape(bsz, nh, hp).astype(jnp.float32)
    rep = nh // g
    b_h = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)   # (B,nh,n)
    c_h = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)

    ssm = state["ssm"] * da_t[..., None, None] + (
        dt[..., None, None] * xh[..., :, None] * b_h[..., None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, c_h) + xh * p["D"][None, :, None]
    y = _gated_norm(y.reshape(bsz, 1, di).astype(x.dtype), z[:, None], p["gate_norm"])
    out = (y @ p["w_out"])
    new_state = {
        "ssm": ssm,
        "conv_x": win_x[:, 1:],
        "conv_bc": win_bc[:, 1:],
    }
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba2 LM (embed → scanned blocks → head)
# ---------------------------------------------------------------------------

def init(key, cfg):
    k_emb, k_layers = jax.random.split(key)

    def one(k):
        kn, kb = jax.random.split(k)
        return {
            "norm": L.init_norm(cfg, cfg.d_model),
            "block": init_mamba_block(kb, cfg),
        }

    layers = jax.vmap(one)(jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "layers": layers,
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def forward(params, tokens, cfg, positions=None):
    del positions
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        y, _ = mamba_block(lp["block"], L.apply_norm(lp["norm"], h, cfg), cfg)
        h = constrain(h + y, "batch", None, None)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, _ = L.scan_or_unroll(body, x, params["layers"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg):
    logits = forward(params, batch["tokens"], cfg)
    return L.cross_entropy(logits, batch["labels"])


def init_decode_cache(cfg, batch: int, s_max: int, dtype=None):
    del s_max, dtype
    st = init_ssm_state(cfg, batch, cfg.n_layers)
    return {"state": st, "len": jnp.zeros((), jnp.int32)}


def prefill(params, tokens, cfg, positions=None, s_max: int | None = None):
    """Forward pass that also returns the decode-ready recurrent state."""
    del positions, s_max
    bsz, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        y, (st, (cx, cbc)) = mamba_block(
            lp["block"], L.apply_norm(lp["norm"], h, cfg), cfg
        )
        h = constrain(h + y, "batch", None, None)
        return h, {"ssm": st, "conv_x": cx, "conv_bc": cbc}

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, states = L.scan_or_unroll(body, x, params["layers"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"state": states, "len": jnp.asarray(s, jnp.int32)}


def decode_step(params, cache, token, cfg):
    x = L.embed(params["embed"], token, cfg)

    def body(h, slices):
        lp, st = slices
        y, new_st = mamba_step(lp["block"], L.apply_norm(lp["norm"], h, cfg), cfg, st)
        return h + y, new_st

    x, new_states = L.scan_or_unroll(body, x, (params["layers"], cache["state"]), cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"state": new_states, "len": cache["len"] + 1}
