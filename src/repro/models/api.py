"""Uniform model API over all families: init / loss / prefill / decode.

Every architecture config routes here; the launcher, trainer, and dry-run
only speak this interface.

  * ``init(key, cfg)``                → params
  * ``param_specs(cfg)``              → ShapeDtypeStruct pytree (eval_shape)
  * ``loss_fn(params, batch, cfg)``   → scalar  (train step body)
  * ``prefill_fn / decode_fn``        → serving step bodies
  * ``batch_specs(cfg, shape)``       → ShapeDtypeStruct inputs per cell
  * ``synth_batch(key, cfg, ...)``    → concrete small batch for smoke tests
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import encdec, frontends, hybrid, ssm, transformer
from .partitioning import param_shardings

__all__ = [
    "module_for", "init", "param_specs", "loss_fn", "forward",
    "prefill", "decode_step", "init_decode_cache", "decode_cache_specs",
    "batch_specs", "synth_batch", "param_shardings",
]

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}


def module_for(cfg):
    return _FAMILIES[cfg.family]


def init(key, cfg):
    return module_for(cfg).init(key, cfg)


def param_specs(cfg):
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


def forward(params, batch, cfg):
    mod = module_for(cfg)
    if cfg.family == "encdec":
        return mod.forward(params, batch["tokens"], cfg, frames=batch["frames"])
    return mod.forward(params, batch["tokens"], cfg, batch.get("positions"))


def loss_fn(params, batch, cfg):
    """Weighted next-token loss.  ``batch['loss_weight']`` (B,) optionally
    down-weights rows — the BLANK-semantics path where a failed replica's
    contribution is dropped and the rest rescaled (runtime/trainer.py)."""
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll                                   # (B, S)
    w = batch.get("loss_weight")
    if w is None:
        loss = nll.mean()
    else:
        wf = w[:, None].astype(nll.dtype)
        loss = (nll * wf).sum() / jnp.maximum((wf * jnp.ones_like(nll)).sum(), 1.0)
    return loss + 1e-4 * jnp.square(lse).mean()


def prefill(params, batch, cfg, s_max=None):
    mod = module_for(cfg)
    if cfg.family == "encdec":
        return mod.prefill(
            params, batch["tokens"], cfg, frames=batch["frames"], s_max=s_max
        )
    return mod.prefill(
        params, batch["tokens"], cfg, positions=batch.get("positions"), s_max=s_max
    )


def decode_step(params, cache, token, cfg):
    return module_for(cfg).decode_step(params, cache, token, cfg)


def init_decode_cache(cfg, batch: int, s_max: int, dtype=None):
    return module_for(cfg).init_decode_cache(cfg, batch, s_max, dtype)


def decode_cache_specs(cfg, batch: int, s_max: int):
    return jax.eval_shape(lambda: init_decode_cache(cfg, batch, s_max))


# ---------------------------------------------------------------------------
# Input specs / synthetic batches per shape cell
# ---------------------------------------------------------------------------

def batch_specs(cfg, kind: str, batch: int, seq: int):
    """ShapeDtypeStruct inputs for a (train | prefill | decode) step."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind == "train":
        out = {"tokens": tok, "labels": tok}
    elif kind == "prefill":
        out = {"tokens": tok}
    elif kind == "decode":
        out = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    else:
        raise ValueError(kind)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        out["frames"] = frontends.audio_frames_spec(cfg, batch)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        out["positions"] = frontends.mrope_positions_spec(cfg, batch, seq)
    return out


def synth_batch(key, cfg, kind: str, batch: int, seq: int):
    """Concrete random batch matching :func:`batch_specs` (smoke tests)."""
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32)
    if kind == "train":
        out = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    elif kind == "prefill":
        out = {"tokens": tokens}
    elif kind == "decode":
        out = {"tokens": tokens[:, :1]}
    else:
        raise ValueError(kind)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        out["frames"] = frontends.audio_frames(k2, cfg, batch)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        span = (8, 8 + min(16, seq // 2)) if seq >= 24 else None
        out["positions"] = frontends.mrope_positions(
            cfg, batch, seq, image_span=span, grid=(4, 4)
        )
    return out
