"""Decoder-only transformer LM covering the dense / MoE / VLM families.

Layers are stacked and scanned (``lax.scan``) so the lowered HLO contains one
layer body regardless of depth — essential for compiling the 40-cell dry-run
matrix on this 1-core container, and the natural remat unit.  Alternating
patterns (gemma2 local/global) scan over a repeating *unit* of ``period``
sublayers, each with its own stacked params and static kind.

Decode caches are stacked along the unit axis and threaded through the same
scan: ``cache = {"kv": tuple_per_position({"k","v"}), "len": ()}`` where k/v
are (n_units, B, KH, T, hd).  Sliding-window sublayers use a ring buffer of
T = window slots (RoPE is applied at write time with absolute positions, so
ring rotation is transparent).

Entry points: :func:`init`, :func:`forward`, :func:`loss_fn`,
:func:`prefill`, :func:`decode_step`, :func:`init_decode_cache`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as M
from .sharding import constrain

__all__ = [
    "unit_pattern", "init", "forward", "loss_fn",
    "prefill", "decode_step", "init_decode_cache", "param_shardings",
]


@dataclasses.dataclass(frozen=True)
class SubKind:
    """Static description of one sublayer in the repeating unit."""

    window: int | None
    moe: bool


def unit_pattern(cfg) -> list[SubKind]:
    """The repeating sublayer pattern (period divides n_layers)."""
    if cfg.local_global:
        # gemma2: sliding-window layer followed by a global layer
        return [SubKind(cfg.sliding_window, cfg.n_experts > 0),
                SubKind(None, cfg.n_experts > 0)]
    return [SubKind(cfg.sliding_window, cfg.n_experts > 0)]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_sublayer(key, cfg, kind: SubKind):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "mlp_norm": L.init_norm(cfg, cfg.d_model),
    }
    if kind.moe:
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg)
    if cfg.post_norms:
        p["post_attn_norm"] = L.init_norm(cfg, cfg.d_model)
        p["post_mlp_norm"] = L.init_norm(cfg, cfg.d_model)
    return p


def init(key, cfg):
    """Params with per-sublayer-position stacks of shape (n_units, ...)."""
    pattern = unit_pattern(cfg)
    period = len(pattern)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    n_units = cfg.n_layers // period
    k_emb, k_layers = jax.random.split(key)

    def one_unit(k):
        ks = jax.random.split(k, period)
        return tuple(
            _init_sublayer(ks[i], cfg, kind) for i, kind in enumerate(pattern)
        )

    units = jax.vmap(one_unit)(jax.random.split(k_layers, n_units))
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "units": units,                      # tuple(period) of stacked dicts
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _positions_default(cfg, b, s, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _cos_sin(cfg, positions):
    return L.rope_cos_sin(
        positions, cfg.d_head, cfg.rope_theta, cfg.mrope_sections
    )


def _sublayer(p, x, cfg, kind: SubKind, cos_sin, cache):
    """One attention+MLP sublayer. Returns (x, aux) — aux per L.attention."""
    if cfg.fsdp:
        from .partitioning import gather_layer_params
        p = gather_layer_params(p)
    h = L.apply_norm(p["attn_norm"], x, cfg)
    h, aux = L.attention(
        p["attn"], h, cfg, cos_sin=cos_sin, causal=True,
        window=kind.window, cache=cache,
    )
    if cfg.post_norms:
        h = L.apply_norm(p["post_attn_norm"], h, cfg)
    x = x + h
    h = L.apply_norm(p["mlp_norm"], x, cfg)
    h = M.moe(p["moe"], h, cfg) if kind.moe else L.mlp(p["mlp"], h, cfg)
    if cfg.post_norms:
        h = L.apply_norm(p["post_mlp_norm"], h, cfg)
    x = x + h
    x = constrain(x, *L.residual_axes(cfg))
    return x, aux


def _remat(body, cfg):
    if not cfg.remat:
        return body
    return jax.checkpoint(body, policy=L.remat_policy())


def forward(params, tokens, cfg, positions=None):
    """tokens (B, S) → logits (B, S, V).  Training/eval forward."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    pos = positions if positions is not None else _positions_default(cfg, b, s)
    cos_sin = _cos_sin(cfg, pos)
    pattern = unit_pattern(cfg)

    def body(h, unit_params):
        for i, kind in enumerate(pattern):
            h, _ = _sublayer(unit_params[i], h, cfg, kind, cos_sin, None)
        return h, None

    x, _ = L.scan_or_unroll(_remat(body, cfg), x, params["units"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg):
    logits = forward(params, batch["tokens"], cfg, batch.get("positions"))
    return L.cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with stacked caches
# ---------------------------------------------------------------------------

def _cache_sizes(cfg, s_max):
    """Per-sublayer-position cache length (ring = window for local layers)."""
    return [
        min(k.window, s_max) if k.window is not None else s_max
        for k in unit_pattern(cfg)
    ]


def _shard_kv(kv):
    """KV caches: batch over ('pod','data'), heads over 'model' (time dim
    when GQA heads don't divide the axis); rank-aware for (n_units, B, KH,
    T, hd) stacks vs (B, KH, T, hd) per-layer slices."""
    from .sharding import constrain_kv

    def spec(a):
        off = 1 if a.ndim == 5 else 0
        return constrain_kv(
            a, head_axis=off + 1, time_axis=off + 2, batch_dim=off
        )

    return {"k": spec(kv["k"]), "v": spec(kv["v"])}


def init_decode_cache(cfg, batch: int, s_max: int, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    pattern = unit_pattern(cfg)
    n_units = cfg.n_layers // len(pattern)
    kh, hd = cfg.n_kv_heads, cfg.d_head
    kv = tuple(
        _shard_kv({
            "k": jnp.zeros((n_units, batch, kh, t, hd), dt),
            "v": jnp.zeros((n_units, batch, kh, t, hd), dt),
        })
        for t in _cache_sizes(cfg, s_max)
    )
    return {"kv": kv, "len": jnp.zeros((), jnp.int32)}


def prefill(params, tokens, cfg, positions=None, s_max: int | None = None):
    """Full forward that also materializes the KV caches (inference-prefill).

    Returns (last-token logits (B, V), cache).  KV tensors come straight out
    of the layer scan (no recompute, no per-token loop).
    """
    b, s = tokens.shape
    s_max = s_max or s
    x = L.embed(params["embed"], tokens, cfg)
    pos = positions if positions is not None else _positions_default(cfg, b, s)
    cos_sin = _cos_sin(cfg, pos)
    pattern = unit_pattern(cfg)
    sizes = _cache_sizes(cfg, s_max)

    def body(h, unit_params):
        kvs = []
        for i, kind in enumerate(pattern):
            h, (k, v) = _sublayer(unit_params[i], h, cfg, kind, cos_sin, None)
            t = min(sizes[i], s)
            pad = sizes[i] - t
            k = jnp.moveaxis(k[:, s - t:], 1, 2)     # (B, KH, t, hd)
            v = jnp.moveaxis(v[:, s - t:], 1, 2)
            if pad:
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            elif kind.window is not None and t == sizes[i]:
                # ring alignment: decode writes token p at slot p % window,
                # so position s-t+j must sit at slot (s-t+j) % t
                k = jnp.roll(k, (s - t) % t, axis=2)
                v = jnp.roll(v, (s - t) % t, axis=2)
            kvs.append(_shard_kv({"k": k, "v": v}))
        return h, tuple(kvs)

    x, kv_stk = L.scan_or_unroll(_remat(body, cfg), x, params["units"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    cache = {
        "kv": tuple(_shard_kv(kv) for kv in kv_stk),
        "len": jnp.asarray(s, jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, token, cfg):
    """One new token (B, 1) against the cache → (logits (B, V), cache).

    Note on ring caches: slots are written at ``len % window`` with RoPE
    already applied at absolute positions, so no rotation is needed.
    After prefill at s == window the ring restarts at slot ``len % window``,
    overwriting the oldest in-window entry — exact sliding-window semantics.
    """
    b = token.shape[0]
    x = L.embed(params["embed"], token, cfg)
    pos_len = cache["len"]
    pos = _positions_default(cfg, b, 1, offset=pos_len)
    cos_sin = _cos_sin(cfg, pos)
    pattern = unit_pattern(cfg)

    def body(h, slices):
        unit_params, unit_kv = slices
        new_kv = []
        for i, kind in enumerate(pattern):
            sub_cache = {
                "k": unit_kv[i]["k"], "v": unit_kv[i]["v"], "len": pos_len,
            }
            h, nc = _sublayer(unit_params[i], h, cfg, kind, cos_sin, sub_cache)
            new_kv.append(_shard_kv({"k": nc["k"], "v": nc["v"]}))
        return h, tuple(new_kv)

    x, new_kv = L.scan_or_unroll(body, x, (params["units"], cache["kv"]), cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"kv": new_kv, "len": pos_len + 1}


# ---------------------------------------------------------------------------
# Param shardings (TP over "model", replicated over batch axes)
# ---------------------------------------------------------------------------

def param_shardings(params_shape, cfg, mesh=None, *, gather_axis=None):
    """PartitionSpec pytree for the param tree.

    TP rule-of-thumb: shard the biggest contraction-free dim over "model" —
    heads for attention, ff for MLPs, vocab for embeddings, expert-ff for
    MoE.  ``gather_axis`` (e.g. "data") additionally spreads every TP'd dim
    over (gather_axis, "model") — the weight-gathered serving layout for
    models whose bf16 weights exceed model-axis HBM (DESIGN.md §6).
    """
    from jax.sharding import PartitionSpec as P

    tp = "model" if gather_axis is None else (gather_axis, "model")

    def spec_for(path: str, leaf) -> P:
        nd = len(leaf.shape)
        stacked = path.startswith("units/")
        pre = (None,) if stacked else ()

        def mk(*axes):
            axes = axes + (None,) * (nd - len(pre) - len(axes))
            return P(*(pre + axes))

        name = path.rsplit("/", 1)[-1]
        if name in ("wq", "wk", "wv"):
            return mk(None, tp)
        if name == "wo":
            return mk(tp, None)
        if name in ("bq", "bk", "bv"):
            return mk(tp)
        if name in ("wg", "wu", "w1"):
            return mk(None, tp)
        if name in ("wd", "w2"):
            return mk(tp, None)
        if name in ("we_gate", "we_up"):          # (E, d, ff)
            return mk(None, None, tp)
        if name == "we_down":                     # (E, ff, d)
            return mk(None, tp, None)
        if name == "tok":
            return P(tp, None)
        if name == "out":
            return P(None, tp)
        return mk()

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, f"{path}/{i}") for i, v in enumerate(tree))
        return spec_for(path, tree)

    return walk(params_shape, "")
