"""Name-based parameter partitioning rules for every model family.

TP rule: shard each tensor's largest contraction-free dim over "model" —
attention heads, MLP ff, SSM heads (d_inner / nh), expert ff, vocab.
Stacked layer params (scan stacks, possibly nested — zamba2 units are
(n_units, attn_every, ...)) get leading ``None`` axes automatically from
the leaf's extra rank.

``gather_axis`` ("data") spreads every TP'd dim over (data, model) — the
weight-gathered layout for decode of models whose bf16 params exceed
model-axis HBM (mixtral-8x22b, qwen2-vl-72b; DESIGN.md §6).
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

__all__ = ["param_shardings", "spec_for_name"]

# name → (base_ndim, spec builder given tp axis)
_RULES: dict[str, tuple[int, ...]] = {}


def _rule(names, base_nd, make):
    for n in names:
        _RULES[n] = (base_nd, make)


_rule(("wq", "wk", "wv", "wg", "wu", "w1", "w_z", "w_x",
       "ws_gate", "ws_up"), 2, lambda tp: (None, tp))
_rule(("wo", "wd", "w2", "w_out", "ws_down"), 2, lambda tp: (tp, None))
_rule(("bq", "bk", "bv"), 1, lambda tp: (tp,))
_rule(("conv_x", "conv_x_b"), None, lambda tp: ("LASTDIM", tp))
_rule(("A_log", "D", "dt_bias", "gate_norm"), 1, lambda tp: (tp,))
_rule(("we_gate", "we_up"), 3, lambda tp: (None, None, tp))
_rule(("we_down",), 3, lambda tp: (None, tp, None))
_rule(("tok",), 2, lambda tp: (tp, None))
_rule(("out",), 2, lambda tp: (None, tp))
# everything else (norm scales/biases, router, w_bc, w_dt, conv_bc,
# w_shared_gate, q_norm, k_norm) is replicated.


def spec_for_name(name: str, leaf, tp) -> P:
    entry = _RULES.get(name)
    nd = len(leaf.shape)
    if entry is None:
        return P(*([None] * nd))
    base_nd, make = entry
    spec = make(tp)
    if spec[0] == "LASTDIM":           # shard only the final dim
        return P(*([None] * (nd - 1) + [tp]))
    pad = nd - base_nd
    if pad < 0:   # scalar-ish leaf under a vector rule — replicate
        return P(*([None] * nd))
    return P(*([None] * pad + list(spec)))


def gather_layer_params(tree, *, skip_experts: bool = True):
    """FSDP helper: constrain a *sliced* (per-layer) param subtree to the
    gathered layout (TP over 'model' only).  Placed inside the layer-scan
    body this forces GSPMD to all-gather each layer's weights per iteration
    (and reduce-scatter its gradients) — without it the partitioner hoists
    one giant all-gather of the whole stacked parameter tensor out of the
    loop (measured: 144 GB/device on qwen2-vl-72b).

    Expert weights (we_*) stay FSDP-sharded: the MoE layer gathers them one
    expert at a time (``moe_scan_experts``).
    """
    import jax

    from .sharding import current_mesh

    if current_mesh() is None:
        return tree
    from jax.sharding import NamedSharding

    mesh = current_mesh()

    def walk(t, name):
        if isinstance(t, dict):
            return {k: walk(v, k) for k, v in t.items()}
        if isinstance(t, (tuple, list)):
            return type(t)(walk(v, name) for v in t)
        if t is None:
            return None
        if skip_experts and name.startswith("we_"):
            return t
        spec = spec_for_name(name, t, "model")
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return walk(tree, "")


def param_shardings(params_tree, *, gather_axis: str | None = None):
    """PartitionSpec pytree mirroring ``params_tree`` (shapes or arrays)."""
    tp = "model" if gather_axis is None else (gather_axis, "model")

    def walk(tree, name):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, (tuple, list)):
            return type(tree)(walk(v, name) for v in tree)
        if tree is None:
            return None
        return spec_for_name(name, tree, tp)

    return walk(params_tree, "")
