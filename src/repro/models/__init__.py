"""Model zoo: composable JAX definitions for the 10 assigned architectures.

Families: dense / MoE / VLM transformers (:mod:`transformer`), Mamba2 SSD
(:mod:`ssm`), Zamba2 hybrid (:mod:`hybrid`), Whisper enc-dec
(:mod:`encdec`).  All expose the uniform :mod:`repro.models.api` surface.
"""
from . import api

__all__ = ["api"]
