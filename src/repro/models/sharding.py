"""Mesh context + activation-sharding helpers for the model zoo.

Models are written once as pure functions; distribution is injected via
``constrain(x, *axes)`` sharding constraints that no-op when no mesh context
is active (CPU smoke tests) and lower to GSPMD annotations under the
production mesh.  Batch dims shard over ``("pod", "data")`` when the pod
axis exists (multi-pod dry-run) and ``("data",)`` otherwise.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["mesh_context", "constrain", "batch_axes", "current_mesh", "named_sharding"]

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def batch_axes(mesh: Mesh | None = None):
    """Axes the global batch shards over: ('pod','data') or ('data',)."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ("data",)
    names = mesh.axis_names
    return tuple(n for n in ("pod", "data") if n in names)


def _resolve(axes):
    """Map the symbolic 'batch' axis to the mesh's real batch axes."""
    out = []
    for a in axes:
        if a == "batch":
            out.append(batch_axes())
        else:
            out.append(a)
    return tuple(out)


def named_sharding(mesh: Mesh, *axes) -> NamedSharding:
    with mesh_context(mesh):
        return NamedSharding(mesh, P(*_resolve(axes)))


def constrain(x, *axes):
    """``with_sharding_constraint`` against the active mesh (no-op if none).

    ``axes`` entries: mesh axis name, tuple of names, None, or the symbolic
    ``"batch"`` which resolves to ('pod','data')/('data',)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = P(*_resolve(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_kv(x, *, head_axis: int, time_axis: int, batch_dim: int = None):
    """KV-cache layout constraint matching launch/shardings._cache_spec:
    heads over 'model' when they divide the axis, else the time dim
    (flash-decode layout); batch over the batch axes when divisible."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = [None] * x.ndim
    model_sz = mesh.shape["model"]
    if x.shape[head_axis] % model_sz == 0:
        spec[head_axis] = "model"
    elif x.shape[time_axis] % model_sz == 0:
        spec[time_axis] = "model"
    if batch_dim is not None:
        ba = batch_axes(mesh)
        sz = 1
        for a in ba:
            sz *= mesh.shape[a]
        if x.shape[batch_dim] % sz == 0:
            spec[batch_dim] = ba
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
