"""Mixture-of-Experts layer (qwen2-moe / mixtral families).

Two compute paths, chosen by sequence length:

  * **train / prefill** (S > 1): per-sequence capacity-based dispatch
    (GShard-style, group = sequence).  Tokens are routed top-k, sorted by
    expert id *within their sequence* (a vmapped argsort — no cross-shard
    collectives), and scattered into a (B, E, C, d) buffer with
    C = ceil(S·k/E · capacity_factor).  Expert FLOPs are therefore the
    *active* FLOPs (× capacity factor), not the dense all-experts product —
    keeping the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.  Overflow
    tokens are dropped (standard capacity semantics).
  * **decode** (S == 1): per-token gather of the k selected experts'
    weights.  With a handful of tokens per shard this moves less HBM than
    an all-experts pass and keeps FLOPs exact.

TP: expert ff dims are sharded over "model" ("expert slicing"); the optional
``cfg.expert_parallel`` EP layout is a §Perf experiment (see EXPERIMENTS.md).
Shared experts (qwen2-moe) are a dense SwiGLU gated by a learned sigmoid.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .sharding import constrain

__all__ = ["init_moe", "moe", "capacity"]


def capacity(cfg, s: int) -> int:
    c = int(math.ceil(s * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)        # sublane-aligned


def init_moe(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    si, so = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * si).astype(jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (e, d, f)) * si).astype(dt),
        "we_up": (jax.random.normal(ks[2], (e, d, f)) * si).astype(dt),
        "we_down": (jax.random.normal(ks[3], (e, f, d)) * so).astype(dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["ws_gate"] = (jax.random.normal(ks[4], (d, fs)) * si).astype(dt)
        p["ws_up"] = (jax.random.normal(ks[5], (d, fs)) * si).astype(dt)
        p["ws_down"] = (jax.random.normal(ks[6], (fs, d)) / math.sqrt(fs)).astype(dt)
        p["w_shared_gate"] = (jax.random.normal(ks[7], (d, 1)) * si).astype(dt)
    return p


def _route(p, x, cfg):
    """x (..., d) → (weights (..., k) f32, ids (..., k) i32)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)      # renormalized top-k
    return w, ids


def _expert_ffn(h, p, cfg):
    """h (..., E, C, d) → (..., E, C, d), ff dim TP-sharded.

    With ``cfg.moe_scan_experts`` (FSDP layouts) experts are processed one
    at a time so only a single expert's weights are gathered per step —
    the all-at-once einsum would transiently materialize the whole
    (E, d, ff) stack on every device."""
    if not cfg.moe_scan_experts:
        g = jnp.einsum("becd,edf->becf", h, p["we_gate"])
        u = jnp.einsum("becd,edf->becf", h, p["we_up"])
        g = constrain(g, "batch", None, None, "model")
        u = constrain(u, "batch", None, None, "model")
        return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["we_down"])

    he = jnp.moveaxis(h, -3, 0)                 # (E, B, C, d)

    def one(xe, wg, wu, wd):
        # per-expert gather: constrain each sliced expert to the TP layout
        # so the all-gather happens inside the expert loop, not hoisted
        wg = constrain(wg, None, "model")
        wu = constrain(wu, None, "model")
        wd = constrain(wd, "model", None)
        g = constrain(xe @ wg, None, None, "model")
        u = constrain(xe @ wu, None, None, "model")
        return (jax.nn.silu(g) * u) @ wd

    if cfg.unroll:
        out = jnp.stack([
            one(he[e], p["we_gate"][e], p["we_up"][e], p["we_down"][e])
            for e in range(he.shape[0])
        ])
    else:
        def body(_, xs):
            xe, wg, wu, wd = xs
            return None, one(xe, wg, wu, wd)

        _, out = jax.lax.scan(
            body, None, (he, p["we_gate"], p["we_up"], p["we_down"])
        )
    return jnp.moveaxis(out, 0, -3)


def _shared(p, x, cfg):
    if "ws_gate" not in p:
        return 0.0
    g = x @ p["ws_gate"]
    u = x @ p["ws_up"]
    g = constrain(g, "batch", None, "model")
    u = constrain(u, "batch", None, "model")
    y = (jax.nn.silu(g) * u) @ p["ws_down"]
    gate = jax.nn.sigmoid((x @ p["w_shared_gate"]).astype(jnp.float32))
    return y * gate.astype(y.dtype)


def _moe_dispatch(p, x, cfg):
    """Capacity-based per-sequence dispatch.  x (B, S, d)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)
    w, ids = _route(p, x, cfg)                      # (B, S, k)

    flat_e = ids.reshape(b, s * k)                  # (B, S·k)
    order = jnp.argsort(flat_e, axis=-1)            # vmapped over B by XLA
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    # position of each sorted assignment inside its expert segment
    counts = jax.vmap(lambda v: jnp.bincount(v, length=e))(flat_e)  # (B, E)
    starts = jnp.cumsum(counts, axis=-1) - counts                    # exclusive
    pos = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < c
    slot = jnp.where(keep, se * c + pos, e * c)     # drop → sentinel slot

    tok = order // k                                # source token of assignment
    xs = jnp.take_along_axis(x, tok[..., None], axis=1)              # (B, S·k, d)
    buf = jnp.zeros((b, e * c + 1, d), x.dtype)
    buf = jax.vmap(lambda bb, sl, xx: bb.at[sl].set(xx))(buf, slot, xs)
    buf = buf[:, : e * c].reshape(b, e, c, d)
    buf = constrain(buf, "batch", None, None, None)

    out = _expert_ffn(buf, p, cfg).reshape(b, e * c, d)
    out = jnp.concatenate([out, jnp.zeros((b, 1, d), out.dtype)], axis=1)
    gathered = jax.vmap(lambda oo, sl: oo[sl])(out, slot)            # (B, S·k, d)
    wsort = jnp.take_along_axis(w.reshape(b, s * k), order, axis=-1)
    contrib = gathered * wsort[..., None].astype(gathered.dtype)
    y = jnp.zeros((b, s, d), x.dtype)
    y = jax.vmap(lambda yy, tk, cc: yy.at[tk].add(cc))(y, tok, contrib)
    return y


def _moe_gather(p, x, cfg):
    """Per-token expert-weight gather — the decode (S == 1) path."""
    b, s, d = x.shape
    w, ids = _route(p, x, cfg)                      # (B, 1, k)
    wg = p["we_gate"][ids[:, 0]]                    # (B, k, d, f)
    wu = p["we_up"][ids[:, 0]]
    wd = p["we_down"][ids[:, 0]]                    # (B, k, f, d)
    xt = x[:, 0]                                    # (B, d)
    g = jnp.einsum("bd,bkdf->bkf", xt, wg)
    u = jnp.einsum("bd,bkdf->bkf", xt, wu)
    g = constrain(g, "batch", None, "model")
    u = constrain(u, "batch", None, "model")
    yk = jnp.einsum("bkf,bkfd->bkd", jax.nn.silu(g) * u, wd)
    y = jnp.einsum("bkd,bk->bd", yk, w[:, 0].astype(yk.dtype))
    return y[:, None, :]


def moe(p, x, cfg):
    if x.shape[1] > 1 and cfg.seq_parallel:
        # dispatch wants whole sequences per DP shard: gather S before
        # routing (Megatron-SP behavior), scatter back via the caller's
        # residual constraint.
        x = constrain(x, "batch", None, None)
    if x.shape[1] == 1:
        if cfg.moe_decode_groups and x.shape[0] % cfg.moe_decode_groups == 0:
            # grouped capacity dispatch for decode: one group per data
            # shard (no cross-shard sort, no giant per-token weight gather
            # — the (B,k,d,ff) gather replicates expert weights on fleets
            # whose experts are sharded finer than the batch).
            g = cfg.moe_decode_groups
            b, _, d = x.shape
            xg = x.reshape(g, b // g, d)
            y = _moe_dispatch(p, xg, cfg).reshape(b, 1, d)
        else:
            y = _moe_gather(p, x, cfg)
    else:
        y = _moe_dispatch(p, x, cfg)
    return y + _shared(p, x, cfg)
