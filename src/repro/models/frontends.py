"""Modality frontends — STUBS per the assignment spec.

``[audio]`` / ``[vlm]`` cells specify the transformer BACKBONE only; the
conv/patch frontends are stubbed: ``input_specs()`` provides precomputed
frame/patch embeddings.  These helpers produce the stand-in shapes (dry-run)
and synthetic embeddings (smoke tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["audio_frames_spec", "audio_frames", "mrope_positions_spec", "mrope_positions"]


def audio_frames_spec(cfg, batch: int):
    """Whisper conv-frontend output: (B, F, d) frame embeddings."""
    return jax.ShapeDtypeStruct(
        (batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype)
    )


def audio_frames(key, cfg, batch: int):
    return jax.random.normal(
        key, (batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype)
    )


def mrope_positions_spec(cfg, batch: int, seq: int):
    """Qwen2-VL M-RoPE position streams (t/h/w): (3, B, S) int32.

    For text-only spans all three streams are equal; image spans get
    (t, h, w) grid positions from the (stubbed) vision pipeline.
    """
    return jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)


def mrope_positions(cfg, batch: int, seq: int, *, image_span: tuple[int, int] | None = None, grid=(16, 16)):
    """Synthetic M-RoPE positions: text positions with an optional image
    span laid out on an h×w grid (dynamic-resolution stand-in)."""
    t = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    pos = jnp.stack([t, t, t])
    if image_span is not None:
        s0, s1 = image_span
        h, w = grid
        n = s1 - s0
        hh = (jnp.arange(n) // w).astype(jnp.int32)
        ww = (jnp.arange(n) % w).astype(jnp.int32)
        tt = jnp.zeros((n,), jnp.int32) + s0
        pos = pos.at[0, :, s0:s1].set(tt[None])
        pos = pos.at[1, :, s0:s1].set(hh[None])
        pos = pos.at[2, :, s0:s1].set(ww[None])
    return pos
