"""Shared neural-net layers for the architecture zoo (pure functions).

Conventions:
  * params are nested dicts of jnp arrays; ``init_*`` functions are pure jax
    so ``jax.eval_shape`` over them yields the dry-run ShapeDtypeStructs.
  * activations x are (B, S, d_model); attention caches are
    ``{"k": (B, KH, S_cache, hd), "v": ..., "len": ()}``.
  * TP: head / ff dims are sharded over "model" via
    :func:`repro.models.sharding.constrain`; batch over ('pod','data').
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .sharding import constrain

__all__ = [
    "init_norm", "apply_norm",
    "rope_cos_sin", "apply_rope",
    "init_attention", "attention", "init_cache",
    "init_mlp", "mlp",
    "init_embedding", "embed", "unembed",
    "softcap", "cross_entropy",
    "scan_or_unroll", "remat_policy", "residual_axes", "resolve_q_chunk",
]


def residual_axes(cfg):
    """Sharding of the residual stream (B, S, d).  With Megatron-style
    sequence parallelism the S dim shards over 'model' between blocks —
    GSPMD then emits the all-gather (entering attention/MLP, whose inner
    dims are model-sharded) and reduce-scatter (leaving) pair, which moves
    the same bytes as the TP all-reduce it replaces but divides stored
    activations (scan carries, remat residuals) by the model-axis size."""
    return ("batch", "model", None) if cfg.seq_parallel else ("batch", None, None)


def remat_policy():
    """Full recompute: only scan carries (the per-layer residual stream)
    survive the forward pass — the production activation-memory posture."""
    return jax.checkpoint_policies.nothing_saveable


def scan_or_unroll(body, carry, xs, cfg):
    """lax.scan over the layer stack, or a python unroll for the dry-run
    accounting build (XLA's HloCostAnalysis counts while-loop bodies once,
    so exact FLOP/collective totals need explicit layers; see
    launch/dryrun.py)."""
    if cfg.scan_layers and not cfg.unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
    return carry, stacked

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d: int):
    if cfg.norm in ("ln_nonparam",):
        return {}
    if cfg.norm in ("rmsnorm", "rmsnorm_offset"):
        return {"scale": jnp.zeros((d,), jnp.float32)
                if cfg.norm == "rmsnorm_offset" else jnp.ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    raise ValueError(cfg.norm)


def apply_norm(p, x, cfg, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln_nonparam":
        # OLMo: LayerNorm without learned scale/bias.
        mu = xf.mean(-1, keepdims=True)
        var = xf.var(-1, keepdims=True)
        return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    if cfg.norm == "ln":
        mu = xf.mean(-1, keepdims=True)
        var = xf.var(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    scale = p["scale"]
    if cfg.norm == "rmsnorm_offset":      # gemma: (1 + w)
        scale = 1.0 + scale
    return (y * scale).astype(x.dtype)


def _rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head q/k RMSNorm (qwen3)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale).astype(x.dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta: float, sections=()):
    """cos/sin tables, each (B, S, head_dim/2).

    ``positions``: (B, S) — standard RoPE — or (3, B, S) for M-RoPE, in which
    case ``sections`` (summing to head_dim/2) assigns frequency bands to the
    temporal/height/width position streams (Qwen2-VL §2.1).
    """
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, half)
    else:
        assert sections and sum(sections) == half, (sections, half)
        sec_id = jnp.repeat(
            jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
        )
        pos = positions[sec_id]                              # (half, B, S)
        ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); rotate-half convention (NeoX/Llama)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / softcap / qk_norm / cross-attn)
# ---------------------------------------------------------------------------

def eff_heads(cfg) -> int:
    """Query-head count incl. sharding padding (``pad_heads_to``): head
    counts that don't divide the model axis (minitron: 24 on 16) otherwise
    trigger GSPMD's replicate-repartition fallback on every attention
    einsum — zero-padding to the next multiple trades +33% attention FLOPs
    for clean head-sharding (EXPERIMENTS.md §Perf)."""
    return cfg.pad_heads_to or cfg.n_heads


def init_attention(key, cfg, d_model: int | None = None):
    d = d_model or cfg.d_model
    h, kh, hd = eff_heads(cfg), cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * scale).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kh * hd)) * scale).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kh * hd)) * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * scale).astype(dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kh * hd,), dt)
        p["bv"] = jnp.zeros((kh * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_cache(cfg, batch: int, s_cache: int, dtype, n_layers: int | None = None):
    """Stacked (L, B, KH, S, hd) KV cache for the scanned decoder."""
    layers = cfg.n_layers if n_layers is None else n_layers
    kh, hd = cfg.n_kv_heads, cfg.d_head
    shape = (layers, batch, kh, s_cache, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _qkv(p, x, cfg):
    h, kh, hd = eff_heads(cfg), cfg.n_kv_heads, cfg.d_head
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    if cfg.qk_norm:
        q = _rms_head_norm(p["q_norm"], q)
        k = _rms_head_norm(p["k_norm"], k)
    return q, k, v


def _expand_kv(cfg) -> bool:
    """Expand KV heads to the full query-head count before the score einsum
    when the KV count doesn't divide the model axis (GQA kv=8 on a 16-wide
    axis).  Without this, GSPMD pads the KV-head dim and resharding the
    padded probs against sequence-parallel layouts triggers involuntary
    full rematerialization of the S×S probability tensor in backward.
    The repeat is free FLOPs-wise and the expanded K/V transient is small."""
    from .sharding import current_mesh

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    msz = mesh.shape["model"]
    return (cfg.n_kv_heads % msz != 0) and (eff_heads(cfg) % msz == 0)


def _gqa_scores(q, k, cfg):
    """q (B,S,H,hd), k (B,T,KH,hd) → scores (B,KH,G,S,T), f32."""
    h, kh = eff_heads(cfg), cfg.n_kv_heads
    g = h // kh
    b, s, _, hd = q.shape
    if _expand_kv(cfg):
        k = jnp.repeat(k, g, axis=2)                      # (B,T,H,hd)
        k = k if k.shape[2] == h else jnp.repeat(k, h // k.shape[2], axis=2)
        scores = jnp.einsum(
            "bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32
        )
        scores = scores.reshape(b, h, 1, s, -1)           # (B,H,1,S,T)
        return scores / math.sqrt(hd)
    qg = q.reshape(b, s, kh, g, hd)
    scores = jnp.einsum(
        "bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    return scores / math.sqrt(hd)


def _gqa_out(probs, v, cfg):
    b, kh, g, s, t = probs.shape
    heff = eff_heads(cfg)
    if g == 1 and kh == heff and cfg.n_kv_heads != heff:
        # expanded-KV layout: probs (B,H,1,S,T), v (B,T,KH,hd)
        vv = jnp.repeat(v, heff // cfg.n_kv_heads, axis=2)
        out = jnp.einsum(
            "bhst,bthd->bshd", probs[:, :, 0].astype(v.dtype), vv,
            preferred_element_type=jnp.float32,
        )
        return out.astype(v.dtype)
    out = jnp.einsum(
        "bkgst,btkh->bskgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, s, kh * g, v.shape[-1]).astype(v.dtype)


def _mask_bias(s, t, *, causal, window, offset):
    """(S, T) additive mask. ``offset``: absolute position of query 0 minus
    that of key 0 (0 for self-attn over the same span)."""
    iq = jnp.arange(s)[:, None] + offset
    jk = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok = ok & (jk <= iq)
    if window is not None:
        ok = ok & ((iq - jk) < window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def resolve_q_chunk(cfg, s: int) -> int:
    """Query-chunk size for flash-style attention (0 = unchunked).

    Unchunked S×S score tensors are fine to ~8k (head-sharded over 'model'
    they stay ~1 GB/device); past that the S² f32 buffer must be tiled.
    On a real TPU this layer is a Pallas flash kernel; the chunked pure-JAX
    form keeps the same FLOPs and a bounded working set for the dry-run.
    """
    if cfg.q_chunk:
        return cfg.q_chunk if s > cfg.q_chunk else 0
    if s <= 8192:
        return 0
    return 1024


def _attend_full(q, k, v, cfg, bias):
    scores = _gqa_scores(q, k, cfg) + bias
    scores = softcap(scores, cfg.attn_logit_softcap)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, cfg)


def _attend_chunked(q, k, v, cfg, *, causal, window, qc: int):
    """Flash-style query chunking: softmax rows are exact per chunk (keys are
    never split), memory is O(qc·T) instead of O(S·T)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    assert s % qc == 0, (s, qc)
    nc = s // qc

    def one(idx, q_blk):
        bias = _mask_bias(qc, t, causal=causal, window=window, offset=idx * qc)
        return _attend_full(q_blk, k, v, cfg, bias)

    if cfg.unroll:
        outs = [one(i, q[:, i * qc:(i + 1) * qc]) for i in range(nc)]
        return jnp.concatenate(outs, axis=1)
    q_blocks = jnp.moveaxis(q.reshape(b, nc, qc, h, hd), 1, 0)

    def body(_, xs):
        idx, q_blk = xs
        return None, one(idx, q_blk)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nc), q_blocks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)


def attention(
    p, x, cfg, *,
    cos_sin=None,
    causal=True,
    window=None,
    cache=None,
    kv=None,
):
    """Returns (y, aux).

    * train/prefill: ``cache=None``, x (B,S,d); aux = (k_roped, v) so prefill
      can materialize caches without recomputing projections.
    * decode: ``cache`` holds T_max keys, x is (B,1,d) at position
      ``cache['len']``; aux = updated cache.
    * cross-attention: ``kv = (k, v)`` precomputed encoder states; aux = None.
    """
    b, s, _ = x.shape
    q, k_new, v_new = _qkv(p, x, cfg)
    chunked = False
    if kv is not None:
        k, v = kv
        if cos_sin is not None:
            q = apply_rope(q, *cos_sin)
        bias = jnp.zeros((s, k.shape[1]), jnp.float32)
        new_cache = None
    elif cache is None:
        if cos_sin is not None:
            q = apply_rope(q, *cos_sin)
            k_new = apply_rope(k_new, *cos_sin)
        k, v = k_new, v_new
        qc = resolve_q_chunk(cfg, s)
        chunked = bool(qc)
        if not chunked:
            bias = _mask_bias(s, s, causal=causal, window=window, offset=0)
        new_cache = (k, v)
    else:
        # single-token decode against a ring/linear cache
        pos = cache["len"]
        if cos_sin is not None:
            q = apply_rope(q, *cos_sin)
            k_new = apply_rope(k_new, *cos_sin)
        t_max = cache["k"].shape[2]
        slot = pos % t_max if window is not None else pos
        k_cache = jax.lax.dynamic_update_index_in_dim(
            cache["k"], jnp.moveaxis(k_new, 1, 2)[:, :, 0], slot, axis=2
        )
        v_cache = jax.lax.dynamic_update_index_in_dim(
            cache["v"], jnp.moveaxis(v_new, 1, 2)[:, :, 0], slot, axis=2
        )
        k = jnp.moveaxis(k_cache, 2, 1)      # (B, T, KH, hd)
        v = jnp.moveaxis(v_cache, 2, 1)
        jk = jnp.arange(t_max)[None, :]
        if window is not None:
            ok = (jk <= pos) | (pos >= t_max)    # ring: all slots live once full
        else:
            ok = jk <= pos
        bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[0]
        bias = jnp.broadcast_to(bias, (s, t_max))
        new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}

    if chunked:
        y = _attend_chunked(q, k, v, cfg, causal=causal, window=window, qc=qc)
    else:
        y = _attend_full(q, k, v, cfg, bias)
    y = constrain(y, "batch", None, "model", None)
    y = y.reshape(b, s, -1) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None, d_model: int | None = None):
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(ff)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wg": (jax.random.normal(ks[0], (d, ff)) * scale_in).astype(dt),
            "wu": (jax.random.normal(ks[1], (d, ff)) * scale_in).astype(dt),
            "wd": (jax.random.normal(ks[2], (ff, d)) * scale_out).astype(dt),
        }
    return {
        "w1": (jax.random.normal(ks[0], (d, ff)) * scale_in).astype(dt),
        "w2": (jax.random.normal(ks[1], (ff, d)) * scale_out).astype(dt),
    }


def mlp(p, x, cfg):
    if cfg.act in ("swiglu", "geglu"):
        g = x @ p["wg"]
        u = x @ p["wu"]
        g = constrain(g, "batch", None, "model")
        u = constrain(u, "batch", None, "model")
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g, approximate=True)
        return (act * u) @ p["wd"]
    h = x @ p["w1"]
    h = constrain(h, "batch", None, "model")
    if cfg.act == "gelu":
        h = jax.nn.gelu(h, approximate=False)
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.act)
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    emb = (jax.random.normal(key, (cfg.vocab, cfg.d_model)) * 0.02).astype(dt)
    p = {"tok": emb}
    if not cfg.tie_embeddings:
        p["out"] = (
            jax.random.normal(jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab))
            / math.sqrt(cfg.d_model)
        ).astype(dt)
    return p


def embed(p, tokens, cfg):
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.norm == "rmsnorm_offset":       # gemma scales embeddings by sqrt(d)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, *residual_axes(cfg))


def unembed(p, x, cfg):
    w = p["tok"].T if cfg.tie_embeddings else p["out"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.seq_parallel:
        # sequence-sharded logits: the f32 (B,S,V) buffer divides by the
        # model axis; the vocab-sharded table is gathered instead.
        return constrain(logits, "batch", "model", None)
    return constrain(logits, "batch", None, "model")


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Mean token NLL (+ z-loss for logit drift).  logits f32 (B,S,V)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    loss = nll.mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss
