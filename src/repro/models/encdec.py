"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, F, d) — see
:mod:`repro.models.frontends`.  The backbone is exact: bidirectional
encoder, causal decoder with cross-attention, GELU MLPs, parametric
LayerNorm, sinusoidal positions (the published model's learned decoder
positions are replaced by sinusoids — dry-run-equivalent shapes).

Decode shapes exercise the *decoder* (self-attn KV cache + precomputed
cross-attention KV) — the encoder has no decode step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .sharding import constrain

__all__ = [
    "init", "forward", "loss_fn", "prefill", "decode_step",
    "init_decode_cache", "encode",
]


def sinusoid(s: int, d: int, offset=0, dtype=jnp.float32):
    pos = (jnp.arange(s) + offset)[:, None].astype(jnp.float32)
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_block(key, cfg, cross: bool):
    ks = jax.random.split(key, 6)
    p = {
        "attn_norm": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "mlp_norm": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg),
    }
    if cross:
        p["cross_norm"] = L.init_norm(cfg, cfg.d_model)
        p["cross"] = L.init_attention(ks[2], cfg)
    return p


def init(key, cfg):
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_block(k, cfg, cross=False))(
        jax.random.split(ks[0], cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: _init_block(k, cfg, cross=True))(
        jax.random.split(ks[1], cfg.n_layers)
    )
    return {
        "embed": L.init_embedding(ks[2], cfg),
        "enc": enc,
        "enc_norm": L.init_norm(cfg, cfg.d_model),
        "dec": dec,
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def encode(params, frames, cfg):
    """frames (B, F, d) — stubbed conv-frontend output — → encoder states."""
    b, f, d = frames.shape
    x = frames + sinusoid(f, d, dtype=frames.dtype)[None]
    x = constrain(x, "batch", None, None)

    def body(h, lp):
        a = L.apply_norm(lp["attn_norm"], h, cfg)
        a, _ = L.attention(lp["attn"], a, cfg, causal=False)
        h = h + a
        m = L.mlp(lp["mlp"], L.apply_norm(lp["mlp_norm"], h, cfg), cfg)
        h = constrain(h + m, *L.residual_axes(cfg))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, _ = L.scan_or_unroll(body, x, params["enc"], cfg)
    return L.apply_norm(params["enc_norm"], x, cfg)


def _cross_kv(lp, enc_out, cfg):
    """Precompute one decoder layer's cross-attention K/V."""
    kh, hd = cfg.n_kv_heads, cfg.d_head
    b, f, _ = enc_out.shape
    k = enc_out @ lp["cross"]["wk"]
    v = enc_out @ lp["cross"]["wv"]
    if cfg.attn_bias:
        k = k + lp["cross"]["bk"]
        v = v + lp["cross"]["bv"]
    return k.reshape(b, f, kh, hd), v.reshape(b, f, kh, hd)


def _dec_block(lp, h, cfg, enc_kv, cache, offset):
    s = h.shape[1]
    a = L.apply_norm(lp["attn_norm"], h, cfg)
    a, aux = L.attention(lp["attn"], a, cfg, causal=True, cache=cache)
    h = h + a
    c = L.apply_norm(lp["cross_norm"], h, cfg)
    c, _ = L.attention(lp["cross"], c, cfg, causal=False, kv=enc_kv)
    h = h + c
    m = L.mlp(lp["mlp"], L.apply_norm(lp["mlp_norm"], h, cfg), cfg)
    h = constrain(h + m, *L.residual_axes(cfg))
    return h, aux


def forward(params, tokens, cfg, frames=None, enc_out=None, positions=None):
    """Teacher-forced decoder over encoder states → logits (B, S, V)."""
    del positions
    assert (frames is None) != (enc_out is None)
    if enc_out is None:
        enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    x = x + sinusoid(s, cfg.d_model, dtype=x.dtype)[None]

    def body(h, lp):
        kv = _cross_kv(lp, enc_out, cfg)
        h, _ = _dec_block(lp, h, cfg, kv, None, 0)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, _ = L.scan_or_unroll(body, x, params["dec"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg):
    logits = forward(params, batch["tokens"], cfg, frames=batch["frames"])
    return L.cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decode_cache(cfg, batch: int, s_max: int, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    kh, hd = cfg.n_kv_heads, cfg.d_head
    nl = cfg.n_layers
    return {
        "kv": {
            "k": jnp.zeros((nl, batch, kh, s_max, hd), dt),
            "v": jnp.zeros((nl, batch, kh, s_max, hd), dt),
        },
        "cross_kv": {
            "k": jnp.zeros((nl, batch, cfg.enc_frames, kh, hd), dt),
            "v": jnp.zeros((nl, batch, cfg.enc_frames, kh, hd), dt),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, tokens, cfg, frames=None, s_max=None, positions=None):
    del positions
    b, s = tokens.shape
    s_max = s_max or s
    enc_out = encode(params, frames, cfg)
    x = L.embed(params["embed"], tokens, cfg)
    x = x + sinusoid(s, cfg.d_model, dtype=x.dtype)[None]

    def body(h, lp):
        kv = _cross_kv(lp, enc_out, cfg)
        h, (k, v) = _dec_block(lp, h, cfg, kv, None, 0)
        pad = s_max - s
        k = jnp.pad(jnp.moveaxis(k, 1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(jnp.moveaxis(v, 1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h, ({"k": k, "v": v}, {"k": kv[0], "v": kv[1]})

    if cfg.remat:
        body = jax.checkpoint(body, policy=L.remat_policy())
    x, (self_kv, cross_kv) = L.scan_or_unroll(body, x, params["dec"], cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, {"kv": self_kv, "cross_kv": cross_kv, "len": jnp.asarray(s, jnp.int32)}


def decode_step(params, cache, token, cfg):
    b = token.shape[0]
    x = L.embed(params["embed"], token, cfg)
    pos_len = cache["len"]
    x = x + sinusoid(1, cfg.d_model, offset=pos_len, dtype=x.dtype)[None]

    def body(h, slices):
        lp, kv, ckv = slices
        sub_cache = {"k": kv["k"], "v": kv["v"], "len": pos_len}
        h, nc = _dec_block(lp, h, cfg, (ckv["k"], ckv["v"]), sub_cache, pos_len)
        return h, {"k": nc["k"], "v": nc["v"]}

    x, new_kv = L.scan_or_unroll(body, x, (params["dec"], cache["kv"], cache["cross_kv"]), cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"kv": new_kv, "cross_kv": cache["cross_kv"], "len": pos_len + 1}
