"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``cfg.attn_every`` layers (arXiv:2411.15242).

The shared block's parameters exist once (the Zamba trick — attention
quality at ~1/13th of the attention parameter cost); each of its
``n_units`` applications keeps its own KV cache.  Deviation from the
published model: the shared block attends over the hidden state x rather
than concat(x, x_embed) (DESIGN.md §6 note).

Structure: n_units = n_layers // attn_every scanned units of
(attn_every mamba layers → shared attn block), then a tail of
n_layers % attn_every mamba layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .sharding import constrain

__all__ = [
    "init", "forward", "loss_fn", "prefill", "decode_step", "init_decode_cache",
]


def _unit_counts(cfg):
    n_units = cfg.n_layers // cfg.attn_every
    n_tail = cfg.n_layers - n_units * cfg.attn_every
    return n_units, n_tail


def init(key, cfg):
    n_units, n_tail = _unit_counts(cfg)
    ks = jax.random.split(key, 5)

    def one_mamba(k):
        kn, kb = jax.random.split(k)
        return {
            "norm": L.init_norm(cfg, cfg.d_model),
            "block": S.init_mamba_block(kb, cfg),
        }

    def unit(k):
        return jax.vmap(one_mamba)(jax.random.split(k, cfg.attn_every))

    units = jax.vmap(unit)(jax.random.split(ks[0], n_units))
    tail = (
        jax.vmap(one_mamba)(jax.random.split(ks[1], n_tail))
        if n_tail else None
    )
    shared = {
        "attn_norm": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(ks[2], cfg),
        "mlp_norm": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(ks[3], cfg),
    }
    return {
        "embed": L.init_embedding(ks[4], cfg),
        "units": units,            # stacked (n_units, attn_every, ...)
        "tail": tail,              # stacked (n_tail, ...) or None
        "shared": shared,          # single copy
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def _mamba_sublayer(lp, h, cfg, state=None):
    y, carry = (
        S.mamba_block(lp["block"], L.apply_norm(lp["norm"], h, cfg), cfg)
        if state is None
        else S.mamba_step(lp["block"], L.apply_norm(lp["norm"], h, cfg), cfg, state)
    )
    return constrain(h + y, "batch", None, None), carry


def _shared_block(sp, h, cfg, cos_sin, cache):
    a = L.apply_norm(sp["attn_norm"], h, cfg)
    a, aux = L.attention(sp["attn"], a, cfg, cos_sin=cos_sin, causal=True, cache=cache)
    h = h + a
    m = L.mlp(sp["mlp"], L.apply_norm(sp["mlp_norm"], h, cfg), cfg)
    h = constrain(h + m, "batch", None, None)
    return h, aux


def _mamba_scan_train(stacked, h, cfg):
    def body(hh, lp):
        hh, _ = _mamba_sublayer(lp, hh, cfg)
        return hh, None

    h, _ = L.scan_or_unroll(body, h, stacked, cfg)
    return h


def forward(params, tokens, cfg, positions=None):
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    pos = positions if positions is not None else jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s)
    )
    cos_sin = L.rope_cos_sin(pos, cfg.d_head, cfg.rope_theta)
    shared = params["shared"]

    def unit_body(h, unit_params):
        h = _mamba_scan_train(unit_params, h, cfg)
        h, _ = _shared_block(shared, h, cfg, cos_sin, None)
        return h, None

    if cfg.remat:
        unit_body = jax.checkpoint(unit_body, policy=L.remat_policy())
    x, _ = L.scan_or_unroll(unit_body, x, params["units"], cfg)
    if params["tail"] is not None:
        x = _mamba_scan_train(params["tail"], x, cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, batch, cfg):
    return L.cross_entropy(forward(params, batch["tokens"], cfg), batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decode_cache(cfg, batch: int, s_max: int, dtype=None):
    n_units, n_tail = _unit_counts(cfg)
    dt = jnp.dtype(dtype or cfg.dtype)
    kh, hd = cfg.n_kv_heads, cfg.d_head
    return {
        "unit_states": S.init_ssm_state(cfg, batch, n_units * cfg.attn_every)
        if n_units else None,
        "tail_states": S.init_ssm_state(cfg, batch, n_tail) if n_tail else None,
        "kv": {
            "k": jnp.zeros((n_units, batch, kh, s_max, hd), dt),
            "v": jnp.zeros((n_units, batch, kh, s_max, hd), dt),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def _reshape_unit_states(st, n_units, attn_every):
    return jax.tree.map(
        lambda a: a.reshape((n_units, attn_every) + a.shape[1:]), st
    )


def prefill(params, tokens, cfg, positions=None, s_max: int | None = None):
    b, s = tokens.shape
    s_max = s_max or s
    x = L.embed(params["embed"], tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cos_sin = L.rope_cos_sin(pos, cfg.d_head, cfg.rope_theta)
    shared = params["shared"]
    n_units, n_tail = _unit_counts(cfg)

    def mamba_scan_state(stacked, h):
        def body(hh, lp):
            hh, (st, (cx, cbc)) = _mamba_sublayer(lp, hh, cfg)
            return hh, {"ssm": st, "conv_x": cx, "conv_bc": cbc}

        return L.scan_or_unroll(body, h, stacked, cfg)

    def unit_body(h, unit_params):
        h, states = mamba_scan_state(unit_params, h)
        h, (k, v) = _shared_block(shared, h, cfg, cos_sin, None)
        pad = s_max - s
        k = jnp.pad(jnp.moveaxis(k, 1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(jnp.moveaxis(v, 1, 2), ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h, (states, {"k": k, "v": v})

    if cfg.remat:
        unit_body = jax.checkpoint(unit_body, policy=L.remat_policy())
    x, (unit_states, kv) = L.scan_or_unroll(unit_body, x, params["units"], cfg)
    tail_states = None
    if params["tail"] is not None:
        x, tail_states = mamba_scan_state(params["tail"], x)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)[:, 0]
    flat_unit_states = jax.tree.map(
        lambda a: a.reshape((n_units * cfg.attn_every,) + a.shape[2:]), unit_states
    )
    return logits, {
        "unit_states": flat_unit_states if n_units else None,
        "tail_states": tail_states,
        "kv": kv,
        "len": jnp.asarray(s, jnp.int32),
    }


def decode_step(params, cache, token, cfg):
    b = token.shape[0]
    x = L.embed(params["embed"], token, cfg)
    pos_len = cache["len"]
    pos = jnp.broadcast_to(pos_len[None, None], (b, 1)).astype(jnp.int32)
    cos_sin = L.rope_cos_sin(pos, cfg.d_head, cfg.rope_theta)
    shared = params["shared"]
    n_units, n_tail = _unit_counts(cfg)
    unit_states = _reshape_unit_states(cache["unit_states"], n_units, cfg.attn_every)

    def unit_body(h, slices):
        unit_params, states, kv = slices

        def inner(hh, inner_slices):
            lp, st = inner_slices
            hh, new_st = _mamba_sublayer(lp, hh, cfg, st)
            return hh, new_st

        h, new_states = L.scan_or_unroll(inner, h, (unit_params, states), cfg)
        sub_cache = {"k": kv["k"], "v": kv["v"], "len": pos_len}
        h, nc = _shared_block(shared, h, cfg, cos_sin, sub_cache)
        return h, (new_states, {"k": nc["k"], "v": nc["v"]})

    x, (new_unit_states, new_kv) = L.scan_or_unroll(
        unit_body, x, (params["units"], unit_states, cache["kv"]), cfg
    )
    new_tail = None
    if params["tail"] is not None:
        def inner(hh, inner_slices):
            lp, st = inner_slices
            hh, new_st = _mamba_sublayer(lp, hh, cfg, st)
            return hh, new_st

        x, new_tail = L.scan_or_unroll(inner, x, (params["tail"], cache["tail_states"]), cfg)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    flat_states = jax.tree.map(
        lambda a: a.reshape((n_units * cfg.attn_every,) + a.shape[2:]),
        new_unit_states,
    )
    return logits, {
        "unit_states": flat_states,
        "tail_states": new_tail,
        "kv": new_kv,
        "len": pos_len + 1,
    }
