"""Thin shim — logic lives in :mod:`repro.bench.cases.training` and is
registered as the ``training`` bench case (``python -m repro.bench run``),
hard-gating the closed training loop: one dispatch per warm train step
(PowerSGD + OrthoSGD with their orthogonalization collectives traced
inline), zero retraces across an elastic shrink→rebuild cycle, loss parity
with the dense non-FT baseline, and survivor/recovery counts for the model
zoo under the cascading and BLANK-under-repeat schedules.

Run with ``PYTHONPATH=src`` (needs ≥ 4 devices; the bench CLI forces 8)."""
import os
import sys

if "jax" not in sys.modules:           # must precede the first jax import
    flag = "--xla_force_host_platform_device_count=8"
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()

from repro.bench.cases.training import PARITY_TOL, case  # noqa: E402,F401

if __name__ == "__main__":
    for name, metric in case().items():
        print(f"{name}: {metric.value}{metric.unit or ''}")
