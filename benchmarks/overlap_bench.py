"""Thin shim — logic lives in :mod:`repro.bench.cases.overlap` and is
registered as the ``overlap`` bench case (``python -m repro.bench run``),
hard-gating the one-butterfly-per-panel claims: fused panel reductions
spend exactly ``K·log2 P`` collective rounds (vs the two-butterfly
driver's ``(2K−1)·log2 P``), the stacked wire bytes match
``Plan.bytes_on_wire_stacked`` to the byte, all ``K−1`` steady-state
panels overlap their reduction with the previous trailing sweep, and the
fused pipeline stays one zero-retrace device program bit-compatible with
the eager two-butterfly driver.

Run with ``PYTHONPATH=src`` for the standalone numbers."""
from repro.bench.cases.overlap import case, main, run  # noqa: F401

if __name__ == "__main__":
    raise SystemExit(main())
