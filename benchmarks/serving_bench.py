"""Shim — the serving benchmark lives in :mod:`repro.bench.cases.serving`.

QR-as-a-service over shape-bucketed continuous batching: sustained
mixed-shape throughput, p50/p99 latency, one batched dispatch per drained
bucket, zero warm retraces after pre-warm, and bitwise fault re-serve.
Run the gated version via ``python -m repro.bench run --case serving``.
"""
from repro.bench.cases.serving import case, main, run  # noqa: F401

if __name__ == "__main__":
    raise SystemExit(main())
