"""Thin shim — logic migrated to :mod:`repro.bench.cases.tsqr_scaling` and
registered as the ``tsqr_scaling`` + ``tsqr_local_qr`` bench cases
(``python -m repro.bench run``).  Run with ``PYTHONPATH=src`` for the
standalone CSV table."""
from repro.bench.cases.tsqr_scaling import (  # noqa: F401
    bench_one,
    case_local_qr,
    case_scaling,
    main,
)

if __name__ == "__main__":
    main()
