"""TSQR wall-clock microbenchmark (CPU, SimComm backend): variant × P ×
local-QR implementation.  The absolute numbers are CPU-simulation times;
the *relative* cost of redundancy (redundant ≈ tree despite 2× messages —
extra QRs land on otherwise-idle ranks) is the paper's Fig. 1/2 story."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tsqr_sim
from repro.core import ref


def bench_one(variant: str, p: int, m_loc: int, n: int, local_qr: str,
              iters: int = 5) -> float:
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(ref.random_tall_skinny(rng, p, m_loc, n))
    fn = jax.jit(lambda a: tsqr_sim(a, variant=variant, local_qr=local_qr).r)
    fn(blocks).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(blocks).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    print("# tsqr scaling (SimComm on CPU): us_per_call")
    print("variant,P,m_local,n,local_qr,us_per_call")
    rows = []
    for p in (4, 16, 64):
        for variant in ("tree", "redundant"):
            us = bench_one(variant, p, 256, 32, "jnp")
            rows.append((variant, p, 256, 32, "jnp", us))
            print(f"{variant},{p},256,32,jnp,{us:.0f}")
    for lq in ("jnp", "cqr2", "cqr2_pallas"):
        us = bench_one("redundant", 16, 512, 64, lq)
        rows.append(("redundant", 16, 512, 64, lq, us))
        print(f"redundant,16,512,64,{lq},{us:.0f}")
    return rows


if __name__ == "__main__":
    main()
