"""Thin shim — logic lives in :mod:`repro.bench.cases.dispatch` and is
registered as the ``dispatch`` bench case (``python -m repro.bench run``),
hard-gating the single-program blocked-QR claims: 1 trace after a repeat
call, 1 device dispatch per factorization independent of the panel count,
1 dispatch for a B-matrix batch, and bit-identity to the eager driver.

Run with ``PYTHONPATH=src`` for the standalone numbers, or with ``--guard``
for the CI tier-1 retrace guard (exits non-zero if any guarded entry point
re-traces on a second call with identical shapes)."""
import os
import sys

if "jax" not in sys.modules:           # must precede the first jax import
    flag = "--xla_force_host_platform_device_count=8"
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()

from repro.bench.cases.dispatch import case, guard, main, run  # noqa: E402,F401

if __name__ == "__main__":
    raise SystemExit(main())
