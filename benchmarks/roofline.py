"""Thin shim — logic migrated to :mod:`repro.bench.cases.roofline` and
registered as the ``roofline`` bench case (``python -m repro.bench run``;
skips cleanly when no dry-run artifacts exist).  Run with
``PYTHONPATH=src`` for the standalone CSV + markdown table."""
from repro.bench.cases.roofline import (  # noqa: F401
    advice,
    analyze_record,
    case,
    cqr2_rows,
    load_all,
    main,
    markdown_table,
    model_flops,
    structural_memory_bytes,
)

if __name__ == "__main__":
    main()
