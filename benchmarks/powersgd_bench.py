"""PowerSGD-TSQR gradient compression: bytes over the data axis vs dense
all-reduce, and reconstruction quality vs rank (the paper-integration
benchmark, DESIGN.md §3.1)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import SimComm
from repro.optim import powersgd


def _psum_id(x):
    return x


def _psum_model(x):
    return jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)


def run():
    key = jax.random.key(0)
    p_model, m_loc, n = 8, 256, 1024          # a (2048 x 1024) sharded grad
    rows = []
    # synthetic gradient with decaying spectrum (realistic for LM grads)
    u, _ = np.linalg.qr(np.random.default_rng(0).standard_normal((p_model * m_loc, 256)))
    v, _ = np.linalg.qr(np.random.default_rng(1).standard_normal((n, 256)))
    sv = np.logspace(0, -3, 256)
    g = jnp.asarray((u * sv) @ v.T, jnp.float32).reshape(p_model, m_loc, n)
    g_norm = float(jnp.linalg.norm(g))
    comm = SimComm(p_model)
    for rank in (2, 8, 32, 128):
        cfg = powersgd.PowerSGDConfig(rank=rank, error_feedback=False)
        state = powersgd.init_state(key, (m_loc, n), cfg, leading=(p_model,))
        fn = jax.jit(lambda gg, st: powersgd.compress_grad(
            gg, st, comm, cfg=cfg, psum_data=_psum_id,
            psum_model=_psum_model, n_data=1)[:2])
        (g_hat, state) = fn(g, state)
        # one power-iteration refinement (warm basis), as in training
        (g_hat, state) = fn(g, state)
        jax.block_until_ready(g_hat)
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(g, state)
            jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 3 * 1e6
        err = float(jnp.linalg.norm(g - g_hat)) / g_norm
        dense = 4 * p_model * m_loc * n
        comp = 4 * rank * (p_model * m_loc + n)
        rows.append({
            "rank": rank, "rel_error": err,
            "bytes_dense": dense, "bytes_compressed": comp,
            "compression_x": dense / comp, "us_per_call": us,
        })
    return rows


def main():
    print("# powersgd-tsqr: data-axis bytes + reconstruction vs rank")
    print("rank,rel_error,bytes_dense,bytes_compressed,compression_x,us_per_call")
    for r in run():
        print(f"{r['rank']},{r['rel_error']:.4f},{r['bytes_dense']},"
              f"{r['bytes_compressed']},{r['compression_x']:.1f},"
              f"{r['us_per_call']:.0f}")
    return run


if __name__ == "__main__":
    main()
