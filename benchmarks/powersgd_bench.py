"""Thin shim — logic migrated to :mod:`repro.bench.cases.powersgd` and
registered as the ``powersgd`` bench case (``python -m repro.bench run``).
Run with ``PYTHONPATH=src`` for the standalone CSV table."""
from repro.bench.cases.powersgd import case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
