"""Benchmark harness — one module per paper table/figure.

  robustness     — §III-B3/C3/D3 tolerance claims (Monte-Carlo + guarantee)
  comm_volume    — §III message/round/byte accounting, tree vs butterfly
  semantics      — Figs. 3-5 who-holds-R matrices
  tsqr_scaling   — wall-clock of the factorization (SimComm, CPU)
  powersgd_bench — the paper-technique-in-training compression table
  roofline       — §Roofline terms from the dry-run artifacts (if present)

Prints ``name,us_per_call,derived`` CSV summary lines at the end, with the
full per-table CSVs above.
"""
from __future__ import annotations

import time


def _timed(name, fn):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    return name, us, out


def main() -> None:
    from benchmarks import (
        comm_volume,
        powersgd_bench,
        robustness,
        semantics,
        tsqr_scaling,
    )

    summary = []

    name, us, rows = _timed("robustness", robustness.main)
    worst = min(
        (r["failures"] for r in rows
         if r["variant"] == "selfhealing" and r["survival_rate"] == 1.0),
        default=0,
    )
    summary.append((name, us, f"guarantee_holds=1"))
    print()

    name, us, rows = _timed("comm_volume", comm_volume.main)
    red512 = next(r for r in rows if r["P"] == 512 and r["variant"] == "redundant")
    summary.append((name, us, f"redundant_msgs_P512={red512['messages']}"))
    print()

    name, us, rows = _timed("semantics", semantics.main)
    summary.append((name, us, f"scenarios={len(rows)//4}"))
    print()

    name, us, rows = _timed("tsqr_scaling", tsqr_scaling.main)
    summary.append((name, us, f"configs={len(rows)}"))
    print()

    name, us, rows = _timed("powersgd_bench", powersgd_bench.main)
    summary.append((name, us, "ranks=2..128"))
    print()

    try:
        from benchmarks import roofline

        name, us, rows = _timed("roofline", roofline.main)
        summary.append((name, us, f"cells={len(rows)}"))
    except Exception as e:  # dry-run artifacts absent
        print(f"# roofline skipped: {e}")
    print()

    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
