"""CLI shim over ``python -m repro.bench`` — the benchmark harness proper
lives in :mod:`repro.bench` (registry + runner + JSON schema + baseline
comparator; see DESIGN.md §5).

``python benchmarks/run.py`` ≡ ``python -m repro.bench run`` and accepts
the same flags (``--tier``, ``--only``, ``--out``, ...).  The old ad-hoc
CSV summary — including the bug where the computed worst-case
tolerated-failure count was dropped in favor of a hardcoded
``guarantee_holds=1`` string — is gone: robustness numbers are now gated
metrics in the emitted ``BENCH_*.json``, and a guarantee violation fails
the run (see ``repro.bench.cases.robustness``).
"""
import sys

from repro.bench.__main__ import main

if __name__ == "__main__":
    sys.exit(main(["run", *sys.argv[1:]]))
