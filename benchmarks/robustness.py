"""Thin shim — logic migrated to :mod:`repro.bench.cases.robustness` and
registered as the ``robustness`` bench case (``python -m repro.bench run``).
Run with ``PYTHONPATH=src`` for the standalone CSV table."""
from repro.bench.cases.robustness import case, main, run, survival  # noqa: F401

if __name__ == "__main__":
    main()
