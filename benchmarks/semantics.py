"""Thin shim — logic migrated to :mod:`repro.bench.cases.semantics` and
registered as the ``semantics`` bench case (``python -m repro.bench run``).
Run with ``PYTHONPATH=src`` for the standalone CSV table."""
from repro.bench.cases.semantics import SCENARIOS, case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
