"""Thin shim over :mod:`repro.bench.cases.autotune` (kept for muscle
memory: ``PYTHONPATH=src python benchmarks/autotune_bench.py``)."""
from repro.bench.cases.autotune import case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
