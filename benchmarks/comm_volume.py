"""Paper §III — communication accounting: baseline TSQR vs the redundant
variants.  The paper's core claim quantified: the butterfly doubles message
*count* but (a) the exchanges are full-duplex pairs (same serial rounds =
same latency on full-duplex ICI) and (b) buys 2^s-copy redundancy.
Also reports the failure-time overhead of Replace (extra serial rounds when
replicas multicast) and Self-Healing (restore transfers)."""
from __future__ import annotations

import numpy as np

from repro.core import FaultSpec, make_plan


def run(n_cols: int = 32, itemsize: int = 4):
    rows = []
    for p in (4, 16, 64, 256, 512):
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            plan = make_plan(variant, p)
            rows.append({
                "P": p, "variant": variant, "failures": 0,
                "messages": plan.message_count(),
                "rounds": plan.round_count(),
                "bytes": plan.bytes_on_wire(n_cols, itemsize),
            })
    # failure-time behavior at P=16: kill 3 ranks within tolerance
    spec = FaultSpec.of({3: 1, 9: 2, 12: 2})
    for variant in ("redundant", "replace", "selfhealing"):
        plan = make_plan(variant, 16, spec)
        rows.append({
            "P": 16, "variant": variant, "failures": 3,
            "messages": plan.message_count(),
            "rounds": plan.round_count(),
            "bytes": plan.bytes_on_wire(n_cols, itemsize),
        })
    return rows


def main():
    print("# comm volume: messages / serial rounds / bytes (n=32, f32)")
    print("P,variant,failures,messages,rounds,bytes")
    for r in run():
        print(f"{r['P']},{r['variant']},{r['failures']},{r['messages']},"
              f"{r['rounds']},{r['bytes']}")
    # structural claims from the paper, asserted
    for p in (16, 256):
        tree = make_plan("tree", p)
        red = make_plan("redundant", p)
        assert red.message_count() == p * int(np.log2(p))
        assert tree.message_count() == p - 1
        assert red.round_count() == tree.round_count()   # wire-latency-neutral
    return run()


if __name__ == "__main__":
    main()
