"""Thin shim — logic migrated to :mod:`repro.bench.cases.comm_volume` and
registered as the ``comm_volume`` bench case (``python -m repro.bench run``).
Run with ``PYTHONPATH=src`` for the standalone CSV table."""
from repro.bench.cases.comm_volume import case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
