"""Paper §III — communication accounting: baseline TSQR vs the redundant
variants, now reported per combiner.  The paper's core claim quantified:
the butterfly doubles message *count* but (a) the exchanges are full-duplex
pairs (same serial rounds = same latency on full-duplex ICI) and (b) buys
2^s-copy redundancy.  Also reports the failure-time overhead of Replace
(extra serial rounds when replicas multicast) and Self-Healing (restore
transfers).

Wire volume depends on the combiner's payload: ``qr_combine`` ships square
(n, n) R factors; ``gram_sum`` payloads are symmetric, so the packed
n(n+1)/2 encoding applies — both numbers are reported (``bytes`` square,
``bytes_packed`` symmetric), quantifying the saving the Gram butterfly
leaves on the table when shipping square."""
from __future__ import annotations

import numpy as np

from repro.collective import COMBINERS, FaultSpec, get_combiner, make_plan

# Combiners whose wire volume we report (ft_allreduce ops + the TSQR combine).
_OPS = ("qr_combine", "sum", "mean", "max", "gram_sum")


def _row(p, variant, failures, plan, op, n_cols, itemsize):
    comb = get_combiner(op)
    sq = plan.bytes_on_wire(n_cols, itemsize)
    packed = plan.bytes_on_wire(n_cols, itemsize, symmetric=True)
    return {
        "P": p, "variant": variant, "failures": failures, "combiner": comb.name,
        "messages": plan.message_count(),
        "rounds": plan.round_count(),
        "bytes": sq,
        # symmetric payloads (gram_sum) can ship packed; square ones cannot
        "bytes_packed": packed if comb.wire_symmetric else sq,
    }


def run(n_cols: int = 32, itemsize: int = 4, ops=_OPS):
    rows = []
    for p in (4, 16, 64, 256, 512):
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            plan = make_plan(variant, p)
            for op in ops:
                rows.append(_row(p, variant, 0, plan, op, n_cols, itemsize))
    # failure-time behavior at P=16: kill 3 ranks within tolerance
    spec = FaultSpec.of({3: 1, 9: 2, 12: 2})
    for variant in ("redundant", "replace", "selfhealing"):
        plan = make_plan(variant, 16, spec)
        for op in ops:
            rows.append(_row(16, variant, 3, plan, op, n_cols, itemsize))
    return rows


def main():
    print("# comm volume per combiner: messages / serial rounds / bytes "
          "(n=32, f32; bytes_packed = symmetric n(n+1)/2 encoding)")
    print("P,variant,failures,combiner,messages,rounds,bytes,bytes_packed")
    for r in run():
        print(f"{r['P']},{r['variant']},{r['failures']},{r['combiner']},"
              f"{r['messages']},{r['rounds']},{r['bytes']},{r['bytes_packed']}")
    # structural claims from the paper, asserted
    for p in (16, 256):
        tree = make_plan("tree", p)
        red = make_plan("redundant", p)
        assert red.message_count() == p * int(np.log2(p))
        assert tree.message_count() == p - 1
        assert red.round_count() == tree.round_count()   # wire-latency-neutral
    # packed-symmetric accounting: n(n+1)/2 vs n² for the Gram butterfly
    n = 32
    plan = make_plan("redundant", 16)
    assert plan.bytes_on_wire(n, symmetric=True) * (2 * n) \
        == plan.bytes_on_wire(n) * (n + 1)
    assert get_combiner("gram_sum").wire_symmetric
    assert not get_combiner("qr_combine").wire_symmetric
    assert set(_OPS) <= set(COMBINERS)
    return run()


if __name__ == "__main__":
    main()
