"""Thin shim — logic lives in :mod:`repro.bench.cases.kernels` and is
registered as the ``kernels`` bench case (``python -m repro.bench run``),
hard-gating the fused CQR2 pipeline's 2-sweep HBM claim.  Run with
``PYTHONPATH=src`` for the standalone CSV."""
from repro.bench.cases.kernels import case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
