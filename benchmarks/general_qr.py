"""Thin shim — logic lives in :mod:`repro.bench.cases.general_qr` and is
registered as the ``general_qr`` bench case (``python -m repro.bench run``),
hard-gating the blocked-QR 1-trailing-sweep-per-panel HBM claim and the
per-variant survival guarantees.  Run with ``PYTHONPATH=src`` for the
standalone CSV."""
from repro.bench.cases.general_qr import case, main, run  # noqa: F401

if __name__ == "__main__":
    main()
