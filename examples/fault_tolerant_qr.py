"""Both QR workloads end-to-end, as an executable test: every assertion is
checked, so a silent numerical regression fails the example.

Part 1 — the paper's tall-and-skinny TSQR: all four variants under the
exact failure scenarios of Figs. 1-5, then a 16-rank stress scenario at the
tolerance boundary, printing who holds R and message/round accounting.

Part 2 — the general-matrix extension (arXiv:1604.02504): fault-tolerant
right-looking blocked QR, with deaths injected into a panel's TSQR
butterfly and into a trailing-update reduction, plus the
one-trailing-sweep-per-panel HBM model.

  PYTHONPATH=src python examples/fault_tolerant_qr.py
"""
import jax.numpy as jnp
import numpy as np

from repro.collective import FaultSpec, make_plan, total_tolerance
from repro.core import ref
from repro.kernels import traffic
from repro.qr import PanelFaultSchedule, QRConfig, factorize

VARIANTS = ("tree", "redundant", "replace", "selfhealing")


def banner(msg):
    print(f"\n=== {msg} " + "=" * max(0, 60 - len(msg)))


def run(p, spec, blocks, truth):
    for variant in VARIANTS:
        plan = make_plan(variant, p, spec)
        res = factorize(
            jnp.asarray(blocks), QRConfig(variant=variant), faults=spec
        )
        valid = np.asarray(res.valid)
        ok = all(
            np.allclose(np.asarray(res.r)[r], truth, atol=1e-3)
            for r in np.nonzero(valid)[0]
        )
        print(f"  {variant:12s} holders={''.join('1' if v else '0' for v in valid)}"
          f"  msgs={plan.message_count():4d} rounds={plan.round_count()}"
          f"  correct={ok}")
        assert ok, f"{variant}: a holder's R deviates from the oracle"


def tall_skinny():
    rng = np.random.default_rng(1)

    banner("Fig 1/2: fault-free, P=4")
    blocks = ref.random_tall_skinny(rng, 4, 64, 8)
    truth = ref.qr_r(blocks.reshape(-1, 8).astype(np.float64)).astype(np.float32)
    run(4, FaultSpec.none(), blocks, truth)

    banner("Figs 3-5: P2 dies at entry of exchange 1, P=4")
    run(4, FaultSpec.of({2: 1}), blocks, truth)

    banner("P=16: cascade finding — data copies exist, Redundant still dies")
    # These 7 failures satisfy the paper's cumulative 2^s-1 data-copy count
    # (1 by exchange 1, 3 by ex.2, 7 by ex.3), yet Redundant TSQR loses all
    # ranks: a rank dead at exchange k invalidates its whole dependency
    # coset (2^-k of the machine), and these cosets cover everything
    # (measure 1/2 + 2/4 + 4/8 = 1.5 >= 1).  Replace reroutes to replicas
    # and keeps every live rank valid; Self-Healing restores all 16.
    # This gap is exactly why the paper introduces Replace (DESIGN.md §2).
    blocks = ref.random_tall_skinny(rng, 16, 64, 8)
    truth = ref.qr_r(blocks.reshape(-1, 8).astype(np.float64)).astype(np.float32)
    # 1 failure by exchange 1, 2 more by exchange 2, 4 more by exchange 3
    spec = FaultSpec.from_events({1: [3], 2: [8, 12], 3: [1, 6, 10, 14]})
    print(f"  injected failures: {spec.n_failures} "
          f"(selfhealing total tolerance: {total_tolerance('selfhealing', 4)})")
    run(16, spec, blocks, truth)

    banner("Q factor via self-healing under failures")
    res = factorize(
        jnp.asarray(blocks),
        QRConfig(variant="selfhealing", compute_q=True),
        faults=spec,
    )
    q = np.asarray(res.q).reshape(-1, 8)
    ortho = np.abs(q.T @ q - np.eye(8)).max()
    recon = np.abs(q @ np.asarray(res.r)[0] - blocks.reshape(-1, 8)).max()
    print(f"  ||QtQ - I||_max = {ortho:.2e}")
    print(f"  ||QR - A||_max  = {recon:.2e}")
    assert ortho < 1e-4, "TSQR Q lost orthogonality"
    assert recon < 1e-3, "TSQR QR does not reconstruct A"


def general_matrix():
    rng = np.random.default_rng(2)
    p, m_local, n, pw = 8, 96, 48, 16
    blocks = rng.standard_normal((p, m_local, n)).astype(np.float32)
    a = jnp.asarray(blocks)
    dense = blocks.reshape(-1, n)
    truth = ref.qr_r(dense.astype(np.float64))
    scale = np.abs(truth).max()

    banner(f"General matrix {p * m_local}x{n}, panel width {pw}: fault-free")
    with traffic.track_traffic() as t:
        res = factorize(a, QRConfig(panel_width=pw, compute_q=True))
    sweeps = t.sweeps_of("panel_cross", "trailing_update")
    r_err = np.abs(np.asarray(res.r)[0] - truth).max() / scale
    q = np.asarray(res.q).reshape(-1, n)
    recon = np.abs(q @ np.asarray(res.r)[0] - dense).max() / scale
    ortho = np.abs(q.T @ q - np.eye(n)).max()
    print(f"  panels={res.n_panels}  trailing-block sweeps={sweeps} "
          f"(1 per panel)")
    print(f"  ||R - R_ref|| / ||R_ref|| = {r_err:.2e}")
    print(f"  ||QR - A|| / ||R_ref||    = {recon:.2e}   "
          f"||QtQ - I||_max = {ortho:.2e}")
    assert sweeps == res.n_panels, "trailing block swept more than 1×/panel"
    assert r_err < 5e-4, "blocked R deviates from the dense QR"
    assert recon < 5e-4, "blocked QR does not reconstruct A"
    assert ortho < 5e-5, "blocked Q lost orthogonality"

    banner("Deaths mid-factorization: panel 1's TSQR and panel 0's update")
    sched = PanelFaultSchedule.of(panel={1: {2: 1}}, update={0: {5: 1}})
    res = factorize(
        a, QRConfig(panel_width=pw, variant="replace"), faults=sched
    )
    valid = np.asarray(res.valid)
    print("  strict survivors:",
          "".join("1" if v else "0" for v in valid),
          f" recovered={sum(r.recovered_r + r.recovered_w for r in res.reports)}")
    for rep in res.reports:
        flag = "ok" if rep.within_tolerance else "EXCEEDED"
        if rep.recovered_r or rep.recovered_w:
            print(f"  panel {rep.panel}: tolerance {flag}, "
                  f"recovered {rep.recovered_r + rep.recovered_w} rank(s) "
                  "from butterfly replicas")
    assert valid.any(), "no survivor holds R"
    # replica recovery: every rank (survivor or respawned) ends exact
    for r in range(p):
        err = np.abs(np.asarray(res.r)[r] - truth).max() / scale
        assert err < 5e-4, f"rank {r} R deviates ({err:.2e}) after recovery"
    print("  every rank's R exact after replica recovery")


def main():
    tall_skinny()
    general_matrix()
    print("\nall assertions passed")


if __name__ == "__main__":
    main()
