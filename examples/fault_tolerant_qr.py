"""The paper end-to-end: all four TSQR variants under escalating failures.

Walks the exact scenarios of Figs. 1-5, then a 16-rank stress scenario at
the tolerance boundary, printing who holds R, message/round accounting,
and (where the plan permits) the orthonormal Q factor quality.

  PYTHONPATH=src python examples/fault_tolerant_qr.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import FaultSpec, make_plan, total_tolerance, tsqr_sim
from repro.core import ref

VARIANTS = ("tree", "redundant", "replace", "selfhealing")


def banner(msg):
    print(f"\n=== {msg} " + "=" * max(0, 60 - len(msg)))


def run(p, spec, blocks, truth):
    for variant in VARIANTS:
        plan = make_plan(variant, p, spec)
        res = tsqr_sim(jnp.asarray(blocks), variant=variant, fault_spec=spec)
        valid = np.asarray(res.valid)
        ok = all(
            np.allclose(np.asarray(res.r)[r], truth, atol=1e-3)
            for r in np.nonzero(valid)[0]
        )
        print(f"  {variant:12s} holders={''.join('1' if v else '0' for v in valid)}"
          f"  msgs={plan.message_count():4d} rounds={plan.round_count()}"
          f"  correct={ok}")


def main():
    rng = np.random.default_rng(1)

    banner("Fig 1/2: fault-free, P=4")
    blocks = ref.random_tall_skinny(rng, 4, 64, 8)
    truth = ref.qr_r(blocks.reshape(-1, 8).astype(np.float64)).astype(np.float32)
    run(4, FaultSpec.none(), blocks, truth)

    banner("Figs 3-5: P2 dies at entry of exchange 1, P=4")
    run(4, FaultSpec.of({2: 1}), blocks, truth)

    banner("P=16: cascade finding — data copies exist, Redundant still dies")
    # These 7 failures satisfy the paper's cumulative 2^s-1 data-copy count
    # (1 by exchange 1, 3 by ex.2, 7 by ex.3), yet Redundant TSQR loses all
    # ranks: a rank dead at exchange k invalidates its whole dependency
    # coset (2^-k of the machine), and these cosets cover everything
    # (measure 1/2 + 2/4 + 4/8 = 1.5 >= 1).  Replace reroutes to replicas
    # and keeps every live rank valid; Self-Healing restores all 16.
    # This gap is exactly why the paper introduces Replace (DESIGN.md §2).
    blocks = ref.random_tall_skinny(rng, 16, 64, 8)
    truth = ref.qr_r(blocks.reshape(-1, 8).astype(np.float64)).astype(np.float32)
    # 1 failure by exchange 1, 2 more by exchange 2, 4 more by exchange 3
    spec = FaultSpec.from_events({1: [3], 2: [8, 12], 3: [1, 6, 10, 14]})
    print(f"  injected failures: {spec.n_failures} "
          f"(selfhealing total tolerance: {total_tolerance('selfhealing', 4)})")
    run(16, spec, blocks, truth)

    banner("Q factor via self-healing under failures")
    res = tsqr_sim(jnp.asarray(blocks), variant="selfhealing",
                   fault_spec=spec, compute_q=True)
    q = np.asarray(res.q).reshape(-1, 8)
    print(f"  ||QtQ - I||_max = {np.abs(q.T @ q - np.eye(8)).max():.2e}")
    print(f"  ||QR - A||_max  = "
          f"{np.abs(q @ np.asarray(res.r)[0] - blocks.reshape(-1, 8)).max():.2e}")


if __name__ == "__main__":
    main()
