"""PowerSGD-TSQR data-parallel training with REAL collectives.

Trains a two-layer MLP regression model under ``shard_map`` on a
(data=2 × model=4) device mesh (8 forced host devices), exchanging
gradients as rank-r factors: the left factor is orthonormalized with the
paper's fault-tolerant butterfly TSQR over the model axis, and a
mid-training simulated rank failure is absorbed by the Self-Healing
variant without interrupting the run.

Reports data-axis bytes: compressed vs dense all-reduce.

  python examples/powersgd_dp.py          # sets its own XLA_FLAGS
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                           # noqa: E402
import jax.numpy as jnp                              # noqa: E402
from jax import lax                                  # noqa: E402
from jax.sharding import PartitionSpec as P           # noqa: E402

import sys                                           # noqa: E402
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.collective import FaultSpec, ShardMapComm  # noqa: E402
from repro.compat import make_mesh, shard_map        # noqa: E402
from repro.optim import powersgd                     # noqa: E402

D, M = 2, 4                    # data x model mesh
DIN, DH, DOUT = 64, 128, 32    # w1 rows sharded over model
RANK = 8
STEPS = 80
LR = 0.3


def main():
    mesh = make_mesh((D, M), ("data", "model"))
    key = jax.random.key(0)
    w_true1 = jax.random.normal(key, (DIN, DH)) / 8
    w_true2 = jax.random.normal(jax.random.fold_in(key, 1), (DH, DOUT)) / 8

    # data: each data-replica sees its own stream
    x = jax.random.normal(jax.random.fold_in(key, 2), (D, 256, DIN))
    y = jnp.maximum(x @ w_true1, 0) @ w_true2

    # small random init (zero init would make the rank-r sketch singular:
    # QR of an all-zero P̄ has no meaning)
    w1 = jax.random.normal(jax.random.fold_in(key, 8), (DIN, DH)) * 0.05
    w2 = jax.random.normal(jax.random.fold_in(key, 9), (DH, DOUT)) * 0.05
    psgd_cfg = powersgd.PowerSGDConfig(rank=RANK, error_feedback=True,
                                       variant="selfhealing")
    q1 = jax.random.normal(jax.random.fold_in(key, 3), (DH, RANK), jnp.float32)
    e1 = jnp.zeros((DIN, DH), jnp.float32)   # sharded over model rows
    comm = ShardMapComm(M, "model")

    def loss_fn(w1_blk, w2_full, xb, yb):
        # w1 rows sharded over model: gather for the forward (toy scale)
        w1_full = lax.all_gather(w1_blk, "model", axis=0, tiled=True)
        pred = jnp.maximum(xb @ w1_full, 0) @ w2_full
        return jnp.mean((pred - yb) ** 2)

    def make_step(fault_spec):
        def step(w1_blk, w2_full, q, e, xb, yb):
            g1_blk, g2 = jax.grad(loss_fn, argnums=(0, 1))(
                w1_blk, w2_full, xb[0], yb[0])
            # dense path for w2 (small); PowerSGD-TSQR path for w1
            g2_mean = lax.pmean(g2, "data")
            state = {"q": q, "e": e}
            g1_hat, new_state, stats = powersgd.compress_grad(
                g1_blk, state, comm, cfg=psgd_cfg,
                psum_data=lambda v: lax.psum(v, "data"),
                psum_model=lambda v: lax.psum(v, "model"),
                n_data=D, fault_spec=fault_spec)
            return (w1_blk - LR * g1_hat, w2_full - LR * g2_mean,
                    new_state["q"], new_state["e"],
                    jnp.asarray(stats["data_bytes_compressed"]),
                    jnp.asarray(stats["data_bytes_dense"]))

        return jax.jit(shard_map(
            step, mesh=mesh,
            in_specs=(P("model", None), P(), P(), P("model", None),
                      P("data", None, None), P("data", None, None)),
            out_specs=(P("model", None), P(), P(), P("model", None),
                       P(), P()),
        ))

    step_ok = make_step(None)
    step_fault = make_step(FaultSpec.of({1: 1}))   # model-rank 1 dies, respawned

    losses = []
    for i in range(STEPS):
        fn = step_fault if i == STEPS // 2 else step_ok
        w1, w2, q1, e1, b_comp, b_dense = fn(w1, w2, q1, e1, x, y)
        l = float(jnp.mean((jnp.maximum(x[0] @ w1, 0) @ w2 - y[0]) ** 2))
        losses.append(l)
        if i % 10 == 0 or i == STEPS // 2:
            tag = "  <-- rank failure absorbed by self-healing TSQR" \
                if i == STEPS // 2 else ""
            print(f"step {i:3d} loss {l:.5f}{tag}")
    print(f"\nfinal loss {losses[-1]:.5f} (from {losses[0]:.5f})")
    print(f"data-axis bytes/step: compressed={int(b_comp)} "
          f"dense={int(b_dense)} ({float(b_dense)/float(b_comp):.1f}x saved)")
    assert losses[-1] < 0.25 * losses[0]


if __name__ == "__main__":
    main()
