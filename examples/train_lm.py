"""End-to-end driver: fault-tolerant training of a ~100M-param LM.

Runs the full production stack — config registry, synthetic corpus,
AdamW+ZeRO trainer, async checkpointing, diskless buddy replication, and
injected failures handled with the paper's three semantics.

Default is a CPU-sized run (~20M params, 60 steps, a failure at step 25
handled by REBUILD with rollback).  ``--hundred-m`` selects the ~100M
configuration for a few hundred steps (sized for a real accelerator).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --hundred-m --steps 300
"""
import argparse
import dataclasses

import jax

from repro.compat import make_mesh
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import FaultEvent, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--on-failure", default="rebuild",
                    choices=["blank", "shrink", "rebuild"])
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    base = get_config("qwen3-0.6b")
    if args.hundred_m:
        # ~100M params: 12 layers x d=768, ff=2048, vocab 32k
        cfg = dataclasses.replace(
            base, name="qwen3-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
            dtype="float32", remat=False,
        )
    else:
        # ~20M params: CPU-friendly end-to-end
        cfg = dataclasses.replace(
            base, name="qwen3-20m", n_layers=4, d_model=384, n_heads=6,
            n_kv_heads=2, head_dim=64, d_ff=1024, vocab=16_000,
            dtype="float32", remat=False,
        )
    n = len(jax.devices())
    mesh = make_mesh((n, 1), ("data", "model"))
    tcfg = TrainerConfig(
        steps=args.steps, log_every=5, ckpt_every=20,
        ckpt_dir="/tmp/repro_train_lm", on_failure=args.on_failure,
        lr=1e-3,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    trainer = Trainer(cfg, tcfg, mesh, dcfg)
    params, opt = trainer.init_state()
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={n} "
          f"failure-semantics={args.on_failure}")
    faults = (FaultEvent(step=min(25, args.steps // 2), kind="fail", replica=0),)
    trainer.run(params, opt, fault_schedule=faults)
    print("\nevents:")
    print("  " + "\n  ".join(trainer.events_log))
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    print(f"\nloss {first:.3f} → {last:.3f} over {len(trainer.metrics_log)} steps")


if __name__ == "__main__":
    main()
