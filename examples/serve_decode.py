"""Least-squares decode serving demo on the QR engine.

A mixed-shape stream of decode requests — solve ``min_x ||A x - b||`` for
tall ``A`` — rides the shape-bucketed :class:`repro.serve.QRServer`:
every request is padded into its bucket, batched through the single
-dispatch scan pipeline, and (optionally) struck by a mid-flight death,
in which case the whole drain is re-served through the replica-recovering
eager driver.  Each response carries the request's exact R factor, which
decodes its system through the corrected semi-normal equations
``R'R x = A'b`` (one refinement step) — no Q ever leaves the server.

  PYTHONPATH=src python examples/serve_decode.py --requests 24 --inject-fault
"""
import argparse
import time

import numpy as np

from repro.serve import BucketSpec, PeriodicFaultInjector, QRServer


def decode(a: np.ndarray, b: np.ndarray, r: np.ndarray) -> np.ndarray:
    """Corrected semi-normal equations: solve with R, refine once."""
    gram = r.T @ r
    x = np.linalg.solve(gram, a.T @ b)
    return x + np.linalg.solve(gram, a.T @ (b - a @ x))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--p", type=int, default=4,
                    help="simulated ranks per factorization")
    ap.add_argument("--inject-fault", action="store_true",
                    help="kill a rank on every 2nd drain (re-serve path)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    buckets = (BucketSpec(64, 8), BucketSpec(128, 16))
    injector = (
        PeriodicFaultInjector.sampled(2, variant="redundant", p=args.p)
        if args.inject_fault else None
    )
    server = QRServer(buckets, p=args.p, fault_injector=injector)

    t0 = time.perf_counter()
    traces = server.prewarm()
    t_warm = time.perf_counter() - t0
    print(f"prewarm: {t_warm*1e3:.0f} ms, traces {traces}")
    for d in server.planner_decisions():
        print(f"  planner: {d}")

    rng = np.random.default_rng(args.seed)
    problems = []
    for _ in range(args.requests):
        spec = buckets[rng.integers(len(buckets))]
        m = int(rng.integers(spec.n_pad + 1, spec.m_pad + 1))
        n = int(rng.integers(2, spec.n_pad + 1))
        a = rng.standard_normal((m, n)).astype(np.float32)
        problems.append((a, rng.standard_normal(m).astype(np.float32)))

    t0 = time.perf_counter()
    responses = server.serve([a for a, _ in problems])
    t_serve = time.perf_counter() - t0

    err = 0.0
    for resp, (a, b) in zip(responses, problems):
        x = decode(a, b, resp.r)
        x_ref = np.linalg.lstsq(a, b, rcond=None)[0]
        err = max(err, float(np.linalg.norm(x - x_ref)
                             / max(np.linalg.norm(x_ref), 1.0)))

    st = server.stats
    lat = np.array([r.latency_s for r in responses])
    via = {v: sum(r.served_via == v for r in responses)
           for v in ("batched", "reserved")}
    print(f"served {st.served} requests in {t_serve*1e3:.0f} ms "
          f"({st.drains} drains, {st.faulted_drains} faulted, "
          f"{st.filler_slots} filler slots)")
    print(f"served_via: {via}, p50 latency {np.median(lat)*1e3:.1f} ms")
    print(f"max decode rel err vs lstsq: {err:.2e}")


if __name__ == "__main__":
    main()
