"""Batched serving demo: prefill a prompt batch, then greedy-decode with the
per-family cache (KV ring buffers / SSM states), reporting per-phase
latency.  Runs any of the 10 architectures at smoke scale on CPU.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b --gen 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    key = jax.random.key(0)
    params = api.init(key, cfg)
    s_max = args.prompt_len + args.gen
    batch = api.synth_batch(key, cfg, "prefill", args.batch, args.prompt_len)

    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg, s_max=s_max))
    decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pref = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0

    ids = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} ({cfg.family})")
    print(f"prefill {args.batch}x{args.prompt_len}: {t_pref*1e3:.1f} ms")
    print(f"decode  {args.gen} tokens: {t_dec*1e3:.1f} ms "
          f"({t_dec/max(args.gen-1,1)*1e3:.2f} ms/token, incl. first-call jit)")
    print(f"generated[0]: {ids[0].tolist()}")


if __name__ == "__main__":
    main()
