"""Quickstart: fault-tolerant TSQR in 30 lines.

Factorizes a tall-skinny matrix distributed over 8 simulated ranks with the
paper's Redundant TSQR, kills a rank mid-factorization, and shows that the
survivors still hold the correct R.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import FaultSpec
from repro.core import ref
from repro.qr import QRConfig, factorize


def main():
    rng = np.random.default_rng(0)
    p, m_local, n = 8, 512, 32
    blocks = ref.random_tall_skinny(rng, p, m_local, n)     # (P, m_local, n)
    truth = ref.qr_r(blocks.reshape(-1, n).astype(np.float64))

    # rank 5 dies at the entry of butterfly exchange 1
    res = factorize(
        jnp.asarray(blocks),
        QRConfig(variant="redundant"),        # panel_width=None: TSQR
        faults=FaultSpec.of({5: 1}),
    )
    valid = np.asarray(res.valid)
    print(f"ranks holding the final R after the failure: {np.nonzero(valid)[0]}")
    for r in np.nonzero(valid)[0]:
        err = np.abs(np.asarray(res.r)[r] - truth).max()
        assert err < 1e-3, err
    print(f"max |R - R_lapack| over survivors: "
          f"{max(np.abs(np.asarray(res.r)[r] - truth).max() for r in np.nonzero(valid)[0]):.2e}")
    print(f"messages={res.plan.message_count()} "
          f"serial_rounds={res.plan.round_count()} "
          f"(tree baseline: {p-1} messages, same rounds)")


if __name__ == "__main__":
    main()
