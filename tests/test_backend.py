"""Backend descriptor resolution: the platform × interpret-flag matrix,
the one-time forced-interpreter warning, and the sublane-derived
``pick_block_rows`` clamp (tiny panels, GPU alignment)."""
import warnings

import jax
import pytest

from repro.kernels import backend


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    saved = set(backend._FORCED_WARNED)
    backend._FORCED_WARNED.clear()
    yield
    backend._FORCED_WARNED.clear()
    backend._FORCED_WARNED.update(saved)


# (platform, interpret flag) → (kind, interpret, sublane)
MATRIX = [
    ("tpu", None, "tpu-mosaic", False, 8),
    ("tpu", False, "tpu-mosaic", False, 8),
    ("tpu", True, "interpret", True, 8),
    ("gpu", None, "gpu-triton", False, 16),
    ("gpu", False, "gpu-triton", False, 16),
    ("gpu", True, "interpret", True, 8),
    ("cpu", None, "interpret", True, 8),
    ("cpu", True, "interpret", True, 8),
    # explicit False on CPU is honored verbatim — it reaches pallas_call
    # (the "explicit always wins" contract test_kernels pins with a spy)
    ("cpu", False, "interpret", False, 8),
]


@pytest.mark.parametrize("platform,flag,kind,interp,sublane", MATRIX)
def test_resolution_matrix(monkeypatch, platform, flag, kind, interp,
                           sublane):
    monkeypatch.setattr(jax, "default_backend", lambda: platform)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        be = backend.resolve_backend(flag)
    assert be.kind == kind
    assert be.interpret is interp
    assert be.sublane == sublane
    assert be.compiled is (not interp)
    assert be.kind in backend.KINDS


@pytest.mark.parametrize("platform", ["tpu", "gpu"])
def test_forced_interpret_warns_once_per_platform(monkeypatch, platform):
    monkeypatch.setattr(jax, "default_backend", lambda: platform)
    expected = "tpu-mosaic" if platform == "tpu" else "gpu-triton"
    with pytest.warns(UserWarning, match=expected):
        backend.resolve_backend(True)
    # second forced resolution is silent — once per process per platform
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        be = backend.resolve_backend(True)
    assert be.kind == "interpret" and be.interpret is True


def test_interpret_on_cpu_never_warns(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        be = backend.resolve_backend(True)
    assert be.interpret is True


def test_default_and_resolve_interpret_agree(monkeypatch):
    for platform, want in (("cpu", True), ("tpu", False), ("gpu", False)):
        monkeypatch.setattr(jax, "default_backend", lambda p=platform: p)
        assert backend.default_interpret() is want
        assert backend.resolve_interpret(None) is want
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")      # forced-on-gpu warning
        assert backend.resolve_interpret(True) is True


def test_bad_kind_rejected():
    with pytest.raises(ValueError, match="kind"):
        backend.Backend("cuda", "cpu", True, 8)


# ---------------------------------------------------------------------------
# pick_block_rows: sublane-derived clamp
# ---------------------------------------------------------------------------

def test_pick_block_rows_tiny_m_gets_one_sublane_tile():
    # m < sublane → exactly one sublane tile; the kernels' row-iota
    # masking makes the ≤ sublane−1 padded rows compute waste, not a bug
    assert backend.pick_block_rows(5, 1024, sublane=8) == 8
    assert backend.pick_block_rows(5, 1024, sublane=16) == 16
    assert backend.pick_block_rows(1, 2, sublane=8) == 8


def test_pick_block_rows_clamps_to_rounded_m():
    assert backend.pick_block_rows(100, 1024, sublane=8) == 104
    assert backend.pick_block_rows(100, 1024, sublane=16) == 112
    assert backend.pick_block_rows(96, 1024, sublane=16) == 96


def test_pick_block_rows_honors_requested_height():
    assert backend.pick_block_rows(10_000, 64, sublane=8) == 64
    # but never below one sublane tile
    assert backend.pick_block_rows(10_000, 4, sublane=16) == 16


def test_pick_block_rows_derives_sublane_from_backend(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert backend.pick_block_rows(5, 1024) == 16
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert backend.pick_block_rows(5, 1024) == 8
