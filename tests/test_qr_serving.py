"""The serving layer (``repro.serve``): bucket routing and identity-extension
padding, planner determinism and Cholesky inadmissibility, fault re-serve
bitwise fidelity, the one-dispatch drain, zero warm retraces, and the async
front-end."""
import asyncio
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (
    BucketSpec,
    CostModel,
    PeriodicFaultInjector,
    QRServer,
    bucket_for,
    default_buckets,
    extract_r,
    filler_matrix,
    pad_request,
    plan_bucket,
)
from repro.serve.buckets import block_rows, validate_buckets

# Small geometry shared by every server in this module: the compile builders
# are process-global lru_caches, so all tests reuse the same two programs.
BUCKETS = (BucketSpec(64, 8), BucketSpec(128, 16))
P = 4
MODEL = CostModel(max_batch_cap=2)


def _server(**kw):
    return QRServer(BUCKETS, p=P, model=MODEL, **kw)


def _sign_normalized_r(a):
    r = np.linalg.qr(a, mode="r")
    sign = np.sign(np.diag(r))
    sign[sign == 0] = 1.0
    return (r.T * sign).T


# ---------------------------------------------------------------------------
# Buckets and padding (pure host logic)
# ---------------------------------------------------------------------------

def test_mixed_shapes_land_in_expected_buckets():
    server = _server()
    assert server.bucket_of(40, 6) == BucketSpec(64, 8)
    assert server.bucket_of(56, 8) == BucketSpec(64, 8)    # exact width
    assert server.bucket_of(120, 14) == BucketSpec(128, 16)
    assert server.bucket_of(96, 8) == BucketSpec(128, 16)  # too tall for b0
    # (64, 8) admits (62, 6) exactly: 62 real + 2 identity rows = 64 …
    assert server.bucket_of(62, 6) == BucketSpec(64, 8)
    # … but NOT (63, 6): 63 + 2 > 64
    assert server.bucket_of(63, 6) == BucketSpec(128, 16)
    with pytest.raises(ValueError, match="no bucket admits"):
        server.bucket_of(256, 8)
    with pytest.raises(ValueError, match="no bucket admits"):
        server.bucket_of(64, 20)


def test_default_buckets_cover_ladder():
    buckets = default_buckets()
    assert bucket_for(buckets, 200, 30) == BucketSpec(256, 32)
    assert bucket_for(buckets, 900, 100) == BucketSpec(1024, 128)


def test_pad_request_identity_extension():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((40, 6)).astype(np.float32)
    spec = BucketSpec(64, 8)
    padded = pad_request(a, spec)
    assert padded.shape == (64, 8)
    np.testing.assert_array_equal(padded[:40, :6], a)
    np.testing.assert_array_equal(padded[40:42, 6:], np.eye(2))
    assert not padded[40:, :6].any()     # pad rows touch only pad columns
    assert not padded[:40, 6:].any()     # pad columns touch only pad rows
    assert not padded[42:].any()
    # the padded R is [[R_A, 0], [0, I]] ⇒ the request's factor is the
    # top-left block, untouched by the pad beyond fp reassociation
    r_pad = _sign_normalized_r(padded.astype(np.float64))
    np.testing.assert_allclose(
        extract_r(r_pad, 6), _sign_normalized_r(a.astype(np.float64)),
        rtol=1e-10, atol=1e-10,
    )
    np.testing.assert_allclose(r_pad[6:, 6:], np.eye(2), atol=1e-12)


def test_filler_matrix_is_orthonormal():
    fill = filler_matrix(BucketSpec(64, 8))
    np.testing.assert_array_equal(fill.T @ fill, np.eye(8))


def test_bucket_validation():
    with pytest.raises(ValueError, match="tall-or-square"):
        BucketSpec(8, 64)
    with pytest.raises(ValueError, match="divisible"):
        validate_buckets((BucketSpec(66, 8),), 4)
    with pytest.raises(ValueError, match="duplicate"):
        validate_buckets((BucketSpec(64, 8), BucketSpec(64, 8)), 4)
    with pytest.raises(ValueError, match="not divisible"):
        block_rows(np.zeros((66, 8), np.float32), 4)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def test_planner_is_deterministic():
    a = plan_bucket(BucketSpec(256, 32), 4)
    b = plan_bucket(BucketSpec(256, 32), 4)
    assert a == b


def test_planner_marks_chol_inadmissible_for_serving():
    """Identity-extension padding leaves pad columns exactly zero on most
    ranks → a per-rank local Gram is singular and its Cholesky NaN; the
    planner must never pick 'chol' for rank-deficient inputs, but keeps it
    in the audit table."""
    plan = plan_bucket(BucketSpec(256, 32), 4)
    assert plan.local_r == "jnp"
    chol_rows = [c for c in plan.candidates if c[1] == "chol"]
    assert chol_rows and all(not c[3] for c in chol_rows)
    # a caller with full-rank inputs may admit chol again
    full = plan_bucket(BucketSpec(256, 32), 4, rank_deficient_inputs=False)
    assert any(c[3] for c in full.candidates if c[1] == "chol")


def test_planner_respects_batch_budget():
    tight = CostModel(batch_bytes_budget=BucketSpec(64, 8).area * 4 * 3)
    assert plan_bucket(BucketSpec(64, 8), 4, tight).max_batch == 3
    assert plan_bucket(BucketSpec(64, 8), 4, MODEL).max_batch == 2  # cap
    huge = plan_bucket(BucketSpec(1024, 128), 4, CostModel(
        batch_bytes_budget=BucketSpec(1024, 128).area * 4
    ))
    assert huge.max_batch == 1


def test_server_configs_follow_plans():
    server = _server()
    for spec in server.buckets:
        plan = server.plans[spec]
        cfg = server.configs[spec]
        assert cfg.panel_width == plan.panel_width
        assert cfg.local_r == plan.local_r == "jnp"


# ---------------------------------------------------------------------------
# Serving (compiled paths)
# ---------------------------------------------------------------------------

def _stream(rng, n=8):
    shapes = [(40, 6), (120, 14), (56, 8), (96, 12)]
    return [
        rng.standard_normal(shapes[i % len(shapes)]).astype(np.float32)
        for i in range(n)
    ]


def test_serve_matches_numpy_and_drains_one_dispatch(rng):
    server = _server()
    server.prewarm()
    mats = _stream(rng)
    responses = server.serve(mats)
    assert [r.rid for r in responses] == list(range(len(mats)))
    for resp, a in zip(responses, mats):
        assert resp.served_via == "batched"
        assert resp.r.shape == (a.shape[1], a.shape[1])
        np.testing.assert_allclose(
            resp.r, _sign_normalized_r(a), rtol=5e-4, atol=5e-4
        )
    assert server.stats.drains == 4
    assert server.stats.dispatches_per_drain == [1, 1, 1, 1]
    assert server.stats.filler_slots == 0


def test_warm_serving_performs_zero_new_traces(rng):
    from repro.kernels import dispatch as disp

    server = _server(
        fault_injector=PeriodicFaultInjector.sampled(
            2, variant="redundant", p=P
        )
    )
    server.prewarm()
    before = disp.trace_count()
    server.serve(_stream(rng))          # batched drains AND fault re-serves
    assert disp.trace_count() - before == 0


def test_flush_tops_up_short_batches_with_fillers(rng):
    server = _server()
    server.prewarm()
    out = server.submit(rng.standard_normal((40, 6)).astype(np.float32))
    assert out == []                     # queue below max_batch: no drain
    responses = server.flush()
    assert len(responses) == 1
    assert server.stats.filler_slots == 1
    assert server.stats.dispatches_per_drain == [1]


def test_fault_reserves_every_affected_request_bitwise(rng):
    """A drain that hits an injected death re-serves EVERY real request of
    the batch, and each re-served factor is bit-identical to a fault-free
    eager re-run of the same padded request."""
    from repro.qr.api import Pipeline, factorize

    injector = PeriodicFaultInjector.sampled(1, variant="redundant", p=P)
    server = _server(fault_injector=injector)
    server.prewarm()
    mats = _stream(rng)
    responses = server.serve(mats)
    assert len(responses) == len(mats)
    assert all(r.served_via == "reserved" for r in responses)
    assert server.stats.reserved == len(mats)
    assert server.stats.faulted_drains == server.stats.drains
    for resp, a in zip(responses, mats):
        cfg = dataclasses.replace(
            server.configs[resp.bucket], pipeline=Pipeline.OFF
        )
        ref = factorize(
            jnp.asarray(block_rows(pad_request(a, resp.bucket), P)), cfg
        )
        np.testing.assert_array_equal(
            resp.r, extract_r(np.asarray(ref.r[0]), a.shape[1])
        )
        # and still a correct factorization
        np.testing.assert_allclose(
            resp.r, _sign_normalized_r(a), rtol=5e-4, atol=5e-4
        )


def test_periodic_injector_strikes_on_schedule(rng):
    injector = PeriodicFaultInjector.sampled(3, variant="redundant", p=P)
    spec = BUCKETS[0]
    strikes = [bool(injector(spec, i)) for i in range(6)]
    assert strikes == [False, False, True, False, False, True]
    with pytest.raises(ValueError, match="period"):
        PeriodicFaultInjector(0, injector.schedule)
    with pytest.raises(ValueError, match="tree"):
        PeriodicFaultInjector.sampled(1, variant="tree", p=P)


def test_async_frontend(rng):
    server = _server()
    server.prewarm()

    async def drive():
        a = rng.standard_normal((40, 6)).astype(np.float32)
        b = rng.standard_normal((44, 7)).astype(np.float32)
        fa = asyncio.ensure_future(server.submit_async(a))
        fb = asyncio.ensure_future(server.submit_async(b))
        await asyncio.sleep(0)           # both queued in bucket (64, 8)
        server.flush()
        ra, rb = await asyncio.gather(fa, fb)
        return (a, ra), (b, rb)

    (a, ra), (b, rb) = asyncio.run(drive())
    assert ra.rid == 0 and rb.rid == 1
    np.testing.assert_allclose(
        ra.r, _sign_normalized_r(a), rtol=5e-4, atol=5e-4
    )
    np.testing.assert_allclose(
        rb.r, _sign_normalized_r(b), rtol=5e-4, atol=5e-4
    )


def test_submit_rejects_non_matrix(rng):
    with pytest.raises(ValueError, match="one \\(m, n\\) matrix"):
        _server().submit(np.zeros((2, 4, 4), np.float32))
