"""Property-based coverage (hypothesis) for the fused stacked-payload
reduction — the one-butterfly-per-panel collective behind DESIGN.md §10:

  * a ``stacked(op1, op2)`` collective over a two-leaf payload is
    bit-identical to composing the two single-payload collectives over the
    *same plan* — values and validity — on both the fault-free fast path
    and the forced general executor, for every plan variant, combiner
    pairing (square QR leaves, packed symmetric Gram leaves, rectangular
    sum leaves), and dtype;
  * under mid-reduction deaths the stacked butterfly degrades exactly like
    its per-leaf composition, and ONE ``replica_fetch`` of the stacked
    tuple restores both leaves bit-identically to per-leaf fetches;
  * at the driver level, ``blocked_qr_sim(fuse="auto")`` is bit-identical
    to the serialized two-butterfly schedule (``fuse="off"``) — pipeline
    and eager, fault-free and with panel-phase fault schedules that
    exercise stacked recovery.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based sweeps need the hypothesis extra "
    "(pip install -r requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.collective import (  # noqa: E402
    FaultSpec,
    SimComm,
    execute_plan,
    make_plan,
    replica_fetch,
    stacked,
)
from repro.qr.blocked import PanelFaultSchedule, blocked_qr_sim  # noqa: E402

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

DTYPES = [jnp.float32, jnp.bfloat16]
VARIANTS = ["tree", "redundant", "replace", "selfhealing"]
# leaf kinds: how the payload for one stacked part is built
PAIRS = [
    ("qr", "sum"),          # the driver's panel payload: R leaf + C leaf
    ("qr", "gram_sum"),     # square + packed-symmetric wire in one message
    ("gram_sum", "sum"),
    ("sum", "max"),
]


def _leaf(kind, p, rows, n, dt, seed):
    rng = np.random.default_rng(seed)
    if kind == "qr":
        # tall f32 blocks — QR combines stay f32 in the driver too
        return jnp.asarray(
            rng.standard_normal((p, max(rows, n) + n, n)).astype(np.float32)
        )
    if kind == "gram_sum":
        base = rng.standard_normal((p, max(rows, 2), n))
        return jnp.asarray(
            np.einsum("pmi,pmj->pij", base, base).astype(np.float32)
        ).astype(dt)
    return jnp.asarray(rng.standard_normal((p, rows, n))).astype(dt)


def _bitwise_tree(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(
        np.array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32),
            equal_nan=True,
        )
        for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# stacked == composed per-part collectives, bit for bit
# ---------------------------------------------------------------------------

@given(
    log_p=st.integers(1, 3),
    variant=st.sampled_from(VARIANTS),
    pair=st.sampled_from(PAIRS),
    dt=st.sampled_from(DTYPES),
    rows=st.integers(1, 10),
    n=st.integers(1, 8),
    fast=st.sampled_from([None, False]),
    seed=st.integers(0, 2**16),
)
@SET
def test_stacked_bit_identical_to_composed(log_p, variant, pair, dt, rows,
                                           n, fast, seed):
    p = 1 << log_p
    op1, op2 = pair
    x1 = _leaf(op1, p, rows, n, dt, seed)
    x2 = _leaf(op2, p, rows, n, dt, seed + 1)
    plan = make_plan(variant, p)
    v_st, ok_st = execute_plan(
        (x1, x2), SimComm(p), plan, stacked(op1, op2), fast=fast
    )
    v1, ok1 = execute_plan(x1, SimComm(p), plan, op1, fast=fast)
    v2, ok2 = execute_plan(x2, SimComm(p), plan, op2, fast=fast)
    assert np.array_equal(np.asarray(ok_st), np.asarray(ok1))
    assert np.array_equal(np.asarray(ok_st), np.asarray(ok2))
    assert _bitwise_tree(v_st, (v1, v2)), (variant, pair, dt, fast)


@given(
    log_p=st.integers(1, 3),
    variant=st.sampled_from(["redundant", "replace", "selfhealing"]),
    pair=st.sampled_from(PAIRS),
    dt=st.sampled_from(DTYPES),
    rows=st.integers(1, 8),
    n=st.integers(1, 6),
    step=st.integers(0, 2),
    dead=st.integers(0, 7),
    seed=st.integers(0, 2**16),
)
@SET
def test_stacked_under_mid_reduction_death(log_p, variant, pair, dt, rows,
                                           n, step, dead, seed):
    """A rank dying mid-butterfly degrades the stacked reduction exactly
    like its per-leaf composition — same survivor values, same validity."""
    p = 1 << log_p
    spec = FaultSpec.of({dead % p: min(step, log_p - 1)})
    plan = make_plan(variant, p, spec)
    op1, op2 = pair
    x1 = _leaf(op1, p, rows, n, dt, seed)
    x2 = _leaf(op2, p, rows, n, dt, seed + 1)
    v_st, ok_st = execute_plan((x1, x2), SimComm(p), plan, stacked(op1, op2))
    v1, ok1 = execute_plan(x1, SimComm(p), plan, op1)
    v2, ok2 = execute_plan(x2, SimComm(p), plan, op2)
    assert np.array_equal(np.asarray(ok_st), np.asarray(ok1))
    assert np.array_equal(np.asarray(ok_st), np.asarray(ok2))
    assert _bitwise_tree(v_st, (v1, v2)), (variant, pair, dt)
    # the planner's host-side verdict is what the engine delivered
    assert np.array_equal(np.asarray(ok_st), np.asarray(plan.final_valid))


@given(
    log_p=st.integers(1, 3),
    variant=st.sampled_from(["redundant", "selfhealing"]),
    dt=st.sampled_from(DTYPES),
    n=st.integers(1, 6),
    dead=st.integers(0, 7),
    seed=st.integers(0, 2**16),
)
@SET
def test_one_stacked_fetch_restores_both_leaves(log_p, variant, dt, n, dead,
                                                seed):
    """The replica copies double as FT copies for BOTH stacked results:
    one pytree ``replica_fetch`` restores the pair bit-identically to two
    per-leaf fetches, and every rank ends with a surviving rank's copy."""
    p = 1 << log_p
    spec = FaultSpec.of({dead % p: 0})
    plan = make_plan(variant, p, spec)
    if not np.asarray(plan.final_valid).any():
        return                      # extinct: nothing to fetch (p == 2 tree)
    x1 = _leaf("qr", p, 6, n, dt, seed)
    x2 = _leaf("sum", p, 6, n, dt, seed + 1)
    (r, c), ok = execute_plan((x1, x2), SimComm(p), plan, stacked("qr", "sum"))
    valid = plan.final_valid
    r_f, c_f = replica_fetch((r, c), SimComm(p), valid)
    r_1 = replica_fetch(r, SimComm(p), valid)
    c_1 = replica_fetch(c, SimComm(p), valid)
    assert _bitwise_tree((r_f, c_f), (r_1, c_1))
    donor = int(np.flatnonzero(np.asarray(valid))[0])
    for rank in range(p):
        assert _bitwise_tree(
            (np.asarray(r_f)[rank], np.asarray(c_f)[rank]),
            (np.asarray(r_f)[donor], np.asarray(c_f)[donor]),
        )


# ---------------------------------------------------------------------------
# driver level: fuse="auto" == fuse="off", pipeline and eager, with faults
# ---------------------------------------------------------------------------

@given(
    variant=st.sampled_from(["redundant", "replace", "selfhealing"]),
    m_local=st.integers(24, 48),
    n=st.integers(6, 20),
    panel_width=st.sampled_from([4, 8]),
    compute_q=st.booleans(),
    pipeline=st.sampled_from(["on", "off"]),
    seed=st.integers(0, 2**16),
)
@SET
def test_driver_fused_bit_identical_to_two_butterfly(variant, m_local, n,
                                                     panel_width, compute_q,
                                                     pipeline, seed):
    p = 4
    m_local = max(m_local, 2 * n)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((p, m_local, n)).astype(np.float32))
    kw = dict(panel_width=panel_width, variant=variant, compute_q=compute_q,
              pipeline=pipeline)
    fused = blocked_qr_sim(a, fuse="auto", **kw)
    split = blocked_qr_sim(a, fuse="off", **kw)
    assert np.array_equal(np.asarray(fused.r), np.asarray(split.r))
    assert np.array_equal(np.asarray(fused.valid), np.asarray(split.valid))
    if compute_q:
        assert np.array_equal(np.asarray(fused.q), np.asarray(split.q))


@given(
    variant=st.sampled_from(["redundant", "selfhealing"]),
    n=st.integers(8, 16),
    fault_panel=st.integers(0, 3),
    dead=st.integers(0, 3),
    step=st.integers(0, 1),
    seed=st.integers(0, 2**16),
)
@SET
def test_driver_fused_recovery_bit_identical(variant, n, fault_panel, dead,
                                             step, seed):
    """Panel-phase deaths ride the fused plan: the stacked fetch restores
    R and W together, bit-identical to the split driver's two fetches."""
    p = 4
    k_panels = -(-n // 4)
    fault_panel %= k_panels
    faults = PanelFaultSchedule.of(panel={fault_panel: {dead: step}})
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((p, 4 * n, n)).astype(np.float32))
    kw = dict(panel_width=4, variant=variant, faults=faults, compute_q=True)
    fused = blocked_qr_sim(a, fuse="auto", **kw)
    split = blocked_qr_sim(a, fuse="off", **kw)
    assert np.array_equal(np.asarray(fused.valid), np.asarray(split.valid))
    assert fused.recoverable == split.recoverable
    if not fused.recoverable:
        # beyond tolerance (e.g. a step-0 death in the redundant butterfly
        # poisons every rank): both schedules NaN-poison — nothing left to
        # compare bit for bit
        return
    assert np.array_equal(np.asarray(fused.r), np.asarray(split.r))
    assert np.array_equal(np.asarray(fused.q), np.asarray(split.q))
    # recovery happened through the stacked payload on the fused run: one
    # fetch restores both leaves, so the counts agree (last panel has no
    # cross-product leaf — nothing for recovered_w to count)
    rep = fused.reports[fault_panel]
    assert rep.fused
    if rep.plan_w is not None:
        assert rep.recovered_r == rep.recovered_w
