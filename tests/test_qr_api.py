"""The unified ``repro.qr.api`` facade: enum coercion and validation,
``QRConfig`` hashability / canonicalization (the jit-cache key), routing by
input rank, bit-identity of ``factorize`` against every legacy entry point,
and the deprecation contract of the old kwarg signatures."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.qr import (
    Fuse,
    Pipeline,
    QRConfig,
    Recover,
    blocked_qr_batched,
    blocked_qr_sim,
    factorize,
    tsqr_sim,
)


def _blocks(rng, p, m_local, n):
    return rng.standard_normal((p, m_local, n)).astype(np.float32)


# ---------------------------------------------------------------------------
# Enums: coercion and actionable validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("enum,raw,want", [
    (Pipeline, "auto", Pipeline.AUTO),
    (Pipeline, "ON", Pipeline.ON),
    (Pipeline, Pipeline.OFF, Pipeline.OFF),
    (Fuse, "off", Fuse.OFF),
    (Recover, "replica", Recover.REPLICA),
    (Recover, "OFF", Recover.OFF),
])
def test_enum_coercion(enum, raw, want):
    assert enum.coerce(raw) is want


@pytest.mark.parametrize("enum,bad", [
    (Pipeline, "maybe"),
    (Fuse, "fused"),
    (Recover, "retry"),
    (Recover, 3),
])
def test_enum_rejects_unknown_with_choices_listed(enum, bad):
    with pytest.raises((ValueError, TypeError)) as exc:
        enum.coerce(bad)
    # the error must tell the caller what IS accepted
    assert any(m.name.lower() in str(exc.value).lower() for m in enum)


def test_config_coerces_enum_strings():
    cfg = QRConfig(panel_width=8, pipeline="on", fuse="off", recover="off")
    assert cfg.pipeline is Pipeline.ON
    assert cfg.fuse is Fuse.OFF
    assert cfg.recover is Recover.OFF


@pytest.mark.parametrize("kwargs,match", [
    ({"panel_width": 0}, "panel_width"),
    ({"panel_width": 8, "variant": "quorum"}, "variant"),
    ({"panel_width": 8, "local_r": "magic"}, "local_r"),
    ({"panel_width": 8, "reorth": -1}, "reorth"),
    ({"panel_width": 8, "gram": True}, "gram"),        # gram is TSQR-only
    ({"panel_width": None, "local_r": "chol"}, "chol"),  # chol is blocked-only
])
def test_config_validation_errors(kwargs, match):
    with pytest.raises(ValueError, match=match):
        QRConfig(**kwargs)


# ---------------------------------------------------------------------------
# QRConfig as the jit-cache key
# ---------------------------------------------------------------------------

def test_config_hashable_and_canonical_collapses_policy_knobs():
    a = QRConfig(panel_width=8)
    b = QRConfig(panel_width=8)
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1
    # AUTO and ON trace the same program; canonical() must agree so the
    # compile cache is not split by a policy spelling
    on = QRConfig(panel_width=8, pipeline="on", fuse="on")
    auto = QRConfig(panel_width=8, pipeline="auto", fuse="auto")
    assert on.canonical() == auto.canonical()
    # OFF is a genuinely different compiled schedule — must NOT collapse
    off = QRConfig(panel_width=8, fuse="off")
    assert off.canonical() != auto.canonical()
    # local_r="auto" resolves per entry point
    assert QRConfig(panel_width=8).canonical().local_r == "chol"
    assert QRConfig(panel_width=None).canonical().local_r == "jnp"


# ---------------------------------------------------------------------------
# factorize(): routing + bit-identity against the legacy entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("p,m_local,n,pw", [(4, 32, 12, 4), (4, 48, 17, 5)])
def test_factorize_bit_identical_to_blocked_qr_sim(seed, p, m_local, n, pw):
    blocks = jnp.asarray(
        _blocks(np.random.default_rng(seed), p, m_local, n)
    )
    new = factorize(blocks, QRConfig(panel_width=pw))
    with pytest.deprecated_call():
        old = blocked_qr_sim(blocks, panel_width=pw)
    assert np.array_equal(np.asarray(new.r), np.asarray(old.r))
    assert np.array_equal(np.asarray(new.valid), np.asarray(old.valid))


@pytest.mark.parametrize("variant", ["tree", "redundant", "selfhealing"])
def test_factorize_bit_identical_to_tsqr_sim(rng, variant):
    blocks = jnp.asarray(_blocks(rng, 4, 32, 8))
    new = factorize(blocks, QRConfig(panel_width=None, variant=variant))
    with pytest.deprecated_call():
        old = tsqr_sim(blocks, variant=variant)
    # equal_nan: tree leaves non-root ranks NaN by design
    assert np.array_equal(
        np.asarray(new.r), np.asarray(old.r), equal_nan=True
    )


def test_factorize_routes_rank4_to_batched(rng):
    batch = jnp.asarray(
        rng.standard_normal((2, 4, 32, 12)).astype(np.float32)
    )
    new = factorize(batch, QRConfig(panel_width=4))
    with pytest.deprecated_call():
        old = blocked_qr_batched(batch, panel_width=4)
    assert np.array_equal(np.asarray(new.r), np.asarray(old.r))


def test_factorize_with_faults_recovers(rng):
    from repro.qr import PanelFaultSchedule

    blocks = _blocks(rng, 4, 32, 12)
    faults = PanelFaultSchedule.of(panel={0: {1: 1}})
    res = factorize(jnp.asarray(blocks), QRConfig(panel_width=4),
                    faults=faults)
    assert res.recoverable
    ref = factorize(jnp.asarray(blocks), QRConfig(panel_width=4))
    np.testing.assert_allclose(
        np.asarray(res.r)[0], np.asarray(ref.r)[0], rtol=5e-4, atol=5e-4
    )


def test_factorize_rejects_faults_on_batched_path(rng):
    from repro.qr import PanelFaultSchedule

    batch = jnp.asarray(
        rng.standard_normal((2, 4, 32, 12)).astype(np.float32)
    )
    faults = PanelFaultSchedule.of(panel={0: {1: 1}})
    with pytest.raises(ValueError, match="serve"):
        factorize(batch, QRConfig(panel_width=4), faults=faults)


def test_factorize_rejects_bad_rank(rng):
    with pytest.raises(ValueError):
        factorize(jnp.zeros((8, 4), jnp.float32), QRConfig(panel_width=4))


def test_default_config_is_tsqr(rng):
    blocks = jnp.asarray(_blocks(rng, 4, 32, 8))
    res = factorize(blocks)                      # config defaults to TSQR
    with pytest.deprecated_call():
        old = tsqr_sim(blocks)
    assert np.array_equal(np.asarray(res.r), np.asarray(old.r))


# ---------------------------------------------------------------------------
# Deprecation contract of the legacy entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("call", [
    lambda a: blocked_qr_sim(a, panel_width=4),
    lambda a: tsqr_sim(a),
])
def test_legacy_entry_points_warn(rng, call):
    blocks = jnp.asarray(_blocks(rng, 4, 32, 8))
    with pytest.deprecated_call() as record:
        call(blocks)
    assert any("factorize" in str(w.message) for w in record)


def test_legacy_string_flags_still_coerce(rng):
    """Old call sites passed pipeline='on'/'off' strings; the shims (and
    QRConfig) must keep accepting them."""
    blocks = jnp.asarray(_blocks(rng, 4, 32, 12))
    with pytest.deprecated_call():
        res = blocked_qr_sim(blocks, panel_width=4, pipeline="off",
                             fuse="off", recover="replica")
    np.testing.assert_allclose(
        np.asarray(res.r)[0],
        np.asarray(factorize(blocks, QRConfig(panel_width=4)).r)[0],
        rtol=5e-4, atol=5e-4,
    )
