"""Property coverage for the coded-redundancy scheme (DESIGN.md §12).

The invariants, swept across inner ops × dtypes × parity counts × fault
mixes (deaths / stragglers / silent corruption):

  * **fault-free is free** — with zero erasures the coded collective is
    *bitwise* identical to the redundant butterfly's value on every data
    rank (the binomial gather+broadcast folds in the same order), so
    turning the scheme on costs nothing numerically until a fault lands;
  * **decode-from-parity is honest arithmetic** — any ≤ c erased
    contributions are reconstructed within the *documented* bound
    (:func:`repro.collective.coded.reconstruction_tol` for the payload
    dtype), never bit-magic, and every data rank ends valid;
  * **> c losses degrade honestly** — the plan declares itself
    unrecoverable, no rank is valid, payloads are NaN-poisoned, and
    nothing ships (no silent garbage, no wasted wire);
  * **detection flags exactly the corrupt ranks** — checksum verification
    is a numerical compare against the parity reconstruction, not an echo
    of the fault spec;
  * **wire accounting is exact** — observed messages / payload bytes
    through ``InstrumentedComm`` equal ``plan.message_count()`` /
    ``plan.bytes_on_wire()`` for every fault mix.

The deterministic sweeps below run everywhere; the randomized hypothesis
sweep widens the fault-pattern space when the extra is installed
(``pip install -r requirements-dev.txt``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collective import (
    FaultSpec,
    InstrumentedComm,
    SimComm,
    coded_allreduce,
    ft_allreduce,
    make_coded_plan,
    reconstruction_tol,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover — optional extra
    st = None

OPS = ["sum", "mean"]
DTYPES = [np.float32, np.float64]


def _payload(p, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((p, 4, 3)).astype(dtype)


def _truth(x, op):
    t = x.astype(np.float64).sum(0)
    return t / x.shape[0] if op == "mean" else t


def _spec(deaths=(), slow=(), corrupt=()):
    return FaultSpec.of({r: 0 for r in deaths}, slow=slow, corrupt=corrupt)


def _run(x, p, c, op, spec=None, observed=None):
    plan = make_coded_plan(p, c, spec)
    comm = InstrumentedComm(SimComm(p + c))
    val, valid, det = coded_allreduce(
        jnp.asarray(x), comm, op=op, plan=plan,
        observed=None if observed is None else jnp.asarray(observed),
    )
    return plan, comm.stats, np.asarray(val), np.asarray(valid), np.asarray(det)


def _wire_bytes(plan, val):
    # exact pricing of the (4, 3) rectangular test payload at the dtype the
    # device actually computed in (x64 stays off in the suite, so float64
    # host input runs as float32 on device)
    return plan.bytes_on_wire_stacked([(4, 3, val.dtype.itemsize, False)])


def _check_recovered(x, op, plan, val, valid, det, corrupt=()):
    p = plan.n_data
    tol = reconstruction_tol(val.dtype)
    truth = _truth(x, op)
    scale = max(1.0, np.abs(truth).max())
    assert plan.recoverable
    assert valid[:p].all()
    err = np.abs(val[0].astype(np.float64) - truth).max() / scale
    assert err <= tol, f"decode err {err:.3e} above documented bound {tol:.3e}"
    assert np.array_equal(np.flatnonzero(det[:p]), np.sort(corrupt))


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("c", [1, 2, 3])
def test_fault_free_bitwise_matches_butterfly(op, dtype, c):
    p = 8
    x = _payload(p, dtype)
    ref, _ = ft_allreduce(jnp.asarray(x), SimComm(p), op=op,
                          variant="redundant")
    plan, stats, val, valid, det = _run(x, p, c, op)
    assert plan.is_fault_free and plan.n_erased == 0
    assert np.array_equal(np.asarray(ref), val[:p])
    assert valid.all() and not det.any()
    assert stats.messages == plan.message_count()


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("c", [1, 2, 3])
def test_decode_from_parity_within_documented_bound(op, dtype, c):
    p = 8
    x = _payload(p, dtype, seed=c)
    dead = tuple(range(0, 2 * c, 2))[:c]       # includes rank 0 (the root)
    plan, _, val, valid, det = _run(x, p, c, op, _spec(deaths=dead))
    assert plan.n_erased == c
    _check_recovered(x, op, plan, val, valid, det)


@pytest.mark.parametrize("dtype", DTYPES)
def test_mixed_erasures_and_detection(dtype):
    # deaths + a straggler + a silent corruption, all inside a c=3 budget;
    # only the corrupt rank may be flagged — its observed payload really
    # disagrees with the parity reconstruction.
    p, c, op = 8, 3, "sum"
    x = _payload(p, dtype, seed=7)
    observed = x.copy()
    observed[6] *= 3.0
    spec = _spec(deaths=(1,), slow=(4,), corrupt=(6,))
    plan, stats, val, valid, det = _run(x, p, c, op, spec, observed)
    _check_recovered(x, op, plan, val, valid, det, corrupt=(6,))
    assert stats.messages == plan.message_count()
    assert stats.payload_bytes == _wire_bytes(plan, val)


@pytest.mark.parametrize("op", OPS)
def test_over_budget_degrades_honestly(op):
    p, c = 8, 2
    x = _payload(p, np.float32)
    plan, stats, val, valid, _ = _run(x, p, c, op, _spec(deaths=(0, 3, 5)))
    assert not plan.recoverable
    assert not valid.any()
    assert np.isnan(val).all()
    assert stats.messages == 0 and plan.message_count() == 0


def test_integer_payload_rejected():
    p, c = 4, 1
    x = np.arange(p * 4, dtype=np.int32).reshape(p, 4)
    with pytest.raises(TypeError, match="inexact"):
        _run(x, p, c, "sum")


def test_wire_accounting_exact_across_fault_mixes():
    p, c = 8, 3
    x = _payload(p, np.float32)
    for spec in (None, _spec(deaths=(2,)), _spec(slow=(1, 5)),
                 _spec(deaths=(0,), corrupt=(7,))):
        plan, stats, val, *_ = _run(x, p, c, "sum", spec)
        assert stats.messages == plan.message_count()
        assert stats.payload_bytes == _wire_bytes(plan, val)


if st is not None:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data(),
           p=st.integers(min_value=2, max_value=9),
           c=st.integers(min_value=1, max_value=3),
           op=st.sampled_from(OPS),
           dtype=st.sampled_from(DTYPES))
    def test_random_fault_mix_sweep(data, p, c, op, dtype):
        """Randomized fault patterns: any disjoint deaths/slow/corrupt mix
        within the parity budget recovers + detects; any over-budget mix
        degrades honestly.  Wire accounting holds either way."""
        x = _payload(p, dtype, seed=p * 10 + c)
        n_faults = data.draw(
            st.integers(min_value=0, max_value=min(c + 1, p)), label="ℓ"
        )
        ranks = data.draw(
            st.permutations(range(p)).map(lambda s: s[:n_faults]),
            label="ranks",
        )
        kinds = data.draw(
            st.lists(st.sampled_from(["death", "slow", "corrupt"]),
                     min_size=n_faults, max_size=n_faults),
            label="kinds",
        )
        dead = tuple(r for r, k in zip(ranks, kinds) if k == "death")
        slow = tuple(r for r, k in zip(ranks, kinds) if k == "slow")
        corrupt = tuple(r for r, k in zip(ranks, kinds) if k == "corrupt")
        observed = x.copy()
        for r in corrupt:
            observed[r] *= 3.0
        plan, stats, val, valid, det = _run(
            x, p, c, op, _spec(dead, slow, corrupt), observed
        )
        assert stats.messages == plan.message_count()
        assert stats.payload_bytes == _wire_bytes(plan, val)
        if n_faults <= c:
            _check_recovered(x, op, plan, val, valid, det, corrupt=corrupt)
        else:
            assert not plan.recoverable
            assert not valid.any()
            assert np.isnan(val).all()
