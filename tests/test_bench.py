"""The benchmark subsystem: registry, runner/schema, comparator gating,
collective fault scenarios, and the comm instrumentation hooks."""
import json

import numpy as np
import pytest

from repro.bench import compare, registry, runner, schema
from repro.bench.registry import BenchFailure, SkipCase, bench_case, cases_for


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registered_cases_cover_migrated_benchmarks():
    from repro.bench import cases  # noqa: F401 — triggers registration

    names = set(registry.REGISTRY)
    assert {
        "robustness", "comm_volume", "semantics", "tsqr_scaling",
        "tsqr_local_qr", "powersgd", "roofline", "fault_scenarios",
        "kernels", "general_qr", "serving", "coded",
    } <= names
    smoke = {c.name for c in cases_for("smoke")}
    assert {
        "robustness", "comm_volume", "semantics", "fault_scenarios", "kernels",
        "general_qr", "serving", "coded",
    } <= smoke


def test_registry_tier_filter_and_duplicates():
    table = {}
    bench_case("a", tiers=("smoke",), registry=table)(lambda: {"m": 1})
    bench_case("b", tiers=("full",), registry=table)(lambda: {"m": 1})
    assert [c.name for c in cases_for("smoke", registry=table)] == ["a"]
    assert [c.name for c in cases_for("full", registry=table)] == ["b"]
    with pytest.raises(ValueError, match="duplicate"):
        bench_case("a", registry=table)(lambda: {})
    with pytest.raises(KeyError, match="unknown bench case"):
        cases_for("smoke", only=("nope",), registry=table)
    with pytest.raises(ValueError, match="unknown tiers"):
        bench_case("c", tiers=("nightly",), registry=table)(lambda: {})


# ---------------------------------------------------------------------------
# runner + schema
# ---------------------------------------------------------------------------

def _toy_registry():
    table = {}
    bench_case(
        "ok_case", registry=table, repeats=3,
        params={"smoke": {"x": 2}},
    )(lambda x: {"doubled": schema.Metric(2 * x, gate="hard", direction="higher"),
                 "info": 3.5})
    bench_case("skippy", registry=table)(
        lambda: (_ for _ in ()).throw(SkipCase("no artifacts"))
    )
    return table


def test_runner_emits_valid_doc(tmp_path):
    doc = runner.run_cases("smoke", registry=_toy_registry(), verbose=False)
    schema.validate(doc)
    ok = doc["cases"]["ok_case"]
    assert ok["status"] == "ok"
    assert ok["params"] == {"x": 2}
    assert ok["metrics"]["doubled"] == {
        "value": 4, "gate": "hard", "direction": "higher"
    }
    # bare numbers become informational warn/exact metrics
    assert ok["metrics"]["info"]["gate"] == "warn"
    # warmup/repeat/percentile timing folded in as warn-gated metrics
    for t in ("time_mean_us", "time_p50_us", "time_p90_us", "time_min_us"):
        assert ok["metrics"][t]["gate"] == "warn"
        assert ok["metrics"][t]["direction"] == "lower"
    assert doc["cases"]["skippy"] == {
        "params": {}, "status": "skipped", "skip_reason": "no artifacts"
    }
    path = runner.write_doc(doc, out_dir=str(tmp_path))
    assert path.startswith(str(tmp_path)) and "BENCH_" in path
    with open(path) as f:
        schema.validate(json.load(f))


def test_runner_records_errors_and_bench_failures():
    table = {}
    bench_case("boom", registry=table)(
        lambda: (_ for _ in ()).throw(BenchFailure("guarantee broke"))
    )
    doc = runner.run_cases("smoke", registry=table, verbose=False)
    c = doc["cases"]["boom"]
    assert c["status"] == "error"
    assert "guarantee broke" in c["error"]


def test_schema_rejects_malformed():
    doc = runner.run_cases("smoke", registry=_toy_registry(), verbose=False)
    for mutate in (
        lambda d: d.update(schema_version=99),
        lambda d: d["cases"]["ok_case"]["metrics"]["doubled"].update(gate="soft"),
        lambda d: d["cases"]["ok_case"].update(status="meh"),
        lambda d: d["cases"].clear(),
        lambda d: d.update(n_devices="eight"),
    ):
        bad = json.loads(json.dumps(doc))
        mutate(bad)
        with pytest.raises(schema.SchemaError):
            schema.validate(bad)


# ---------------------------------------------------------------------------
# comparator gating
# ---------------------------------------------------------------------------

def _doc(metrics, status="ok", case="c"):
    entry = {"status": status, "params": {}}
    if status == "ok":
        entry["metrics"] = {
            k: schema.metric_to_json(m) for k, m in metrics.items()
        }
    elif status == "skipped":
        entry["skip_reason"] = "n/a"
    return schema.validate({
        "schema_version": schema.SCHEMA_VERSION,
        "created": "2026-07-27T00:00:00Z",
        "git_sha": None, "jax_version": "0.4.37", "backend": "cpu",
        "platform": "test", "python": "3.10", "n_devices": 1,
        "tier": "smoke", "cases": {case: entry},
    })


def test_compare_hard_regression_fails():
    old = _doc({"survivors": schema.Metric(12, gate="hard", direction="higher")})
    new = _doc({"survivors": schema.Metric(8, gate="hard", direction="higher")})
    cmp = compare.compare_docs(old, new)
    assert cmp.failures and cmp.exit_code() == 1
    # improvement passes
    up = _doc({"survivors": schema.Metric(16, gate="hard", direction="higher")})
    assert compare.compare_docs(old, up).exit_code() == 0


def test_compare_exact_and_bool_metrics():
    old = _doc({"msgs": schema.Metric(64, gate="hard", direction="exact"),
                "holds": schema.Metric(True, gate="hard", direction="exact")})
    same = _doc({"msgs": schema.Metric(64, gate="hard", direction="exact"),
                 "holds": schema.Metric(True, gate="hard", direction="exact")})
    assert compare.compare_docs(old, same).exit_code() == 0
    drift = _doc({"msgs": schema.Metric(65, gate="hard", direction="exact"),
                  "holds": schema.Metric(True, gate="hard", direction="exact")})
    assert compare.compare_docs(old, drift).exit_code() == 1
    flipped = _doc({"msgs": schema.Metric(64, gate="hard", direction="exact"),
                    "holds": schema.Metric(False, gate="hard", direction="exact")})
    assert compare.compare_docs(old, flipped).exit_code() == 1


def test_compare_timing_warns_only_unless_strict():
    old = _doc({"time_mean_us": schema.Metric(
        100.0, gate="warn", direction="lower", unit="us")})
    slow = _doc({"time_mean_us": schema.Metric(
        1000.0, gate="warn", direction="lower", unit="us")})
    cmp = compare.compare_docs(old, slow)
    assert cmp.warnings and not cmp.failures
    assert cmp.exit_code() == 0
    assert cmp.exit_code(strict_timing=True) == 1
    # inside the (generous) timing tolerance: no warning at all
    near = _doc({"time_mean_us": schema.Metric(
        120.0, gate="warn", direction="lower", unit="us")})
    assert not compare.compare_docs(old, near).warnings


def test_compare_per_metric_tolerance_override():
    old = _doc({"err": schema.Metric(
        0.10, gate="hard", direction="lower", tolerance=0.5)})
    within = _doc({"err": schema.Metric(
        0.14, gate="hard", direction="lower", tolerance=0.5)})
    beyond = _doc({"err": schema.Metric(
        0.16, gate="hard", direction="lower", tolerance=0.5)})
    assert compare.compare_docs(old, within).exit_code() == 0
    assert compare.compare_docs(old, beyond).exit_code() == 1


def test_compare_coverage_regressions():
    old = _doc({"m": schema.Metric(1, gate="hard", direction="exact")})
    # case disappears entirely
    gone = _doc({"m": schema.Metric(1, gate="hard", direction="exact")},
                case="other")
    assert compare.compare_docs(old, gone).exit_code() == 1
    # ok → skipped is a coverage regression
    skipped = _doc({}, status="skipped")
    assert compare.compare_docs(old, skipped).exit_code() == 1
    # skipped → skipped is fine (e.g. roofline with no artifacts anywhere)
    assert compare.compare_docs(skipped, skipped).exit_code() == 0
    # hard metric disappears from a still-ok case
    fewer = _doc({"other": schema.Metric(1, gate="hard", direction="exact")})
    assert compare.compare_docs(old, fewer).exit_code() == 1


def test_compare_refuses_tier_and_param_mismatches():
    old = _doc({"m": schema.Metric(1, gate="hard", direction="exact")})
    other_tier = json.loads(json.dumps(old))
    other_tier["tier"] = "full"
    cmp = compare.compare_docs(old, other_tier)
    assert cmp.exit_code() == 1 and "tier mismatch" in cmp.failures[0]
    other_params = json.loads(json.dumps(old))
    other_params["cases"]["c"]["params"] = {"trials": 9}
    cmp = compare.compare_docs(old, other_params)
    assert cmp.exit_code() == 1 and "params changed" in cmp.failures[0]


def test_compare_cli_roundtrip(tmp_path):
    from repro.bench.__main__ import main

    old = _doc({"m": schema.Metric(10, gate="hard", direction="higher")})
    bad = _doc({"m": schema.Metric(1, gate="hard", direction="higher")})
    po, pb = tmp_path / "old.json", tmp_path / "bad.json"
    po.write_text(json.dumps(old))
    pb.write_text(json.dumps(bad))
    assert main(["compare", str(po), str(po)]) == 0
    assert main(["compare", str(po), str(pb)]) == 1


# ---------------------------------------------------------------------------
# fault scenarios (collective half; trainer half runs in test_elastic.py's
# subprocess with 8 forced devices)
# ---------------------------------------------------------------------------

def test_collective_scenarios_survive_and_match():
    from repro.bench import scenarios

    byname = {s.name: s for s in scenarios.get_scenarios()}
    assert {"correlated_block_wipe", "cascading_failures",
            "blank_under_repeat", "fail_during_rebuild",
            "shrink_then_rebuild"} <= set(byname)
    for name in ("correlated_block_wipe", "cascading_failures",
                 "blank_under_repeat"):
        m = scenarios.run_collective_scenario(byname[name])
        assert m["survived"].value is True, name
        assert m["values_match"].value is True, name
        assert m["messages"].value > 0
    # the distilled expectations the baseline gates on
    m = scenarios.run_collective_scenario(byname["correlated_block_wipe"])
    assert m["round0_survivors"].value == 12      # 16 − the wiped domain
    m = scenarios.run_collective_scenario(byname["cascading_failures"])
    assert m["round0_survivors"].value == 16      # selfhealing respawns all
    m = scenarios.run_collective_scenario(byname["blank_under_repeat"])
    assert [m[f"round{i}_survivors"].value for i in range(3)] == [8, 6, 4]


def test_coded_scenarios_detect_and_degrade():
    from repro.bench import scenarios

    byname = {s.name: s for s in scenarios.get_scenarios()}
    assert {"straggler_reconstruction", "silent_corruption_detected",
            "over_parity_death"} <= set(byname)
    got = {}
    for name in ("straggler_reconstruction", "silent_corruption_detected",
                 "over_parity_death"):
        m = got[name] = scenarios.run_collective_scenario(byname[name])
        assert m["values_match"].value is True, name
        assert m["wire_matches_plan"].value is True, name
        assert m["honest_degradation"].value is True, name
    # stragglers are decoded from parity, not awaited: every data rank valid
    m = got["straggler_reconstruction"]
    assert m["round0_survivors"].value == 8
    assert m["survived"].value is True
    # checksum verification flags exactly the corrupted ranks, both rounds
    m = got["silent_corruption_detected"]
    assert m["corruption_detected"].value is True
    assert [m[f"round{i}_survivors"].value for i in range(2)] == [8, 8]
    # 3 deaths > c=2 parity lanes: all-invalid round, then a clean decode
    m = got["over_parity_death"]
    assert m["round0_within_tolerance"].value is False
    assert m["round0_survivors"].value == 0
    assert m["round1_survivors"].value == 8


def test_coded_rounds_rejected_under_butterfly():
    from repro.bench import scenarios

    sc = scenarios.CollectiveScenario(
        name="bad", p=4, variant="redundant",
        rounds=(scenarios.ReduceRound(corrupt=(1,)),),
    )
    with pytest.raises(ValueError, match="coded"):
        scenarios.run_collective_scenario(sc)


def test_blocked_qr_scenarios_survive_and_match():
    from repro.bench import scenarios

    byname = {s.name: s for s in scenarios.get_scenarios()}
    assert {"panel_death_midsweep", "death_during_trailing_update",
            "cascading_panels"} <= set(byname)
    got = {}
    for name in ("panel_death_midsweep", "death_during_trailing_update",
                 "cascading_panels"):
        m = got[name] = scenarios.run_blocked_qr_scenario(byname[name])
        assert m["within_tolerance"].value is True, name
        assert m["values_match"].value is True, name
        assert m["survivors_match_plan"].value is True, name
        assert m["sweeps_per_panel"].value == 1.0, name
    # the distilled expectations the baseline gates on
    assert got["panel_death_midsweep"]["survivors"].value == 6   # 8 − 2 deaths
    m = got["death_during_trailing_update"]
    assert m["survivors"].value == 4              # rank 5's step-1 coset
    assert m["recovered"].value == 4              # …restored from replicas
    assert got["cascading_panels"]["survivors"].value == 8  # respawned all


def test_scenario_seed_determinism():
    from repro.bench import scenarios

    sc = [s for s in scenarios.get_scenarios()
          if s.name == "blank_under_repeat"][0]
    a = scenarios.run_collective_scenario(sc, seed=7)
    b = scenarios.run_collective_scenario(sc, seed=7)
    assert {k: v.value for k, v in a.items()} == {k: v.value for k, v in b.items()}


# ---------------------------------------------------------------------------
# comm instrumentation hooks
# ---------------------------------------------------------------------------

def test_instrumented_comm_matches_plan_accounting():
    import jax.numpy as jnp

    from repro.collective import (
        FaultSpec, InstrumentedComm, SimComm, execute_plan, make_plan,
    )

    n = 4
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(8, n, n)).astype(np.float32)
    )
    from repro.collective import plan_is_fault_free

    for variant in ("tree", "redundant", "replace", "selfhealing"):
        plan = make_plan(variant, 8)
        ic = InstrumentedComm(SimComm(8))
        execute_plan(x, ic, plan, "sum")
        assert ic.stats.messages == plan.message_count(), variant
        assert ic.stats.rounds == plan.round_count(), variant
        if plan_is_fault_free(plan):
            # fast path: payload only, validity is host-proven
            assert ic.stats.payload_bytes == plan.bytes_on_wire(n, 4), variant
        else:
            # general path (tree): payload + 1 validity byte per message
            assert ic.stats.payload_bytes == \
                plan.bytes_on_wire(n, 4) + plan.message_count(), variant
        # the forced general executor always ships the validity bit
        ic = InstrumentedComm(SimComm(8))
        execute_plan(x, ic, plan, "sum", fast=False)
        assert ic.stats.payload_bytes == \
            plan.bytes_on_wire(n, 4) + plan.message_count(), variant
    # faulted selfhealing: restore transfers are counted too
    plan = make_plan("selfhealing", 8, FaultSpec.of({5: 1, 2: 2}))
    ic = InstrumentedComm(SimComm(8))
    execute_plan(x, ic, plan, "sum")
    assert ic.stats.messages == plan.message_count()
    assert any(r["messages"] for r in ic.stats.per_round)
    ic.stats.reset()
    assert ic.stats.messages == 0


def test_robustness_case_guarantee_and_metrics():
    from repro.bench.cases import robustness

    m = robustness.case(p=8, trials=60, seed=0)
    assert m["guarantee_holds"].value is True
    assert m["guaranteed_max_f_tree"].value == 0
    assert m["guaranteed_max_f_selfhealing"].value >= 1
    # sum of (2^s − 1) over the 3 levels of P=8
    assert m["selfhealing_total_tolerance"].value == 4
