"""Core TSQR correctness: all variants vs the numpy oracle, the paper's
worked failure examples (Figs. 3-5), Q factors, dtypes and shapes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FaultSpec, make_plan, tsqr_sim
from repro.core import ref


def _truth(blocks):
    n = blocks.shape[-1]
    return ref.qr_r(blocks.reshape(-1, n).astype(np.float64)).astype(np.float32)


@pytest.mark.parametrize("variant", ["tree", "redundant", "replace", "selfhealing"])
@pytest.mark.parametrize("p,m,n", [(4, 16, 3), (8, 32, 8), (16, 24, 5)])
def test_fault_free_matches_oracle(rng, variant, p, m, n):
    blocks = ref.random_tall_skinny(rng, p, m, n)
    res = tsqr_sim(jnp.asarray(blocks), variant=variant)
    truth = _truth(blocks)
    valid = np.asarray(res.valid)
    expect = (np.arange(p) == 0) if variant == "tree" else np.ones(p, bool)
    assert (valid == expect).all()
    for r in np.nonzero(valid)[0]:
        np.testing.assert_allclose(np.asarray(res.r)[r], truth, rtol=5e-4, atol=5e-4)


def test_butterfly_equals_sequential_oracle(rng):
    blocks = ref.random_tall_skinny(rng, 8, 16, 4)
    seq = ref.butterfly_tsqr(blocks.astype(np.float64))
    res = tsqr_sim(jnp.asarray(blocks), variant="redundant")
    for r in range(8):
        np.testing.assert_allclose(
            np.asarray(res.r)[r], seq[r].astype(np.float32), rtol=5e-4, atol=5e-4
        )


def test_paper_fig3_redundant(rng):
    """P2 dies after step 1 → P0 cascades out; P1, P3 hold the final R."""
    blocks = ref.random_tall_skinny(rng, 4, 16, 3)
    res = tsqr_sim(jnp.asarray(blocks), variant="redundant",
                   fault_spec=FaultSpec.of({2: 1}))
    assert list(np.asarray(res.valid)) == [False, True, False, True]
    truth = _truth(blocks)
    np.testing.assert_allclose(np.asarray(res.r)[1], truth, rtol=5e-4, atol=5e-4)


def test_paper_fig4_replace(rng):
    """Same failure; P0 reroutes to the replica P3 and survives."""
    blocks = ref.random_tall_skinny(rng, 4, 16, 3)
    res = tsqr_sim(jnp.asarray(blocks), variant="replace",
                   fault_spec=FaultSpec.of({2: 1}))
    assert list(np.asarray(res.valid)) == [True, True, False, True]
    truth = _truth(blocks)
    np.testing.assert_allclose(np.asarray(res.r)[0], truth, rtol=5e-4, atol=5e-4)


def test_paper_fig5_selfhealing(rng):
    """Same failure; P2 is respawned from a replica — everyone ends valid."""
    blocks = ref.random_tall_skinny(rng, 4, 16, 3)
    res = tsqr_sim(jnp.asarray(blocks), variant="selfhealing",
                   fault_spec=FaultSpec.of({2: 1}))
    assert np.asarray(res.valid).all()
    truth = _truth(blocks)
    np.testing.assert_allclose(np.asarray(res.r)[2], truth, rtol=5e-4, atol=5e-4)


def test_q_factor(rng):
    blocks = ref.random_tall_skinny(rng, 8, 32, 6)
    res = tsqr_sim(jnp.asarray(blocks), variant="redundant", compute_q=True)
    q = np.asarray(res.q).reshape(-1, 6)
    np.testing.assert_allclose(q.T @ q, np.eye(6), atol=2e-5)
    np.testing.assert_allclose(
        q @ np.asarray(res.r)[0], blocks.reshape(-1, 6), rtol=2e-4, atol=2e-4
    )


def test_q_refused_when_data_lost(rng):
    blocks = ref.random_tall_skinny(rng, 4, 8, 3)
    with pytest.raises(ValueError):
        tsqr_sim(jnp.asarray(blocks), variant="redundant",
                 fault_spec=FaultSpec.of({2: 1}), compute_q=True)


def test_selfhealing_q_with_faults(rng):
    """Self-healing restores everyone → Q is computable despite the failure."""
    blocks = ref.random_tall_skinny(rng, 8, 16, 4)
    res = tsqr_sim(jnp.asarray(blocks), variant="selfhealing",
                   fault_spec=FaultSpec.of({5: 1}), compute_q=True)
    q = np.asarray(res.q).reshape(-1, 4)
    np.testing.assert_allclose(q.T @ q, np.eye(4), atol=2e-5)


def test_local_qr_cqr2_paths(rng):
    blocks = ref.random_tall_skinny(rng, 4, 64, 8, cond=1e3)
    truth = _truth(blocks)
    for lq in ["jnp", "cqr2", "cqr2_pallas"]:
        res = tsqr_sim(jnp.asarray(blocks), variant="redundant", local_qr=lq)
        np.testing.assert_allclose(np.asarray(res.r)[0], truth, rtol=2e-3, atol=2e-3)


def test_ill_conditioned_tall_skinny(rng):
    blocks = ref.random_tall_skinny(rng, 8, 64, 6, cond=1e5)
    res = tsqr_sim(jnp.asarray(blocks), variant="redundant", compute_q=True)
    q = np.asarray(res.q).reshape(-1, 6)
    np.testing.assert_allclose(q.T @ q, np.eye(6), atol=1e-4)


def test_non_power_of_two_rejected(rng):
    blocks = ref.random_tall_skinny(rng, 6, 8, 3)
    with pytest.raises(ValueError):
        tsqr_sim(jnp.asarray(blocks), variant="redundant")


def test_comm_accounting():
    """Message counts: tree sends P-1 totals; the butterfly P·log2(P) —
    the paper's §III comparison (redundancy costs messages, not wire time,
    because exchanges are full-duplex)."""
    for p in (4, 8, 16, 32):
        tree = make_plan("tree", p)
        red = make_plan("redundant", p)
        assert tree.message_count() == p - 1
        assert red.message_count() == p * int(np.log2(p))
        assert tree.round_count() == red.round_count() == int(np.log2(p))
        # fault-free replace/selfheal run the identical butterfly
        rep = make_plan("replace", p)
        assert [s.perm_rounds for s in rep.steps] == [s.perm_rounds for s in red.steps]
