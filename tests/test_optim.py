"""Optimizer tests: AdamW behavior, PowerSGD-TSQR compression (the paper's
algorithm in the gradient path), low-rank and ortho-momentum updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collective import FaultSpec, SimComm
from repro.optim import adamw, lowrank, orthosgd, powersgd


def _quad_problem(key, d=16):
    target = jax.random.normal(key, (d, d))

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    params = {"w": jnp.zeros((d, d))}
    return loss, params


def test_adamw_minimizes_quadratic():
    loss, params = _quad_problem(jax.random.key(0))
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup=0, total_steps=200)
    state = adamw.init(params)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, params, g, state)
    assert float(loss(params)) < 0.02 * l0


def test_adamw_grad_clip_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1e-3, warmup=10, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    new_p, state, m = adamw.update(cfg, params, g, state)
    # warmup step 1: lr = 0.1; Adam normalizes the (clipped) gradient so
    # the step magnitude is bounded by lr, not the clip threshold
    assert float(jnp.abs(new_p["w"] - params["w"]).max()) <= 0.1 + 1e-5
    assert float(m["grad_norm"]) > 10        # pre-clip norm is reported
    assert float(m["lr"]) == pytest.approx(0.1)


def test_zero1_state_shardings_divisibility():
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = {"a": P(None, "model"), "b": P("model")}
    struct = {
        "a": jax.ShapeDtypeStruct((3, 64), jnp.float32),   # 3 not divisible
        "b": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    out = adamw.state_shardings(specs, struct, mesh, zero1_axis=("data",))
    # single free axis is unpacked to its bare name (canonical on all jax
    # versions; older PartitionSpec does not equate ('data',) with 'data')
    assert out["m"]["a"] == P("data", "model")  # dim0 divisible by 1
    assert out["step"] == P()


# ---------------------------------------------------------------------------
# PowerSGD with FT-TSQR orthogonalization (SimComm backend)
# ---------------------------------------------------------------------------

def _psum_id(x):
    return x


def _psum_model_sim(x):
    # SimComm carries the model ranks in the leading axis: sum & broadcast
    return jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)


def test_powersgd_exact_on_lowrank():
    """A rank-r gradient must be reconstructed exactly in one round."""
    key = jax.random.key(3)
    p_ranks, m_loc, n, r = 4, 32, 24, 4
    u = jax.random.normal(key, (p_ranks * m_loc, r))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    g_full = (u @ v.T).reshape(p_ranks, m_loc, n)

    cfg = powersgd.PowerSGDConfig(rank=r, error_feedback=False)
    comm = SimComm(p_ranks)
    state = powersgd.init_state(jax.random.key(9), (m_loc, n), cfg, leading=(p_ranks,))
    g_hat, state, stats = powersgd.compress_grad(
        g_full, state, comm,
        cfg=cfg, psum_data=_psum_id, psum_model=_psum_model_sim, n_data=1,
    )
    np.testing.assert_allclose(np.asarray(g_hat), np.asarray(g_full), rtol=1e-3, atol=1e-3)
    assert stats["data_bytes_compressed"] < stats["data_bytes_dense"]


def test_powersgd_error_feedback_reduces_residual():
    key = jax.random.key(4)
    p_ranks, m_loc, n, r = 4, 16, 16, 2
    g = jax.random.normal(key, (p_ranks, m_loc, n))
    cfg = powersgd.PowerSGDConfig(rank=r, error_feedback=True)
    comm = SimComm(p_ranks)
    state = powersgd.init_state(jax.random.key(5), (m_loc, n), cfg, leading=(p_ranks,))
    # feed the SAME gradient repeatedly: error feedback should recover more
    # of it cumulatively
    acc = jnp.zeros_like(g)
    for _ in range(8):
        g_hat, state, _ = powersgd.compress_grad(
            g, state, comm,
            cfg=cfg, psum_data=_psum_id, psum_model=_psum_model_sim, n_data=1,
        )
        acc = acc + g_hat
    resid0 = float(jnp.linalg.norm(g))
    resid = float(jnp.linalg.norm(g - acc / 8))
    # with EF the running mean of reconstructions approaches g
    assert resid < 0.9 * resid0


def test_powersgd_survives_rank_failure():
    """The butterfly orthogonalization tolerates a model-rank failure within
    the paper's bound (2^s − 1 at entry of step s) — survivors still agree."""
    key = jax.random.key(6)
    p_ranks, m_loc, n, r = 4, 16, 12, 3
    g = jax.random.normal(key, (p_ranks, m_loc, n))
    cfg = powersgd.PowerSGDConfig(rank=r, error_feedback=False,
                                  variant="selfhealing")
    comm = SimComm(p_ranks)
    state = powersgd.init_state(jax.random.key(7), (m_loc, n), cfg, leading=(p_ranks,))
    g_hat, _, stats = powersgd.compress_grad(
        g, state, comm, cfg=cfg, psum_data=_psum_id,
        psum_model=_psum_model_sim, n_data=1,
        fault_spec=FaultSpec.of({2: 1}),
    )
    assert np.asarray(stats["valid"]).all()
    assert np.isfinite(np.asarray(g_hat)).all()


# ---------------------------------------------------------------------------

def test_lowrank_optimizer_state_compression():
    key = jax.random.key(8)
    params = {"w": jax.random.normal(key, (512, 512), jnp.float32),
              "b": jnp.zeros((512,), jnp.float32)}
    cfg = lowrank.LowRankConfig(rank=16, min_dim=256, lr=1e-2)
    state = lowrank.init(params, cfg)
    assert state["per_param"]["w"]["m"].shape == (512, 16)   # 32× smaller
    assert state["per_param"]["b"]["basis"] is None

    target = jax.random.normal(jax.random.fold_in(key, 2), (512, 512))
    loss = lambda p: jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)
    l0 = float(loss(params))
    for _ in range(30):
        g = jax.grad(loss)(params)
        params, state = lowrank.update(cfg, params, g, state)
    assert float(loss(params)) < l0


def test_orthosgd_update_is_orthogonal():
    key = jax.random.key(9)
    m = jax.random.normal(key, (64, 16))
    q = orthosgd._orth_update(m)
    qn = np.asarray(q) / np.sqrt(64 / 16)
    np.testing.assert_allclose(qn.T @ qn, np.eye(16), atol=1e-4)


def test_orthosgd_minimizes():
    key = jax.random.key(10)
    target = jax.random.normal(key, (32, 8))
    params = {"w": jnp.zeros((32, 8))}
    loss = lambda p: jnp.mean((p["w"] - target) ** 2)
    cfg = orthosgd.OrthoSGDConfig(lr=0.05)
    state = orthosgd.init(params)
    l0 = float(loss(params))
    for _ in range(40):
        g = jax.grad(loss)(params)
        params, state = orthosgd.update(cfg, params, g, state)
    assert float(loss(params)) < 0.5 * l0


def test_ft_cqr2_q_matches_dense():
    """Sharded FT CholeskyQR2 returns an orthonormal Q that agrees with the
    dense gram_cqr2_q, including on batched and non-divisible inputs, and
    certifies through a faulted (within-tolerance) butterfly plan."""
    from repro.collective import make_plan
    from repro.optim.ftqr import ft_cqr2_q

    key = jax.random.key(11)
    for shape in ((64, 12), (3, 50, 8)):
        a = jax.random.normal(key, shape, jnp.float32)
        q_ft = ft_cqr2_q(a, shards=4)
        q_dense = lowrank.gram_cqr2_q(a)
        np.testing.assert_allclose(np.asarray(q_ft), np.asarray(q_dense),
                                   rtol=2e-4, atol=2e-4)
        qf = np.asarray(q_ft).reshape(-1, shape[-2], shape[-1])
        for qi in qf:
            np.testing.assert_allclose(qi.T @ qi, np.eye(shape[-1]),
                                       atol=1e-4)
    # faulted plan: a death inside the Gram butterfly, still certified
    a = jax.random.normal(key, (64, 12), jnp.float32)
    plan = make_plan("redundant", 4, FaultSpec.of({2: 1}))
    q_faulted = ft_cqr2_q(a, shards=4, plan=plan)
    np.testing.assert_allclose(np.asarray(q_faulted),
                               np.asarray(lowrank.gram_cqr2_q(a)),
                               rtol=2e-4, atol=2e-4)


def test_gram_cqr2_rank_deficient_stays_finite():
    """The trace-scaled ridge keeps CholeskyQR2 finite on singular Gram
    matrices (zero columns / duplicated columns — the rank-deficient
    momenta real training produces), and zero input maps to zero Q."""
    from repro.optim.ftqr import ft_cqr2_q

    key = jax.random.key(12)
    col = jax.random.normal(key, (48, 1), jnp.float32)
    a = jnp.concatenate([col, col, jnp.zeros((48, 2))], axis=1)
    for q in (lowrank.gram_cqr2_q(a), ft_cqr2_q(a, shards=4)):
        assert bool(jnp.isfinite(q).all()), "rank-deficient input made NaNs"
    assert float(jnp.abs(lowrank.gram_cqr2_q(jnp.zeros((16, 4)))).max()) == 0.0


def test_compress_mean_grad_exact_and_ft_parity():
    """In-step replicated PowerSGD: exact on a rank-<=r mean gradient, and
    the FT path (butterfly mean + row-distributed FT orthonormalization)
    matches the dense path on the same inputs."""
    key = jax.random.key(13)
    R, m, n, r = 4, 24, 10, 3
    u = jax.random.normal(key, (m, r))
    v = jax.random.normal(jax.random.fold_in(key, 1), (R, n, r))
    g_rep = jnp.einsum("mr,Rnr->Rmn", u, v)      # mean has rank <= r
    g_mean = np.asarray(g_rep).mean(0)
    q0 = jax.random.normal(jax.random.fold_in(key, 2), (n, r), jnp.float32)
    cfg = powersgd.PowerSGDConfig(rank=r, error_feedback=False)

    g_ft, _ = powersgd.compress_mean_grad(g_rep, q0, cfg=cfg, ft=True)
    g_dense, _ = powersgd.compress_mean_grad(g_rep, q0, cfg=cfg, ft=False)
    np.testing.assert_allclose(np.asarray(g_ft), g_mean, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(g_ft), np.asarray(g_dense),
                               rtol=2e-4, atol=2e-4)

    # masked replica (BLANK): zero slot + n_live rescale is still the mean
    # over the survivors
    g_masked = g_rep.at[1].set(0.0)
    g_surv, _ = powersgd.compress_mean_grad(
        g_masked, q0, cfg=cfg, ft=True, n_live=jnp.float32(R - 1))
    np.testing.assert_allclose(np.asarray(g_surv),
                               np.asarray(g_masked).sum(0) / (R - 1),
                               rtol=2e-4, atol=2e-4)
