"""Property-based coverage (hypothesis) for the blocked-QR hot path:

  * the fused trailing-update Pallas kernel against the unfused kernel
    composition (bit-identical — the lookahead ``S`` accumulator uses the
    same panel boundaries and cast points as ``panel_cross`` re-run on the
    stored output) and the pure-jnp oracle (tolerance), across dtypes
    (bf16/f32), ragged shapes (m, n_trail, panel widths not multiples of
    the block size), streaming block sizes, and batch dims;
  * the blocked driver end-to-end against the dense numpy QR over ragged
    m/n/panel-width combinations.

Mirrors tests/test_fused_property.py; runs in interpret mode on CPU
(backend auto-detection), compiles under Mosaic on TPU.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property-based sweeps need the hypothesis extra "
    "(pip install -r requirements-dev.txt)"
)
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402
from repro.kernels.trailing_update import (  # noqa: E402
    panel_cross,
    trailing_update,
)

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

DTYPES = [jnp.float32, jnp.bfloat16]


def _arr(seed, shape, dt):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dt)


# ---------------------------------------------------------------------------
# trailing_update: ragged shapes, dtypes, block sizes — bit-level fusion
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 500),
    nt=st.integers(1, 40),
    b=st.integers(1, 24),
    next_frac=st.floats(0.0, 1.0),
    block_rows=st.sampled_from([8, 32, 136, 1024]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
@SET
def test_fused_lookahead_bit_matches_separate_cross(
    m, nt, b, next_frac, block_rows, dt, seed
):
    """One fused sweep == update then ``panel_cross`` on the stored output,
    bit for bit, at any raggedness (edge-tile masking) and panel height."""
    next_width = max(1, round(next_frac * nt))
    a = _arr(seed, (m, nt), dt)
    q = _arr(seed + 1, (m, b), dt)
    w = _arr(seed + 2, (b, nt), dt)
    a_new, s = trailing_update(
        a, q, w, next_width=next_width, block_rows=block_rows
    )
    a_sep = trailing_update(a, q, w, block_rows=block_rows)
    s_sep = panel_cross(a_sep, split=next_width, block_rows=block_rows)
    assert a_new.shape == (m, nt) and s.shape == (next_width, nt)
    assert np.array_equal(
        np.asarray(a_new, np.float32), np.asarray(a_sep, np.float32)
    )
    assert np.array_equal(np.asarray(s), np.asarray(s_sep))


@given(
    m=st.integers(1, 500),
    nt=st.integers(1, 32),
    b=st.integers(1, 16),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
@SET
def test_trailing_update_close_to_oracle(m, nt, b, dt, seed):
    a = _arr(seed, (m, nt), dt)
    q = _arr(seed + 1, (m, b), dt)
    w = _arr(seed + 2, (b, nt), dt)
    next_width = min(4, nt)
    a_new, s = ops.trailing_update(a, q, w, next_width=next_width,
                                   use_pallas=True)
    a_ref, s_ref = ref.trailing_update(a, q, w, next_width=next_width)
    if dt == jnp.bfloat16:
        tol = dict(rtol=5e-2, atol=5e-1)
    else:
        tol = dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(
        np.asarray(a_new, np.float32), np.asarray(a_ref, np.float32), **tol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), **tol)


@given(
    batch=st.integers(1, 4),
    m=st.integers(4, 60),
    nt=st.integers(2, 16),
    b=st.integers(1, 8),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
@SET
def test_batch_dims_match_stacked_singles(batch, m, nt, b, dt, seed):
    """The ops wrapper's vmap over leading batch dims (the SimComm (P,)
    rank axis) equals per-slice kernel calls exactly."""
    a = _arr(seed, (batch, m, nt), dt)
    q = _arr(seed + 1, (batch, m, b), dt)
    w = _arr(seed + 2, (batch, b, nt), dt)
    nw = min(3, nt)
    a_new, s = ops.trailing_update(a, q, w, next_width=nw, use_pallas=True)
    for i in range(batch):
        ai, si = trailing_update(a[i], q[i], w[i], next_width=nw)
        assert np.array_equal(
            np.asarray(a_new[i], np.float32), np.asarray(ai, np.float32)
        )
        assert np.array_equal(np.asarray(s[i]), np.asarray(si))
    s0 = ops.panel_cross(a, split=nw, use_pallas=True)
    for i in range(batch):
        assert np.array_equal(
            np.asarray(s0[i]), np.asarray(panel_cross(a[i], split=nw))
        )


@given(
    m=st.integers(1, 300),
    n=st.integers(1, 24),
    split_frac=st.floats(0.01, 1.0),
    block_rows=st.sampled_from([8, 32, 1024]),
    dt=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
@SET
def test_panel_cross_close_to_oracle(m, n, split_frac, block_rows, dt, seed):
    split = max(1, round(split_frac * n))
    a = _arr(seed, (m, n), dt)
    s = panel_cross(a, split=split, block_rows=block_rows)
    s_ref = ref.panel_cross(a, split=split)
    assert s.shape == (split, n)
    tol = dict(rtol=5e-2, atol=5e-1) if dt == jnp.bfloat16 else \
        dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), **tol)


# ---------------------------------------------------------------------------
# blocked driver end-to-end over ragged m / n / panel widths
# ---------------------------------------------------------------------------

@given(
    log_p=st.integers(0, 3),
    m_local=st.integers(1, 6),       # × n keeps blocks tall enough
    n=st.integers(2, 20),
    pw=st.integers(1, 20),
    seed=st.integers(0, 2**16),
)
@SET
def test_blocked_qr_matches_dense_qr(log_p, m_local, n, pw, seed):
    from repro.qr import blocked_qr_sim

    p = 1 << log_p
    pw = min(pw, n)
    m_local = max(m_local * n, pw)   # each rank's block at least pw tall
    from repro.core import ref

    blocks = np.asarray(_arr(seed, (p, m_local, n), jnp.float32))
    res = blocked_qr_sim(jnp.asarray(blocks), panel_width=pw)
    rt = ref.qr_r(blocks.reshape(-1, n).astype(np.float64))
    assert np.asarray(res.valid).all()
    scale = max(1.0, np.abs(rt).max())
    assert np.abs(np.asarray(res.r)[0] - rt).max() / scale < 5e-4
