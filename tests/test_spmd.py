"""Multi-device SPMD behavior, run in subprocesses with 8 forced host
devices (the in-process suite keeps the default single device — see the
dry-run spec).  Covers: shard_map TSQR all variants + faults + Q, the
PowerSGD butterfly under real collectives, elastic mesh shrink, and a
(4 data × 2 model) trainer run with failure semantics."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_shard_map_tsqr_variants_and_faults():
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core import tsqr_shard_map, FaultSpec, make_plan
    from repro.core import ref

    mesh = make_mesh((8,), ("rows",))
    rng = np.random.default_rng(1)
    blocks = ref.random_tall_skinny(rng, 8, 16, 4)
    a = jnp.asarray(blocks.reshape(128, 4))
    truth = ref.qr_r(blocks.reshape(-1, 4).astype(np.float64)).astype(np.float32)
    for v in ["tree", "redundant", "replace", "selfhealing"]:
        res = tsqr_shard_map(a, mesh=mesh, axis="rows", variant=v)
        val = np.asarray(res.valid)
        exp = (np.arange(8) == 0) if v == "tree" else np.ones(8, bool)
        assert (val == exp).all(), (v, val)
        for r in np.nonzero(val)[0]:
            np.testing.assert_allclose(np.asarray(res.r)[r], truth, rtol=5e-4, atol=5e-4)
    # fault scenarios across variants agree with the host plan
    for fs in [FaultSpec.of({5: 1}), FaultSpec.of({5: 1, 2: 2}),
               FaultSpec.of({1: 1, 4: 2, 6: 2})]:
        for v in ["redundant", "replace", "selfhealing"]:
            res = tsqr_shard_map(a, mesh=mesh, axis="rows", variant=v, fault_spec=fs)
            plan = make_plan(v, 8, fs)
            assert (np.asarray(res.valid) == plan.final_valid).all(), (v, fs)
            for r in np.nonzero(plan.final_valid)[0]:
                np.testing.assert_allclose(np.asarray(res.r)[r], truth,
                                           rtol=7e-4, atol=7e-4)
    # Q on the SPMD path
    res = tsqr_shard_map(a, mesh=mesh, axis="rows", variant="redundant", compute_q=True)
    q = np.asarray(res.q)
    np.testing.assert_allclose(q.T @ q, np.eye(4), atol=2e-5)
    print("SPMD TSQR OK")
    """)


@pytest.mark.slow
def test_blocked_qr_shard_map():
    """General-matrix blocked QR on the SPMD backend: fault-free R matches
    the dense oracle on every rank, a mid-panel death under Replace keeps
    survivors exact (and replica fetch restores the rest over real
    ppermute wires), and Q reconstructs A."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core import ref
    from repro.qr import blocked_qr_shard_map, PanelFaultSchedule

    mesh = make_mesh((8,), ("rows",))
    rng = np.random.default_rng(3)
    blocks = rng.standard_normal((8, 24, 15)).astype(np.float32)
    a = jnp.asarray(blocks.reshape(8 * 24, 15))
    rt = ref.qr_r(blocks.reshape(-1, 15).astype(np.float64))

    res = blocked_qr_shard_map(a, mesh=mesh, axis="rows", panel_width=4,
                               compute_q=True)
    assert np.asarray(res.valid).all()
    for r in range(8):
        np.testing.assert_allclose(np.asarray(res.r)[r], rt,
                                   rtol=5e-4, atol=5e-4)
    q = np.asarray(res.q)
    np.testing.assert_allclose(q.T @ q, np.eye(15), atol=5e-5)
    np.testing.assert_allclose(q @ np.asarray(res.r)[0],
                               np.asarray(a), rtol=5e-4, atol=5e-4)

    sched = PanelFaultSchedule.of(panel={1: {2: 1}}, update={2: {5: 1}})
    res2 = blocked_qr_shard_map(a, mesh=mesh, axis="rows", panel_width=4,
                                variant="replace", faults=sched)
    valid = np.asarray(res2.valid)
    expect = res2.reports[1].plan_r.final_valid & \
        res2.reports[2].plan_w.final_valid
    assert (valid == expect).all(), (valid, expect)
    for r in range(8):       # replica fetch restored every rank
        np.testing.assert_allclose(np.asarray(res2.r)[r], rt,
                                   rtol=5e-4, atol=5e-4)
    print("SPMD blocked QR OK")
    """)


@pytest.mark.slow
def test_powersgd_under_shard_map():
    """PowerSGD round on a (data=2, model=4) mesh with real psum/ppermute:
    the decompressed mean gradient must equal the dense data-mean for a
    rank-r gradient, on every device."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.collective import ShardMapComm
    from repro.optim import powersgd

    D, M, m_loc, n, r = 2, 4, 8, 12, 3
    mesh = make_mesh((D, M), ("data", "model"))
    key = jax.random.key(0)
    # distinct rank-r gradient per data replica, rows sharded over model
    u = jax.random.normal(key, (D, M * m_loc, r))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, r))
    g = jnp.einsum("dmr,nr->dmn", u, v)          # (D, M*m_loc, n)
    g_mean = g.mean(0)

    cfg = powersgd.PowerSGDConfig(rank=r, error_feedback=False)
    comm = ShardMapComm(M, "model")
    q0 = jax.random.normal(jax.random.fold_in(key, 2), (n, r), jnp.float32)

    def body(g_blk, q_blk):
        state = {"q": q_blk, "e": None}
        ghat, _, _ = powersgd.compress_grad(
            g_blk[0], state, comm, cfg=cfg,
            psum_data=lambda x: lax.psum(x, "data"),
            psum_model=lambda x: lax.psum(x, "model"),
            n_data=D)
        return ghat[None]

    f = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P("data", "model", None), P()),
        out_specs=P("data", "model", None)))
    out = f(g, q0)                                # (D, M*m_loc, n)
    for d in range(D):
        np.testing.assert_allclose(np.asarray(out[d]), np.asarray(g_mean),
                                   rtol=2e-3, atol=2e-3)
    print("PowerSGD SPMD OK")
    """)


@pytest.mark.slow
def test_trainer_multidevice_and_shrink():
    _run("""
    import jax, numpy as np
    from repro.compat import make_mesh
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig, FaultEvent
    from repro.runtime.elastic import shrink_mesh

    cfg = get_config("qwen3-0.6b").smoke(n_layers=2)
    mesh = make_mesh((4, 2), ("data", "model"))
    tc = TrainerConfig(steps=8, log_every=100, ckpt_every=0, on_failure="shrink",
                       ckpt_dir="/tmp/ck_spmd")
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    tr = Trainer(cfg, tc, mesh, dc)
    assert tr.n_replicas == 4
    p, o = tr.init_state()
    p, o = tr.run(p, o, fault_schedule=(FaultEvent(step=4, kind="fail", replica=1),))
    assert tr.n_replicas == 2, tr.n_replicas     # elastic shrink happened
    assert any("elastic shrink" in e for e in tr.events_log)
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.isfinite(losses).all() and losses[-1] < losses[0] + 0.5
    # shrink helper topology
    small = shrink_mesh(mesh)
    assert dict(zip(small.axis_names, small.devices.shape)) == {"data": 2, "model": 2}
    print("trainer shrink OK")
    """)


@pytest.mark.slow
def test_blank_rescaling_unbiased():
    """BLANK semantics: masking one replica and rescaling gives the same
    loss value as training on the survivors alone."""
    _run("""
    import jax, numpy as np
    from repro.configs.base import get_config
    from repro.models import api
    import jax.numpy as jnp

    cfg = get_config("olmo-1b").smoke(n_layers=1)
    key = jax.random.key(0)
    params = api.init(key, cfg)
    batch = api.synth_batch(key, cfg, "train", batch=8, seq=16)
    w = np.ones(8, np.float32); w[:4] = 0        # replica 0 of 2 dead
    w = w / w.mean()
    masked = dict(batch, loss_weight=jnp.asarray(w))
    l_masked = float(api.loss_fn(params, masked, cfg))
    survivors = {k: v[4:] for k, v in batch.items()}
    l_surv = float(api.loss_fn(params, survivors, cfg))
    np.testing.assert_allclose(l_masked, l_surv, rtol=1e-5)
    print("blank unbiased OK")
    """)


@pytest.mark.slow
def test_ft_allreduce_under_shard_map():
    """ft_allreduce on the SPMD backend: every combiner agrees with the
    dense reduction fault-free, and faulted plans within tolerance leave
    survivors holding the full reduction — same assertions the SimComm
    suite makes, under real ppermute collectives."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.collective import (ShardMapComm, FaultSpec, ft_allreduce,
                                  make_plan, within_tolerance)

    p = 8
    mesh = make_mesh((p,), ("rows",))
    comm = ShardMapComm(p, "rows")
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(p, 4, 5)).astype(np.float32))
    dense = {"sum": np.asarray(x).sum(0), "mean": np.asarray(x).mean(0),
             "max": np.asarray(x).max(0), "gram_sum": np.asarray(x).sum(0)}

    def run(op, fs, variant):
        plan = make_plan(variant, p, fs)
        def body(blk):
            v, ok = ft_allreduce(blk[0], comm, op=op, variant=variant,
                                 fault_spec=fs)
            return v[None], ok[None]
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("rows"),
                              out_specs=(P("rows"), P("rows"))))
        v, ok = f(x)
        assert (np.asarray(ok) == plan.final_valid).all(), (op, variant, fs)
        for r in np.nonzero(plan.final_valid)[0]:
            np.testing.assert_allclose(np.asarray(v)[r], dense[op],
                                       rtol=1e-5, atol=1e-5)

    for op in ("sum", "mean", "max", "gram_sum"):
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            run(op, None, variant)
    fs = FaultSpec.of({5: 1, 2: 2})
    for variant in ("redundant", "replace", "selfhealing"):
        assert within_tolerance(variant, fs, 3)
        for op in ("sum", "mean", "max", "gram_sum"):
            run(op, fs, variant)

    # fault-free fast path: bit-identical (value, valid) to the general
    # executor for every variant on the SPMD backend (symmetric payloads so
    # gram_sum exercises the packed wire)
    from repro.collective import execute_plan, plan_is_fault_free
    sym = jnp.einsum("pmi,pmj->pij", x, x)
    tall = jnp.asarray(rng.normal(size=(p, 12, 4)).astype(np.float32))
    for op in ("sum", "max", "gram_sum", "qr"):
        payload = tall if op == "qr" else sym
        for variant in ("tree", "redundant", "replace", "selfhealing"):
            plan = make_plan(variant, p)
            def body(blk):
                va, oa = execute_plan(blk[0], comm, plan, op)
                vg, og = execute_plan(blk[0], comm, plan, op, fast=False)
                return va[None], oa[None], vg[None], og[None]
            f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("rows"),
                                  out_specs=(P("rows"),) * 4))
            va, oa, vg, og = f(payload)
            assert np.array_equal(np.asarray(oa), np.asarray(og)), (op, variant)
            assert np.array_equal(np.asarray(va), np.asarray(vg),
                                  equal_nan=True), (op, variant)
            assert plan_is_fault_free(plan) == (variant != "tree")
    print("SPMD ft_allreduce OK")
    """)


@pytest.mark.slow
def test_ft_allreduce_jit_shard_map():
    """The jitted entry point on the SPMD backend: bit-for-bit with the
    SimComm compiled path fault-free (same global (P,)-leading layout),
    identical validity bits + NaN-aware values on a faulted plan, and zero
    retraces on a repeat call (the lru-cached shard_map compile)."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.collective import (ShardMapComm, SimComm, FaultSpec,
                                  ft_allreduce_jit, make_plan)
    from repro.kernels import dispatch as disp

    p = 8
    mesh = make_mesh((p,), ("rows",))
    scomm = ShardMapComm(p, "rows")
    sim = SimComm(p)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(p, 6, 5)).astype(np.float32))
    sym = jnp.einsum("pmi,pmj->pij", x, x)      # gram_sum needs symmetry

    # fault-free: bitwise parity with the SimComm path, both combiners
    for op, payload in (("sum", x), ("gram_sum", sym)):
        vs, oks = ft_allreduce_jit(payload, sim, op=op)
        vm, okm = ft_allreduce_jit(payload, scomm, op=op, mesh=mesh)
        assert np.array_equal(np.asarray(vs), np.asarray(vm)), op
        assert np.array_equal(np.asarray(oks), np.asarray(okm)), op

    # faulted plan: same validity bits as the host plan, NaN-aware value
    # parity with SimComm (invalid slots are NaN-poisoned on both paths)
    fs = FaultSpec.of({5: 1, 2: 2})
    plan = make_plan("redundant", p, fs)
    vs, oks = ft_allreduce_jit(x, sim, op="sum", plan=plan)
    vm, okm = ft_allreduce_jit(x, scomm, op="sum", plan=plan, mesh=mesh)
    assert (np.asarray(okm) == plan.final_valid).all()
    assert np.array_equal(np.asarray(oks), np.asarray(okm))
    assert np.array_equal(np.asarray(vs), np.asarray(vm), equal_nan=True)

    # warm path: a repeat call with identical statics must not retrace
    before = disp.trace_count("ft_allreduce")
    ft_allreduce_jit(x, scomm, op="sum", plan=plan, mesh=mesh)
    assert disp.trace_count("ft_allreduce") == before

    # misuse guards: mesh omitted / wrong axis size
    for bad in (dict(), dict(mesh=make_mesh((4,), ("rows",)))):
        try:
            ft_allreduce_jit(x, scomm, op="sum", **bad)
        except ValueError:
            pass
        else:
            raise AssertionError(f"no ValueError for {bad}")
    print("SPMD ft_allreduce_jit OK")
    """)


@pytest.mark.slow
def test_trainer_blank_ft_gradient_allreduce():
    """BLANK mode with >1 replicas routes the gradient combine through
    ft_allreduce over the explicit replica axis; training stays finite
    through a replica failure + recovery."""
    _run("""
    import numpy as np
    from repro.compat import make_mesh
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig, FaultEvent

    cfg = get_config("qwen3-0.6b").smoke(n_layers=2)
    mesh = make_mesh((4, 2), ("data", "model"))
    tc = TrainerConfig(steps=8, log_every=100, ckpt_every=0,
                       on_failure="blank", ckpt_dir="/tmp/ck_blank_ft")
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    tr = Trainer(cfg, tc, mesh, dc)
    assert tr.ft_grad_allreduce
    p, o = tr.init_state()
    p, o = tr.run(p, o, fault_schedule=(
        FaultEvent(step=3, kind="fail", replica=1),
        FaultEvent(step=6, kind="recover", replica=1),
    ))
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0] + 0.5
    assert any("ft_allreduce" in e for e in tr.events_log)
    print("blank ft-gradient trainer OK")
    """)
