"""Checkpoint manager (atomicity, keep-k, async) and the diskless buddy
store (replica placement math shared with the butterfly — 2^s copies)."""
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, flatten_tree, unflatten_like
from repro.checkpoint.replicated import BuddyStore


def _tree():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "none": None},
        "opt": ({"m": jnp.ones((4,))}, {"v": jnp.zeros((2,))}),
        "step": jnp.asarray(17),
    }


def test_flatten_roundtrip():
    t = _tree()
    flat = flatten_tree(t)
    back = unflatten_like(t, flat)
    assert back["params"]["none"] is None
    np.testing.assert_array_equal(back["params"]["w"], np.asarray(t["params"]["w"]))
    assert back["step"] == 17


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    restored, meta = mgr.restore(t)
    assert meta["step"] == 10
    np.testing.assert_array_equal(restored["opt"][0]["m"], np.ones((4,)))


def test_keep_k_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    th = mgr.save(5, t, block=False)
    assert isinstance(th, threading.Thread)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_incomplete_checkpoint_ignored(tmp_path):
    """A crash mid-write (tmp dir, no manifest) must not be restorable."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(tmp_path / "step_00000009")      # no MANIFEST.json
    os.makedirs(tmp_path / "step_00000008.tmp")
    assert mgr.steps() == []
    mgr.save(3, _tree())
    assert mgr.latest_step() == 3


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"w": jnp.ones((2, 2))})
    with pytest.raises(AssertionError):
        mgr.restore({"w": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# Diskless buddy store
# ---------------------------------------------------------------------------

def test_buddy_replication_counts():
    bs = BuddyStore(8)
    shards = {r: {"r": r} for r in range(8)}
    bs.checkpoint(1, shards, levels=2)          # 2^2 = 4 copies
    for r in range(8):
        assert bs.copies(r) == 4


def test_buddy_recover_within_tolerance():
    bs = BuddyStore(8)
    bs.checkpoint(1, {r: {"val": r * 10} for r in range(8)}, levels=2)
    # kill 3 ranks = 2^2 - 1 — every shard must still be recoverable
    for dead in (0, 3, 5):
        bs.fail(dead)
    for r in range(8):
        step, state = bs.recover(r)
        assert step == 1 and state["val"] == r * 10


def test_buddy_tolerance_is_tight():
    bs = BuddyStore(4)
    bs.checkpoint(1, {r: {"v": r} for r in range(4)}, levels=1)  # 2 copies
    bs.fail(0)
    bs.fail(1)          # 2 failures > 2^1 - 1: shard 0 lived on {0,1} only
    with pytest.raises(KeyError):
        bs.recover(0)
    # but shard 2's copies {2,3} are intact
    assert bs.recover(2)[1] == {"v": 2}


def test_buddy_respawn_rejoins():
    bs = BuddyStore(4)
    bs.checkpoint(1, {r: {"v": r} for r in range(4)}, levels=1)
    bs.fail(2)
    step, state = bs.recover(2)
    bs.respawn(2)
    bs.checkpoint(2, {2: state}, levels=1)
    assert bs.copies(2) >= 2
