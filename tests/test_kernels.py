"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 4), (257, 7), (1024, 128), (500, 130), (2048, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    # blocked accumulation reorders sums vs the single-matmul oracle
    if dt == jnp.bfloat16:
        return dict(rtol=3e-2, atol=3e-2)
    return dict(rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_gram_matches_ref(rng, m, n, dt):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dt)
    got = ops.gram(a, use_pallas=True)
    want = ref.gram(a)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dt))


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_apply_right_matches_ref(rng, m, n, dt):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dt)
    w = jnp.asarray(rng.standard_normal((n, n)), dtype=dt)
    got = ops.apply_right(a, w, use_pallas=True)
    want = ref.apply_right(a, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dt)
    )


@pytest.mark.parametrize("n", [3, 16, 129, 256])
def test_combine_gram_matches_ref(rng, n):
    r1 = jnp.asarray(np.triu(rng.standard_normal((n, n))), dtype=jnp.float32)
    r2 = jnp.asarray(np.triu(rng.standard_normal((n, n))), dtype=jnp.float32)
    got = ops.combine_gram(r1, r2, use_pallas=True)
    want = ref.combine_gram(r1, r2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


@pytest.mark.parametrize("m,n", [(256, 16), (1000, 32), (4096, 64)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_cholesky_qr2_orthogonality_and_reconstruction(rng, m, n, use_pallas):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)
    q, r = ops.cholesky_qr2(a, use_pallas=use_pallas)
    np.testing.assert_allclose(
        np.asarray(q.T @ q), np.eye(n), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), rtol=1e-4, atol=1e-4)
    # R matches Householder ground truth (unique with positive diagonal)
    rt = np.linalg.qr(np.asarray(a, np.float64), mode="r")
    rt = rt * np.where(np.diagonal(rt) < 0, -1.0, 1.0)[:, None]
    np.testing.assert_allclose(np.asarray(r), rt, rtol=2e-3, atol=2e-3)


def test_cholesky_qr2_batched(rng):
    a = jnp.asarray(rng.standard_normal((5, 256, 16)), dtype=jnp.float32)
    q, r = ops.cholesky_qr2(a, use_pallas=True)
    assert q.shape == (5, 256, 16) and r.shape == (5, 16, 16)
    eye = np.broadcast_to(np.eye(16), (5, 16, 16))
    np.testing.assert_allclose(
        np.asarray(jnp.einsum("bmi,bmj->bij", q, q)), eye, atol=2e-5
    )


def test_gram_block_rows_invariance(rng):
    """Result must not depend on the streaming block size."""
    a = jnp.asarray(rng.standard_normal((777, 50)), dtype=jnp.float32)
    outs = [
        np.asarray(ops.gram(a, use_pallas=True))
    ]
    from repro.kernels.gram import gram as raw_gram

    for br in (128, 256, 1024):
        outs.append(np.asarray(raw_gram(a, block_rows=br)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=2e-3)  # accumulation order


def test_tri_inv(rng):
    r = jnp.asarray(
        np.triu(rng.standard_normal((24, 24))) + 8 * np.eye(24), jnp.float32
    )
    inv = ops.tri_inv(r)
    np.testing.assert_allclose(np.asarray(r @ inv), np.eye(24), atol=1e-5)
