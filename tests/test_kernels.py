"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(64, 4), (257, 7), (1024, 128), (500, 130), (2048, 64)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    # blocked accumulation reorders sums vs the single-matmul oracle
    if dt == jnp.bfloat16:
        return dict(rtol=3e-2, atol=3e-2)
    return dict(rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_gram_matches_ref(rng, m, n, dt):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dt)
    got = ops.gram(a, use_pallas=True)
    want = ref.gram(a)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dt))


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_apply_right_matches_ref(rng, m, n, dt):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dt)
    w = jnp.asarray(rng.standard_normal((n, n)), dtype=dt)
    got = ops.apply_right(a, w, use_pallas=True)
    want = ref.apply_right(a, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dt)
    )


@pytest.mark.parametrize("m,n", SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_fused_apply_gram_matches_ref(rng, m, n, dt):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=dt)
    w = jnp.asarray(rng.standard_normal((n, n)), dtype=dt)
    q, g = ops.fused_apply_gram(a, w, use_pallas=True)
    q_ref, g_ref = ref.fused_apply_gram(a, w)
    assert q.dtype == a.dtype and g.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(q, np.float32), np.asarray(q_ref, np.float32), **_tol(dt)
    )
    # blocked Gram accumulation reorders sums and bf16 squares grow large
    gt = dict(rtol=5e-2, atol=5e-1) if dt == jnp.bfloat16 else _tol(dt)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), **gt)
    # want_q=False consumes the panel in VMEM; the Gram must be identical
    g_only = ops.fused_apply_gram(a, w, use_pallas=True, want_q=False)
    assert np.array_equal(np.asarray(g_only), np.asarray(g))


def test_fused_apply_gram_bit_matches_unfused_kernels(rng):
    """The fused sweep takes the Gram of the *cast* panel with the same
    panel boundaries, so it must reproduce gram(apply_right(A, W)) exactly."""
    a = jnp.asarray(rng.standard_normal((1500, 40)), dtype=jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((40, 40)), dtype=jnp.bfloat16)
    q, g = ops.fused_apply_gram(a, w, use_pallas=True)
    q_u = ops.apply_right(a, w, use_pallas=True)
    g_u = ops.gram(q_u, use_pallas=True)
    assert np.array_equal(np.asarray(q, np.float32), np.asarray(q_u, np.float32))
    assert np.array_equal(np.asarray(g), np.asarray(g_u))


@pytest.mark.parametrize("n", [3, 16, 129, 256])
def test_combine_gram_matches_ref(rng, n):
    r1 = jnp.asarray(np.triu(rng.standard_normal((n, n))), dtype=jnp.float32)
    r2 = jnp.asarray(np.triu(rng.standard_normal((n, n))), dtype=jnp.float32)
    got = ops.combine_gram(r1, r2, use_pallas=True)
    want = ref.combine_gram(r1, r2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


@pytest.mark.parametrize("m,n", [(256, 16), (1000, 32), (4096, 64)])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_cholesky_qr2_orthogonality_and_reconstruction(rng, m, n, use_pallas):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)
    q, r = ops.cholesky_qr2(a, use_pallas=use_pallas)
    np.testing.assert_allclose(
        np.asarray(q.T @ q), np.eye(n), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), rtol=1e-4, atol=1e-4)
    # R matches Householder ground truth (unique with positive diagonal)
    rt = np.linalg.qr(np.asarray(a, np.float64), mode="r")
    rt = rt * np.where(np.diagonal(rt) < 0, -1.0, 1.0)[:, None]
    np.testing.assert_allclose(np.asarray(r), rt, rtol=2e-3, atol=2e-3)


def test_cholesky_qr2_batched(rng):
    a = jnp.asarray(rng.standard_normal((5, 256, 16)), dtype=jnp.float32)
    q, r = ops.cholesky_qr2(a, use_pallas=True)
    assert q.shape == (5, 256, 16) and r.shape == (5, 16, 16)
    eye = np.broadcast_to(np.eye(16), (5, 16, 16))
    np.testing.assert_allclose(
        np.asarray(jnp.einsum("bmi,bmj->bij", q, q)), eye, atol=2e-5
    )


def test_gram_block_rows_invariance(rng):
    """Result must not depend on the streaming block size."""
    a = jnp.asarray(rng.standard_normal((777, 50)), dtype=jnp.float32)
    outs = [
        np.asarray(ops.gram(a, use_pallas=True))
    ]
    from repro.kernels.gram import gram as raw_gram

    for br in (128, 256, 1024):
        outs.append(np.asarray(raw_gram(a, block_rows=br)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=2e-3)  # accumulation order


def test_tri_inv(rng):
    r = jnp.asarray(
        np.triu(rng.standard_normal((24, 24))) + 8 * np.eye(24), jnp.float32
    )
    inv = ops.tri_inv(r)
    np.testing.assert_allclose(np.asarray(r @ inv), np.eye(24), atol=1e-5)


def test_tri_inv_batched_no_broadcast_identity(rng):
    """Batched factors solve against the single unbatched eye (vmapped)."""
    r = jnp.asarray(
        np.triu(rng.standard_normal((3, 2, 24, 24))) + 8 * np.eye(24),
        jnp.float32,
    )
    inv = ops.tri_inv(r)
    assert inv.shape == r.shape
    np.testing.assert_allclose(
        np.asarray(r @ inv),
        np.broadcast_to(np.eye(24), r.shape),
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# fused pipeline: sweep counts and R-only equivalence
# ---------------------------------------------------------------------------

def test_cholesky_qr2_r_matches_full_and_unfused(rng):
    a = jnp.asarray(rng.standard_normal((1000, 32)), dtype=jnp.float32)
    for pallas in (False, True):
        r_only = ops.cholesky_qr2_r(a, use_pallas=pallas)
        _, r_full = ops.cholesky_qr2(a, use_pallas=pallas)
        _, r_unfused = ops.cholesky_qr2(a, use_pallas=pallas, fused=False)
        assert np.array_equal(np.asarray(r_only), np.asarray(r_full)), pallas
        assert np.array_equal(np.asarray(r_only), np.asarray(r_unfused)), pallas


def test_traffic_model_sweep_counts(rng):
    from repro.kernels import traffic

    a = jnp.asarray(rng.standard_normal((2048, 32)), dtype=jnp.float32)
    with traffic.track_traffic() as t_fused:
        ops.cholesky_qr2_r(a, use_pallas=True)
    with traffic.track_traffic() as t_unfused:
        ops.cholesky_qr2(a, use_pallas=True, fused=False)
    assert t_fused.tall_sweeps == 2
    assert t_unfused.tall_sweeps == 4
    panel = 2048 * 32 * 4
    assert t_fused.read_bytes == 2 * panel + 32 * 32 * 4   # A twice + W once
    assert t_unfused.read_bytes > 4 * panel                # A, A, Q1, Q1 (+Ws)
    # R-only never writes a tall intermediate: only the two (n, n) Grams
    assert t_fused.write_bytes == 2 * 32 * 32 * 4
    assert t_unfused.write_bytes == 2 * panel + 2 * 32 * 32 * 4
    # nothing records outside a tracking block
    ops.gram(a, use_pallas=True)
    assert t_fused.tall_sweeps == 2


# ---------------------------------------------------------------------------
# backend auto-detection: the resolved flag must reach pallas_call
# ---------------------------------------------------------------------------

def test_interpret_flag_reaches_pallas_call(rng, monkeypatch):
    from jax.experimental import pallas as pl

    from repro.kernels import apply_right as apply_mod
    from repro.kernels import backend, fused_apply_gram as fused_mod
    from repro.kernels import gram as gram_mod

    captured = []
    real = pl.pallas_call

    def spy(*args, **kw):
        captured.append(kw.get("interpret"))
        kw["interpret"] = True          # CPU cannot compile Mosaic
        return real(*args, **kw)

    for mod in (gram_mod, apply_mod, fused_mod):
        monkeypatch.setattr(mod.pl, "pallas_call", spy, raising=True)

    # unique shapes so jit can't replay a cached trace from earlier tests
    a = jnp.asarray(rng.standard_normal((333, 11)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((11, 11)), dtype=jnp.float32)

    ops.gram(a, use_pallas=True, interpret=False)
    assert captured[-1] is False        # explicit override wins
    ops.apply_right(a, w, use_pallas=True, interpret=True)
    assert captured[-1] is True
    ops.fused_apply_gram(a, w, use_pallas=True)          # auto-detect
    assert captured[-1] is backend.default_interpret()
    assert backend.default_interpret() is True           # CPU container


# ---------------------------------------------------------------------------
# GPU (Triton) lowerings: per-program partial accumulators vs the TPU
# kernels' revisited-block accumulators — same math, parallel-grid-safe
# ---------------------------------------------------------------------------

def test_gpu_lowerings_match_tpu_kernels(rng):
    from repro.kernels import gpu
    from repro.kernels import gram as gram_mod
    from repro.kernels import apply_right as apply_mod
    from repro.kernels import fused_apply_gram as fused_mod
    from repro.kernels import trailing_update as trail_mod

    tol = dict(rtol=1e-5, atol=1e-5)
    m, n, b = 333, 11, 8
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((n, n)) / n, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((m, b)), dtype=jnp.float32)
    wt = jnp.asarray(rng.standard_normal((b, n)) / n, dtype=jnp.float32)

    def close(got, want):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)

    close(gpu.gram(a), gram_mod.gram(a, interpret=True))
    close(gpu.apply_right(a, w), apply_mod.apply_right(a, w, interpret=True))

    q_g, s_g = gpu.fused_apply_gram(a, w)
    q_t, s_t = fused_mod.fused_apply_gram(a, w, interpret=True)
    close(q_g, q_t)
    close(s_g, s_t)
    close(
        gpu.fused_apply_gram(a, w, want_q=False),
        fused_mod.fused_apply_gram(a, w, interpret=True, want_q=False),
    )

    an_g, s2_g = gpu.trailing_update(a, q, wt, next_width=b)
    an_t, s2_t = trail_mod.trailing_update(
        a, q, wt, next_width=b, interpret=True
    )
    close(an_g, an_t)
    close(s2_g, s2_t)
    close(
        gpu.trailing_update(a, q, wt),
        trail_mod.trailing_update(a, q, wt, interpret=True),
    )

    close(
        gpu.panel_cross(a, split=4),
        trail_mod.panel_cross(a, split=4, interpret=True),
    )
    ap_g, sp_g = gpu.pad_cross(a, split=4, out_width=16)
    ap_t, sp_t = trail_mod.pad_cross(a, split=4, out_width=16,
                                     interpret=True)
    close(ap_g, ap_t)
    close(sp_g, sp_t)
    # the padded columns are exact zeros on both lowerings
    assert not np.asarray(ap_g)[:, n:].any()
    assert not np.asarray(sp_g)[:, n:].any()


def test_gpu_routing_reaches_compiled_pallas_call(rng, monkeypatch):
    """On a (mocked) GPU runtime the jitted kernel wrappers must route to
    the Triton lowerings in repro.kernels.gpu with interpret=False — the
    compiled path — while CPU CI swaps the interpreter in underneath."""
    import jax

    from repro.kernels import gpu
    from repro.kernels import trailing_update as trail_mod

    captured = []
    real = gpu.pl.pallas_call

    def spy(*args, **kw):
        captured.append(kw.get("interpret"))
        kw["interpret"] = True          # CPU cannot compile Triton
        return real(*args, **kw)

    monkeypatch.setattr(gpu.pl, "pallas_call", spy, raising=True)
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")

    # unique shapes so jit can't replay a cached trace from earlier tests
    m, n, b = 451, 9, 4
    a = jnp.asarray(rng.standard_normal((m, n)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((n, n)) / n, dtype=jnp.float32)
    q = jnp.asarray(rng.standard_normal((m, b)), dtype=jnp.float32)
    wt = jnp.asarray(rng.standard_normal((b, n)) / n, dtype=jnp.float32)

    got = ops.gram(a, use_pallas=True)
    assert captured and captured[-1] is False
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a).T @ np.asarray(a),
        rtol=1e-4, atol=1e-4,
    )
    n_calls = len(captured)
    out = trail_mod.trailing_update(a, q, wt, next_width=b)
    assert len(captured) > n_calls and captured[-1] is False
    np.testing.assert_allclose(
        np.asarray(out[0]),
        np.asarray(a) - np.asarray(q) @ np.asarray(wt),
        rtol=1e-4, atol=1e-4,
    )
