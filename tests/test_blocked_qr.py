"""Fault-tolerant blocked QR (general matrices): correctness vs the dense
numpy oracle, per-panel failure guarantees across variants, replica
recovery vs honest corruption, the one-trailing-sweep-per-panel traffic
model, and the 4096×512 acceptance shape."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.collective import FaultSpec, within_tolerance
from repro.kernels import traffic
from repro.qr import (
    PanelFactorizer,
    PanelFaultSchedule,
    blocked_qr_sim,
    panel_widths,
)

VARIANTS = ("tree", "redundant", "replace", "selfhealing")


def _dense_r(blocks):
    from repro.core import ref

    n = blocks.shape[-1]
    return ref.qr_r(blocks.reshape(-1, n).astype(np.float64)).astype(
        np.float32
    )


def _blocks(rng, p, m_local, n):
    return rng.standard_normal((p, m_local, n)).astype(np.float32)


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("p,m_local,n,pw", [(4, 32, 12, 4), (8, 24, 17, 5)])
def test_fault_free_matches_dense_qr(rng, variant, p, m_local, n, pw):
    blocks = _blocks(rng, p, m_local, n)
    res = blocked_qr_sim(jnp.asarray(blocks), panel_width=pw, variant=variant)
    truth = _dense_r(blocks)
    valid = np.asarray(res.valid)
    expect = (np.arange(p) == 0) if variant == "tree" else np.ones(p, bool)
    assert (valid == expect).all()
    assert res.n_panels == len(panel_widths(n, pw))
    # every rank holds the replicated R (tree's non-roots got it via fetch)
    for r in range(p):
        np.testing.assert_allclose(
            np.asarray(res.r)[r], truth, rtol=5e-4, atol=5e-4
        )
        assert np.allclose(np.tril(np.asarray(res.r)[r], -1), 0.0)


@pytest.mark.parametrize("local_r", ["chol", "jnp"])
def test_local_r_modes_agree(rng, local_r):
    blocks = _blocks(rng, 4, 48, 20)
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=6, local_r=local_r
    )
    np.testing.assert_allclose(
        np.asarray(res.r)[0], _dense_r(blocks), rtol=5e-4, atol=5e-4
    )


def test_single_panel_degenerates_to_tsqr(rng):
    """panel_width ≥ n: one panel, and R agrees with the TSQR entry point."""
    from repro.qr import tsqr_sim

    blocks = _blocks(rng, 4, 32, 8)
    res = blocked_qr_sim(jnp.asarray(blocks), panel_width=8)
    assert res.n_panels == 1
    ref = tsqr_sim(jnp.asarray(blocks), variant="redundant")
    np.testing.assert_allclose(
        np.asarray(res.r)[0], np.asarray(ref.r)[0], rtol=5e-4, atol=5e-4
    )


def test_q_factor_orthonormal_and_reconstructs(rng):
    blocks = _blocks(rng, 8, 32, 20)
    res = blocked_qr_sim(jnp.asarray(blocks), panel_width=6, compute_q=True)
    q = np.asarray(res.q).reshape(-1, 20)
    np.testing.assert_allclose(q.T @ q, np.eye(20), atol=5e-5)
    np.testing.assert_allclose(
        q @ np.asarray(res.r)[0], blocks.reshape(-1, 20), rtol=5e-4, atol=5e-4
    )


def test_one_trailing_sweep_per_panel(rng):
    """THE HBM claim: K panels cost exactly K trailing-block sweeps (the
    prime cross + one fused update per non-final panel), on both the jnp
    and Pallas paths, for both the scan pipeline (whose prime is the
    column-padded ``pad_cross``) and the eager driver."""
    blocks = _blocks(rng, 4, 32, 20)
    for use_pallas in (False, True):
        for pipeline in ("auto", "off"):
            with traffic.track_traffic() as t:
                res = blocked_qr_sim(
                    jnp.asarray(blocks), panel_width=6,
                    use_pallas=use_pallas, pipeline=pipeline,
                )
            assert t.sweeps_of(
                "panel_cross", "pad_cross", "trailing_update"
            ) == res.n_panels
            cross = [r for r in t.records
                     if r["op"] in ("panel_cross", "pad_cross")]
            upd = [r for r in t.records if r["op"] == "trailing_update"]
            assert len(cross) == 1 and len(upd) == res.n_panels - 1
            # the pipeline is one compiled program: 1 dispatch total
            expect_dispatch = 1 if pipeline == "auto" else res.n_panels
            assert t.dispatches == expect_dispatch


def test_pallas_matches_jnp_path(rng):
    blocks = _blocks(rng, 4, 40, 16)
    r_j = blocked_qr_sim(jnp.asarray(blocks), panel_width=5, use_pallas=False)
    r_p = blocked_qr_sim(jnp.asarray(blocks), panel_width=5, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(r_j.r)[0], np.asarray(r_p.r)[0], rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Failure semantics
# ---------------------------------------------------------------------------

def test_death_during_panel_reduction(rng):
    """Replace reroutes around a mid-panel death; survivors (and recovered
    ranks) hold the exact same R as the fault-free run."""
    blocks = _blocks(rng, 8, 32, 15)
    sched = PanelFaultSchedule.of(panel={1: {2: 1}})
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant="replace", faults=sched
    )
    rep = res.reports[1]
    assert rep.within_tolerance and rep.recovered_r == 1
    valid = np.asarray(res.valid)
    assert (valid == rep.plan_r.final_valid).all()
    truth = _dense_r(blocks)
    for r in range(8):       # recovery: every rank ends with the factor
        np.testing.assert_allclose(
            np.asarray(res.r)[r], truth, rtol=5e-4, atol=5e-4
        )


def test_death_during_trailing_update(rng):
    """A death inside panel k's W butterfly (the trailing-update reduction)
    invalidates the redundant-variant coset but survivors stay exact."""
    blocks = _blocks(rng, 8, 32, 15)
    sched = PanelFaultSchedule.of(update={0: FaultSpec.of({5: 1})})
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant="redundant", faults=sched
    )
    rep = res.reports[0]
    assert rep.plan_w is not None and rep.within_tolerance_w
    valid = np.asarray(res.valid)
    assert (valid == rep.plan_w.final_valid).all()
    assert valid.sum() == 4                     # rank 5's step-1 coset dies
    truth = _dense_r(blocks)
    np.testing.assert_allclose(
        np.asarray(res.r)[np.flatnonzero(valid)[0]], truth,
        rtol=5e-4, atol=5e-4,
    )


def test_cascading_panel_deaths_selfhealing(rng):
    """Deaths across three successive panels: self-healing respawns within
    each butterfly, so every rank stays valid through the whole sweep."""
    blocks = _blocks(rng, 8, 32, 15)
    sched = PanelFaultSchedule.of(
        panel={0: {1: 1}, 1: {6: 2}, 2: {3: 1}}
    )
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant="selfhealing", faults=sched
    )
    assert np.asarray(res.valid).all()
    assert all(rep.within_tolerance for rep in res.reports)
    np.testing.assert_allclose(
        np.asarray(res.r)[0], _dense_r(blocks), rtol=5e-4, atol=5e-4
    )


@pytest.mark.parametrize("variant", ["redundant", "replace", "selfhealing"])
def test_guaranteed_failures_per_variant(rng, variant):
    """Each variant survives its guaranteed failure count injected into a
    mid-sweep panel: within-tolerance specs leave ≥1 valid holder whose R
    is exact."""
    blocks = _blocks(rng, 8, 32, 12)
    spec = FaultSpec.of({3: 1, 6: 2})           # 1 by step 1, 2 by step 2
    assert within_tolerance(variant, spec, 3)
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant=variant,
        faults=PanelFaultSchedule.of(panel={1: spec}),
    )
    valid = np.asarray(res.valid)
    assert valid.any()
    truth = _dense_r(blocks)
    for r in np.flatnonzero(valid):
        np.testing.assert_allclose(
            np.asarray(res.r)[r], truth, rtol=5e-4, atol=5e-4
        )


def test_no_recovery_corrupts_later_panels(rng):
    """recover='off' shows why the general-matrix paper needs a recovery
    story: the NaN-poisoned rank's contributions corrupt every later
    panel's reduction."""
    blocks = _blocks(rng, 8, 32, 15)
    sched = PanelFaultSchedule.of(panel={0: {5: 1}})
    res = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant="redundant",
        faults=sched, recover="off",
    )
    assert all(rep.recovered_r + rep.recovered_w == 0 for rep in res.reports)
    r0 = np.asarray(res.r)[np.flatnonzero(np.asarray(res.valid))[0]]
    # the faulted panel itself stays exact on survivors (the Q polish is
    # skipped rather than mixing the poisoned rank's NaN back in)…
    truth = _dense_r(blocks)
    assert np.isfinite(r0[:4]).all()
    np.testing.assert_allclose(r0[:4], truth[:4], rtol=5e-4, atol=5e-4)
    assert np.isnan(r0[4:]).any()               # …panels after the death rot
    # …whereas the default replica recovery keeps the whole R exact
    res2 = blocked_qr_sim(
        jnp.asarray(blocks), panel_width=4, variant="redundant", faults=sched
    )
    np.testing.assert_allclose(
        np.asarray(res2.r)[np.flatnonzero(np.asarray(res2.valid))[0]],
        _dense_r(blocks), rtol=5e-4, atol=5e-4,
    )


# ---------------------------------------------------------------------------
# Validation and scheduling errors
# ---------------------------------------------------------------------------

def test_schedule_validation(rng):
    blocks = _blocks(rng, 4, 16, 8)
    with pytest.raises(ValueError, match="panel 9"):
        blocked_qr_sim(
            jnp.asarray(blocks), panel_width=4,
            faults=PanelFaultSchedule.of(panel={9: {0: 1}}),
        )
    with pytest.raises(ValueError, match="last panel"):
        blocked_qr_sim(
            jnp.asarray(blocks), panel_width=4,
            faults=PanelFaultSchedule.of(update={1: {0: 1}}),
        )
    with pytest.raises(ValueError, match="unknown local_r"):
        blocked_qr_sim(jnp.asarray(blocks), panel_width=4, local_r="qr")
    with pytest.raises(ValueError, match="recover"):
        blocked_qr_sim(jnp.asarray(blocks), panel_width=4, recover="maybe")


def test_panel_taller_than_rank_block_rejected(rng):
    blocks = _blocks(rng, 4, 6, 8)
    with pytest.raises(ValueError, match="row block"):
        blocked_qr_sim(jnp.asarray(blocks), panel_width=8)


def test_acceptance_4096x512_panel128(rng):
    """The acceptance shape: 4096×512 at panel width 128 on 8 ranks matches
    ``jnp.linalg.qr``'s R to fp32 tolerance."""
    blocks = rng.standard_normal((8, 512, 512)).astype(np.float32)
    with traffic.track_traffic() as t:
        res = blocked_qr_sim(jnp.asarray(blocks), panel_width=128)
    assert res.n_panels == 4
    assert t.sweeps_of("panel_cross", "trailing_update") == 4
    # sign-normalized jnp.linalg.qr R (f64 oracle for a clean fp32 verdict)
    from repro.core import ref

    rt = _dense_r(blocks)
    jt = ref.posdiag(np.asarray(
        jnp.linalg.qr(jnp.asarray(blocks.reshape(-1, 512)), mode="r")
    ))
    got = np.asarray(res.r)[0]
    scale = np.abs(rt).max()
    assert np.abs(got - rt).max() / scale < 5e-4
    assert np.abs(got - jt).max() / scale < 1e-3   # vs jnp's own fp32 R
    assert np.asarray(res.valid).all()


# ---------------------------------------------------------------------------
# PanelFactorizer unit behavior + deprecated shims
# ---------------------------------------------------------------------------

def test_panel_factorizer_backend_agnostic(rng):
    """reduce_r == the TSQR entry point's R on SimComm, for both the
    prepare-inside and prepared-local-R spellings."""
    from repro.collective import SimComm, make_plan
    from repro.qr.panel import chol_r

    blocks = jnp.asarray(_blocks(rng, 4, 32, 6))
    pf = PanelFactorizer()
    plan = make_plan("redundant", 4)
    r1, v1 = pf.reduce_r(blocks, SimComm(4), plan)
    g = jnp.einsum("pmi,pmj->pij", blocks, blocks)
    r2, v2 = pf.reduce_r_prepared(chol_r(g), SimComm(4), plan)
    assert np.asarray(v1).all() and np.asarray(v2).all()
    np.testing.assert_allclose(
        np.asarray(r1), np.asarray(r2), rtol=2e-4, atol=2e-4
    )


def test_core_submodule_shims_removed():
    """The deprecated re-export stubs are gone; the canonical homes serve
    the same names."""
    import importlib
    import sys

    for mod in ("repro.core.plan", "repro.core.faults", "repro.core.comm"):
        sys.modules.pop(mod, None)
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(mod)
    core = importlib.import_module("repro.core")
    collective = importlib.import_module("repro.collective")
    for name in ("Plan", "FaultSpec", "SimComm", "make_plan"):
        assert getattr(core, name) is getattr(collective, name)
